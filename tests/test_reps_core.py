"""REPS core: differential testing against the paper-pseudocode oracle,
Table 1 footprint, and behavioural invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reps


def run_differential(seed: int, steps: int = 150, p_ecn: float = 0.3):
    cfg = reps.REPSConfig(
        buffer_size=8, evs_size=256, num_pkts_bdp=4, freezing_timeout=50
    )
    state = reps.init_state(cfg, 1)
    oracle = reps.REPSOracle(cfg)
    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    for t in range(steps):
        op = rng.randint(0, 3)
        if op == 0:
            key, sub = jax.random.split(key)
            evs, state = reps.choose_ev(cfg, state, jnp.array([True]), sub)
            rand_ev = int(
                jax.random.randint(sub, (1,), 0, cfg.evs_size, jnp.int32)[0]
            )
            assert int(evs[0]) == oracle.on_send(rand_ev), f"step {t}"
        elif op == 1:
            ev, ecn = int(rng.randint(256)), bool(rng.rand() < p_ecn)
            state = reps.on_ack(
                cfg, state, jnp.array([True]), jnp.array([ev]),
                jnp.array([ecn]), jnp.int32(t),
            )
            oracle.on_ack(ev, ecn, t)
        else:
            state = reps.on_failure_detection(
                cfg, state, jnp.array([True]), jnp.int32(t)
            )
            oracle.on_failure_detection(t)
        assert int(state.head[0]) == oracle.head
        assert int(state.num_valid[0]) == oracle.num_valid
        assert bool(state.is_freezing[0]) == oracle.is_freezing
        assert int(state.explore_counter[0]) == oracle.explore_counter
        assert list(np.asarray(state.buf_ev[0])) == oracle.buf_ev
        assert list(np.asarray(state.buf_valid[0])) == oracle.buf_valid


@pytest.mark.parametrize("seed", [0, 1, 2, 7])
def test_differential_vs_oracle(seed):
    run_differential(seed)


def test_table1_footprint():
    cfg = reps.REPSConfig(buffer_size=8)
    fp = reps.state_footprint_bits(cfg)
    assert fp["total_bits"] == 193  # paper Table 1, 8-element buffer
    assert fp["total_bytes_ceil"] == 25
    fp1 = reps.state_footprint_bits(reps.REPSConfig(buffer_size=1))
    assert fp1["total_bits"] == 74  # paper Table 1, 1-element buffer


def test_warmup_explores():
    """During the first BDP worth of packets REPS behaves like OPS."""
    cfg = reps.REPSConfig(num_pkts_bdp=5, evs_size=64)
    state = reps.init_state(cfg, 3)
    key = jax.random.PRNGKey(0)
    # cache some clean EVs first
    state = reps.on_ack(
        cfg, state, jnp.ones(3, bool), jnp.array([1, 2, 3]),
        jnp.zeros(3, bool), jnp.int32(0),
    )
    for i in range(5):
        evs, state = reps.choose_ev(
            cfg, state, jnp.ones(3, bool), jax.random.fold_in(key, i)
        )
    # after warmup, the cached EVs are recycled (oldest valid first)
    evs, state = reps.choose_ev(
        cfg, state, jnp.ones(3, bool), jax.random.fold_in(key, 99)
    )
    assert list(np.asarray(evs)) == [1, 2, 3]


def test_ecn_marked_acks_are_discarded():
    cfg = reps.REPSConfig()
    state = reps.init_state(cfg, 1)
    state = reps.on_ack(
        cfg, state, jnp.array([True]), jnp.array([42]), jnp.array([True]),
        jnp.int32(0),
    )
    assert int(state.num_valid[0]) == 0
    assert int(state.n_cached[0]) == 0


def test_freezing_recycles_invalid_entries():
    """In freezing mode with no valid EVs, entries at head are reused and
    head advances (Algorithm 2, getNextEV else-branch)."""
    cfg = reps.REPSConfig(num_pkts_bdp=0, evs_size=999, freezing_timeout=100)
    state = reps.init_state(cfg, 1)
    # fill the whole 8-deep buffer, then drain it (getNextEV cycles through
    # every buffer slot in freezing mode, so all slots must hold known EVs)
    cached = [10, 20, 30, 40, 50, 60, 70, 80]
    for i, ev in enumerate(cached):
        state = reps.on_ack(
            cfg, state, jnp.array([True]), jnp.array([ev]),
            jnp.array([False]), jnp.int32(i),
        )
    key = jax.random.PRNGKey(0)
    for i in range(8):
        _, state = reps.choose_ev(
            cfg, state, jnp.array([True]), jax.random.fold_in(key, i)
        )
    assert int(state.num_valid[0]) == 0
    # enter freezing
    state = reps.on_failure_detection(cfg, state, jnp.array([True]), jnp.int32(5))
    assert bool(state.is_freezing[0])
    got = []
    for i in range(6):
        evs, state = reps.choose_ev(
            cfg, state, jnp.array([True]), jax.random.fold_in(key, 100 + i)
        )
        got.append(int(evs[0]))
    # recycles cached (now-invalid) entries round-robin, never random
    assert set(got) <= set(cached)


def test_freezing_exit_rearms_explore():
    cfg = reps.REPSConfig(num_pkts_bdp=7, freezing_timeout=10)
    state = reps.init_state(cfg, 1)
    state = state.replace(
        is_freezing=jnp.array([True]),
        exit_freezing=jnp.array([5], jnp.int32),
        explore_counter=jnp.array([0], jnp.int32),
    )
    state = reps.on_ack(
        cfg, state, jnp.array([True]), jnp.array([3]), jnp.array([False]),
        jnp.int32(20),
    )
    assert not bool(state.is_freezing[0])
    assert int(state.explore_counter[0]) == 7
