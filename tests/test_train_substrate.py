"""Optimizer, data pipeline, checkpointing (incl. elastic restore)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train import (
    AdamWConfig,
    TrainConfig,
    init_train_state,
    make_train_step,
)

KEY = jax.random.PRNGKey(0)


def test_loss_decreases_on_markov_stream():
    cfg = reduced(get_config("mistral-nemo-12b"))
    m = build_model(cfg)
    params, opt = init_train_state(m, KEY)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=3e-3, warmup_steps=2, weight_decay=0.0, decay_steps=500)
    )
    step = jax.jit(make_train_step(m, tcfg))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=1)
    losses = []
    for i in range(20):
        b = {k: jnp.asarray(v) for k, v in data.shard_batch(i).items()}
        params, opt, metrics = step(params, opt, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses
    assert data.entropy_floor() < losses[-1]  # can't beat the floor


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduced(get_config("qwen1.5-4b"))
    m = build_model(cfg)
    params, opt = init_train_state(m, KEY)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=2)
    b = {k: jnp.asarray(v) for k, v in data.shard_batch(0).items()}
    tc1 = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1), microbatches=1,
                      compute_dtype=jnp.float32)
    tc4 = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1), microbatches=4,
                      compute_dtype=jnp.float32)
    p1, _, m1 = jax.jit(make_train_step(m, tc1))(params, opt, b)
    p4, _, m4 = jax.jit(make_train_step(m, tc4))(params, opt, b)
    # same data, fp32: accumulated grads match full-batch grads closely
    diffs = [
        float(jnp.max(jnp.abs(a - b2)))
        for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p4))
    ]
    assert max(diffs) < 5e-3, max(diffs)


def test_data_pipeline_sharding_partitions_batch():
    data = SyntheticLM(vocab=128, seq_len=16, global_batch=8, seed=3)
    full = data.shard_batch(5)
    parts = [data.shard_batch(5, i, 4) for i in range(4)]
    got = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(full["tokens"], got)


def test_data_pipeline_deterministic():
    d1 = SyntheticLM(vocab=128, seq_len=16, global_batch=4, seed=3)
    d2 = SyntheticLM(vocab=128, seq_len=16, global_batch=4, seed=3)
    np.testing.assert_array_equal(
        d1.shard_batch(7)["tokens"], d2.shard_batch(7)["tokens"]
    )


def test_checkpoint_roundtrip_and_latest():
    cfg = reduced(get_config("rwkv6-1.6b"))
    m = build_model(cfg)
    params, opt = init_train_state(m, KEY)
    with tempfile.TemporaryDirectory() as d:
        p1 = os.path.join(d, "step_5")
        p2 = os.path.join(d, "step_9")
        ckpt.save(p1, 5, {"params": params})
        ckpt.save(p2, 9, {"params": params})
        assert ckpt.latest(d) == p2
        restored, step = ckpt.restore(p2, {"params": params})
        assert step == 9
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_save():
    cfg = reduced(get_config("musicgen-large"))
    m = build_model(cfg)
    params, _ = init_train_state(m, KEY)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "step_1")
        t = ckpt.save_async(p, 1, {"params": params})
        t.join(timeout=60)
        assert ckpt.is_committed(p)
