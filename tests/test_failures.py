"""Expanded fault model (repro.netsim.failures + engine kind codes).

The contract under test:

* **Flapping == composed stack** — ``link_flapping`` materializes to the
  exact kind-0 window rows of the hand-composed ``link_down`` stack, and
  the two drive a sweep bit-identically (same pack plan, same RNG).
* **Gray loss determinism** — kind-2 probabilistic drops come from the
  engine's tick-keyed threefry stream (fold 3): the same seed reproduces
  the same drops, and a kill/resume through the soak runtime is
  bit-identical to the uninterrupted run while the gray window is live.
* **Switch-level composition** — ``switch_down`` injected mid-run via
  ``SoakRunner.inject`` equals declaring it statically.
* **Validation** — ``FailureSchedule.validate`` raises ``ValueError``
  naming the offending row for unknown kinds, inverted/negative windows,
  out-of-range gray params and non-inert pads; builder arguments are
  checked at construction.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.netsim import (
    FailureSchedule, SoakConfig, SoakRunner, SweepCase, SweepEngine,
    Topology, failures, workloads,
)
from repro.netsim.engine import GRAY_SCALE, K_DEGRADED, K_DOWN, K_GRAY

CFG = FATTREE_32_CI
TICKS = 360
CHUNK = 120
SLOTS = 12

WL = workloads.permutation(32, 24, seed=3)


def _case(name, fs, lb="reps", ticks=TICKS):
    return SweepCase(
        name=name, workload=WL, lb=lb, ticks=ticks, failures=fs, seeds=(5,),
    )


def _run(fs, lb="reps", ticks=TICKS):
    eng = SweepEngine(CFG, [_case("cell", fs, lb, ticks)], devices=None,
                      min_failure_slots=SLOTS)
    res = eng.run(collect="summary", chunk=CHUNK)
    state = jax.tree_util.tree_map(np.asarray, res.buckets[0].final_state)
    tel = np.asarray(res.buckets[0].telemetry)
    return res.summaries()["cell"][0], state, tel


def _assert_states_equal(a, b):
    for g, w in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# link_flapping
# ---------------------------------------------------------------------------


def test_flapping_materializes_down_windows():
    fs = failures.link_flapping([3], start=40, end=400, period=120,
                                down_ticks=30)
    np.testing.assert_array_equal(fs.queue, [3, 3, 3])
    np.testing.assert_array_equal(fs.start, [40, 160, 280])
    np.testing.assert_array_equal(fs.end, [70, 190, 310])
    assert (fs.kind == K_DOWN).all() and (fs.param == 0).all()
    fs.validate(CFG.n_hosts * 100)  # plain kind-0 rows, nothing exotic


def test_flapping_bit_equals_composed_stack_through_sweep():
    q = int(Topology.build(CFG).t0_up_queues(0)[2])
    flap = failures.link_flapping([q], start=24, end=TICKS, period=150,
                                  down_ticks=40)
    stack = FailureSchedule.concat(
        failures.link_down([q], 24, 64),
        failures.link_down([q], 174, 214),
        failures.link_down([q], 324, 364),  # window may outlive `end`
    )
    np.testing.assert_array_equal(flap.start, stack.start)
    np.testing.assert_array_equal(flap.end, stack.end)
    sum_a, st_a, tel_a = _run(flap)
    sum_b, st_b, tel_b = _run(stack)
    assert repr(sum_a) == repr(sum_b)
    _assert_states_equal(st_a, st_b)
    np.testing.assert_array_equal(tel_a, tel_b)
    assert sum_a.drops_fail > 0, "flap windows must actually drop traffic"


def test_flapping_builder_rejects_bad_duty_cycle():
    with pytest.raises(ValueError, match="down_ticks"):
        failures.link_flapping([0], 0, 100, period=50, down_ticks=50)
    with pytest.raises(ValueError, match="down_ticks"):
        failures.link_flapping([0], 0, 100, period=50, down_ticks=0)
    assert failures.link_flapping([0], 90, 80, 50, 10).queue.size == 0


# ---------------------------------------------------------------------------
# gray_loss
# ---------------------------------------------------------------------------


def test_gray_loss_rows_and_rate_mapping():
    fs = failures.gray_loss([1, 5], start=10, end=200, rate=0.25)
    assert (fs.kind == K_GRAY).all()
    np.testing.assert_array_equal(fs.param, [16384, 16384])
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="rate"):
            failures.gray_loss([1], 0, 10, bad)


def test_gray_loss_drops_deterministically():
    # the window closes early enough for every dropped packet to be
    # retransmitted: drop <= 160, RTO 400, horizon 720
    topo = Topology.build(CFG)
    qs = [int(topo.t0_up_queues(t)[0]) for t in range(CFG.n_tors)]
    fs = failures.gray_loss(qs, start=0, end=160, rate=0.5)
    sum_a, st_a, tel_a = _run(fs, ticks=720)
    sum_b, st_b, tel_b = _run(fs, ticks=720)
    assert sum_a.drops_fail > 0, "rate 0.5 over live uplinks must drop"
    assert sum_a.completed == sum_a.n_conns, "gray loss is survivable"
    assert repr(sum_a) == repr(sum_b)
    _assert_states_equal(st_a, st_b)
    np.testing.assert_array_equal(tel_a, tel_b)


def test_gray_loss_kill_resume_bit_parity(tmp_path):
    """Kill/resume lands mid-gray-window: the tick-keyed fold-3 stream
    must reproduce the exact same per-packet drops after restore."""
    topo = Topology.build(CFG)
    qs = [int(topo.t0_up_queues(t)[0]) for t in range(CFG.n_tors)]
    fs = failures.gray_loss(qs, start=0, end=TICKS, rate=0.4)

    def engine():
        return SweepEngine(CFG, [_case("cell", fs)], devices=None,
                           min_failure_slots=SLOTS)

    golden = engine().run(collect="summary", chunk=CHUNK)
    g_state = jax.tree_util.tree_map(np.asarray, golden.buckets[0].final_state)
    g_tel = np.asarray(golden.buckets[0].telemetry)

    d = str(tmp_path / "ck")
    first = SoakRunner(engine(), SoakConfig(chunk=CHUNK, ckpt_dir=d))
    first.advance(CHUNK)  # die inside the gray window
    del first
    resumed = SoakRunner(engine(), SoakConfig(chunk=CHUNK, ckpt_dir=d)).resume()
    assert resumed.cursor == CHUNK
    resumed.advance(TICKS)
    res = resumed.result()
    assert repr(res.summaries()) == repr(golden.summaries())
    _assert_states_equal(
        jax.tree_util.tree_map(np.asarray, res.buckets[0].final_state), g_state
    )
    np.testing.assert_array_equal(np.asarray(res.buckets[0].telemetry), g_tel)


# ---------------------------------------------------------------------------
# switch-level composition
# ---------------------------------------------------------------------------


def test_switch_down_covers_all_tor_uplinks():
    fs = failures.switch_down(CFG, 1, 50, 90)
    topo = Topology.build(CFG)
    np.testing.assert_array_equal(
        np.sort(fs.queue), np.sort(topo.t0_up_queues(1))
    )
    assert (fs.kind == K_DOWN).all()
    deg = failures.switch_degraded(CFG, 1, 50, 90)
    np.testing.assert_array_equal(np.sort(deg.queue), np.sort(fs.queue))
    assert (deg.kind == K_DEGRADED).all()


def test_switch_down_inject_equals_static(tmp_path):
    delta = failures.switch_down(CFG, 2, start=CHUNK + 8, end=CHUNK + 128)

    def engine(extra=None):
        fs = extra if extra is not None else FailureSchedule.none()
        return SweepEngine(CFG, [_case("cell", fs)], devices=None,
                           min_failure_slots=SLOTS)

    static = engine(extra=delta).run(collect="summary", chunk=CHUNK)
    soak = SoakRunner(
        engine(), SoakConfig(chunk=CHUNK, ckpt_dir=str(tmp_path / "ck"))
    )
    soak.advance(CHUNK)
    soak.inject(delta)
    soak.advance(TICKS)
    res = soak.result()
    assert repr(res.summaries()) == repr(static.summaries())
    _assert_states_equal(
        jax.tree_util.tree_map(np.asarray, res.buckets[0].final_state),
        jax.tree_util.tree_map(np.asarray, static.buckets[0].final_state),
    )
    np.testing.assert_array_equal(
        np.asarray(res.buckets[0].telemetry),
        np.asarray(static.buckets[0].telemetry),
    )


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def _sched(queue, start, end, kind, param=None):
    n = len(queue)
    return FailureSchedule(
        queue=np.asarray(queue, np.int32),
        start=np.asarray(start, np.int32),
        end=np.asarray(end, np.int32),
        kind=np.asarray(kind, np.int32),
        param=None if param is None else np.asarray(param, np.int32),
    )


def test_validate_rejects_unknown_kind_naming_row():
    fs = _sched([0, 1], [0, 0], [10, 10], [0, 9])
    with pytest.raises(ValueError, match=r"row 1.*kind"):
        fs.validate(8)


def test_validate_rejects_inverted_and_negative_windows():
    with pytest.raises(ValueError, match="row 0"):
        _sched([0], [20], [10], [0]).validate(8)
    with pytest.raises(ValueError, match="row 0"):
        _sched([0], [-5], [10], [0]).validate(8)


def test_validate_rejects_bad_gray_param():
    with pytest.raises(ValueError, match=r"row 0.*param"):
        _sched([0], [0], [10], [K_GRAY], [0]).validate(8)
    with pytest.raises(ValueError, match=r"row 0.*param"):
        _sched([0], [0], [10], [K_GRAY], [GRAY_SCALE + 1]).validate(8)
    _sched([0], [0], [10], [K_GRAY], [GRAY_SCALE]).validate(8)  # 100% ok


def test_validate_rejects_param_on_non_gray_rows():
    with pytest.raises(ValueError, match=r"row 0.*param"):
        _sched([0], [0], [10], [K_DOWN], [7]).validate(8)


def test_validate_rejects_out_of_range_queue():
    with pytest.raises(ValueError, match="row 0"):
        _sched([99], [0], [10], [0]).validate(8)


def test_simulator_build_rejects_bad_schedule():
    from repro.netsim.engine import Simulator
    from repro.core import make_lb

    fs = _sched([0], [0], [10], [5])
    with pytest.raises(ValueError, match="kind"):
        Simulator(CFG, WL, make_lb("reps"), failures=fs)
