"""Packet-level simulator: conservation invariants and the paper's
qualitative results at CI scale."""
import jax
import numpy as np
import pytest

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.netsim import (
    MixedLB,
    SimConfig,
    Simulator,
    Topology,
    failures,
    summarize,
    workloads,
)

CFG = FATTREE_32_CI


def run(cfg, wl, lb, ticks, fs=None, seed=0):
    sim = Simulator(cfg, wl, lb, failures=fs, seed=seed)
    st, tr = sim.run(ticks)
    jax.block_until_ready(st.c_done)
    return sim, st, summarize(sim, st)


def assert_conserved(sim, st, s):
    assert s.alloc_fails == 0
    assert s.unprocessed_events == 0
    if s.completed == s.n_conns:
        assert int(np.asarray(st.c_inflight).clip(0).sum()) == 0
        # every packet slot eventually returns to the free list (orphans of
        # finished conns may still be draining; allow small slack)
        assert int(st.fl_count) >= sim.NP - 64


@pytest.mark.parametrize("lbn", ["ops", "reps", "ecmp", "plb", "flowlet",
                                 "mptcp", "mprdma", "bitmap", "adaptive_roce"])
def test_all_lbs_complete_permutation(lbn):
    wl = workloads.permutation(32, 48, seed=1)
    lb = make_lb(lbn, evs_size=CFG.evs_size)
    sim, st, s = run(CFG, wl, lb, 1500)
    assert s.completed == s.n_conns, s
    assert_conserved(sim, st, s)


def test_ecmp_collides_ops_does_not():
    wl = workloads.permutation(32, 64, seed=3)
    _, _, s_ecmp = run(CFG, wl, make_lb("ecmp", evs_size=CFG.evs_size), 2000)
    _, _, s_ops = run(CFG, wl, make_lb("ops", evs_size=CFG.evs_size), 2000)
    assert s_ops.runtime_ticks < s_ecmp.runtime_ticks  # paper's core premise


def test_reps_beats_ops_under_failure():
    topo = Topology.build(CFG)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 200, 2**30)
    wl = workloads.permutation(32, 64, seed=3)
    _, _, s_ops = run(CFG, wl, make_lb("ops", evs_size=CFG.evs_size), 4000, fs)
    _, _, s_reps = run(
        CFG, wl, make_lb("reps", evs_size=CFG.evs_size, freezing_timeout=600),
        4000, fs,
    )
    assert s_reps.completed == s_reps.n_conns
    assert s_reps.runtime_ticks < s_ops.runtime_ticks
    assert s_reps.timeouts <= s_ops.timeouts


def test_reps_adapts_to_asymmetry():
    topo = Topology.build(CFG)
    fs = failures.link_degraded([int(topo.t0_up_queues(0)[0])], 0, 2**30)
    wl = workloads.permutation(32, 64, seed=5)
    _, _, s_ops = run(CFG, wl, make_lb("ops", evs_size=CFG.evs_size), 3000, fs)
    _, _, s_reps = run(CFG, wl, make_lb("reps", evs_size=CFG.evs_size), 3000, fs)
    assert s_reps.runtime_ticks <= s_ops.runtime_ticks


def test_trimming_reduces_timeouts():
    wl = workloads.incast(32, 16, 48)
    cfg_t = CFG.replace(trimming=True, queue_capacity=24)
    cfg_n = CFG.replace(trimming=False, queue_capacity=24)
    _, _, s_t = run(cfg_t, wl, make_lb("reps", evs_size=CFG.evs_size), 4000)
    _, _, s_n = run(cfg_n, wl, make_lb("reps", evs_size=CFG.evs_size), 4000)
    assert s_t.completed == s_t.n_conns
    assert s_t.timeouts <= s_n.timeouts


def test_ack_coalescing_still_completes():
    wl = workloads.permutation(32, 48, seed=2)
    cfg = CFG.replace(ack_coalesce=4)
    sim, st, s = run(cfg, wl, make_lb("reps", evs_size=CFG.evs_size), 2500)
    assert s.completed == s.n_conns
    assert_conserved(sim, st, s)


def test_three_tier_topology():
    cfg = SimConfig(
        n_hosts=32, hosts_per_tor=4, tiers=3, tors_per_pod=2, aggs_per_pod=4,
        agg_uplinks=2, evs_size=256, queue_capacity=48, init_cwnd_pkts=40,
        max_cwnd_pkts=80, rto_ticks=500, max_msg_pkts=256,
    )
    wl = workloads.permutation(32, 32, seed=1)
    sim, st, s = run(cfg, wl, make_lb("reps", evs_size=256), 2500)
    assert s.completed == s.n_conns, s
    assert_conserved(sim, st, s)


def test_collective_dependencies():
    wl = workloads.ring_allreduce(8, 32)
    cfg = CFG.replace(n_hosts=32)
    sim, st, s = run(cfg, wl, make_lb("reps", evs_size=256), 6000)
    assert s.completed == s.n_conns
    # rounds must finish in dependency order
    done_tick = np.asarray(st.c_done_tick)
    n = 8
    for r in range(1, 2 * (n - 1)):
        for i in range(n):
            c = r * n + i
            dep = (r - 1) * n + (i - 1) % n
            assert done_tick[c] > done_tick[dep]


def test_mixed_traffic():
    wl, bg = workloads.permutation_with_background(32, 48, 0.25, seed=1)
    lb = MixedLB(
        make_lb("reps", evs_size=CFG.evs_size),
        make_lb("ecmp", evs_size=CFG.evs_size),
        bg,
    )
    sim, st, s = run(CFG, wl, lb, 2500)
    assert s.completed == s.n_conns
    assert_conserved(sim, st, s)


def test_pallas_reps_backend_matches_jnp_in_engine():
    """fig06-style failure recovery with the Pallas-kernel-backed RepsLB
    (interpret mode) must produce identical metrics to the jnp backend."""
    topo = Topology.build(CFG)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 150, 900)
    wl = workloads.permutation(32, 48, seed=3)
    kwargs = dict(evs_size=CFG.evs_size, freezing_timeout=400)
    _, st_j, s_j = run(CFG, wl, make_lb("reps", backend="jnp", **kwargs), 1500, fs)
    _, st_p, s_p = run(CFG, wl, make_lb("reps", backend="pallas", **kwargs), 1500, fs)
    assert s_p.completed == s_j.completed
    assert s_p.timeouts == s_j.timeouts
    assert s_p.drops_fail == s_j.drops_fail
    assert s_p.runtime_ticks == s_j.runtime_ticks
    np.testing.assert_array_equal(
        np.asarray(st_p.c_done_tick), np.asarray(st_j.c_done_tick)
    )
    np.testing.assert_array_equal(
        np.asarray(st_p.s_stats), np.asarray(st_j.s_stats)
    )


def test_pallas_arrivals_backend_matches_jnp():
    """Routing the arrivals enqueue through the queue_tick kernel must not
    change simulation results (incl. tail-drop + RED marking under load)."""
    wl = workloads.incast(32, 12, 48)
    cfg_j = CFG.replace(arrivals_backend="jnp", queue_capacity=24)
    cfg_p = CFG.replace(arrivals_backend="pallas", queue_capacity=24)
    _, st_j, s_j = run(cfg_j, wl, make_lb("reps", evs_size=CFG.evs_size), 1200)
    _, st_p, s_p = run(cfg_p, wl, make_lb("reps", evs_size=CFG.evs_size), 1200)
    np.testing.assert_array_equal(
        np.asarray(st_p.c_done_tick), np.asarray(st_j.c_done_tick)
    )
    np.testing.assert_array_equal(
        np.asarray(st_p.s_stats), np.asarray(st_j.s_stats)
    )
    assert s_p.drops_cong == s_j.drops_cong
    assert s_p.ecn_marks == s_j.ecn_marks


def test_deterministic_given_seed():
    wl = workloads.permutation(32, 32, seed=4)
    _, st1, s1 = run(CFG, wl, make_lb("reps", evs_size=256), 800, seed=9)
    _, st2, s2 = run(CFG, wl, make_lb("reps", evs_size=256), 800, seed=9)
    assert s1.runtime_ticks == s2.runtime_ticks
    assert np.array_equal(np.asarray(st1.c_done_tick), np.asarray(st2.c_done_tick))
