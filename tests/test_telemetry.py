"""Telemetry sketch channels (repro.netsim.telemetry, collect="summary").

The contract under test:

* **Counter/scalar bit-parity** — summary-mode ``RunSummary`` counters
  (drops/timeouts/delivered/...), completion counts, runtime_ticks and
  mean FCT are bit-identical to the state-built summaries of a
  ``collect="full"`` reference, across ≥2 shape buckets and multiple
  seeds; the CounterTotals channel telescopes to the final ``s_stats``
  exactly.
* **Percentiles to bin resolution** — sketch percentiles of random traces
  land within one bin width of the exact host-side percentile.
* **Early-exit equivalence** — the stacked sketch carries of an
  early-exited summary run are bit-identical to scanning the full horizon
  (reducers are no-ops on quiescent ticks).
* **Bandwidth** — host transfer bytes per row drop ≥10× vs the raw trace
  streams at CI scale (the O(rows × bins) vs O(rows × ticks) model).
* **Figure grids** — fig02 and fig07 smoke grids run end-to-end with
  ``collect="summary"`` + ``early_exit=True`` and reproduce the
  ``collect="full"`` reference metrics (acceptance shape).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; shim keeps tests live
    from _hypothesis_fallback import given, settings, st

import benchmarks.fig02_symmetric as fig02
import benchmarks.fig07_failures_macro as fig07
from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.netsim import (
    FleetRunner, PackerConfig, Simulator, SweepCase, SweepEngine,
    TelemetrySpec, Topology, failures, sketch_bin_index, sketch_percentile,
    us_to_ticks, workloads,
)

CFG = FATTREE_32_CI


def _case(name, wl, lb, ticks, fs=None, seeds=(0,), **lb_kwargs):
    lb_kwargs.setdefault("evs_size", CFG.evs_size)
    return SweepCase(
        name=name, workload=wl, lb=lb, ticks=ticks, lb_kwargs=lb_kwargs,
        failures=fs, seeds=tuple(seeds),
    )


def _assert_summary_matches(a, b, tel, context=""):
    """a = state-built RunSummary (reference), b = sketch-built."""
    exact = (
        "completed", "runtime_ticks", "mean_fct_ticks", "drops_cong",
        "drops_fail", "timeouts", "delivered", "injected", "ecn_marks",
        "unprocessed_events", "alloc_fails",
    )
    for f in exact:
        va, vb = getattr(a, f), getattr(b, f)
        if isinstance(va, float) and np.isnan(va):
            assert np.isnan(vb), (context, f, va, vb)
        else:
            assert va == vb, (context, f, va, vb)
    if a.completed:
        edges = tel["fct_hist"]["edges"]
        ba = sketch_bin_index(edges, a.p99_fct_ticks)
        bb = sketch_bin_index(edges, b.p99_fct_ticks)
        assert abs(ba - bb) <= 1, (context, a.p99_fct_ticks, b.p99_fct_ticks)
        # the sketch estimate is a bin lower edge ≤ one bin above the exact
        assert b.p99_fct_ticks <= a.p99_fct_ticks + (
            edges[min(ba + 1, len(edges) - 2) + 1] - edges[ba]
        ), context


# ---------------------------------------------------------------------------
# Sketch statistics: percentiles to bin resolution on random traces.
# ---------------------------------------------------------------------------

VALUES = st.lists(st.integers(1, 5000), min_size=1, max_size=400)


@settings(max_examples=60, deadline=None)
@given(VALUES, st.integers(4, 96), st.integers(0, 1), st.integers(0, 300),
       st.integers(0, 3))
def test_sketch_percentiles_within_one_bin(values, n_bins, log_spacing,
                                           zeros, q_i):
    """Histogram percentiles of random traces match the exact host-side
    percentile within the width of the bin the exact value falls in —
    including reconstructed zero counts (the qlen channel)."""
    q = [50.0, 90.0, 99.0, 99.9][q_i]
    vals = np.asarray(values, np.int64)
    hi = max(int(vals.max()) + 1, 2)
    if log_spacing:
        edges = np.geomspace(1.0, hi, n_bins + 1).astype(np.float32)
    else:
        edges = np.linspace(1.0, hi, n_bins + 1).astype(np.float32)
    edges64 = edges.astype(np.float64)
    counts = np.zeros((n_bins,), np.int64)
    for v in vals:
        counts[sketch_bin_index(edges64, v)] += 1

    est = sketch_percentile(counts, edges64, q, zeros=zeros)
    all_vals = np.concatenate([np.zeros((zeros,), np.int64), vals])
    exact = float(np.percentile(all_vals, q, method="higher"))
    if exact == 0.0:
        assert est == 0.0
        return
    b = sketch_bin_index(edges64, exact)
    width = edges64[b + 1] - edges64[b]
    assert abs(est - exact) <= width + 1e-9, (est, exact, width)


def test_running_scalar_wide_sums_past_int32():
    """The (hi, lo) split accumulators stay exact when a run-long sum
    crosses 2^31 (paper-scale NQ × occupancy × ticks) — the int32 stacked
    carry must not silently wrap."""
    import jax.numpy as jnp

    from repro.netsim import Probe
    from repro.netsim.engine import N_STATS
    from repro.netsim.telemetry import RunningScalars, _wide_total

    ch = RunningScalars()
    built = {"nq": 4}
    v = {k: jnp.asarray(x) for k, x in ch.init(built).items()}
    qlen = jnp.full((4,), 10**8, jnp.int32)  # 4e8 per tick
    probe = Probe(
        now=jnp.asarray(0, jnp.int32), q_len=qlen,
        served=jnp.zeros((4,), jnp.int32),
        watch_qlen=qlen, watch_served=jnp.zeros((4,), jnp.int32),
        stats_delta=jnp.zeros((N_STATS,), jnp.int32),
        done_now=jnp.zeros((2,), bool), fct=jnp.zeros((2,), jnp.int32),
    )
    n = 8  # 3.2e9 total > 2^31
    for _ in range(n):
        v = ch.update(built, v, probe)
    assert _wide_total(v["qlen_sum_hi"], v["qlen_sum_lo"]) == n * 4 * 10**8
    out = ch.finalize(built, v, horizon=n)
    assert out["mean_qlen"] == 10**8

    # histogram bins carry the same (hi, lo) split: a lo word at the carry
    # threshold must roll into hi without losing a count
    from repro.netsim.telemetry import SUM_SHIFT, Histogram

    class _FakeSim:
        NQ = 4

        class cfg:
            queue_capacity = 48

    h = Histogram(source="qlen", n_bins=8, spacing="linear")
    hb = h.build(_FakeSim(), 100)
    hv = {k: jnp.asarray(x) for k, x in h.init(hb).items()}
    hv["counts_lo"] = jnp.full((8,), (1 << SUM_SHIFT) - 2, jnp.int32)
    hprobe = probe._replace(q_len=jnp.full((4,), 10, jnp.int32))
    before = h.finalize(hb, hv, horizon=0)["counts"].copy()
    hv = h.update(hb, hv, hprobe)
    assert int(jnp.max(hv["counts_lo"])) < (1 << SUM_SHIFT)
    after = h.finalize(hb, hv, horizon=0)["counts"]
    assert (after - before).sum() == 4  # all 4 observations kept


def test_sketch_percentile_unit_bins_exact():
    """Unit-width linear bins make sketch percentiles exact on integers."""
    rng = np.random.default_rng(0)
    vals = rng.integers(1, 48, size=500)
    edges = np.arange(1.0, 49.0)  # 47 unit bins [k, k+1)
    counts = np.zeros((47,), np.int64)
    for v in vals:
        counts[sketch_bin_index(edges, v)] += 1
    for q in (50, 90, 99):
        exact = float(np.percentile(vals, q, method="higher"))
        assert sketch_percentile(counts, edges, q) == exact, q


# ---------------------------------------------------------------------------
# Fleet (single-scenario) summary path.
# ---------------------------------------------------------------------------


def test_fleet_summary_bit_parity_and_counters():
    """run_summary: sketch summaries match state summaries bit-for-bit on
    every exact field, per seed, and the CounterTotals channel telescopes
    to the final s_stats exactly."""
    wl = workloads.permutation(32, 48, seed=1)
    fleet = FleetRunner(
        CFG, wl, make_lb("reps", evs_size=CFG.evs_size), seeds=(0, 3, 7)
    )
    states, tel = fleet.run_summary(600)
    ref = fleet.summaries(states)
    sketch = tel.summaries()
    for i in range(fleet.n_runs):
        r = tel.result(i)
        _assert_summary_matches(ref[i], sketch[i], r, f"seed_idx={i}")
        st_i = fleet.state_at(states, i)
        np.testing.assert_array_equal(
            np.asarray(st_i.s_stats), r["counters"]["totals"]
        )
        # exact scalar cross-checks against the raw final state
        done = np.asarray(st_i.c_done)
        done_tick = np.asarray(st_i.c_done_tick)
        fct = (done_tick - wl.start)[done]
        s = r["scalars"]
        assert s["fct_min"] == (int(fct.min()) if len(fct) else -1)
        assert s["fct_max"] == (int(fct.max()) if len(fct) else -1)
        assert s["fct_sum"] == int(fct.sum())
    # window series accounting: per-window deliveries sum to the total
    r0 = tel.result(0)
    assert r0["windows"]["delivered"].sum() == sketch[0].delivered
    assert r0["windows"]["util"].shape == r0["windows"]["mean_qlen"].shape


# ---------------------------------------------------------------------------
# Sweep summary mode: parity, early exit, bandwidth.
# ---------------------------------------------------------------------------


def _mixed_cases():
    topo = Topology.build(CFG)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 100, 400)
    wl_p = workloads.permutation(32, 48, seed=1)
    wl_i = workloads.incast(32, 5, 48)
    return [
        _case("perm/ecmp", wl_p, "ecmp", 500),
        _case("perm/reps", wl_p, "reps", 500, seeds=(0, 5)),
        _case("fail/reps", wl_p, "reps", 700, fs=fs),
        _case("incast/ops", wl_i, "ops", 700),
    ]


def test_sweep_summary_vs_full_bit_parity():
    """≥2 shape buckets, multi-seed rows, a failure cell: every cell's
    sketch summary reproduces the collect="full" reference exactly on all
    exact fields, p99 within one bin, and host bytes per row shrink ≥10×."""
    cases = _mixed_cases()
    eng_f = SweepEngine(CFG, cases, packer=PackerConfig(merge=False))
    assert len(eng_f.buckets) >= 2
    res_f = eng_f.run(collect="full", chunk=250)
    eng_s = SweepEngine(CFG, cases, packer=PackerConfig(merge=False))
    res_s = eng_s.run(collect="summary", early_exit=True)

    ref = res_f.summaries()
    sketch = res_s.summaries()  # auto → sketch path in summary mode
    for c in cases:
        for i in range(len(c.seeds)):
            tel = res_s.telemetry_for(c.name, i)
            _assert_summary_matches(
                ref[c.name][i], sketch[c.name][i], tel, f"{c.name}[{i}]"
            )
            # counters telescope to the final state of the summary run too
            st = res_s.state_for(c.name, i)
            np.testing.assert_array_equal(
                np.asarray(st.s_stats), tel["counters"]["totals"]
            )

    # bandwidth: O(ticks) trace rows vs O(bins) sketch rows, per row
    for bf, bs in zip(res_f.buckets, res_s.buckets):
        trace_bytes = sum(
            np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(bf.traces)
        ) / bf.n_rows
        sketch_bytes = bs.telemetry.nbytes / bs.n_rows
        assert sketch_bytes * 10 <= trace_bytes, (
            bf.plan.key, trace_bytes, sketch_bytes
        )
        assert bs.tel_prog.nbytes == bs.telemetry.nbytes / bs.n_rows

    # the state-built summaries of the summary run equal the reference
    # (summary mode never perturbs the simulation itself)
    state_sums = res_s.summaries(source="state")
    for c in cases:
        assert state_sums[c.name][0] == ref[c.name][0], c.name


def test_sweep_summary_early_exit_bit_equivalence():
    """Early-exited summary sketches are bit-identical to the full-horizon
    scan: reducers are no-ops on post-quiescent ticks.  Also covers a
    horizon-merged (masked) bucket — frozen rows stop reducing at their own
    horizon."""
    wl = workloads.permutation(32, 48, seed=1)
    cases = [
        _case("short/ops", wl, "ops", 300),
        _case("long/reps", wl, "reps", 900),
    ]
    eng = SweepEngine(CFG, cases, packer=PackerConfig(waste_budget=2.0))
    assert len(eng.buckets) == 1 and eng.buckets[0].program.masked
    res_full_h = eng.run(collect="summary", early_exit=False)
    tel_full = [b.telemetry.copy() for b in res_full_h.buckets]

    eng2 = SweepEngine(CFG, cases, packer=PackerConfig(waste_budget=2.0))
    res_early = eng2.run(collect="summary", early_exit=True, chunk=100)
    assert res_early.buckets[0].ticks_run < 900, "early exit should fire"
    for te, tf in zip([b.telemetry for b in res_early.buckets], tel_full):
        np.testing.assert_array_equal(te, tf)


def test_recovery_tracker_failure_latency():
    """Permanent uplink failures: the tracker pins the first failure drop
    inside the failure window and sees a successful delivery shortly after
    — the paper's sub-100µs re-route claim at CI scale."""
    topo = Topology.build(CFG)
    fail_start = 100
    fs = failures.link_down(
        list(topo.t0_up_queues(0)[:2]), fail_start, failures.FOREVER
    )
    wl = workloads.permutation(32, 256, seed=2)
    eng = SweepEngine(
        CFG, [_case("f/reps", wl, "reps", 2500, fs=fs, freezing_timeout=300)]
    )
    res = eng.run(collect="summary", early_exit=True)
    rec = res.telemetry_for("f/reps")["recovery"]
    s = res.summaries()["f/reps"][0]
    assert s.drops_fail > 0, "scenario must produce failure drops"
    assert rec["first_drop_tick"] >= fail_start
    assert rec["first_redeliver_tick"] > rec["first_drop_tick"]
    assert 0 < rec["recovery_ticks"] <= us_to_ticks(100), rec
    assert rec["recovery_us"] < 100.0


# ---------------------------------------------------------------------------
# Acceptance: fig02 + fig07 grids end-to-end under collect="summary".
# ---------------------------------------------------------------------------


def _shrink(cases, factor=16, floor=300):
    return [
        dataclasses.replace(c, ticks=max(floor, c.ticks // factor), seeds=(0,))
        for c in cases
    ]


def _grid_roundtrip(cases):
    eng_s = SweepEngine(CFG, cases)
    res_s = eng_s.run(collect="summary", early_exit=True)
    eng_f = SweepEngine(CFG, cases)
    res_f = eng_f.run(collect="full")
    ref, sketch = res_f.summaries(), res_s.summaries()
    for c in cases:
        tel = res_s.telemetry_for(c.name)
        _assert_summary_matches(ref[c.name][0], sketch[c.name][0], tel, c.name)
    ratios = []
    for bf, bs in zip(res_f.buckets, res_s.buckets):
        trace_bytes = sum(
            np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(bf.traces)
        ) / bf.n_rows
        ratios.append(trace_bytes / (bs.telemetry.nbytes / bs.n_rows))
    return ratios


def test_fig02_summary_grid_end_to_end():
    # factor 8 keeps horizons at 500 ticks — still 8× below the real fig02
    # grid (4000), where the trace-vs-sketch ratio only grows (the sketch
    # side is O(bins), horizon-independent)
    ratios = _grid_roundtrip(_shrink(fig02.cases(CFG, smoke=True), factor=8,
                                     floor=500))
    assert min(ratios) >= 10, ratios


def test_fig07_summary_grid_end_to_end():
    ratios = _grid_roundtrip(_shrink(fig07.cases(CFG, smoke=True)))
    assert min(ratios) >= 10, ratios


# ---------------------------------------------------------------------------
# Per-cohort channel masks (fig05-style fg/bg mixed workloads).
# ---------------------------------------------------------------------------


def test_cohort_masks_partition_fct_sketches():
    """``with_cohorts`` adds per-cohort FCT histogram/scalar channels that
    exactly partition the global ones: counts and FCT sums of fg + bg
    equal the unfiltered channels, and each cohort sum matches the
    state-path FCTs of its conn ids."""
    import pytest

    wl, bg = workloads.permutation_with_background(32, 24, 0.25, seed=4)
    fg_ids = tuple(int(i) for i in np.nonzero(~bg)[0])
    bg_ids = tuple(int(i) for i in np.nonzero(bg)[0])
    spec = TelemetrySpec.default().with_cohorts({"fg": fg_ids, "bg": bg_ids})
    case = _case("cell", wl, "reps", 360)
    res = SweepEngine(CFG, [case], devices=None).run(
        collect="summary", telemetry=spec, chunk=120
    )
    tel = res.telemetry_for("cell")

    total = int(tel["fct_hist"]["counts"].sum())
    fg_n = int(tel["fct_hist_fg"]["counts"].sum())
    bg_n = int(tel["fct_hist_bg"]["counts"].sum())
    assert total == wl.n_conns, "baseline grid must complete"
    assert fg_n == len(fg_ids) and bg_n == len(bg_ids)
    assert fg_n + bg_n == total

    assert tel["scalars_fg"]["fct_count"] == len(fg_ids)
    assert tel["scalars_bg"]["fct_count"] == len(bg_ids)
    assert (
        tel["scalars_fg"]["fct_sum"] + tel["scalars_bg"]["fct_sum"]
        == tel["scalars"]["fct_sum"]
    )

    # state-path cross-check: cohort FCT sums from the final state
    st = res.state_for("cell")
    fct = np.asarray(st.c_done_tick) - np.asarray(wl.start)
    assert tel["scalars_fg"]["fct_sum"] == int(fct[list(fg_ids)].sum())
    assert tel["scalars_bg"]["fct_sum"] == int(fct[list(bg_ids)].sum())

    # per-cohort histograms and scalars see disjoint mins/maxes
    assert tel["scalars_fg"]["fct_max"] <= tel["scalars"]["fct_max"]
    assert tel["scalars_bg"]["fct_max"] <= tel["scalars"]["fct_max"]


# ---------------------------------------------------------------------------
# sketch_percentile hardening + windowed-series streaming (stream_rows).
# ---------------------------------------------------------------------------


def test_sketch_percentile_empty_is_nan_never_zero():
    import pytest

    edges = np.linspace(1.0, 10.0, 5)
    est = sketch_percentile(np.zeros((4,), np.int64), edges, 99.0)
    assert np.isnan(est), "empty sketch must be NaN, not a fabricated 0.0"
    # zeros-only sketches DO have order statistics: all of them are 0
    assert sketch_percentile(np.zeros((4,), np.int64), edges, 99.0,
                             zeros=7) == 0.0
    with pytest.raises(ValueError, match="q must be"):
        sketch_percentile(np.ones((4,), np.int64), edges, 101.0)
    with pytest.raises(ValueError, match="q must be"):
        sketch_percentile(np.ones((4,), np.int64), edges, -0.5)
    with pytest.raises(ValueError, match="zeros"):
        sketch_percentile(np.ones((4,), np.int64), edges, 50.0, zeros=-1)
    with pytest.raises(ValueError, match="non-negative"):
        sketch_percentile(np.asarray([3, -1, 2]), edges, 50.0)
    # q=0 / q=100 boundary queries stay legal
    assert sketch_percentile(np.asarray([1, 0, 0, 0]), edges, 0.0) == edges[0]
    assert sketch_percentile(np.asarray([0, 0, 0, 1]), edges,
                             100.0) == edges[3]


def _stream_serial(sim, ticks, stride, cuts):
    """Scan a serial sim in windows tiled by ``cuts``, draining
    ``stream_rows`` at each boundary (the soak flush pattern)."""
    from repro.netsim.telemetry import TelemetrySpec as Spec

    prog = Spec.default(stride=stride).build(sim, ticks)

    def body(carry, t):
        st, tel = carry
        new, probe = sim.step_probe(st, t, sim.base_key, sim.scn)
        return (new, prog.update(tel, probe)), None

    carry = (sim.init_state(), prog.init())
    emitted, t0 = [], 0
    for t1 in cuts:
        carry, _ = jax.lax.scan(
            body, carry, jnp.arange(t0, t1, dtype=jnp.int32)
        )
        emitted.append(prog.stream_rows(np.asarray(carry[1]), t0, t1))
        t0 = t1
    return prog, np.asarray(carry[1]), emitted


def test_stream_rows_tiling_concatenates_to_one_shot():
    """Any chunk tiling of [0, ticks) emits adjacent, non-overlapping
    window ranges whose concatenation equals the one-shot decode — the
    soak runtime's streamed series are exactly the finalize arrays."""
    wl = workloads.permutation(32, 24, seed=1)
    sim = Simulator(CFG, wl, make_lb("reps", evs_size=CFG.evs_size))
    ticks, stride = 360, 24
    for cuts in ([360], [120, 240, 360], [97, 247, 360], [1, 359, 360]):
        prog, flat, emitted = _stream_serial(sim, ticks, stride, cuts)
        one = prog.stream_rows(flat, 0, ticks)
        assert set(one) == {"windows"}
        ranges = [e["windows"] for e in emitted if e]
        # adjacency: each emission starts where the previous ended
        lo = 0
        for r in ranges:
            assert r["lo"] == lo, cuts
            lo = r["hi"]
        assert lo == one["windows"]["hi"] == ticks // stride
        for k in ("util", "qlen_sum", "stats"):
            np.testing.assert_array_equal(
                np.concatenate([r[k] for r in ranges]),
                one["windows"][k], err_msg=f"{cuts}:{k}",
            )


def test_stream_rows_partial_last_window_completes_at_horizon():
    """A horizon that is not a stride multiple still flushes the partial
    last window once t1 reaches it — and never before."""
    wl = workloads.permutation(32, 24, seed=1)
    sim = Simulator(CFG, wl, make_lb("reps", evs_size=CFG.evs_size))
    ticks, stride = 350, 24  # 15 windows, last covers [336, 350)
    prog, flat, emitted = _stream_serial(sim, ticks, stride, [340, 350])
    first, second = emitted[0]["windows"], emitted[1]["windows"]
    assert first["hi"] == 340 // 24  # window 14 incomplete at t=340
    assert second["lo"] == first["hi"]
    assert second["hi"] == -(-ticks // stride)  # horizon completes it
    one = prog.stream_rows(flat, 0, ticks)["windows"]
    np.testing.assert_array_equal(
        np.concatenate([first["util"], second["util"]]), one["util"]
    )


def test_cohort_mask_validation():
    """Out-of-range cohort ids are rejected at program build, and
    ``conn_filter`` composes only with the FCT source."""
    import pytest

    from repro.netsim import Histogram, RunningScalars, Simulator
    from repro.netsim.telemetry import TelemetrySpec as Spec

    wl = workloads.permutation(32, 8, seed=0)
    sim = Simulator(CFG, wl, make_lb("reps"))
    bad = Spec(channels=(RunningScalars(name="s_x", conn_filter=(99,)),))
    with pytest.raises(ValueError, match="conn"):
        bad.build(sim, 100)
    qlen = Spec(channels=(
        Histogram(source="qlen", name="q_x", conn_filter=(0,)),
    ))
    with pytest.raises(ValueError, match="fct"):
        qlen.build(sim, 100)
