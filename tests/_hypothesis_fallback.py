"""Minimal stand-in for `hypothesis` when it is not installed.

The container image does not ship hypothesis and installing packages is not
an option, so property tests fall back to this shim: each strategy is a
callable `rng -> value`, and `given` runs the test body over a fixed number
of seeded-random examples (deterministic across runs).  Coverage is thinner
than real hypothesis (no shrinking, no example database) but the same
property bodies execute, which keeps the parity/invariant assertions live.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def tuples(*parts: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(p.draw(rng) for p in parts))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0, max_size: int = 32) -> _Strategy:
        return _Strategy(
            lambda rng: [
                elem.draw(rng) for _ in range(rng.randint(min_size, max_size))
            ]
        )

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))


st = strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Records max_examples on the (already `given`-wrapped) test."""

    def apply(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return apply


def given(*strats: _Strategy):
    def decorate(fn):
        # No functools.wraps: the wrapper must expose a ZERO-arg signature,
        # otherwise pytest treats the strategy-filled parameters as fixtures.
        def wrapper():
            n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_EXAMPLES)
            for ex in range(n):
                rng = random.Random(0xC0FFEE ^ (ex * 0x9E3779B1))
                drawn = tuple(s.draw(rng) for s in strats)
                fn(*drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return decorate
