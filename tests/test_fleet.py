"""FleetRunner: vmapped multi-seed execution must be bit-identical to
serial single-scenario runs, per seed."""
import jax
import numpy as np
import pytest

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.netsim import FleetRunner, Simulator, Topology, failures, workloads

CFG = FATTREE_32_CI
SEEDS = [0, 3, 11]


def _serial(cfg, wl, lb_factory, ticks, fs=None, seed=0):
    sim = Simulator(cfg, wl, lb_factory(), failures=fs, seed=seed)
    st, tr = sim.run(ticks)
    jax.block_until_ready(st.c_done)
    return st, tr


@pytest.mark.parametrize("lbn", ["reps", "ops", "plb"])
def test_fleet_matches_serial_per_seed(lbn):
    wl = workloads.permutation(32, 48, seed=1)
    lb_factory = lambda: make_lb(lbn, evs_size=CFG.evs_size)
    fleet = FleetRunner(CFG, wl, lb_factory(), seeds=SEEDS)
    states, traces = fleet.run(700)
    jax.block_until_ready(states.c_done)
    for i, seed in enumerate(SEEDS):
        st, tr = _serial(CFG, wl, lb_factory, 700, seed=seed)
        np.testing.assert_array_equal(
            np.asarray(states.c_done_tick[i]), np.asarray(st.c_done_tick)
        )
        np.testing.assert_array_equal(
            np.asarray(states.s_stats[i]), np.asarray(st.s_stats)
        )
        np.testing.assert_array_equal(
            np.asarray(traces.delivered[:, i]), np.asarray(tr.delivered)
        )
        np.testing.assert_array_equal(
            np.asarray(traces.watch_qlen[:, i]), np.asarray(tr.watch_qlen)
        )


def test_fleet_matches_serial_under_failures():
    topo = Topology.build(CFG)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 150, 2**30)
    wl = workloads.permutation(32, 48, seed=3)
    lb_factory = lambda: make_lb("reps", evs_size=CFG.evs_size, freezing_timeout=600)
    fleet = FleetRunner(CFG, wl, lb_factory(), failures=fs, seeds=SEEDS)
    states, _ = fleet.run(1200)
    for i, seed in enumerate(SEEDS):
        st, _ = _serial(CFG, wl, lb_factory, 1200, fs=fs, seed=seed)
        np.testing.assert_array_equal(
            np.asarray(states.c_done_tick[i]), np.asarray(st.c_done_tick)
        )
        np.testing.assert_array_equal(
            np.asarray(states.s_stats[i]), np.asarray(st.s_stats)
        )


def test_fleet_summaries_shape():
    wl = workloads.permutation(32, 32, seed=4)
    fleet = FleetRunner(
        CFG, wl, make_lb("reps", evs_size=256), seeds=[5, 9]
    )
    states, _ = fleet.run(600)
    sums = fleet.summaries(states)
    assert len(sums) == 2
    # different seeds take different paths through the network
    assert sums[0].completed == sums[1].completed == wl.n_conns
