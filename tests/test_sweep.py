"""Sweep engine: shape-bucketed heterogeneous cells must be bit-identical
to serial ``Simulator.run`` on the padded serial reference (`serial_sim`),
per cell and per seed — across buckets, SwitchLB branches, failure padding,
chunked trace streaming, and quiescence early exit.  Plus conservation
invariants for the AI-collective workloads."""
import jax
import numpy as np
import pytest

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.netsim import (
    SweepCase, SweepEngine, Topology, failures, workloads,
)

CFG = FATTREE_32_CI


def _case(name, wl, lb, ticks, fs=None, seeds=(0,), **lb_kwargs):
    lb_kwargs.setdefault("evs_size", CFG.evs_size)
    return SweepCase(
        name=name, workload=wl, lb=lb, ticks=ticks, lb_kwargs=lb_kwargs,
        failures=fs, seeds=tuple(seeds),
    )


def _assert_cell_matches_serial(eng, res, name, ticks, seed_idx=0, seed=0,
                                traces=True):
    ref = eng.serial_sim(name, seed=seed)
    st, tr = ref.run(ticks)
    jax.block_until_ready(st.c_done)
    sw = res.state_for(name, seed_idx)
    np.testing.assert_array_equal(np.asarray(st.c_done_tick), sw.c_done_tick)
    np.testing.assert_array_equal(np.asarray(st.s_stats), sw.s_stats)
    np.testing.assert_array_equal(np.asarray(st.q_served), sw.q_served)
    if traces:
        sw_tr = res.trace_for(name, seed_idx)
        np.testing.assert_array_equal(np.asarray(tr.delivered), sw_tr.delivered)
        np.testing.assert_array_equal(np.asarray(tr.watch_qlen), sw_tr.watch_qlen)
    return st, sw


def test_sweep_parity_across_buckets_and_lbs():
    """≥2 shape buckets (NC 32 and NC 8→padded), three LB variants behind
    one lax.switch, full traces streamed in chunks — every cell equals its
    serial reference bit-for-bit."""
    wl_p = workloads.permutation(32, 48, seed=1)
    wl_i = workloads.incast(32, 5, 48)
    cases = [
        _case("perm/ecmp", wl_p, "ecmp", 500),
        _case("perm/ops", wl_p, "ops", 500),
        _case("perm/reps", wl_p, "reps", 500),
        _case("incast/reps", wl_i, "reps", 500),
    ]
    eng = SweepEngine(CFG, cases)
    assert len(eng.buckets) >= 2, "expected distinct shape buckets"
    res = eng.run(collect="full", chunk=200)
    for c in cases:
        _assert_cell_matches_serial(eng, res, c.name, 500)
    sums = res.summaries()
    assert sums["perm/ecmp"][0].lb == "ecmp"
    assert sums["incast/reps"][0].n_conns == wl_i.n_conns  # unpadded count


def test_sweep_parity_failures_and_seeds():
    """Padded failure schedules and a multi-seed row axis: per-seed rows
    equal serial runs with those seeds, including the LB pytree of the
    active switch branch."""
    topo = Topology.build(CFG)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 100, 400)
    wl = workloads.permutation(32, 48, seed=3)
    cases = [
        _case("f/ops", wl, "ops", 600, fs=fs),
        _case("f/reps", wl, "reps", 600, fs=fs, seeds=(0, 5),
              freezing_timeout=300),
    ]
    eng = SweepEngine(CFG, cases)
    res = eng.run(collect="none")
    _assert_cell_matches_serial(eng, res, "f/ops", 600, traces=False)
    for i, seed in enumerate((0, 5)):
        ref = eng.serial_sim("f/reps", seed=seed)
        st, _ = ref.run(600)
        jax.block_until_ready(st.c_done)
        sw = res.state_for("f/reps", i)
        np.testing.assert_array_equal(np.asarray(st.c_done_tick), sw.c_done_tick)
        np.testing.assert_array_equal(np.asarray(st.s_stats), sw.s_stats)
        # the active branch's LB state matches the serial variant's
        bidx, variant_states = sw.lb_state
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            st.lb_state, variant_states[int(bidx)],
        )


def test_sweep_early_exit_is_fixed_point():
    """Quiescence early exit must leave every engine-state leaf (everything
    but LB-internal clocks) bit-identical to running the full horizon."""
    wl = workloads.permutation(32, 48, seed=1)
    cases = [
        _case("p/ecmp", wl, "ecmp", 2000),
        _case("p/plb", wl, "plb", 2000),
    ]
    eng = SweepEngine(CFG, cases)
    res = eng.run(collect="none", early_exit=True, chunk=250)
    bucket = eng.buckets[0]
    assert bucket.ticks_run < 2000, "early exit should fire well before 2000"
    for name in ("p/ecmp", "p/plb"):
        ref = eng.serial_sim(name)
        st, _ = ref.run(2000)  # full horizon
        jax.block_until_ready(st.c_done)
        sw = res.state_for(name)
        for field in st._fields:
            if field == "lb_state":
                continue  # PLB epoch clocks legitimately keep advancing
            np.testing.assert_array_equal(
                np.asarray(getattr(st, field)),
                np.asarray(getattr(sw, field)),
                err_msg=field,
            )


def test_collectives_conservation_and_sweep_parity():
    """alltoall / ring_allreduce / butterfly_allreduce, swept over ≥2 shape
    buckets: at quiescence every message is fully delivered, no packet slot
    leaks, and injected == delivered + drops (exact when no timeouts —
    retransmissions are the only source of duplicate injections)."""
    ticks = 400
    wls = {
        "ring": workloads.ring_allreduce(8, 32),
        "butterfly": workloads.butterfly_allreduce(8, 32),
        "alltoall": workloads.alltoall(8, 4, window=2),
    }
    cases = [_case(f"coll/{k}", wl, "reps", ticks) for k, wl in wls.items()]
    eng = SweepEngine(CFG, cases)
    assert len(eng.buckets) >= 2
    res = eng.run(collect="none")
    sums = res.summaries()
    for k, wl in wls.items():
        name = f"coll/{k}"
        st, _sw = _assert_cell_matches_serial(
            eng, res, name, ticks, traces=False
        )
        sw = res.state_for(name)
        s = sums[name][0]
        # completion: every conn done, every message fully delivered
        assert s.completed == wl.n_conns, (k, s.completed)
        np.testing.assert_array_equal(
            sw.c_delivered[: wl.n_conns], wl.msg_pkts.astype(np.int32)
        )
        # conservation at quiescence: no slots leaked, nothing in flight
        assert int(sw.fl_count) == eng.serial_sim(name).NP, k
        assert not np.any(sw.c_inflight), k
        # injected == delivered + drops (timeout-free runs are exact)
        injected, delivered = int(s.injected), int(s.delivered)
        drops = int(s.drops_cong) + int(s.drops_fail)
        assert injected >= delivered, k
        if s.timeouts == 0:
            assert injected == delivered + drops, (k, injected, delivered, drops)


def test_sweep_engine_rejects_full_traces_with_early_exit():
    wl = workloads.permutation(32, 32, seed=4)
    eng = SweepEngine(CFG, [_case("x", wl, "ops", 100)])
    with pytest.raises(AssertionError):
        eng.run(collect="full", early_exit=True)
