"""Sweep engine: shape-bucketed heterogeneous cells must be bit-identical
to serial ``Simulator.run`` on the padded serial reference (`serial_sim`),
per cell and per seed — across buckets, SwitchLB branches, failure padding,
chunked trace streaming, and quiescence early exit.  Plus conservation
invariants for the AI-collective workloads, property tests for the
cost-aware bucket packer (``pack``), and failure-schedule padding /
truncation edge cases (golden figure-grid parity lives in
tests/test_figure_parity.py)."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; shim keeps tests live
    from _hypothesis_fallback import given, settings, st

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.netsim import (
    CellShape, FailureSchedule, PackerConfig, Simulator, SweepCase,
    SweepEngine, Topology, failures, pack, workloads,
)

CFG = FATTREE_32_CI
# pure shape quantization (no cost-aware merging): these tests assert on
# *distinct* shape buckets; the packer itself is covered further down.
NO_MERGE = PackerConfig(merge=False)


def _case(name, wl, lb, ticks, fs=None, seeds=(0,), **lb_kwargs):
    lb_kwargs.setdefault("evs_size", CFG.evs_size)
    return SweepCase(
        name=name, workload=wl, lb=lb, ticks=ticks, lb_kwargs=lb_kwargs,
        failures=fs, seeds=tuple(seeds),
    )


def _assert_cell_matches_serial(eng, res, name, ticks, seed_idx=0, seed=0,
                                traces=True):
    ref = eng.serial_sim(name, seed=seed)
    st, tr = ref.run(ticks)
    jax.block_until_ready(st.c_done)
    sw = res.state_for(name, seed_idx)
    np.testing.assert_array_equal(np.asarray(st.c_done_tick), sw.c_done_tick)
    np.testing.assert_array_equal(np.asarray(st.s_stats), sw.s_stats)
    np.testing.assert_array_equal(np.asarray(st.q_served), sw.q_served)
    if traces:
        sw_tr = res.trace_for(name, seed_idx)
        np.testing.assert_array_equal(np.asarray(tr.delivered), sw_tr.delivered)
        np.testing.assert_array_equal(np.asarray(tr.watch_qlen), sw_tr.watch_qlen)
    return st, sw


def test_sweep_parity_across_buckets_and_lbs():
    """≥2 shape buckets (NC 32 and NC 8→padded), three LB variants behind
    one lax.switch, full traces streamed in chunks — every cell equals its
    serial reference bit-for-bit."""
    wl_p = workloads.permutation(32, 48, seed=1)
    wl_i = workloads.incast(32, 5, 48)
    cases = [
        _case("perm/ecmp", wl_p, "ecmp", 500),
        _case("perm/ops", wl_p, "ops", 500),
        _case("perm/reps", wl_p, "reps", 500),
        _case("incast/reps", wl_i, "reps", 500),
    ]
    eng = SweepEngine(CFG, cases, packer=NO_MERGE)
    assert len(eng.buckets) >= 2, "expected distinct shape buckets"
    res = eng.run(collect="full", chunk=200)
    for c in cases:
        _assert_cell_matches_serial(eng, res, c.name, 500)
    sums = res.summaries()
    assert sums["perm/ecmp"][0].lb == "ecmp"
    assert sums["incast/reps"][0].n_conns == wl_i.n_conns  # unpadded count


def test_sweep_parity_failures_and_seeds():
    """Padded failure schedules and a multi-seed row axis: per-seed rows
    equal serial runs with those seeds, including the LB pytree of the
    active switch branch."""
    topo = Topology.build(CFG)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 100, 400)
    wl = workloads.permutation(32, 48, seed=3)
    cases = [
        _case("f/ops", wl, "ops", 600, fs=fs),
        _case("f/reps", wl, "reps", 600, fs=fs, seeds=(0, 5),
              freezing_timeout=300),
    ]
    eng = SweepEngine(CFG, cases)
    res = eng.run(collect="none")
    _assert_cell_matches_serial(eng, res, "f/ops", 600, traces=False)
    for i, seed in enumerate((0, 5)):
        ref = eng.serial_sim("f/reps", seed=seed)
        st, _ = ref.run(600)
        jax.block_until_ready(st.c_done)
        sw = res.state_for("f/reps", i)
        np.testing.assert_array_equal(np.asarray(st.c_done_tick), sw.c_done_tick)
        np.testing.assert_array_equal(np.asarray(st.s_stats), sw.s_stats)
        # the active branch's LB state matches the serial variant's
        bidx, variant_states = sw.lb_state
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            st.lb_state, variant_states[int(bidx)],
        )


def test_sweep_parity_new_contenders():
    """PR 8 arena contenders (prime / seqbalance / flowlet_table) behind
    the lax.switch dispatch: sweep rows equal plain-LB serial runs
    bit-for-bit — across ≥2 shape buckets, one horizon-merged (frozen)
    row, and a permanent failure schedule — including the threaded
    on_ack/on_timeout engine keys through SwitchLB._dispatch."""
    topo = Topology.build(CFG)
    fs = failures.link_down(
        list(topo.t0_up_queues(0)[:2]), 100, failures.FOREVER
    )
    wl_p = workloads.permutation(32, 48, seed=1)
    wl_i = workloads.incast(32, 5, 48)
    cases = [
        _case("n/prime", wl_p, "prime", 600),
        _case("n/seqbalance", wl_p, "seqbalance", 600),
        _case("n/flowlet_table", wl_p, "flowlet_table", 600),
        # short horizon, same shape family: freezes inside the 600 bucket
        _case("n/short/prime", wl_p, "prime", 300),
        # failure schedule exercises the keyed on_timeout re-hash paths
        _case("n/fail/prime", wl_p, "prime", 700, fs=fs),
        _case("n/fail/seqbalance", wl_p, "seqbalance", 700, fs=fs),
        _case("n/fail/flowlet_table", wl_p, "flowlet_table", 700, fs=fs),
        # distinct conn-count bucket
        _case("n/incast/seqbalance", wl_i, "seqbalance", 400),
    ]
    eng = SweepEngine(CFG, cases)
    assert len(eng.buckets) >= 2, eng.plan.describe()
    assert any(b.program.masked for b in eng.buckets), "no frozen row"
    res = eng.run(collect="none")
    for c in cases:
        _assert_cell_matches_serial(eng, res, c.name, c.ticks, traces=False)
    # the active branch's LB pytree equals the plain serial variant's —
    # the switch passed the same threaded keys the variant sees serially
    for name in ("n/fail/prime", "n/fail/flowlet_table"):
        ref = eng.serial_sim(name)
        st, _ = ref.run(700)
        jax.block_until_ready(st.c_done)
        bidx, variant_states = res.state_for(name).lb_state
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)
            ),
            st.lb_state, variant_states[int(bidx)],
        )


def test_sweep_early_exit_is_fixed_point():
    """Quiescence early exit must leave every engine-state leaf (everything
    but LB-internal clocks) bit-identical to running the full horizon."""
    wl = workloads.permutation(32, 48, seed=1)
    cases = [
        _case("p/ecmp", wl, "ecmp", 2000),
        _case("p/plb", wl, "plb", 2000),
    ]
    eng = SweepEngine(CFG, cases)
    res = eng.run(collect="none", early_exit=True, chunk=250)
    bucket = eng.buckets[0]
    assert bucket.ticks_run < 2000, "early exit should fire well before 2000"
    for name in ("p/ecmp", "p/plb"):
        ref = eng.serial_sim(name)
        st, _ = ref.run(2000)  # full horizon
        jax.block_until_ready(st.c_done)
        sw = res.state_for(name)
        for field in st._fields:
            if field == "lb_state":
                continue  # PLB epoch clocks legitimately keep advancing
            np.testing.assert_array_equal(
                np.asarray(getattr(st, field)),
                np.asarray(getattr(sw, field)),
                err_msg=field,
            )


def test_collectives_conservation_and_sweep_parity():
    """alltoall / ring_allreduce / butterfly_allreduce, swept over ≥2 shape
    buckets: at quiescence every message is fully delivered, no packet slot
    leaks, and injected == delivered + drops (exact when no timeouts —
    retransmissions are the only source of duplicate injections)."""
    ticks = 400
    wls = {
        "ring": workloads.ring_allreduce(8, 32),
        "butterfly": workloads.butterfly_allreduce(8, 32),
        "alltoall": workloads.alltoall(8, 4, window=2),
    }
    cases = [_case(f"coll/{k}", wl, "reps", ticks) for k, wl in wls.items()]
    eng = SweepEngine(CFG, cases, packer=NO_MERGE)
    assert len(eng.buckets) >= 2
    res = eng.run(collect="none")
    sums = res.summaries()
    for k, wl in wls.items():
        name = f"coll/{k}"
        st, _sw = _assert_cell_matches_serial(
            eng, res, name, ticks, traces=False
        )
        sw = res.state_for(name)
        s = sums[name][0]
        # completion: every conn done, every message fully delivered
        assert s.completed == wl.n_conns, (k, s.completed)
        np.testing.assert_array_equal(
            sw.c_delivered[: wl.n_conns], wl.msg_pkts.astype(np.int32)
        )
        # conservation at quiescence: no slots leaked, nothing in flight
        assert int(sw.fl_count) == eng.serial_sim(name).NP, k
        assert not np.any(sw.c_inflight), k
        # injected == delivered + drops (timeout-free runs are exact)
        injected, delivered = int(s.injected), int(s.delivered)
        drops = int(s.drops_cong) + int(s.drops_fail)
        assert injected >= delivered, k
        if s.timeouts == 0:
            assert injected == delivered + drops, (k, injected, delivered, drops)


def test_sweep_collect_contract():
    """The three-mode collect contract: unknown modes and the
    full-traces-with-early-exit combination raise actionable ValueErrors
    (pointing at collect='summary'), a telemetry spec is rejected outside
    summary mode, and a custom spec without the RunSummary channels still
    runs — summaries() auto-falls back to the state path."""
    from repro.netsim import TelemetrySpec, WindowedSeries

    wl = workloads.permutation(32, 32, seed=4)
    eng = SweepEngine(CFG, [_case("x", wl, "ops", 100)])
    with pytest.raises(ValueError, match="summary"):
        eng.run(collect="full", early_exit=True)
    with pytest.raises(ValueError, match="collect"):
        eng.run(collect="traces")
    with pytest.raises(ValueError, match="summary"):
        eng.run(collect="none", telemetry=TelemetrySpec.default())
    res = eng.run(
        collect="summary",
        telemetry=TelemetrySpec(channels=(WindowedSeries(),)),
    )
    assert "windows" in res.telemetry_for("x")
    assert res.summaries()["x"][0].n_conns == wl.n_conns  # state fallback


# ---------------------------------------------------------------------------
# Cost-aware bucket packer: pure-plan property tests (no jax execution).
# ---------------------------------------------------------------------------

GRID = st.lists(
    st.tuples(
        st.integers(1, 20),  # ticks / 100
        st.booleans(),  # adaptive
        st.integers(0, 5),  # log2(nc / 8)
        st.integers(1, 8),  # log2 msg
        st.integers(0, 6),  # log2 f
        st.integers(0, 4),  # log2 w
        st.integers(1, 5),  # rows (seeds)
    ),
    min_size=1,
    max_size=24,
)
PACKER_SPEC = st.tuples(
    st.integers(4, 64),  # max_rows_per_bucket
    st.integers(0, 3),  # waste budget index
    st.booleans(),  # merge on/off
)
BUDGETS = [0.0, 0.1, 0.25, 1.0]


@settings(max_examples=80, deadline=None)
@given(GRID, PACKER_SPEC, st.integers(0, 2))
def test_packer_plan_properties(grid, packer_spec, ndev_log2):
    """Random cell grids: the plan covers every cell exactly once, no
    bucket exceeds the (device-rounded, atomic-cell) split threshold,
    per-bucket merge waste stays under budget, and device row-assignment
    is exactly balanced with shared padded shapes per split group."""
    max_rows, b_i, merge = packer_spec
    pc = PackerConfig(
        max_rows_per_bucket=max_rows, waste_budget=BUDGETS[b_i], merge=merge
    )
    n_devices = 2**ndev_log2
    shapes = [
        CellShape(
            name=f"c{i}", ticks=100 * t, adaptive=ad, nc=8 << k_nc,
            msg=2 << k_msg, f=1 << k_f, w=1 << k_w, rows=rows,
            nc_exact=8 << k_nc,
        )
        for i, (t, ad, k_nc, k_msg, k_f, k_w, rows) in enumerate(grid)
    ]
    plan = pack(FATTREE_32_CI, shapes, pc, n_devices)

    # coverage: every cell in exactly one bucket, all rows accounted for
    seen = [n for b in plan.buckets for n in b.cells]
    assert sorted(seen) == sorted(s.name for s in shapes)
    assert plan.n_rows == sum(s.rows for s in shapes)

    by_name = {s.name: s for s in shapes}
    groups: dict = {}
    for b in plan.buckets:
        groups.setdefault(b.group, []).append(b)
        members = [by_name[n] for n in b.cells]
        # members fit the bucket shape; adaptive never mixes
        assert len({m.adaptive for m in members}) == 1
        for m in members:
            t, _ad, nc, msg, f, w = b.key
            assert m.ticks <= t and m.nc_exact <= nc
            assert m.msg <= msg and m.f <= f and m.w <= w
        # device alignment: equal rows on every device
        assert b.n_padded_rows % n_devices == 0
        assert b.n_padded_rows >= b.n_rows
        dr = b.device_rows
        assert len(dr) == n_devices and max(dr) == min(dr)

    # padding waste within budget at the split-group level (where the
    # merge decision was taken)
    for gid, waste in plan.group_merge_waste().items():
        assert waste <= pc.waste_budget + 1e-9, (gid, waste)

    for bs in groups.values():
        gmax = max(by_name[n].rows for b in bs for n in b.cells)
        cap = -(-max(pc.max_rows_per_bucket, gmax) // n_devices) * n_devices
        for b in bs:
            # split threshold (device-rounded; single cells stay atomic)
            assert b.n_rows <= cap, (b, cap)
            assert b.n_padded_rows <= cap, (b, cap)
        if len(bs) > 1:
            # sub-buckets share one compiled program: same padded rows
            assert len({b.n_padded_rows for b in bs}) == 1

    # deterministic: replanning yields the identical plan
    assert pack(FATTREE_32_CI, shapes, pc, n_devices) == plan


def test_packer_merges_failure_axis_and_rejects_costly_merges():
    """fig08's shape family (same grid, F varies) fuses into one bucket;
    a conn-count mismatch with real padding cost does not."""
    f_axis = [
        CellShape(f"f{f}", 1000, False, 64, 256, f, 16, 1, nc_exact=64)
        for f in (8, 16, 32)
    ]
    plan = pack(FATTREE_32_CI, f_axis, PackerConfig(), 1)
    assert len(plan.buckets) == 1
    assert plan.buckets[0].key[4] == 32
    assert plan.buckets[0].merge_waste <= 0.01

    nc_axis = [
        CellShape("big", 4000, False, 64, 256, 1, 16, 3, nc_exact=64),
        CellShape("small", 4000, False, 8, 128, 1, 16, 3, nc_exact=8),
    ]
    plan2 = pack(FATTREE_32_CI, nc_axis, PackerConfig(waste_budget=0.25), 1)
    assert len(plan2.buckets) == 2


def test_packer_measured_costs_replan_deterministic():
    """pack(measured_costs=...) — the measured-cost model (ROADMAP's
    feedback loop): empty == pure estimate, replans are deterministic, and
    measured numbers that contradict the footprint estimate flip the merge
    decision while the waste budget stays enforced under the measured
    model."""
    from repro.netsim.sweep import est_row_tick_cost, measured_costs_from_bench

    shapes = [
        CellShape("a", 1000, False, 32, 128, 1, 16, 2, nc_exact=32),
        CellShape("b", 1000, False, 64, 128, 1, 16, 2, nc_exact=60),
    ]
    base = pack(FATTREE_32_CI, shapes, PackerConfig(), 1)
    assert pack(FATTREE_32_CI, shapes, PackerConfig(), 1,
                measured_costs={}) == base
    assert pack(FATTREE_32_CI, shapes, PackerConfig(), 1,
                measured_costs=None) == base

    # The footprint estimate refuses this merge (padding 32 -> 64 conns
    # doubles the packet-table term, beyond the 25% budget).
    assert len(base.buckets) == 2
    # Measured truth says both shapes cost the same per row-tick: the
    # padded union is free under the measured model -> the decision flips.
    flat = {
        (False, 32, 128, 1, 16): 500.0,
        (False, 64, 128, 1, 16): 500.0,
    }
    merged = pack(FATTREE_32_CI, shapes, PackerConfig(), 1,
                  measured_costs=flat)
    assert len(merged.buckets) == 1
    assert merged.buckets[0].merge_waste <= PackerConfig().waste_budget + 1e-9
    assert pack(FATTREE_32_CI, shapes, PackerConfig(), 1,
                measured_costs=dict(flat)) == merged  # deterministic
    # Measured truth that agrees with the estimate (the big shape is much
    # costlier than the padded small one) keeps them split.
    expensive = {
        (False, 32, 128, 1, 16): 100.0,
        (False, 64, 128, 1, 16): 1000.0,
    }
    split = pack(FATTREE_32_CI, shapes, PackerConfig(), 1,
                 measured_costs=expensive)
    assert len(split.buckets) == 2

    # Harvesting from BENCH rows: bucket rows keyed by PackPlan, exact conn
    # counts quantize onto the packer's pow2 grid, samples average, and
    # non-bucket rows / malformed files are ignored.
    rows = {
        "figX/bucket/g0.0": {"bucket_key": [1000, 0, 60, 128, 1, 16],
                             "measured_row_tick_us": 700.0},
        "figX/bucket/g0.1": {"bucket_key": [1000, 0, 64, 128, 1, 16],
                             "measured_row_tick_us": 900.0},
        "figX/sweep_total": {"ticks_per_sec": 1.0},
        "figY/bucket/bad": {"bucket_key": [1, 2], "measured_row_tick_us": 1},
        "figY/bucket/null": {"bucket_key": [1000, 0, None, 128, 1, 16],
                             "measured_row_tick_us": 5.0},
        "figY/bucket/str": {"bucket_key": "oops",
                            "measured_row_tick_us": "fast"},
    }
    assert measured_costs_from_bench(rows) == {(False, 64, 128, 1, 16): 800.0}
    assert measured_costs_from_bench("/nonexistent/path.json") == {}
    # calibration: unmeasured shapes scale the estimate by the median
    # measured/est ratio, so relative estimate ordering is preserved
    mc = measured_costs_from_bench(rows)
    scaled = pack(FATTREE_32_CI, shapes, PackerConfig(), 1, measured_costs=mc)
    assert {len(b.cells) for b in scaled.buckets} == {
        len(b.cells) for b in base.buckets
    }
    del est_row_tick_cost  # imported for documentation of the model


# ---------------------------------------------------------------------------
# Failure-schedule padding / truncation semantics.
# ---------------------------------------------------------------------------


def test_failure_schedule_pad_truncate_validate():
    """pad_to only appends inert rows, truncate_dead only drops provably
    dead ones (never clipping an end tick), and the engine rejects the
    clipped-row shape that would resurrect a link at the clip boundary."""
    fs = failures.link_down([3, 4], 100, 400)
    padded = fs.pad_to(8)
    assert len(padded) == 8
    padded.validate()
    for t in (0, 99, 100, 399, 400):
        live = (np.asarray(fs.start) <= t) & (t < np.asarray(fs.end))
        live_p = (np.asarray(padded.start) <= t) & (t < np.asarray(padded.end))
        assert live.sum() == live_p.sum(), t  # pad never changes active-set
    with pytest.raises(AssertionError):
        padded.pad_to(4)  # padding never silently drops rows

    mixed = FailureSchedule.concat(
        failures.link_down([1], 50, failures.FOREVER),  # live, permanent
        failures.link_down([2], 1000, 2000),  # dead before horizon 600
    )
    live = failures.truncate_dead(mixed, 600)
    assert len(live) == 1 and int(live.queue[0]) == 1
    assert int(live.end[0]) == failures.FOREVER  # end is never clipped

    clipped = FailureSchedule(
        queue=np.asarray([1], np.int32), start=np.asarray([5], np.int32),
        end=np.asarray([5], np.int32), kind=np.asarray([0], np.int32),
    )
    with pytest.raises(ValueError, match="row"):
        Simulator(
            FATTREE_32_CI, workloads.permutation(32, 16, seed=0),
            make_lb("ops", evs_size=FATTREE_32_CI.evs_size),
            failures=clipped,
        )


def test_failure_edge_cases_sweep_vs_serial():
    """Empty schedule, events past the horizon, overlapping down+degraded
    windows on one queue, and incremental failures at max uplinks: the
    padded sweep rows agree bit-exactly with the serial path (both the
    pinned serial reference and a raw unpinned Simulator)."""
    topo = Topology.build(CFG)
    q0 = int(topo.t0_up_queues(0)[0])
    q1 = int(topo.t0_up_queues(1)[0])
    wl = workloads.permutation(32, 48, seed=2)
    past = FailureSchedule.concat(
        failures.link_down([q0], 100, 250),
        failures.link_down([q1], 5000, failures.FOREVER),  # past horizon
    )
    overlap = FailureSchedule.concat(
        failures.link_down([q0], 100, 300),
        failures.link_degraded([q0], 200, 450),
    )
    incr = failures.incremental_uplink_failures(
        CFG, 0, CFG.uplinks_per_tor, 60, 40
    )
    assert len(incr) == CFG.uplinks_per_tor  # max uplinks of the TOR
    cases = [
        _case("e/none", wl, "ops", 500),
        _case("e/past", wl, "ops", 500, fs=past),
        _case("e/overlap", wl, "ops", 500, fs=overlap),
        _case("e/incr", wl, "reps", 500, fs=incr, freezing_timeout=250),
    ]
    eng = SweepEngine(CFG, cases)
    res = eng.run(collect="none")
    for c in cases:
        _assert_cell_matches_serial(eng, res, c.name, 500, traces=False)
    # raw (unpinned) serial agreement: NC/cph/msg pins are no-ops here, so
    # the sweep row must equal a plain PR 2-style Simulator.run too
    for name, lb, fs, kw in (
        ("e/past", "ops", past, {}),
        ("e/incr", "reps", incr, {"freezing_timeout": 250}),
    ):
        raw = Simulator(
            CFG, wl, make_lb(lb, evs_size=CFG.evs_size, **kw), failures=fs
        )
        st, _ = raw.run(500)
        jax.block_until_ready(st.c_done)
        sw = res.state_for(name)
        np.testing.assert_array_equal(
            np.asarray(st.c_done_tick), sw.c_done_tick[: wl.n_conns]
        )
        np.testing.assert_array_equal(np.asarray(st.s_stats), sw.s_stats)
    # all TOR-0 uplinks eventually down: TOR-0 traffic must suffer
    s_incr = res.summaries()["e/incr"][0]
    assert s_incr.drops_fail > 0 or s_incr.completed < wl.n_conns


def test_horizon_merge_never_resurrects_failures():
    """Regression: a short cell with a *permanent* failure fused into a
    longer bucket must freeze at its own horizon — the link may never come
    back up inside the cell's observable window, and the row's final state
    equals the serial run stopped exactly there (clip-style truncation of
    the schedule would break both)."""
    topo = Topology.build(CFG)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 50, failures.FOREVER)
    wl = workloads.permutation(32, 48, seed=1)
    cases = [
        _case("short/ops", wl, "ops", 300, fs=fs),
        _case("long/reps", wl, "reps", 900),
    ]
    eng = SweepEngine(CFG, cases, packer=PackerConfig(waste_budget=2.0))
    assert len(eng.buckets) == 1, eng.plan.describe()
    assert eng.buckets[0].program.masked  # heterogeneous horizons
    res = eng.run(collect="none")
    for name, ticks in (("short/ops", 300), ("long/reps", 900)):
        ref = eng.serial_sim(name)
        st, _ = ref.run(ticks)
        jax.block_until_ready(st.c_done)
        sw = res.state_for(name)
        for field in ("c_done_tick", "s_stats", "q_served"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st, field)), getattr(sw, field),
                err_msg=f"{name}:{field}",
            )
    # chunked early exit composes with per-row horizons
    res2 = eng.run(collect="none", early_exit=True, chunk=100)
    ref = eng.serial_sim("short/ops")
    st, _ = ref.run(300)
    jax.block_until_ready(st.c_done)
    np.testing.assert_array_equal(
        np.asarray(st.s_stats), res2.state_for("short/ops").s_stats
    )
