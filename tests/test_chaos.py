"""Chaos engine (repro.netsim.chaos): invariants, campaign, shrinking.

The contract under test:

* **Scenario plumbing** — generated scenarios are a pure function of the
  campaign seed, cover every fault archetype across the first cycle, and
  round-trip through their JSON artifact encoding.
* **Green path** — REPS survives a generated scenario (invariants all
  hold, including the kill/resume bit-parity check on scenario 0).
* **Teeth** — the known-bad fixture (ecmp under a permanent half-fabric
  outage) violates deterministically; the same faults under REPS do not.
* **Shrinking** — a violating scenario shrinks to a smaller one that
  still violates, and the emitted artifact replays bit-exactly (digest
  equality), which is the repro contract the CI job uploads.
* **Checker sensitivity** — the invariant monitor flags corrupted
  carries (conservation / monotonicity), not just macro outcomes.
"""
import dataclasses
import json

from repro.netsim import chaos
from repro.netsim.chaos import (
    ARCHETYPES, ChaosCampaign, ChaosFault, ChaosInvariants, ChaosScenario,
    known_bad_scenario, record_digest,
)


def _small_campaign(**kw):
    c = ChaosCampaign(seed=11, budget_s=1.0, min_scenarios=1,
                      max_scenarios=1, **kw)
    # lighter messages keep a test-scale run in CI budget; the horizon
    # must stay at full scale (fault windows need rto + chunk slack)
    c.MSG_PKTS = 24
    return c


def test_generate_is_deterministic_and_covers_archetypes():
    c = _small_campaign()
    a = [c.generate(i) for i in range(len(ARCHETYPES))]
    b = [c.generate(i) for i in range(len(ARCHETYPES))]
    assert a == b
    primaries = [s.faults[0].archetype for s in a]
    assert primaries[0] == "link_down"
    assert primaries[1] == "link_degraded"
    assert primaries[2] == "link_flapping"
    assert primaries[3] == "gray_loss"
    assert primaries[4] in ("switch_down", "switch_degraded", "spine_down")


def test_scenario_round_trips_through_json():
    s = known_bad_scenario()
    blob = json.dumps(s.to_dict(), sort_keys=True)
    assert ChaosScenario.from_dict(json.loads(blob)) == s


def test_reps_survives_generated_scenario_with_resume_parity():
    c = _small_campaign()
    s = c.generate(0)  # resume_check=True: includes kill/resume parity
    assert s.resume_check
    violations, record = c.run_scenario(s)
    assert violations == []
    assert record["summaries"][s.name][0]["completed"] == 32


def test_known_bad_fixture_violates_and_reps_does_not():
    c = ChaosCampaign(seed=1)
    bad = known_bad_scenario(ticks=640, chunk=160)
    violations, _ = c.run_scenario(bad)
    assert violations, "ecmp under half-fabric outage must violate"
    assert {v.invariant for v in violations} == {"completion"}
    # the control needs the full fixture horizon: REPS rides out up to two
    # 400-tick RTO rounds before every retransmit lands on the live half
    good = dataclasses.replace(
        known_bad_scenario(), name="chaos/control/reps", lb="reps"
    )
    assert c.run_scenario(good)[0] == []


def test_shrink_produces_smaller_bit_exact_replayable_repro(tmp_path):
    c = ChaosCampaign(seed=1)
    # start from an already-small violating scenario so the greedy loop
    # converges in a handful of runs
    seedling = dataclasses.replace(
        known_bad_scenario(ticks=320, chunk=160),
        faults=(ChaosFault("spine_down", tor=0, spine=3, start=8,
                           end=chaos.failures.FOREVER),),
        msg_pkts=6, n_conns=8,
    )
    violations, _ = c.run_scenario(seedling)
    assert violations
    minimal, mv, mrec = c.shrink(seedling)
    assert mv, "shrunken scenario must still violate"
    assert (
        minimal.n_conns < 8 or minimal.msg_pkts < 6
    ), f"shrink made no progress: {minimal}"
    artifact = c.make_artifact(minimal, mv, mrec)
    path = tmp_path / "repro.json"
    path.write_text(json.dumps(artifact, sort_keys=True))
    loaded = json.loads(path.read_text())
    rv, bit_exact = c.replay(loaded)
    assert rv and bit_exact, "artifact replay must reproduce bit-exactly"
    assert "chaos_campaign" in loaded["repro"]


def test_monitor_flags_corrupted_carry():
    """Feed the checker a deliberately corrupted state: conservation and
    monotone invariants must fire (the checker is not outcome-only)."""
    import jax

    c = _small_campaign()
    s = dataclasses.replace(c.generate(0), resume_check=False,
                            faults=(), name="chaos/corrupt")
    runner = c._runner(s)
    inv = ChaosInvariants(no_progress_window=10**9)
    mon = inv.monitor(runner)
    runner.advance(s.chunk)
    assert mon.boundary() == []
    # corrupt: free-list count off by one + rewind a stats counter
    states, tel = runner.carries[0]
    states = states._replace(
        fl_count=states.fl_count + 1,
        s_stats=states.s_stats.at[:, :].set(0),
    )
    runner.carries[0] = (states, tel)
    got = {v.invariant for v in mon.boundary()}
    assert "conservation" in got
    assert "monotone" in got


def test_invariants_recovery_bound_fires_on_tight_budget():
    """A genuine recovery that exceeds an artificially tight bound is
    reported — the bound is a real parameter, not decoration."""
    c = ChaosCampaign(
        seed=2,
        invariants=ChaosInvariants(
            no_progress_window=10**9, recovery_bound_ticks=1,
            require_completion=False,
        ),
    )
    c.MSG_PKTS = 24
    s = dataclasses.replace(
        c.generate(0), resume_check=False, name="chaos/tightrec",
        faults=(ChaosFault("link_down", tor=0, spine=0, start=8, end=200),),
    )
    violations, _ = c.run_scenario(s)
    if any(v.invariant == "recovery" for v in violations):
        return  # drop happened and the 1-tick bound fired, as intended
    # the fault window may have dropped nothing for this seed; then the
    # invariant correctly stays silent — but the scenario must have run
    assert violations == []
