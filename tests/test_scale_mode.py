"""Scale-mode lock: conn-sharded sweeps, the sparse active set, footprint.

The million-connection scale mode (ARCHITECTURE.md §10) is opt-in via
``SimConfig.conn_sharding`` and must be *bit-invisible* at figure scales:

* a conn-sharded sweep row (2-D (rows, conns) mesh, ``conn_devices > 1``)
  is bit-identical to its unsharded ``serial_sim`` reference — verified in
  a 4-device subprocess across >= 2 buckets, including a frozen-horizon
  row and a failure schedule;
* the sparse active set tracks exactly the non-FREE packet slots, and
  post-quiescent ticks do zero packet-table work (the final state is a
  bit-exact fixed point with an empty active set);
* REPS per-conn state bit-packs at <= 25 B/conn, measured end-to-end at
  1e5 connections (the 1e6 point stays in benchmarks/table1_footprint.py);
* the auto packet-table sizing raises a clear ValueError instead of
  silently overflowing int32 near 1e6 conns.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.netsim.config import SimConfig, checked_auto_pkt_slots


SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, numpy as np
    from repro.netsim.config import SimConfig
    from repro.netsim.sweep import SweepCase, SweepEngine
    from repro.netsim import workloads
    from repro.netsim.failures import FailureSchedule

    nh = 16
    cfg = SimConfig(n_hosts=nh, hosts_per_tor=4, uplinks_per_tor=4,
                    rto_ticks=120, conn_sharding=True)
    fs = FailureSchedule(
        queue=np.array([16, 17], np.int32),
        start=np.array([50, 80], np.int32),
        end=np.array([150, 200], np.int32),
        kind=np.array([0, 1], np.int32),
        param=np.array([0, 0], np.int32),
    )
    cases = [
        # merges with b -> b becomes the frozen-horizon row of the bucket
        SweepCase("a/reps", workloads.permutation(nh, msg_pkts=24, seed=3),
                  "reps", ticks=400, failures=fs, seeds=(0, 1)),
        SweepCase("b/ecmp", workloads.permutation(nh, msg_pkts=16, seed=5),
                  "ecmp", ticks=300, seeds=(7,)),
        # switch-adaptive routing is a static property -> second bucket
        SweepCase("c/adaptive",
                  workloads.permutation(nh, msg_pkts=12, seed=9),
                  "adaptive_roce", ticks=250, seeds=(1,)),
    ]
    eng = SweepEngine(cfg, cases, conn_devices=2)
    assert len(eng.plan.buckets) >= 2, eng.plan.describe()
    res = eng.run(collect="full")
    checked = 0
    for case in cases:
        for si, seed in enumerate(case.seeds):
            st = res.state_for(case.name, si)
            tr = res.trace_for(case.name, si)
            ref = eng.serial_sim(case.name, seed=seed)
            rs, rt = jax.block_until_ready(ref.run(case.ticks))
            for f in rs._fields:
                if f == "lb_state":
                    continue
                assert np.array_equal(
                    np.asarray(getattr(st, f)), np.asarray(getattr(rs, f))
                ), (case.name, si, f)
            for f in rt._fields:
                assert np.array_equal(
                    np.asarray(getattr(tr, f)), np.asarray(getattr(rt, f))
                ), (case.name, si, "trace", f)
            checked += 1

    # guard rails: opt-in enforcement and the summary-mode restriction
    try:
        SweepEngine(cfg.replace(conn_sharding=False), cases, conn_devices=2)
        raise AssertionError("conn_devices>1 without conn_sharding must raise")
    except ValueError as e:
        assert "conn_sharding" in str(e)
    try:
        eng.run(collect="summary")
        raise AssertionError("summary collect under conn sharding must raise")
    except ValueError as e:
        assert "conn_devices" in str(e)
    print(json.dumps({"buckets": len(eng.plan.buckets), "rows_checked": checked}))
    """
)


def test_conn_sharded_sweep_bit_parity_subprocess():
    """>= 2 buckets of a conn-sharded (rows=2, conns=2) sweep — with a
    failure schedule and a frozen-horizon row — are bit-identical to their
    serial references, and the opt-in/summary guard rails hold."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["buckets"] >= 2
    assert out["rows_checked"] == 4


def test_active_set_empty_and_fixed_point_after_quiescence():
    """Once every message completes, the sparse active set is empty, the
    free list holds every slot, and further ticks are a bit-exact no-op —
    post-quiescent ticks do zero packet-table work."""
    from repro.core.load_balancers import make_lb
    from repro.netsim import workloads
    from repro.netsim.engine import Simulator

    cfg = SimConfig(n_hosts=16, hosts_per_tor=4, uplinks_per_tor=4,
                    rto_ticks=120, conn_sharding=True)
    wl = workloads.permutation(16, msg_pkts=24, seed=3)
    sim = Simulator(cfg, wl, make_lb("reps", evs_size=cfg.evs_size), seed=7)
    s1, _ = jax.block_until_ready(sim.run(550))
    assert bool(np.asarray(s1.c_done).all()), "workload must finish by t=550"
    assert int(s1.as_count) == 0
    assert int(s1.fl_count) == sim.NP
    assert (np.asarray(s1.as_idx) == sim.NP).all()  # all sentinel-padded
    s2, _ = jax.block_until_ready(sim.run(600))
    for f in s1._fields:
        if f == "lb_state":
            continue
        assert np.array_equal(
            np.asarray(getattr(s1, f)), np.asarray(getattr(s2, f))
        ), f"post-quiescent tick mutated {f}"
    for a, b in zip(
        jax.tree_util.tree_leaves(s1.lb_state),
        jax.tree_util.tree_leaves(s2.lb_state),
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_active_set_tracks_non_free_slots_mid_flight():
    """Mid-run (traffic still flying), as_idx is exactly the ascending set
    of non-FREE packet slots and as_count + fl_count == NP."""
    from repro.core.load_balancers import make_lb
    from repro.netsim import workloads
    from repro.netsim.engine import Simulator

    cfg = SimConfig(n_hosts=16, hosts_per_tor=4, uplinks_per_tor=4,
                    rto_ticks=120, conn_sharding=True)
    wl = workloads.permutation(16, msg_pkts=24, seed=3)
    sim = Simulator(cfg, wl, make_lb("reps", evs_size=cfg.evs_size), seed=7)
    st, _ = jax.block_until_ready(sim.run(40))
    as_idx = np.asarray(st.as_idx)
    live = as_idx[as_idx < sim.NP]
    assert len(live) > 0, "expected in-flight packets at t=40"
    assert (np.diff(live) > 0).all(), "as_idx must stay ascending"
    nonfree = np.nonzero(np.asarray(st.pkt[0]) != 0)[0]
    assert np.array_equal(live, nonfree)
    assert int(st.as_count) == len(live) == sim.NP - int(st.fl_count)


def test_footprint_1e5_conns_under_25_bytes():
    """Measured end-to-end: 1e5 conns of live REPS state bit-pack at
    <= 25 B/conn with a lossless round trip (asserted inside
    measure_scale; the 1e6 point runs as a benchmark, not in tier 1)."""
    from benchmarks.common import Rows
    from benchmarks.table1_footprint import measure_scale

    rows = Rows()
    bpc = measure_scale(100_000, rows)
    assert bpc <= 25.0
    assert any("scale/footprint_conns100000" in str(r) for r in rows.records)


def test_auto_pkt_slots_int32_overflow_raises():
    """The auto packet-table sizing near 1e6 conns must raise a clear
    ValueError naming its inputs, never silently wrap int32 (the dense
    Simulator path funnels through this rule; the conn-sharded scale mode
    sizes NP = min(conn-auto, lifetime bound) instead, which is what makes
    10^6 conns representable at all)."""
    # figure scale: fine and exact
    assert checked_auto_pkt_slots(1024, 170, 128) < 2**31
    # a pinned size is respected but still validated
    assert checked_auto_pkt_slots(1024, 170, 128, pin=4096) == 4096
    with pytest.raises(ValueError, match="int32") as e:
        checked_auto_pkt_slots(2**26, 170, 128)
    assert "n_conns" in str(e.value)  # names its inputs
    with pytest.raises(ValueError, match="int32"):
        checked_auto_pkt_slots(1024, 170, 128, pin=2**40)
