"""Property tests for the fabric generator (netsim/topogen.py).

Every generated fabric must satisfy the structural contract the engine
relies on — queue regions partition the id space exactly once, up blocks
respect declared port degrees, and every (src, dst, flow, EV) routes to
the destination's host downlink within the fabric diameter — including
the degenerate 1-pod / 1-uplink / 1-ToR corners.  The clos3 generator is
additionally pinned bit-exactly against the built-in arithmetic 3-tier
fat-tree through a full engine run.
"""
import jax
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; shim keeps tests live
    from _hypothesis_fallback import given, settings, st

from repro.netsim.topogen import (
    GENERATORS, RAIL_SALT, build_spec, fabric_str, parse_fabric,
)

# small random fabrics of every kind (kept tiny: the walk test is
# exhaustive over (src, dst) pairs)
CLOS3 = st.tuples(
    st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
    st.integers(1, 3), st.integers(1, 3),
)
RAIL = st.tuples(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4))
MESH = st.tuples(st.integers(1, 4), st.integers(1, 3), st.integers(1, 3))


def _specs(clos, rail, mesh):
    p, t, h, a, u = clos
    return [
        build_spec(fabric_str("clos3", pods=p, tors=t, hosts=h, aggs=a, up=u)),
        build_spec(fabric_str("rail", tors=rail[0], hosts=rail[1], rails=rail[2])),
        build_spec(fabric_str("mesh", tors=mesh[0], hosts=mesh[1], planes=mesh[2])),
    ]


@settings(max_examples=25, deadline=None)
@given(CLOS3, RAIL, MESH)
def test_regions_partition_queue_space(clos, rail, mesh):
    """Queue-id regions cover [0, NQ) exactly once; host downlinks are the
    final region with one queue per host (independent re-check of what
    validate() enforces, so a validator regression cannot hide one)."""
    for spec in _specs(clos, rail, mesh):
        covered = np.zeros(spec.n_queues, np.int64)
        for r in spec.regions:
            assert 0 <= r.base and r.base + r.size <= spec.n_queues
            covered[r.base : r.base + r.size] += 1
        assert (covered == 1).all(), spec.name
        tail = max(spec.regions, key=lambda r: r.base)
        assert tail.base == spec.t0_down_base
        assert tail.size == spec.n_hosts
        assert tail.base + tail.size == spec.n_queues
        assert (spec.q_sw[spec.t0_down_base :] == -1).all()


@settings(max_examples=25, deadline=None)
@given(CLOS3, RAIL, MESH)
def test_port_degrees_respected(clos, rail, mesh):
    """Up blocks stay inside their switch's declared span and match the
    declared degree; every up candidate feeds a *different* switch than
    the one spraying (no self-loops)."""
    for spec in _specs(clos, rail, mesh):
        for sw in range(spec.n_switches):
            deg = int(spec.up_deg[sw])
            base, size = (int(v) for v in spec.sw_up_span[sw])
            needs_up = spec.down_next[sw] < 0
            if not needs_up.any():
                continue
            assert deg >= 1, (spec.name, sw)
            for dst in np.nonzero(needs_up)[0][:8]:
                b = int(spec.up_base[sw, dst])
                assert base <= b and b + deg <= base + size
                feeds = spec.q_sw[b : b + deg]
                assert (feeds != sw).all(), (spec.name, sw, int(dst))
                assert (feeds >= 0).all() and (feeds < spec.n_switches).all()


@settings(max_examples=15, deadline=None)
@given(CLOS3, RAIL, MESH, st.integers(0, 2**30))
def test_every_pair_routes_to_destination(clos, rail, mesh, seed):
    """walk() reaches dst's host downlink for every (src, dst) pair and a
    sampled (flow, EV), visiting only valid queues, within the declared
    diameter (walk raises beyond it)."""
    rng = np.random.default_rng(seed)
    for spec in _specs(clos, rail, mesh):
        for src in range(spec.n_hosts):
            for dst in range(spec.n_hosts):
                flow = int(rng.integers(0, 1 << 16))
                ev = int(rng.integers(0, 1 << 16))
                path = spec.walk(src, dst, flow, ev)
                assert path[-1] == spec.t0_down_base + dst
                assert len(path) <= spec.diameter + 1
                for q in path:
                    assert 0 <= q < spec.n_queues


def test_degenerate_corners():
    """1-pod / 1-uplink / 1-ToR fabrics build, validate, and route."""
    corners = [
        fabric_str("clos3", pods=1, tors=1, hosts=1, aggs=1, up=1),
        fabric_str("clos3", pods=1, tors=2, hosts=2, aggs=1, up=1),
        fabric_str("rail", tors=1, hosts=1, rails=1),
        fabric_str("rail", tors=2, hosts=1, rails=1),
        fabric_str("mesh", tors=1, hosts=2, planes=1),  # no mesh links at all
        fabric_str("mesh", tors=2, hosts=1, planes=1),
    ]
    for s in corners:
        spec = build_spec(s)
        spec.validate()
        for src in range(spec.n_hosts):
            for dst in range(spec.n_hosts):
                path = spec.walk(src, dst, 7, 11)
                assert path[-1] == spec.t0_down_base + dst, s


def test_rail_shares_one_salt_plane():
    """All ToRs of a rail fabric share the RAIL_SALT plane, so one
    (flow, EV) lands on the same rail at every ToR (the rail-affinity
    property); clos3 salts per switch instead."""
    spec = build_spec(fabric_str("rail", tors=4, hosts=2, rails=4))
    assert (spec.salt[: spec.n_tors] == RAIL_SALT).all()
    for flow, ev in [(3, 9), (12, 101), (77, 4096)]:
        rails = set()
        for src in range(spec.n_hosts):
            dst = (src + spec.params["hosts"]) % spec.n_hosts  # cross-tor
            q = spec.walk(src, dst, flow, ev)[0]
            rails.add(int(spec.q_sw[q]))
        assert len(rails) == 1, "same (flow, EV) must pick one rail fabric-wide"
    clos = build_spec(fabric_str("clos3", pods=2, tors=2, hosts=2, aggs=2, up=2))
    assert len(set(int(s) for s in clos.salt[: clos.n_tors])) == clos.n_tors


def test_parse_fabric_errors_and_roundtrip():
    import pytest

    for kind, want in GENERATORS.items():
        s = fabric_str(kind, **{k: 2 for k in want})
        assert parse_fabric(s) == (kind, {k: 2 for k in want})
    with pytest.raises(ValueError, match="unknown fabric kind"):
        parse_fabric("torus:x=2")
    with pytest.raises(ValueError, match="malformed"):
        parse_fabric("rail:tors=two")
    with pytest.raises(ValueError, match="missing"):
        parse_fabric("rail:tors=2")
    with pytest.raises(ValueError, match="unexpected"):
        parse_fabric("mesh:tors=2,hosts=2,planes=1,extra=3")
    with pytest.raises(ValueError, match="divide evenly|>= 1"):
        build_spec("rail:tors=0,hosts=2,rails=1")


def test_clos3_bit_matches_arithmetic_three_tier():
    """An engine run on the generated clos3 tables is bit-identical to the
    built-in arithmetic 3-tier fat-tree with matching parameters — the
    'no special-casing' contract made executable."""
    from repro.core.load_balancers import make_lb
    from repro.netsim import workloads
    from repro.netsim.config import SimConfig
    from repro.netsim.engine import Simulator

    base = dict(
        n_hosts=16, hosts_per_tor=2, rto_ticks=120, evs_size=256,
        tors_per_pod=2, aggs_per_pod=2, agg_uplinks=2,
    )
    cfg_a = SimConfig(tiers=3, **base)
    cfg_t = SimConfig(
        tiers=3, fabric=fabric_str(
            "clos3", pods=4, tors=2, hosts=2, aggs=2, up=2
        ), **base,
    )
    wl = workloads.permutation(16, msg_pkts=12, seed=2)
    out = []
    for cfg in (cfg_a, cfg_t):
        sim = Simulator(
            cfg, wl, make_lb("reps", evs_size=cfg.evs_size), seed=5
        )
        out.append(jax.block_until_ready(sim.run(300)))
    (sa, ta), (st_, tt) = out
    for f in sa._fields:
        if f == "lb_state":
            continue
        assert np.array_equal(
            np.asarray(getattr(sa, f)), np.asarray(getattr(st_, f))
        ), f
    for f in ta._fields:
        assert np.array_equal(
            np.asarray(getattr(ta, f)), np.asarray(getattr(tt, f))
        ), f
