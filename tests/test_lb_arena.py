"""PR 8 regressions: the threaded LB RNG (hardcoded PRNGKey(0/1/2) bugfix),
PLB's reset-then-count epoch rollover, SwitchLB evs_size validation, and
unit behavior of the arena contenders (prime / seqbalance / flowlet_table).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.core.load_balancers import MptcpLB, PlbLB, SwitchLB
from repro.netsim import FleetRunner, workloads

CFG = FATTREE_32_CI


# ---------------------------------------------------------------------------
# Headline bugfix: repath draws must come from the threaded engine key.
# ---------------------------------------------------------------------------


def test_repath_draws_are_keyed_not_hardcoded():
    """plb/mptcp re-path EVs depend on the threaded per-run key.

    The old code drew from ``fold_in(PRNGKey(0|1|2), now)`` — a function of
    ``now`` alone — so every seed, sweep row, and connection drew the same
    "random" new EV at the same tick (demonstrated below), and a fleet's
    vmap-over-seeds averaged N copies of one correlated trajectory.
    """
    now = jnp.int32(37)
    # The old scheme, reproduced: byte-identical across any two "runs"
    # because nothing run-specific ever entered the key.
    old_run_a = jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), now), (8,), 0, 65536
    )
    old_run_b = jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), now), (8,), 0, 65536
    )
    np.testing.assert_array_equal(np.asarray(old_run_a), np.asarray(old_run_b))

    # The fix: the engine threads fold_in(tick_key, 5) into on_timeout, and
    # tick_key = fold_in(PRNGKey(seed), tick) — two seeds, two draws.
    def engine_key(seed, tick, slot):
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), tick), slot
        )

    mask = jnp.ones((8,), bool)
    plb = PlbLB(evs_size=65536)
    st = plb.init_state(8, jax.random.PRNGKey(0))
    ev_a = plb.on_timeout(st, mask, now, engine_key(0, 37, 5)).ev
    ev_b = plb.on_timeout(st, mask, now, engine_key(1, 37, 5)).ev
    ev_a2 = plb.on_timeout(st, mask, now, engine_key(0, 37, 5)).ev
    assert not np.array_equal(np.asarray(ev_a), np.asarray(ev_b))
    np.testing.assert_array_equal(np.asarray(ev_a), np.asarray(ev_a2))

    mptcp = MptcpLB(evs_size=65536)
    stm = mptcp.init_state(8, jax.random.PRNGKey(0))
    sub_a = mptcp.on_timeout(stm, mask, now, engine_key(0, 37, 5)).sub_evs
    sub_b = mptcp.on_timeout(stm, mask, now, engine_key(1, 37, 5)).sub_evs
    assert not np.array_equal(np.asarray(sub_a), np.asarray(sub_b))


@pytest.mark.parametrize("lbn", ["plb", "mptcp"])
def test_fleet_seeds_decorrelated_under_congestion(lbn):
    """FleetRunner per-seed rows must not be bit-identical for plb/mptcp
    once congestion makes them re-path (the repath draw is now per-seed)."""
    cfg = CFG.replace(queue_capacity=16)
    wl = workloads.incast(32, 8, 48)
    fleet = FleetRunner(
        cfg, wl, make_lb(lbn, evs_size=CFG.evs_size), seeds=(0, 1)
    )
    states, _ = fleet.run(1200)
    jax.block_until_ready(states.c_done)
    sums = fleet.summaries(states)
    # the congested incast actually exercised the repath paths
    assert all(s.ecn_marks > 0 for s in sums), sums
    if lbn == "mptcp":
        assert all(s.timeouts > 0 for s in sums), sums
    evs = (
        states.lb_state.ev if lbn == "plb" else states.lb_state.sub_evs
    )
    assert not np.array_equal(np.asarray(evs[0]), np.asarray(evs[1]))
    assert not np.array_equal(
        np.asarray(states.c_done_tick[0]), np.asarray(states.c_done_tick[1])
    )


# ---------------------------------------------------------------------------
# PLB epoch rollover: reset-then-count across an idle gap.
# ---------------------------------------------------------------------------


def test_plb_idle_gap_rollover_resets_then_counts():
    """An idle gap spanning the epoch boundary: the completed epoch is
    judged on its *own* counters, then the first ACK of the next burst
    counts into a fresh epoch.  The pre-fix order (count-then-judge) mixed
    that clean ACK into the stale epoch, flipping the verdict here."""
    plb = PlbLB(
        evs_size=65536, epoch_ticks=64, ecn_frac_threshold=0.5,
        repath_after_epochs=1,
    )
    st = plb.init_state(1, jax.random.PRNGKey(0))
    mask = jnp.ones((1,), bool)
    ev = jnp.zeros((1,), jnp.int32)
    k = jax.random.PRNGKey(9)
    marked = jnp.ones((1,), bool)
    clean = jnp.zeros((1,), bool)
    # burst 1 inside epoch 0: two ACKs, both ECN-marked (2/2 > 50%)
    for t in (10, 11):
        st = plb.on_ack(
            st, mask, ev, marked, jnp.int32(t), jax.random.fold_in(k, t)
        )
    assert int(st.acks[0]) == 2 and int(st.marked[0]) == 2
    ev_before = int(st.ev[0])
    # idle past epoch_end (64); the next burst's first ACK is clean.
    # Old order: acks=3/marked=2 -> 2 > ceil(1.5)=2 is False -> no repath.
    # Reset-then-count: stale epoch judged at 2/2 -> bad -> repath fires,
    # and the clean ACK opens the fresh epoch.
    st = plb.on_ack(
        st, mask, ev, clean, jnp.int32(200), jax.random.fold_in(k, 200)
    )
    assert int(st.ev[0]) != ev_before, "stale congested epoch must repath"
    assert int(st.acks[0]) == 1 and int(st.marked[0]) == 0
    assert int(st.epoch_end[0]) == 200 + 64
    assert int(st.bad_epochs[0]) == 0  # consumed by the repath


# ---------------------------------------------------------------------------
# SwitchLB construction: homogeneous evs_size.
# ---------------------------------------------------------------------------


def test_switchlb_rejects_mismatched_evs_size():
    """BitmapLB's 256 default silently sampled out-of-range next to 65536
    variants under the old max() rule — now an actionable ValueError."""
    with pytest.raises(ValueError, match="evs_size"):
        SwitchLB([make_lb("ops"), make_lb("bitmap")])
    # homogeneous sizes construct fine (and keep that size)
    sw = SwitchLB(
        [make_lb("ops", evs_size=256), make_lb("bitmap", evs_size=256)]
    )
    assert sw.evs_size == 256


# ---------------------------------------------------------------------------
# Arena contenders: unit behavior.
# ---------------------------------------------------------------------------


def test_prime_rotates_within_window_and_rehashes_on_rto():
    lb = make_lb("prime", evs_size=4096, sub_bits=3)
    st = lb.init_state(4, jax.random.PRNGKey(1))
    base0 = np.asarray(st.base).copy()
    mask = jnp.ones((4,), bool)
    evs = []
    for t in range(8):
        ev, st = lb.choose_ev(st, mask, jax.random.PRNGKey(t), jnp.int32(t))
        evs.append(np.asarray(ev))
    evs = np.stack(evs)
    # the flow part never moves without a timeout...
    np.testing.assert_array_equal(np.asarray(st.base), base0)
    # ...and packets spray inside the 2**sub_bits window anchored at it
    off = (evs - base0[None, :]) % 4096
    assert (off < 8).all(), off
    assert len(np.unique(evs[:, 0])) > 2, "per-packet sub-entropy rotation"
    # an RTO re-hashes the flow part via the threaded key
    st2 = lb.on_timeout(st, mask, jnp.int32(99), jax.random.PRNGKey(7))
    assert not np.array_equal(np.asarray(st2.base), base0)


def test_seqbalance_repaths_only_at_message_boundaries():
    lb = make_lb(
        "seqbalance", evs_size=65536, msg_pkts=4, ecn_frac_threshold=0.25
    )
    st = lb.init_state(2, jax.random.PRNGKey(0))
    mask = jnp.ones((2,), bool)
    ecn = jnp.ones((2,), bool)
    ev0 = np.asarray(st.ev).copy()
    for t in range(4):
        ev, st = lb.choose_ev(
            st, mask, jax.random.fold_in(jax.random.PRNGKey(1), t),
            jnp.int32(t),
        )
        # congested or not, no intra-message re-path (no reordering)
        np.testing.assert_array_equal(np.asarray(ev), ev0)
        st = lb.on_ack(
            st, mask, ev, ecn, jnp.int32(t),
            jax.random.fold_in(jax.random.PRNGKey(2), t),
        )
    # the 5th send crosses the boundary with a fully-marked window
    ev, st = lb.choose_ev(st, mask, jax.random.PRNGKey(3), jnp.int32(4))
    assert not np.array_equal(np.asarray(ev), ev0)


def test_flowlet_table_prefers_uncongested_candidate():
    lb = make_lb("flowlet_table", evs_size=65536, table=4, gap_ticks=8)
    st = lb.init_state(1, jax.random.PRNGKey(0))
    mask = jnp.ones((1,), bool)
    ecn = jnp.ones((1,), bool)
    ev, st = lb.choose_ev(st, mask, jax.random.PRNGKey(1), jnp.int32(0))
    for t in range(1, 4):  # ECN-mark the active candidate's cached score
        st = lb.on_ack(st, mask, ev, ecn, jnp.int32(t), jax.random.PRNGKey(t))
    # after a flowlet gap the cached feedback steers off the marked EV
    ev2, st = lb.choose_ev(st, mask, jax.random.PRNGKey(9), jnp.int32(100))
    assert int(ev2[0]) != int(ev[0])
    # an RTO re-hashes the active candidate (threaded key), score cleared
    cand_before = np.asarray(st.cand).copy()
    st = lb.on_timeout(st, mask, jnp.int32(200), jax.random.PRNGKey(5))
    cur = int(st.cur[0])
    assert int(np.asarray(st.cand)[0, cur]) != int(cand_before[0, cur])
    assert int(st.score[0, cur]) == 0
