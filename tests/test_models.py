"""Per-architecture smoke tests (reduced configs, CPU) + serve consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, ShapeConfig, all_configs, get_config, reduced
from repro.models import build_model
from repro.train import AdamWConfig, TrainConfig, init_train_state, make_train_step

ARCHS = sorted(all_configs().keys())
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    if cfg.frontend != "none":
        return {
            "embeds": jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params, opt = init_train_state(m, KEY)
    batch = _batch(cfg)
    loss, metrics = m.loss_fn(params, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    step = jax.jit(make_train_step(m, TrainConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=1), remat=True)))
    p2, o2, mx = step(params, opt, batch)
    assert bool(jnp.isfinite(mx["loss"]))
    assert bool(jnp.isfinite(mx["grad_norm"])) and float(mx["grad_norm"]) > 0
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_param_axes_structure(arch):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init_params(KEY)
    axes = m.param_axes()
    s1 = jax.tree_util.tree_structure(jax.tree.map(lambda x: 0, params))
    s2 = jax.tree_util.tree_structure(
        jax.tree.map(lambda x: 0, axes, is_leaf=lambda x: isinstance(x, tuple))
    )
    assert s1 == s2
    # ndim of each axes tuple matches the param
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    for p, a in zip(flat_p, flat_a):
        assert len(a) == p.ndim, (p.shape, a)


@pytest.mark.parametrize(
    "arch", ["mistral-nemo-12b", "gemma3-4b", "qwen1.5-4b", "phi3.5-moe-42b-a6.6b"]
)
def test_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init_params(KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    from repro.models import transformer

    full, _ = transformer.forward(params, cfg, {"tokens": toks}, remat=False)
    _, cache, clen = m.prefill_fn(params, {"tokens": toks[:, :15]}, max_len=20)
    ld, _ = m.decode_fn(params, cache, toks[:, 15:16], clen)
    ref, got = full[:, 15], ld[:, 0]
    rel = float(jnp.max(jnp.abs(ref - got)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.03, f"{arch}: rel err {rel}"


def test_rwkv_decode_matches_chunked():
    cfg = reduced(get_config("rwkv6-1.6b"))
    m = build_model(cfg)
    params = m.init_params(KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    from repro.models import recurrent

    full, _, _ = recurrent.rwkv_forward(params, cfg, {"tokens": toks})
    state = m.init_decode_state(ShapeConfig("t", 8, 1, "decode"))
    outs = []
    for t in range(8):
        lg, state = m.decode_fn(params, state, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(full - got)) / (jnp.max(jnp.abs(full)) + 1e-9))
    assert rel < 0.01, rel


def test_zamba_decode_runs_and_is_finite():
    cfg = reduced(get_config("zamba2-7b"))
    m = build_model(cfg)
    params = m.init_params(KEY)
    state = m.init_decode_state(ShapeConfig("t", 64, 2, "decode"))
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    for t in range(3):
        lg, state = m.decode_fn(params, state, toks, jnp.int32(t))
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_gemma3_window_schedule():
    from repro.models.transformer import window_schedule

    cfg = get_config("gemma3-4b")
    ws = np.asarray(window_schedule(cfg, 4096))
    assert (ws[5::6] > 4096).all()  # every 6th layer global
    local = np.ones(len(ws), bool)
    local[5::6] = False
    assert (ws[local] == 1024).all()


def test_moe_outputs_depend_on_routing():
    cfg = reduced(get_config("qwen3-moe-235b-a22b"))
    m = build_model(cfg)
    params = m.init_params(KEY)
    b1 = _batch(cfg)
    loss1, _ = m.loss_fn(params, b1, remat=False)
    # perturbing the router asymmetrically must change the loss (routing is
    # live; a uniform shift would be softmax-invariant)
    params2 = jax.tree_util.tree_map_with_path(
        lambda path, x: x.at[..., 0].add(3.0) if "router" in str(path) else x,
        params,
    )
    loss2, _ = m.loss_fn(params2, b1, remat=False)
    assert abs(float(loss1) - float(loss2)) > 1e-6
