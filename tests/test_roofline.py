"""Roofline machinery: trip-count-aware HLO cost analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import PEAK_FLOPS, Roofline, model_flops_for
from repro.configs import SHAPES, get_config


def _cost(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return analyze_hlo(c.as_text())


def test_scan_flops_match_unrolled():
    def body(x, w):
        return jnp.tanh(x @ w), None

    W = jnp.ones((8, 128, 128), jnp.float32)
    x = jnp.ones((4, 128), jnp.float32)

    def scanned(w, x):
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    def unrolled(w, x):
        for i in range(8):
            x, _ = body(x, w[i])
        return x.sum()

    cs, cu = _cost(scanned, W, x), _cost(unrolled, W, x)
    assert cs.flops == pytest.approx(cu.flops, rel=0.01)
    assert cs.flops == pytest.approx(8 * 2 * 4 * 128 * 128, rel=0.05)


def test_nested_scan_trip_counts():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def step(x, w3):
            y, _ = jax.lax.scan(inner, x, w3)
            return y, None

        y, _ = jax.lax.scan(step, x, ws)
        return y.sum()

    ws = jnp.ones((5, 3, 64, 64), jnp.float32)
    x = jnp.ones((2, 64), jnp.float32)
    c = _cost(outer, x, ws)
    assert c.flops == pytest.approx(5 * 3 * 2 * 2 * 64 * 64, rel=0.05)


def test_model_flops_for():
    cfg = get_config("mistral-nemo-12b")
    tf = model_flops_for(cfg, SHAPES["train_4k"])
    # 6 * ~12B * 1M tokens ~ 7.6e16within 2x of the closed form
    assert 3e16 < tf < 2e17
    moe = get_config("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.2 * moe.param_count()


def test_roofline_terms():
    r = Roofline(
        flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, coll_breakdown={},
        n_devices=256, model_flops=197e12 * 256,
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")
    assert r.roofline_fraction == pytest.approx(1.0)


def test_collective_parse():
    mesh = jax.make_mesh((len(jax.devices()),), ("d",))

    def f(x):
        return jax.lax.psum(x, "d")

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.utils import compat

    sf = compat.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)
    c = jax.jit(sf).lower(jnp.ones((128, 128), jnp.float32)).compile()
    cost = analyze_hlo(c.as_text())
    if len(jax.devices()) > 1:
        assert cost.coll_bytes > 0
