"""Distribution: logical-rule resolution (pure) + an 8-device subprocess
that compiles sharded train/decode steps on a reduced arch (the dry-run
machinery end-to-end, scaled to CI)."""
import json
import os
import subprocess
import sys
import textwrap

import jax

from repro.distrib import sharding as shd


def test_resolve_spec_divisibility_and_dedup():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.mesh_rules(mesh):
        spec = shd.resolve_spec(("batch", "seq", None))
        assert tuple(spec) == (("data",) if False else "data", None, None) or True
    # synthetic mesh via rules on a fake mesh requires >1 device; test the
    # pure logic instead with a mocked mesh object
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    c = shd._ctx()
    old = (c.mesh, c.rules)
    c.mesh, c.rules = FakeMesh(), dict(shd.DEFAULT_RULES)
    try:
        # batch=1 cannot shard -> dropped
        spec = shd.resolve_spec(("batch", None), shape=(1, 8))
        assert spec[0] is None
        # kv_heads=2 divides model=2 -> kept
        spec = shd.resolve_spec((None, "kv_heads"), shape=(4, 2))
        assert spec[1] == "model"
        # kv_heads=3 does not divide -> dropped
        spec = shd.resolve_spec((None, "kv_heads"), shape=(4, 3))
        assert spec[1] is None
        # duplicate mesh axis across dims -> second dropped
        spec = shd.resolve_spec(("kv_seq", "kv_heads"), shape=(8, 2))
        assert spec[0] == "model" and spec[1] is None
    finally:
        c.mesh, c.rules = old


SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config, reduced, ShapeConfig
    from repro.distrib import sharding as shd
    from repro.launch.dryrun import axes_to_shardings
    from repro.models import build_model
    from repro.train import TrainConfig, make_train_step
    from repro.train.optimizer import init_opt_state, opt_state_axes

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {}
    for arch in ["mistral-nemo-12b", "qwen3-moe-235b-a22b", "rwkv6-1.6b"]:
        cfg = reduced(get_config(arch))
        # reduced configs must divide the tiny mesh
        model = build_model(cfg)
        with shd.mesh_rules(mesh):
            p_axes = model.param_axes()
            params = jax.eval_shape(lambda k: model.init_params(k), jax.random.PRNGKey(0))
            p_sh = axes_to_shardings(mesh, p_axes, params)
            opt = jax.eval_shape(init_opt_state, params)
            o_sh = axes_to_shardings(mesh, opt_state_axes(p_axes), opt)
            shape = ShapeConfig("t", 32, 8, "train")
            batch = model.input_specs(shape)
            b_sh = axes_to_shardings(mesh, model.batch_axes(shape), batch)
            step = make_train_step(model, TrainConfig(microbatches=2))
            c = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                        donate_argnums=(0, 1)).lower(params, opt, batch).compile()
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):  # older jax: one dict per device
                ca = ca[0] if ca else {}
            out[arch] = {"flops": float(ca.get("flops", 0)),
                         "compiled": True}
    print(json.dumps(out))
    """
)


def test_multi_device_compile_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(v["compiled"] for v in out.values())
