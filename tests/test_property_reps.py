"""Hypothesis property tests for the REPS state machine and theory models."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; shim keeps tests live
    from _hypothesis_fallback import given, settings, st

from repro.core import balls_bins, reps

OPS = st.lists(
    st.tuples(
        st.integers(0, 2),  # 0=send, 1=ack, 2=failure
        st.integers(0, 255),  # ev
        st.booleans(),  # ecn
    ),
    min_size=1,
    max_size=60,
)


def _apply_ops(ops, buffer_size=8, num_pkts_bdp=3, freezing_timeout=20):
    cfg = reps.REPSConfig(
        buffer_size=buffer_size,
        evs_size=256,
        num_pkts_bdp=num_pkts_bdp,
        freezing_timeout=freezing_timeout,
    )
    state = reps.init_state(cfg, 1)
    oracle = reps.REPSOracle(cfg)
    key = jax.random.PRNGKey(1234)
    for t, (op, ev, ecn) in enumerate(ops):
        if op == 0:
            key, sub = jax.random.split(key)
            evs, state = reps.choose_ev(cfg, state, jnp.array([True]), sub)
            rand_ev = int(
                jax.random.randint(sub, (1,), 0, cfg.evs_size, jnp.int32)[0]
            )
            o_ev = oracle.on_send(rand_ev)
            assert int(evs[0]) == o_ev
        elif op == 1:
            state = reps.on_ack(
                cfg, state, jnp.array([True]), jnp.array([ev]),
                jnp.array([ecn]), jnp.int32(t),
            )
            oracle.on_ack(ev, ecn, t)
        else:
            state = reps.on_failure_detection(
                cfg, state, jnp.array([True]), jnp.int32(t)
            )
            oracle.on_failure_detection(t)
    return cfg, state, oracle


@settings(max_examples=40, deadline=None)
@given(OPS)
def test_vectorized_matches_oracle(ops):
    cfg, state, oracle = _apply_ops(ops)
    assert int(state.head[0]) == oracle.head
    assert int(state.num_valid[0]) == oracle.num_valid
    assert bool(state.is_freezing[0]) == oracle.is_freezing
    assert list(np.asarray(state.buf_ev[0])) == oracle.buf_ev


@settings(max_examples=40, deadline=None)
@given(OPS, st.integers(1, 16))
def test_invariants(ops, buffer_size):
    cfg, state, _ = _apply_ops(ops, buffer_size=buffer_size)
    B = cfg.buffer_size
    assert 0 <= int(state.head[0]) < B
    assert 0 <= int(state.num_valid[0]) <= B
    # num_valid always equals the number of set validity bits
    assert int(state.num_valid[0]) == int(np.asarray(state.buf_valid[0]).sum())
    assert int(state.explore_counter[0]) >= 0


@settings(max_examples=20, deadline=None)
@given(OPS)
def test_recycled_evs_were_cached(ops):
    """Any EV returned while not exploring must have entered via an ACK."""
    cfg = reps.REPSConfig(buffer_size=8, evs_size=1 << 20, num_pkts_bdp=0)
    state = reps.init_state(cfg, 1)
    key = jax.random.PRNGKey(7)
    acked = set()
    for t, (op, ev, ecn) in enumerate(ops):
        if op == 1 and not ecn:
            acked.add(ev)
        if op in (1, 2):
            if op == 1:
                state = reps.on_ack(
                    cfg, state, jnp.array([True]), jnp.array([ev]),
                    jnp.array([ecn]), jnp.int32(t),
                )
            else:
                state = reps.on_failure_detection(
                    cfg, state, jnp.array([True]), jnp.int32(t)
                )
        else:
            had_valid = int(state.num_valid[0]) > 0
            key, sub = jax.random.split(key)
            evs, state = reps.choose_ev(cfg, state, jnp.array([True]), sub)
            if had_valid:  # recycled, not explored (evs_size huge => distinct)
                assert int(evs[0]) in acked


def test_theorem51_recycled_bins_bounded():
    """Theorem 5.1 flavour: at full injection, recycled balls-into-bins max
    load stays O(log n) while OPS grows unboundedly."""
    n = 32
    tau = int(4 * np.log(n))  # ~13
    b = int(np.ceil(2.4 * np.log(n)))  # ~9
    tr = balls_bins.simulate_recycled_bins(
        jax.random.PRNGKey(0), n, b, tau, steps=4000
    )
    # lambda=0.99: Bernoulli-thinned arrivals keep the variance the paper's
    # batched model has (exact lambda=1.0 thinning is variance-free and
    # grows much more slowly)
    ops_ml = balls_bins.simulate_ops_bins(jax.random.PRNGKey(0), n, 0.99, 4000)
    ml = np.asarray(tr.max_load)
    assert int(ml[-1]) <= 3 * tau  # bounded (log-scale)
    assert int(ml[2000:].max()) <= 3 * tau  # and STAYS bounded
    assert int(np.asarray(ops_ml)[-1]) > 3 * tau  # OPS keeps growing
    # a majority of colors hold a remembered bin throughout steady state
    # (full convergence-to-1 is not observed in our variant: at full
    # injection bins hover near tau and keep trimming memories — the
    # bounded-load contrast, which is the theorem's operative claim for
    # REPS, is what we pin; deviation documented in EXPERIMENTS.md)
    assert float(tr.frac_remember[-1]) > 0.3


def test_ops_bins_stable_below_capacity():
    ml = balls_bins.simulate_ops_bins(jax.random.PRNGKey(1), 32, 0.5, 3000)
    assert int(ml[-1]) < 20  # lambda=0.5 is stable
