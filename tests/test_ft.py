"""Fault tolerance: REPS channel scheduler, straggler detection."""
import numpy as np

from repro.ft import (
    ChannelSim,
    ChannelSimConfig,
    LatencyECN,
    OpsChannelScheduler,
    RepsChannelScheduler,
    StepWatchdog,
    run_cross_pod_reduce,
)


def test_reps_channels_avoid_failures():
    cfg = ChannelSimConfig(n_channels=16)
    results = {}
    for name, mk in [
        ("ops", lambda: OpsChannelScheduler(16, seed=0)),
        ("reps", lambda: RepsChannelScheduler(16, seed=0)),
    ]:
        sim = ChannelSim(cfg, seed=0)
        sim.set_failed(range(6))
        results[name] = run_cross_pod_reduce(mk(), sim, 256, 32)
    assert results["reps"].timeouts < results["ops"].timeouts / 3
    assert results["reps"].total_latency_us < results["ops"].total_latency_us


def test_reps_channels_freeze_and_recover():
    sched = RepsChannelScheduler(16, seed=1, freezing_timeout_rounds=2)
    sim = ChannelSim(ChannelSimConfig(n_channels=16), seed=1)
    # healthy warmup
    run_cross_pod_reduce(sched, sim, 64, 16)
    assert not sched.is_freezing
    sim.set_failed(range(8))
    run_cross_pod_reduce(sched, sim, 64, 16)
    # after failures, scheduler must have frozen at some point and still
    # completed; now heal and confirm it exits freezing
    sim.set_failed(range(8), failed=False)
    rep = run_cross_pod_reduce(sched, sim, 128, 16)
    assert rep.timeouts == 0


def test_latency_ecn_marks_outliers():
    m = LatencyECN(factor=1.5)
    lat = np.array([100.0] * 20 + [500.0, 100.0, 100.0])
    marks = m.mark(lat)
    assert marks[20] and not marks[:20].any()


def test_step_watchdog():
    w = StepWatchdog(factor=3.0, trigger_after=2)
    for _ in range(10):
        assert not w.observe(1.0)
    assert not w.observe(10.0)  # first slow step
    assert w.observe(10.0)  # second consecutive -> trigger
