"""Golden sweep-vs-serial parity for the migrated figure grids.

Every grid figure now rides the figure→sweep-batch path
(benchmarks.common.figure_grid → repro.netsim.sweep): this suite rebuilds
each figure's BENCH_SMOKE cell list at the CI-scale FATTREE_32_CI config
with proportionally shrunk tick horizons (heterogeneity preserved, so the
horizon-merge machinery is exercised) and runs it exactly like the
benchmark harness (``collect="none"`` + quiescence early exit).  Every cell
must be bit-identical to a serial ``Simulator.run`` on its padded reference
(``serial_sim``), and every figure must plan into at most 4 bucket scans —
the acceptance shape for fig04/fig07/fig08.

fig02's cell family is covered by tests/test_sweep.py (same shapes); this
file owns the figures migrated on top of the cost-aware packer: fig03,
fig04, fig05, fig06, fig07, fig08.
"""
import dataclasses

import jax
import numpy as np

import benchmarks.fig03_asym_micro as fig03
import benchmarks.fig04_asym_macro as fig04
import benchmarks.fig05_background as fig05
import benchmarks.fig06_failures_micro as fig06
import benchmarks.fig07_failures_macro as fig07
import benchmarks.fig08_extreme as fig08
from repro.configs.arcane_paper import FATTREE_32_CI
from repro.netsim import SweepEngine

CFG = FATTREE_32_CI


def _shrink(cases, factor=16, floor=300):
    """Scale each cell's horizon down for CI (relative heterogeneity is
    preserved so multi-horizon figures still bucket/merge like the full
    runs) and pin the seed axis to the golden seed."""
    return [
        dataclasses.replace(c, ticks=max(floor, c.ticks // factor),
                            seeds=(0,))
        for c in cases
    ]


def _run_and_check(cases, max_buckets=4):
    """The figure_grid execution path (collect='none', early exit) with a
    bit-exactness check of every cell against its serial reference."""
    eng = SweepEngine(CFG, cases)
    assert len(eng.buckets) <= max_buckets, eng.plan.describe()
    res = eng.run(collect="none", early_exit=True)
    for c in cases:
        ref = eng.serial_sim(c.name)
        st, _ = ref.run(c.ticks)
        jax.block_until_ready(st.c_done)
        sw = res.state_for(c.name)
        for field in ("c_done_tick", "c_delivered", "s_stats", "q_served"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st, field)), getattr(sw, field),
                err_msg=f"{c.name}:{field}",
            )
    return eng, res


def test_fig04_smoke_parity():
    """Asymmetry macro grid: 2 workloads x 3 LBs over degraded uplinks in
    one bucket scan, every cell bit-identical to its serial reference."""
    eng, _ = _run_and_check(_shrink(fig04.cases(CFG, smoke=True)))
    # the synthetic block shares one compiled scan
    assert eng.plan.n_groups <= 2, eng.plan.describe()


def test_fig07_smoke_parity():
    """Failure macro grid: permutation + ring-AllReduce blocks (different
    conn counts AND horizons) in <= 4 scans, bit-exact per cell."""
    _run_and_check(_shrink(fig07.cases(CFG, smoke=True)))


def test_fig08_smoke_parity():
    """Extreme-failure grid: the failure-fraction axis (F shapes 2^k) must
    fuse into ONE bucket under the default waste budget, bit-exact."""
    eng, _ = _run_and_check(_shrink(fig08.cases(CFG, smoke=True)))
    assert len(eng.buckets) == 1, eng.plan.describe()
    assert eng.plan.merge_waste <= 0.05


def test_fig03_smoke_parity():
    """Asymmetric micro: watch-list cells (degraded uplink share metric)
    ride one bucket; q_served parity guarantees the derived share."""
    eng, _ = _run_and_check(_shrink(fig03.cases(CFG, smoke=True)))
    assert len(eng.buckets) == 1


def test_fig05_smoke_parity():
    """Mixed-cohort cells (registry-backed MixedLB) share one lax.switch
    scan; c_done_tick parity guarantees the derived cohort FCTs."""
    eng, _ = _run_and_check(_shrink(fig05.cases(CFG, smoke=True)))
    assert len(eng.buckets) == 1


def test_fig06_smoke_parity():
    """Transient-failure micro grid stays a single bucket with bit-exact
    cells after the packer rewrite."""
    eng, _ = _run_and_check(_shrink(fig06.cases(CFG, smoke=True)))
    assert len(eng.buckets) == 1
