"""Soak runtime (repro.netsim.soak): preemption-proof checkpointed sweeps.

The contract under test:

* **Straight-through parity** — driving a grid to its horizon through
  ``SoakRunner.advance`` yields summaries, telemetry sketch bytes and
  final states bit-identical to the batch ``SweepEngine.run`` path.
* **Kill-at-every-chunk-boundary resume** — for every chunk boundary k:
  advance a checkpointing runner to k, abandon it (the simulated
  preemption), build a *fresh* engine + runner, ``resume()``, run to the
  horizon — and every row's summary, sketch carry and final state (and in
  full mode, the complete trace stream) bit-matches the uninterrupted
  golden.  Covered across ≥2 shape buckets including a horizon-merged
  bucket (frozen rows), for ``collect="summary"`` and ``collect="full"``.
* **Injection ≡ static schedule** — a failure delta injected mid-run via
  ``SoakRunner.inject`` produces results bit-identical to declaring the
  same events in the cases' ``FailureSchedule`` up front (same
  ``min_failure_slots``, hence identical pack plans and RNG streams);
  invalid deltas (past start, overlap with a down window, no headroom)
  raise before any state is touched.
* **Merge validation** — ``FailureSchedule.merge`` preserves the base
  rows bit-unchanged and produces exactly the union active-set, or raises;
  property-tested over random schedules.
* **Checkpoint hardening** — atomic commits (no stale staging dirs after
  save), ``latest`` skipping uncommitted/corrupt snapshots, ``prune``
  keep-last-K + stale-dir sweeping, ``save`` retry on transient OSError,
  ``save_async`` surfacing worker exceptions on join, and fingerprint
  gating on resume.
* **Fleet chunked resume** — ``FleetRunner.run_summary`` with
  ``tel=``/``t0=``/``horizon=`` splits bit-identically to one shot.
"""
import json
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; shim keeps tests live
    from _hypothesis_fallback import given, settings, st

from repro import checkpoint as ckpt
from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.netsim import (
    FailureSchedule, FleetRunner, SoakConfig, SoakRunner, SweepCase,
    SweepEngine, Topology, TraceSpec, failures, workloads,
)

CFG = FATTREE_32_CI
TICKS = 360  # grid horizon; chunk 120 -> boundaries at 120, 240
CHUNK = 120
SLOTS = 8  # injection headroom (and plan identity with the static grids)

WL_A = workloads.permutation(32, 24, seed=1)
WL_B = workloads.permutation(32, 24, seed=2)
WL_C = workloads.incast(32, 5, 24)


def _grid(extra_failures=None):
    """Three cells, ≥2 shape buckets, one horizon-merged (frozen) row:
    a (360 ticks) and b (300 ticks) share shapes and merge into one masked
    bucket; c's conn count lands in a second bucket."""
    return [
        SweepCase(
            name="a", workload=WL_A, lb="reps", ticks=TICKS,
            lb_kwargs={"evs_size": CFG.evs_size}, failures=extra_failures,
            seeds=(0, 1),
        ),
        SweepCase(
            name="b", workload=WL_B, lb="ops", ticks=300,
            failures=extra_failures, seeds=(0,),
        ),
        SweepCase(
            name="c", workload=WL_C, lb="reps", ticks=TICKS,
            lb_kwargs={"evs_size": CFG.evs_size}, failures=extra_failures,
            seeds=(0,),
        ),
    ]


def _engine(extra_failures=None):
    return SweepEngine(
        CFG, _grid(extra_failures), devices=None, min_failure_slots=SLOTS
    )


def _bit_state(res):
    """Canonical bytes of every cell row's result: summaries (repr covers
    every RunSummary field exactly), telemetry carries, final states."""
    out = {"summaries": repr(sorted(res.summaries().items()))}
    for bi, b in enumerate(res.buckets):
        out[f"b{bi}_state"] = jax.tree_util.tree_map(
            np.asarray, b.final_state
        )
        if b.telemetry is not None:
            out[f"b{bi}_tel"] = np.asarray(b.telemetry)
    return out


def _assert_bit_equal(got, want):
    assert got["summaries"] == want["summaries"]
    for k in want:
        if k == "summaries":
            continue
        for g, w in zip(
            jax.tree_util.tree_leaves(got[k]),
            jax.tree_util.tree_leaves(want[k]),
        ):
            np.testing.assert_array_equal(g, w)


@pytest.fixture(scope="module")
def golden_summary():
    res = _engine().run(collect="summary", chunk=CHUNK)
    return _bit_state(res)


def test_grid_has_frozen_row_and_two_buckets():
    eng = _engine()
    assert len(eng.buckets) >= 2, eng.plan.describe()
    assert any(b.program.masked for b in eng.buckets), (
        "grid must exercise the horizon-freeze path; packer no longer "
        "merges a/b — adjust ticks"
    )


def test_soak_straight_through_equals_batch(tmp_path, golden_summary):
    soak = SoakRunner(
        _engine(),
        SoakConfig(chunk=CHUNK, ckpt_dir=str(tmp_path / "ck")),
    )
    soak.advance(TICKS)
    assert soak.done
    _assert_bit_equal(_bit_state(soak.result()), golden_summary)


@pytest.mark.parametrize("kill_at", [CHUNK, 2 * CHUNK])
def test_kill_at_chunk_boundary_resumes_bit_exact(
    tmp_path, golden_summary, kill_at
):
    d = str(tmp_path / "ck")
    cfg = SoakConfig(chunk=CHUNK, ckpt_dir=d)
    first = SoakRunner(_engine(), cfg)
    first.advance(kill_at)
    assert first.cursor == kill_at
    del first  # simulated preemption: nothing survives but the snapshots

    resumed = SoakRunner(_engine(), cfg).resume()
    assert resumed.cursor == kill_at
    resumed.advance(TICKS)
    _assert_bit_equal(_bit_state(resumed.result()), golden_summary)


def test_kill_resume_full_traces_bit_exact(tmp_path):
    golden = _engine().run(collect="full", chunk=CHUNK)
    d = str(tmp_path / "ck")
    cfg = SoakConfig(chunk=CHUNK, ckpt_dir=d, collect="full")
    first = SoakRunner(_engine(), cfg)
    first.advance(CHUNK)
    del first

    resumed = SoakRunner(_engine(), cfg).resume()
    res = resumed.result() if resumed.done else (
        resumed.advance(TICKS), resumed.result())[1]
    for name in ("a", "b", "c"):
        tg = golden.trace_for(name)
        tr = res.trace_for(name)
        for field in tg._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(tg, field)),
                np.asarray(getattr(tr, field)),
            )
    _assert_bit_equal(_bit_state(res), _bit_state(golden))


def test_injection_equals_static_schedule(tmp_path):
    """The acceptance grid: a spine failure injected at a chunk boundary
    must be bit-identical to pre-declaring it in every case — across the
    whole figure-style grid (both buckets, frozen row included)."""
    delta = failures.spine_down(CFG, 0, start=CHUNK)
    static = _bit_state(
        _engine(extra_failures=delta).run(collect="summary", chunk=CHUNK)
    )

    soak = SoakRunner(
        _engine(),
        SoakConfig(chunk=CHUNK, ckpt_dir=str(tmp_path / "ck")),
    )
    soak.advance(CHUNK)
    soak.inject(delta)
    soak.advance(TICKS)
    _assert_bit_equal(_bit_state(soak.result()), static)


def test_injection_survives_kill_and_resume(tmp_path):
    """The injection log rides in the snapshot manifest and is replayed
    through the same merge path on resume."""
    delta = failures.spine_down(CFG, 1, start=CHUNK)
    static = _bit_state(
        _engine(extra_failures=delta).run(collect="summary", chunk=CHUNK)
    )
    d = str(tmp_path / "ck")
    cfg = SoakConfig(chunk=CHUNK, ckpt_dir=d)
    first = SoakRunner(_engine(), cfg)
    first.advance(CHUNK)
    first.inject(delta)
    first.advance(CHUNK)  # one more boundary past the injection
    del first

    resumed = SoakRunner(_engine(), cfg).resume()
    assert resumed.cursor == 2 * CHUNK
    assert len(resumed.injections) == 1
    resumed.advance(TICKS)
    _assert_bit_equal(_bit_state(resumed.result()), static)


def test_inject_rejects_bad_deltas(tmp_path):
    soak = SoakRunner(_engine(), SoakConfig(chunk=CHUNK))
    soak.advance(CHUNK)
    past = failures.link_down([0], start=CHUNK - 10, end=failures.FOREVER)
    with pytest.raises(ValueError, match="past"):
        soak.inject(past)
    down = failures.spine_down(CFG, 0, start=CHUNK)
    soak.inject(down)
    with pytest.raises(ValueError, match="resurrect"):
        soak.inject(failures.spine_down(CFG, 0, start=CHUNK + 5))
    # validation happens before mutation: the run is still advanceable and
    # equal to the single-injection static reference
    soak.advance(TICKS)
    static = _bit_state(
        _engine(extra_failures=down).run(collect="summary", chunk=CHUNK)
    )
    _assert_bit_equal(_bit_state(soak.result()), static)


def test_inject_without_headroom_raises():
    eng = SweepEngine(
        CFG, [_grid()[0]], devices=None  # natural f slots: 1
    )
    soak = SoakRunner(eng, SoakConfig(chunk=CHUNK))
    soak.advance(CHUNK)
    with pytest.raises(ValueError, match="min_failure_slots"):
        soak.inject(failures.spine_down(CFG, 0, start=CHUNK))


def test_inspect_reports_live_cursor_and_telemetry():
    soak = SoakRunner(_engine(), SoakConfig(chunk=CHUNK))
    soak.advance(CHUNK)
    info = soak.inspect()
    assert set(info) == {"a", "b", "c"}
    assert info["a"]["cursor"] == CHUNK and not info["a"]["done"]
    assert info["b"]["ticks"] == 300
    assert info["a"]["telemetry"], "summary mode exposes live channels"
    soak.advance(TICKS)
    assert soak.inspect()["b"]["done"]
    assert soak.inspect()["b"]["cursor"] == 300  # clamped to own horizon


# ---------------------------------------------------------------------------
# Flight-recorder streaming (SoakConfig.trace=TraceSpec(...)).
# ---------------------------------------------------------------------------

TRACE = TraceSpec(ring=512)


def _flight_state(res):
    """Every cell row's decoded ring, in canonical order."""
    out = {}
    for name in ("a", "b", "c"):
        n_seeds = 2 if name == "a" else 1
        for i in range(n_seeds):
            ev = res.flight_for(name, i)
            out[(name, i)] = {
                k: (np.asarray(v) if isinstance(v, np.ndarray) else v)
                for k, v in ev.items()
            }
    return out


def _flight_files(d):
    """{part name: raw bytes} of every flushed flight part under ckpt d."""
    fd = os.path.join(d, "flight")
    return {
        f: open(os.path.join(fd, f), "rb").read()
        for f in sorted(os.listdir(fd))
        if f.endswith(".npz")
    }


@pytest.fixture(scope="module")
def golden_traced(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("traced") / "ck")
    soak = SoakRunner(
        _engine(), SoakConfig(chunk=CHUNK, ckpt_dir=d, trace=TRACE)
    )
    soak.advance(TICKS)
    res = soak.result()
    return {
        "bits": _bit_state(res),
        "flight": _flight_state(res),
        "files": _flight_files(d),
    }


def test_traced_soak_is_bit_invisible(golden_summary, golden_traced):
    """The whole-point contract: carrying the flight ring changes no
    summary, sketch byte or final state of the soak run."""
    _assert_bit_equal(golden_traced["bits"], golden_summary)
    assert any(
        ev["cursor"] > 0 for ev in golden_traced["flight"].values()
    ), "an active grid must record events"


@pytest.mark.parametrize("kill_at", [CHUNK, 2 * CHUNK])
def test_traced_kill_resume_rings_and_parts_bit_exact(
    tmp_path, golden_traced, kill_at
):
    """Kill/resume with tracing on: the restored rings continue bit-exactly
    (cursor, ring contents, failure edges) and the streamed flight part
    files are byte-identical to the uninterrupted run's — including the
    boundary parts rewritten by the replayed window."""
    d = str(tmp_path / "ck")
    cfg = SoakConfig(chunk=CHUNK, ckpt_dir=d, trace=TRACE)
    first = SoakRunner(_engine(), cfg)
    first.advance(kill_at)
    del first

    resumed = SoakRunner(_engine(), cfg).resume()
    assert resumed.cursor == kill_at
    resumed.advance(TICKS)
    res = resumed.result()
    _assert_bit_equal(_bit_state(res), golden_traced["bits"])
    got = _flight_state(res)
    for key, want in golden_traced["flight"].items():
        ev = got[key]
        assert ev["cursor"] == want["cursor"], key
        assert ev["lost"] == want["lost"], key
        assert ev["first_drop_tick"] == want["first_drop_tick"], key
        assert ev["first_redeliver_tick"] == want["first_redeliver_tick"]
        for k in ("seq", "tick", "code", "value"):
            np.testing.assert_array_equal(ev[k], want[k], err_msg=str(key))
    assert _flight_files(d) == golden_traced["files"]


def test_traced_inspect_exposes_flight_tail_mid_run(tmp_path):
    soak = SoakRunner(
        _engine(),
        SoakConfig(chunk=CHUNK, ckpt_dir=str(tmp_path / "ck"), trace=TRACE),
    )
    soak.advance(CHUNK)
    info = soak.inspect()
    assert all("flight" in v for v in info.values())
    fl = info["a"]["flight"]
    assert fl["cursor"] > 0
    assert np.all(np.asarray(fl["tick"]) < CHUNK)


def test_trace_on_fingerprint_rejects_trace_off_snapshot(tmp_path):
    """A trace-on resume must never restore a trace-off snapshot (the ring
    carry would be missing): the fingerprint covers the TraceSpec."""
    d = str(tmp_path / "ck")
    SoakRunner(_engine(), SoakConfig(chunk=CHUNK, ckpt_dir=d)).advance(CHUNK)
    cfg_on = SoakConfig(chunk=CHUNK, ckpt_dir=d, trace=TRACE)
    with pytest.raises(ValueError, match="fingerprint"):
        SoakRunner(_engine(), cfg_on).resume()


def test_trace_requires_summary_collect():
    with pytest.raises(ValueError, match="summary"):
        SoakRunner(
            _engine(), SoakConfig(chunk=CHUNK, collect="full", trace=TRACE)
        )


# ---------------------------------------------------------------------------
# FailureSchedule.merge property tests (host-only, no engine).
# ---------------------------------------------------------------------------

N_QUEUES = 8
T_MAX = 48

EVENT = st.tuples(
    st.integers(0, N_QUEUES - 1),  # queue
    st.integers(0, T_MAX - 8),     # start
    st.integers(1, 8),             # duration
    st.integers(0, 1),             # kind
)
EVENTS = st.lists(EVENT, min_size=0, max_size=5)


def _sched(events):
    if not events:
        return FailureSchedule.none()
    q, s, d, k = zip(*events)
    return FailureSchedule(
        queue=np.asarray(q, np.int32),
        start=np.asarray(s, np.int32),
        end=np.asarray(s, np.int32) + np.asarray(d, np.int32),
        kind=np.asarray(k, np.int32),
    )


def _active_sets(fs, t):
    """(down queues, degraded queues) active at tick t."""
    q = np.asarray(fs.queue)
    s = np.asarray(fs.start)
    e = np.asarray(fs.end)
    k = np.asarray(fs.kind)
    on = (s <= t) & (t < e)
    return set(q[on & (k == 0)].tolist()), set(q[on & (k == 1)].tolist())


@settings(max_examples=120, deadline=None)
@given(EVENTS, EVENTS, st.integers(0, T_MAX // 2))
def test_merge_union_semantics_or_rejects(base_ev, delta_ev, at_tick):
    base = _sched(base_ev)
    try:
        base.validate(N_QUEUES)
    except ValueError:
        return  # not a legal base; merge contract starts from valid inputs
    delta = _sched(delta_ev)
    try:
        merged = base.merge(delta, at_tick=at_tick, n_queues=N_QUEUES)
    except ValueError:
        return  # rejected: past start / resurrection / double-schedule
    # base rows bit-unchanged, in place
    n = len(base)
    np.testing.assert_array_equal(np.asarray(merged.queue[:n]), base.queue)
    np.testing.assert_array_equal(np.asarray(merged.start[:n]), base.start)
    np.testing.assert_array_equal(np.asarray(merged.end[:n]), base.end)
    np.testing.assert_array_equal(np.asarray(merged.kind[:n]), base.kind)
    merged.validate(N_QUEUES)
    # exact union active-set at every tick
    for t in range(T_MAX + 2):
        bd, bg = _active_sets(base, t)
        dd, dg = _active_sets(delta, t)
        md, mg = _active_sets(merged, t)
        assert md == bd | dd, t
        assert mg == bg | dg, t
    # accepted deltas never start in the past
    d_live = np.asarray(delta.end) > np.asarray(delta.start)
    assert np.all(np.asarray(delta.start)[d_live] >= at_tick)


@settings(max_examples=60, deadline=None)
@given(EVENT, st.integers(0, 4))
def test_merge_rejects_resurrection_and_double_schedule(ev, shift):
    q, s, d, k = ev
    base = _sched([(q, s, d, 0)])  # a down window
    overlapping = _sched([(q, s + shift, d, k)])
    if shift < d:  # overlaps the down window -> always rejected
        with pytest.raises(ValueError):
            base.merge(overlapping, at_tick=0, n_queues=N_QUEUES)
    else:  # disjoint -> accepted, appended
        merged = base.merge(overlapping, at_tick=0, n_queues=N_QUEUES)
        assert len(merged) == 2


def test_merge_is_the_static_composite():
    """down-over-degraded stays legal and equals the hand-declared
    composite (the fig-4 style degraded background + a hard failure)."""
    degraded = failures.link_degraded([3], start=0, end=40)
    down = failures.link_down([3], start=10, end=failures.FOREVER)
    merged = degraded.merge(down, at_tick=5, n_queues=N_QUEUES)
    composite = FailureSchedule.concat(degraded, down)
    for t in range(60):
        assert _active_sets(merged, t) == _active_sets(composite, t)


# ---------------------------------------------------------------------------
# Checkpoint hardening.
# ---------------------------------------------------------------------------

def _tiny_trees(v=0):
    return {"state": {"x": np.arange(4, dtype=np.int32) + v}}


def test_save_commit_is_atomic_and_extra_roundtrips(tmp_path):
    base = str(tmp_path)
    p = os.path.join(base, "step_5")
    ckpt.save(p, 5, _tiny_trees(), extra={"soak": {"cursor": 5, "inj": []}})
    assert ckpt.is_committed(p)
    assert not [d for d in os.listdir(base) if ".tmp." in d], (
        "staging dir must not survive a successful commit"
    )
    m = ckpt.read_manifest(p)
    assert m["soak"] == {"cursor": 5, "inj": []}
    out, step = ckpt.restore(p, {"state": _tiny_trees()["state"]})
    assert step == 5
    np.testing.assert_array_equal(
        np.asarray(out["state"]["x"]), _tiny_trees()["state"]["x"]
    )


def test_latest_skips_uncommitted_and_corrupt(tmp_path):
    base = str(tmp_path)
    ckpt.save(os.path.join(base, "step_1"), 1, _tiny_trees(1))
    ckpt.save(os.path.join(base, "step_2"), 2, _tiny_trees(2))
    ckpt.save(os.path.join(base, "step_3"), 3, _tiny_trees(3))
    os.unlink(os.path.join(base, "step_2", "COMMITTED"))  # interrupted
    with open(os.path.join(base, "step_3", "manifest.json"), "w") as f:
        f.write("{ truncated")  # corrupt
    assert ckpt.latest(base) == os.path.join(base, "step_1")
    os.unlink(os.path.join(base, "step_1", "COMMITTED"))
    assert ckpt.latest(base) is None


def test_prune_keeps_last_k_and_sweeps_stale_dirs(tmp_path):
    base = str(tmp_path)
    for i in range(1, 6):
        ckpt.save(os.path.join(base, f"step_{i}"), i, _tiny_trees(i))
    os.makedirs(os.path.join(base, "step_9.tmp.123"))  # stale staging
    os.makedirs(os.path.join(base, "step_7"))  # uncommitted husk
    deleted = ckpt.prune(base, keep=2)
    left = sorted(os.listdir(base))
    assert left == ["step_4", "step_5"], left
    assert len(deleted) == 5
    with pytest.raises(AssertionError):
        ckpt.prune(base, keep=0)


def test_save_retries_transient_oserror(tmp_path, monkeypatch):
    from repro.checkpoint import checkpoint as ckpt_mod

    real = ckpt_mod._save_once
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return real(*a, **kw)

    monkeypatch.setattr(ckpt_mod, "_save_once", flaky)
    p = os.path.join(str(tmp_path), "step_1")
    with pytest.raises(OSError):
        ckpt.save(p, 1, _tiny_trees(), retries=1, backoff_s=0.0)
    calls["n"] = 0
    ckpt.save(p, 1, _tiny_trees(), retries=2, backoff_s=0.0)
    assert calls["n"] == 3 and ckpt.is_committed(p)


def test_save_async_surfaces_worker_exception(tmp_path):
    blocker = tmp_path / "blocked"
    blocker.write_text("a file where the snapshot dir's parent should be")
    handle = ckpt.save_async(
        str(blocker / "ck" / "step_1"), 1, _tiny_trees()
    )
    with pytest.raises(OSError):
        handle.join()
    ok = ckpt.save_async(str(tmp_path / "ok" / "step_1"), 1, _tiny_trees())
    ok.join()
    assert ckpt.is_committed(str(tmp_path / "ok" / "step_1"))


def test_resume_rejects_fingerprint_mismatch(tmp_path):
    d = str(tmp_path / "ck")
    cfg = SoakConfig(chunk=CHUNK, ckpt_dir=d)
    SoakRunner(_engine(), cfg).advance(CHUNK)
    other = SweepEngine(
        CFG, _grid()[:1], devices=None, min_failure_slots=SLOTS
    )
    with pytest.raises(ValueError, match="fingerprint"):
        SoakRunner(other, cfg).resume()


def test_async_save_soak_run_bit_exact(tmp_path, golden_summary):
    """async_save exercises SaveHandle end-to-end on the real run path."""
    d = str(tmp_path / "ck")
    cfg = SoakConfig(chunk=CHUNK, ckpt_dir=d, async_save=True)
    first = SoakRunner(_engine(), cfg)
    first.advance(2 * CHUNK)
    first._join_pending()  # the preemption may land mid-write; committed
    del first              # snapshots are still the contract
    resumed = SoakRunner(_engine(), cfg).resume()
    assert resumed.cursor in (CHUNK, 2 * CHUNK)
    resumed.advance(TICKS)
    _assert_bit_equal(_bit_state(resumed.result()), golden_summary)


# ---------------------------------------------------------------------------
# Fleet chunked resume.
# ---------------------------------------------------------------------------

def test_fleet_run_summary_chunked_resume_bit_exact():
    lb = make_lb("reps", evs_size=CFG.evs_size)
    fleet = FleetRunner(CFG, WL_A, lb, seeds=(0, 1))
    st_g, tel_g = fleet.run_summary(300)
    st_a, tel_a = fleet.run_summary(100, horizon=300)
    st_b, tel_b = fleet.run_summary(
        200, states=st_a, tel=tel_a.tel, t0=100, horizon=300
    )
    np.testing.assert_array_equal(np.asarray(tel_g.tel), np.asarray(tel_b.tel))
    for g, b in zip(
        jax.tree_util.tree_leaves(st_g), jax.tree_util.tree_leaves(st_b)
    ):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(b))
    assert repr(tel_g.summaries()) == repr(tel_b.summaries())
