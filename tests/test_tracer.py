"""Flight recorder (repro.netsim.tracer): on-device decision tracing.

The contract under test:

* **Bit-invisibility** — running with the tracer folded in
  (``step_events`` / ``trace=TraceSpec(...)``) leaves every simulation
  state, telemetry sketch and derived metric bit-identical to the
  untraced run: tracing is observation-only, and the trace-port key folds
  consume no randomness.
* **Sweep ≡ serial** — every sweep row's ring carry is bit-identical to
  the serial ``tracer.run_serial`` reference for the same cell, across
  ≥2 shape buckets including a horizon-merged (frozen) row, and invariant
  to the chunk tiling.
* **Recovery-span parity** — the ring's first-drop / first-redelivery
  edges mirror ``telemetry.RecoveryTracker`` bit-exactly, so a decoded
  recovery span has precisely the tracker's duration (the acceptance
  criterion for the Perfetto export).
* **Ring mechanics** — wrap-around overwrites are reported (``lost``),
  incremental ``since``-based decoding concatenates to the one-shot
  decode, and spec validation rejects degenerate rings.
* **Event semantics** — REPS EV-cache hit/miss/recycle/freeze counts and
  per-LB re-path cause codes come from pure state diffs and match
  independent expectations on crafted scenarios.
"""
import jax
import numpy as np
import pytest

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.netsim import (
    PackerConfig, Simulator, SweepCase, SweepEngine, Topology, failures,
    tracer, workloads,
)
from repro.netsim.tracer import TracerProgram, TraceSpec

CFG = FATTREE_32_CI


def _case(name, wl, lb, ticks, fs=None, seeds=(0,), **lb_kwargs):
    lb_kwargs.setdefault("evs_size", CFG.evs_size)
    return SweepCase(
        name=name, workload=wl, lb=lb, ticks=ticks, lb_kwargs=lb_kwargs,
        failures=fs, seeds=tuple(seeds),
    )


def _fail_grid():
    topo = Topology.build(CFG)
    fs = failures.link_down(
        list(topo.t0_up_queues(0)[:2]), 100, failures.FOREVER
    )
    wl = workloads.permutation(32, 64, seed=3)
    return [
        _case("perm/reps", wl, "reps", 500, seeds=(0, 5)),
        _case("fail/reps", wl, "reps", 900, fs=fs, freezing_timeout=300),
        _case("incast/plb", workloads.incast(16, 4, 96), "plb", 700),
    ]


SPEC = TraceSpec(ring=4096, marker_every=128)


def _decode_equal(a, b, ctx=""):
    assert a["cursor"] == b["cursor"], (ctx, a["cursor"], b["cursor"])
    for k in ("seq", "tick", "code", "value"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{ctx}:{k}")
    for k in ("first_drop_tick", "first_redeliver_tick", "lost"):
        assert a[k] == b[k], (ctx, k, a[k], b[k])


# ---------------------------------------------------------------------------
# Bit-invisibility + serial reference.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lbn,kw", [
    ("reps", {"freezing_timeout": 300}), ("plb", {}), ("flowlet", {}),
])
def test_tracing_is_bit_invisible_serial(lbn, kw):
    """step_events advances the simulation bit-identically to plain run():
    the trace port observes state diffs, never mutates, and fold_in-based
    key derivation is untouched by the extra stages."""
    wl = workloads.permutation(32, 48, seed=1)
    sim = Simulator(CFG, wl, make_lb(lbn, evs_size=CFG.evs_size, **kw))
    plain, _ = sim.run(400)
    traced, trc = tracer.run_serial(sim, 400, SPEC)
    for p, t in zip(
        jax.tree_util.tree_leaves(plain), jax.tree_util.tree_leaves(traced)
    ):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(t))
    assert int(np.asarray(trc)[0]) > 0, "an active run must record events"


def test_sweep_trace_off_on_bit_parity_and_serial_match():
    """Trace-on sweeps reproduce trace-off states + telemetry bit-exactly;
    every cell row's ring equals the serial reference; the ring is
    invariant to the chunk tiling.  Covers ≥2 shape buckets and a
    horizon-merged frozen row."""
    cases = _fail_grid()
    eng_off = SweepEngine(CFG, cases, packer=PackerConfig(merge=False))
    res_off = eng_off.run(collect="summary", chunk=250)
    eng_on = SweepEngine(CFG, cases, packer=PackerConfig(merge=False))
    res_on = eng_on.run(collect="summary", chunk=250, trace=SPEC)
    assert len(eng_on.buckets) >= 2

    for bo, bn in zip(res_off.buckets, res_on.buckets):
        for lo, ln in zip(
            jax.tree_util.tree_leaves(bo.final_state),
            jax.tree_util.tree_leaves(bn.final_state),
        ):
            np.testing.assert_array_equal(np.asarray(lo), np.asarray(ln))
        np.testing.assert_array_equal(bo.telemetry, bn.telemetry)

    for c in cases:
        for i in range(len(c.seeds)):
            got = res_on.flight_for(c.name, i)
            sim = eng_on.serial_sim(c.name, seed=c.seeds[i])
            _, trc = tracer.run_serial(sim, c.ticks, SPEC)
            ref = SPEC.build(sim, c.ticks).decode_row(np.asarray(trc))
            _decode_equal(got, ref, f"{c.name}[{i}]")

    # different chunking, same rings
    eng2 = SweepEngine(CFG, cases, packer=PackerConfig(merge=False))
    res2 = eng2.run(collect="summary", chunk=97, trace=SPEC)
    for c in cases:
        _decode_equal(
            res_on.flight_for(c.name), res2.flight_for(c.name), c.name
        )


def test_frozen_horizon_row_ring_stops_at_its_own_horizon():
    """In a horizon-merged bucket the short cell's ring must freeze at its
    own horizon: bit-equal to the serial run of that horizon, even though
    the bucket scans on."""
    wl = workloads.permutation(32, 48, seed=1)
    cases = [
        _case("short/ops", wl, "ops", 300),
        _case("long/reps", wl, "reps", 900),
    ]
    eng = SweepEngine(CFG, cases, packer=PackerConfig(waste_budget=2.0))
    assert len(eng.buckets) == 1 and eng.buckets[0].program.masked
    res = eng.run(collect="summary", trace=SPEC)
    for name, ticks in (("short/ops", 300), ("long/reps", 900)):
        sim = eng.serial_sim(name)
        _, trc = tracer.run_serial(sim, ticks, SPEC)
        # decode with the bucket program (bucket horizon) — layout depends
        # only on the ring size, so the serial carry decodes identically
        ref = SPEC.build(sim, ticks).decode_row(np.asarray(trc))
        _decode_equal(res.flight_for(name), ref, name)


def test_trace_requires_summary_mode():
    eng = SweepEngine(
        CFG, [_case("x", workloads.permutation(32, 24, seed=0), "ops", 200)]
    )
    with pytest.raises(ValueError, match="summary"):
        eng.run(collect="none", trace=SPEC)
    with pytest.raises(ValueError, match="flight-recorder"):
        SweepEngine(
            CFG,
            [_case("x", workloads.permutation(32, 24, seed=0), "ops", 200)],
        ).run(collect="summary").flight_for("x")


# ---------------------------------------------------------------------------
# Recovery-span parity (the Perfetto-export acceptance criterion).
# ---------------------------------------------------------------------------


def test_recovery_span_matches_recovery_tracker():
    topo = Topology.build(CFG)
    fs = failures.link_down(
        list(topo.t0_up_queues(0)[:2]), 100, failures.FOREVER
    )
    cases = [
        _case("f/reps", workloads.permutation(32, 64, seed=3), "reps",
              900, fs=fs, freezing_timeout=300),
    ]
    eng = SweepEngine(CFG, cases)
    res = eng.run(collect="summary", trace=SPEC)
    rec = res.telemetry_for("f/reps")["recovery"]
    ev = res.flight_for("f/reps")
    assert rec["first_drop_tick"] >= 100
    assert ev["first_drop_tick"] == rec["first_drop_tick"]
    assert ev["first_redeliver_tick"] == rec["first_redeliver_tick"]
    codes = list(ev["code"])
    assert tracer.FAIL_FIRST_DROP in codes
    ri = codes.index(tracer.FAIL_REROUTED)
    # the FAIL_REROUTED value IS the recovery span in ticks
    assert int(ev["value"][ri]) == rec["recovery_ticks"]
    assert int(ev["tick"][ri]) == rec["first_redeliver_tick"]


# ---------------------------------------------------------------------------
# Ring mechanics + event semantics.
# ---------------------------------------------------------------------------


def test_ring_wraparound_reports_lost_and_incremental_decode():
    """A ring smaller than the event count overwrites oldest-first and
    reports exactly the overwritten count; draining incrementally (the
    soak flush pattern) loses nothing and concatenates to the full
    history."""
    wl = workloads.permutation(32, 64, seed=3)
    sim = Simulator(CFG, wl, make_lb("reps", evs_size=CFG.evs_size))
    big = TraceSpec(ring=4096)
    small = TraceSpec(ring=16)
    _, trc_big = tracer.run_serial(sim, 400, big)
    _, trc_small = tracer.run_serial(sim, 400, small)
    full = big.build(sim, 400).decode_row(np.asarray(trc_big))
    tail = small.build(sim, 400).decode_row(np.asarray(trc_small))
    n = full["cursor"]
    assert n > 16, "scenario must push more events than the small ring"
    assert tail["cursor"] == n
    assert tail["lost"] == n - 16
    np.testing.assert_array_equal(tail["tick"], full["tick"][-16:])
    np.testing.assert_array_equal(tail["code"], full["code"][-16:])

    # incremental drain of the big ring: arbitrary cut points
    prog = big.build(sim, 400)
    cuts = [0, 3, 17, n // 2, n]
    parts = [
        prog.decode_row(np.asarray(trc_big), since=a) for a in cuts[:-1]
    ]
    got_ticks = np.concatenate([
        p["tick"][: b - a] for p, (a, b) in zip(parts, zip(cuts, cuts[1:]))
    ])
    np.testing.assert_array_equal(got_ticks, full["tick"])
    assert all(p["lost"] == 0 for p in parts)


def test_reps_event_counts_match_state_diff_expectations():
    """EV-cache decisions decode to sane, internally-consistent counts: on
    a symmetric fabric REPS starts all-miss (exploring) and converges to
    hits; with a failure + freezing timeout the freeze event appears."""
    wl = workloads.permutation(32, 64, seed=3)
    sim = Simulator(CFG, wl, make_lb("reps", evs_size=CFG.evs_size))
    _, trc = tracer.run_serial(sim, 400, SPEC)
    ev = SPEC.build(sim, 400).decode_row(np.asarray(trc))
    codes = np.asarray(ev["code"])
    vals = np.asarray(ev["value"])
    hits = int(vals[codes == tracer.EV_HIT].sum())
    misses = int(vals[codes == tracer.EV_MISS].sum())
    assert misses > 0, "cold EV cache must explore"
    assert hits > 0, "recycled entropy must produce cache hits"
    # first choose-stage event of the run must be a miss (cache is cold)
    first_choice = codes[np.isin(codes, [tracer.EV_HIT, tracer.EV_MISS])][0]
    assert first_choice == tracer.EV_MISS

    topo = Topology.build(CFG)
    fs = failures.link_down(
        list(topo.t0_up_queues(0)[:2]), 100, failures.FOREVER
    )
    sim_f = Simulator(
        CFG, wl, make_lb("reps", evs_size=CFG.evs_size,
                         freezing_timeout=300),
        failures=fs,
    )
    _, trc_f = tracer.run_serial(sim_f, 900, SPEC)
    ev_f = SPEC.build(sim_f, 900).decode_row(np.asarray(trc_f))
    cnt = {
        name: int((np.asarray(ev_f["code"]) == code).sum())
        for code, name in tracer.CODE_NAMES.items()
    }
    assert cnt["fail_active"] == 1, "one window activation edge"
    assert cnt["fail_first_drop"] == 1 and cnt["fail_rerouted"] == 1


def test_spec_validation_and_layout():
    with pytest.raises(ValueError, match="ring"):
        TraceSpec(ring=4).build(None, 100)
    with pytest.raises(ValueError, match="marker_every"):
        TraceSpec(marker_every=0).build(None, 100)
    prog = TracerProgram(TraceSpec(ring=32), None, 100)
    assert prog.size == 3 + 3 * 32
    assert prog.nbytes == prog.size * 4
    flat = np.asarray(prog.init())
    assert flat[0] == 0 and flat[1] >= 10**9 and flat[2] >= 10**9
    d = prog.decode_row(flat)
    assert d["cursor"] == 0 and len(d["seq"]) == 0
    assert d["first_drop_tick"] == -1 and d["first_redeliver_tick"] == -1


def test_quiescent_run_records_nothing_after_drain():
    """Once the workload drains, no further events push (the no-op-on-
    quiescence contract): the ring of a 400-tick run equals the ring of
    the same scenario run far past quiescence."""
    wl = workloads.permutation(32, 16, seed=1)  # tiny: drains early
    sim = Simulator(CFG, wl, make_lb("ops", evs_size=CFG.evs_size))
    _, trc_short = tracer.run_serial(sim, 400, SPEC)
    _, trc_long = tracer.run_serial(sim, 1600, SPEC)
    np.testing.assert_array_equal(
        np.asarray(trc_short), np.asarray(trc_long)
    )
