"""Pallas kernel parity on randomized multi-tick event streams.

Feeds randomized ACK/timeout/send streams through the fused kernels in
interpret mode, threading state tick-to-tick, and asserts bit-identity
against both the pure-jnp refs and the scalar REPSOracle — including the
freezing-mode recycle branch (getNextEV with no valid entries).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reps as reps_core
from repro.core.load_balancers import RepsLB
from repro.kernels import ops, ref
from repro.kernels.reps_update import BUF


def _stream_inputs(key, N, evs, p_ack=0.5, p_to=0.2, p_send=0.7):
    ks = [jax.random.fold_in(key, i) for i in range(6)]
    return dict(
        ack_mask=jax.random.bernoulli(ks[0], p_ack, (N,)).astype(jnp.int32),
        ack_ev=jax.random.randint(ks[1], (N,), 0, evs, jnp.int32),
        ack_ecn=jax.random.bernoulli(ks[2], 0.3, (N,)).astype(jnp.int32),
        timeout_mask=jax.random.bernoulli(ks[3], p_to, (N,)).astype(jnp.int32),
        send_mask=jax.random.bernoulli(ks[4], p_send, (N,)).astype(jnp.int32),
        rand_ev=jax.random.randint(ks[5], (N,), 0, evs, jnp.int32),
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_reps_tick_stream_matches_ref(seed):
    """40 ticks of chained kernel state == chained ref state, bit for bit."""
    N, evs, bdp, freeze = 70, 128, 3, 12
    key = jax.random.PRNGKey(seed)
    cfg = reps_core.REPSConfig(
        buffer_size=BUF, evs_size=evs, num_pkts_bdp=bdp, freezing_timeout=freeze
    )
    st = reps_core.init_state(cfg, N)
    kstate = rstate = (
        st.buf_ev, st.buf_valid.astype(jnp.int32), st.head, st.num_valid,
        st.explore_counter, st.is_freezing.astype(jnp.int32),
        st.exit_freezing, st.n_cached,
    )
    for t in range(40):
        inp = _stream_inputs(jax.random.fold_in(key, t), N, evs)
        args = tuple(inp.values()) + (t, bdp, freeze)
        kout = ops.reps_tick(*kstate, *args)
        rout = ref.reps_tick_ref(*rstate, *args)
        for i, (g, w) in enumerate(zip(kout, rout)):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w), err_msg=f"tick {t} field {i}"
            )
        kstate, rstate = kout[:8], rout[:8]


def test_reps_tick_stream_matches_scalar_oracle():
    """Chained kernel ticks == the paper-pseudocode oracle, per connection,
    on a stream that drives connections into freezing mode and back out."""
    N, evs, bdp, freeze = 13, 64, 2, 6
    key = jax.random.PRNGKey(7)
    cfg = reps_core.REPSConfig(
        buffer_size=BUF, evs_size=evs, num_pkts_bdp=bdp, freezing_timeout=freeze
    )
    oracles = [reps_core.REPSOracle(cfg) for _ in range(N)]
    st = reps_core.init_state(cfg, N)
    kstate = (
        st.buf_ev, st.buf_valid.astype(jnp.int32), st.head, st.num_valid,
        st.explore_counter, st.is_freezing.astype(jnp.int32),
        st.exit_freezing, st.n_cached,
    )
    saw_freezing_recycle = False
    for t in range(80):
        # heavy timeouts + sparse acks exercise the recycle-at-head branch
        inp = _stream_inputs(
            jax.random.fold_in(key, t), N, evs, p_ack=0.3, p_to=0.5, p_send=0.8
        )
        am, ev, ecn, tm, sm, rnd = (np.asarray(v) for v in inp.values())
        args = tuple(inp.values()) + (t, bdp, freeze)
        kout = ops.reps_tick(*kstate, *args)
        for i, o in enumerate(oracles):
            if am[i]:
                o.on_ack(int(ev[i]), bool(ecn[i]), t)
            if tm[i]:
                o.on_failure_detection(t)
            if sm[i]:
                if o.is_freezing and o.num_valid == 0 and o.n_cached > 0:
                    saw_freezing_recycle = True
                got_ev = o.on_send(int(rnd[i]))
                assert int(kout[8][i]) == got_ev, (t, i)
            assert int(kout[2][i]) == o.head, (t, i)
            assert int(kout[3][i]) == o.num_valid, (t, i)
            assert bool(kout[5][i]) == o.is_freezing, (t, i)
            assert list(np.asarray(kout[0][i])) == o.buf_ev, (t, i)
        kstate = kout[:8]
    assert saw_freezing_recycle, "stream never hit the freezing recycle branch"


@pytest.mark.parametrize("seed", [0, 1])
def test_queue_tick_stream_matches_ref(seed):
    """Chained queue ticks (serve + enqueue) stay bit-identical to the ref."""
    Q, K, cap = 48, 160, 24
    key = jax.random.PRNGKey(seed + 100)
    qlen = jnp.zeros((Q,), jnp.int32)
    qlen_ref = jnp.zeros((Q,), jnp.int32)
    for t in range(30):
        k = jax.random.fold_in(key, t)
        serve = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.8, (Q,)).astype(jnp.int32)
        target = jax.random.randint(jax.random.fold_in(k, 2), (K,), 0, Q + 6, jnp.int32)
        u = jax.random.uniform(jax.random.fold_in(k, 3), (K,))
        got = ops.queue_tick(target, u, qlen, serve, cap, 5, 19)
        want = ref.queue_tick_ref(
            np.asarray(target), np.asarray(u), qlen_ref, serve, cap, 5, 19
        )
        for name, g, w in zip(["qlen", "accept", "mark"], got[:3], want[:3]):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w), err_msg=f"tick {t} {name}"
            )
        acc = np.asarray(got[1])
        np.testing.assert_array_equal(
            np.asarray(got[3])[acc], np.asarray(want[3])[acc], err_msg=f"tick {t} pos"
        )
        qlen, qlen_ref = got[0], want[0]


def test_repslb_backends_bit_identical():
    """RepsLB(backend=pallas) == RepsLB(backend=jnp) through the LB API,
    state and chosen EVs, over a random stream."""
    kwargs = dict(evs_size=512, num_pkts_bdp=4, freezing_timeout=16)
    lbj = RepsLB(backend="jnp", **kwargs)
    lbp = RepsLB(backend="pallas", **kwargs)
    key = jax.random.PRNGKey(3)
    N = 29
    sj, sp = lbj.init_state(N, key), lbp.init_state(N, key)
    for t in range(50):
        k = jax.random.fold_in(key, t)
        am = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.4, (N,))
        ev = jax.random.randint(jax.random.fold_in(k, 2), (N,), 0, 512, jnp.int32)
        ecn = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.25, (N,))
        tm = jax.random.bernoulli(jax.random.fold_in(k, 4), 0.3, (N,))
        sm = jax.random.bernoulli(jax.random.fold_in(k, 5), 0.7, (N,))
        now = jnp.int32(t)
        sj = lbj.on_ack(sj, am, ev, ecn, now, jax.random.fold_in(k, 7))
        sp = lbp.on_ack(sp, am, ev, ecn, now, jax.random.fold_in(k, 7))
        sj = lbj.on_timeout(sj, tm, now, jax.random.fold_in(k, 8))
        sp = lbp.on_timeout(sp, tm, now, jax.random.fold_in(k, 8))
        ej, sj = lbj.choose_ev(sj, sm, jax.random.fold_in(k, 6), now)
        ep, sp = lbp.choose_ev(sp, sm, jax.random.fold_in(k, 6), now)
        m = np.asarray(sm)
        np.testing.assert_array_equal(np.asarray(ej)[m], np.asarray(ep)[m])
        for a, b in zip(jax.tree_util.tree_leaves(sj), jax.tree_util.tree_leaves(sp)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Batched tick hot-spot kernels (seg_rank / seg_sum) — unit parity plus the
# sweep-path contract: kernels_backend="pallas" (interpret off-TPU) must be
# bit-identical to the jnp scatter formulations across multi-bucket grids,
# including horizon-frozen rows and failure schedules.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,S", [(7, 4), (64, 33), (130, 12), (320, 195)])
def test_seg_primitives_match_refs(K, S):
    """seg_rank / seg_sum kernels == the pure-jnp oracles, per element and
    under vmap (the sweep row axis adds a grid dimension)."""
    key = jax.random.PRNGKey(K * 1000 + S)
    seg = jax.random.randint(key, (3, K), 0, S + 2, jnp.int32)  # incl. >= S
    vals = jax.random.randint(jax.random.fold_in(key, 1), (3, 5, K), -4, 9,
                              jnp.int32)
    rk = jax.vmap(lambda s: ops.seg_rank(s, S))(seg)
    rr = jax.vmap(lambda s: ref.seg_rank_ref(s, S))(seg)
    in_range = np.asarray(seg) < S  # kernel ranks out-of-range ids as 0
    np.testing.assert_array_equal(
        np.asarray(rk)[in_range], np.asarray(rr)[in_range]
    )
    sk = jax.vmap(lambda s, v: ops.seg_sum(s, v, S))(seg, vals)
    sr = jax.vmap(lambda s, v: ref.seg_sum_ref(s, v, S))(seg, vals)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


def test_sweep_kernels_backend_pallas_bit_identical():
    """A ≥2-bucket sweep grid under kernels_backend="pallas" (interpret
    mode) bit-matches the jnp path cell by cell — including a frozen-horizon
    row (two horizons merged into one bucket) and a failure schedule."""
    from repro.configs.arcane_paper import FATTREE_32_CI
    from repro.netsim import (
        SweepCase, SweepEngine, Topology, failures, workloads,
    )

    cfg = FATTREE_32_CI
    topo = Topology.build(cfg)
    fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 20, 90)
    wl_p = workloads.permutation(32, 12, seed=1)
    wl_i = workloads.incast(32, 5, 12)

    def cases():
        return [
            # same shapes, different horizons -> one bucket, the 90-tick
            # row freezes at its own horizon while the bucket scans to 140
            SweepCase("p/reps", wl_p, "reps", 140,
                      lb_kwargs=dict(evs_size=cfg.evs_size)),
            SweepCase("p/ops/frozen", wl_p, "ops", 90,
                      lb_kwargs=dict(evs_size=cfg.evs_size)),
            # distinct shape bucket (NC 5 -> padded 8) with failures
            SweepCase("i/reps/fail", wl_i, "reps", 140, failures=fs,
                      lb_kwargs=dict(evs_size=cfg.evs_size)),
        ]

    engines = {
        kb: SweepEngine(cfg, cases(), devices=1, kernels_backend=kb)
        for kb in ("jnp", "pallas")
    }
    assert len(engines["jnp"].buckets) >= 2
    assert engines["jnp"].plan == engines["pallas"].plan
    results = {kb: e.run(collect="none") for kb, e in engines.items()}
    for c in cases():
        a = results["jnp"].state_for(c.name)
        b = results["pallas"].state_for(c.name)
        for name in ("c_done_tick", "s_stats", "q_served", "c_delivered",
                     "pkt", "q_len"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
                err_msg=f"{c.name}: {name}",
            )
    # and the jnp sweep equals its serial reference (the existing sweep
    # contract holds with the backend switch threaded through)
    ref_sim = engines["jnp"].serial_sim("i/reps/fail")
    st, _ = ref_sim.run(140)
    jax.block_until_ready(st.c_done)
    sw = results["jnp"].state_for("i/reps/fail")
    np.testing.assert_array_equal(np.asarray(st.c_done_tick), sw.c_done_tick)
    np.testing.assert_array_equal(np.asarray(st.s_stats), sw.s_stats)
