"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; shim keeps tests live
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows", [1, 8, 17, 64])
@pytest.mark.parametrize("nports", [2, 8, 16, 64])
def test_ecmp_hash_sweep(rows, nports):
    key = jax.random.PRNGKey(rows * 101 + nports)
    flow = jax.random.randint(key, (rows, 128), 0, 1 << 20, jnp.int32)
    ev = jax.random.randint(jax.random.fold_in(key, 1), (rows, 128), 0, 65536, jnp.int32)
    salt = jax.random.randint(jax.random.fold_in(key, 2), (rows, 128), 0, 64, jnp.int32)
    got = ops.ecmp_hash(flow, ev, salt, jnp.int32(nports))
    want = ref.ecmp_hash_ref(flow, ev, salt, nports)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # output range
    assert int(jnp.min(got)) >= 0 and int(jnp.max(got)) < nports


def test_ecmp_hash_uniformity():
    """the mixing hash should spread EVs near-uniformly over ports."""
    key = jax.random.PRNGKey(0)
    flow = jnp.zeros((64, 128), jnp.int32)
    ev = jnp.arange(64 * 128, dtype=jnp.int32).reshape(64, 128)
    salt = jnp.zeros((64, 128), jnp.int32)
    got = np.asarray(ops.ecmp_hash(flow, ev, salt, jnp.int32(16)))
    counts = np.bincount(got.reshape(-1), minlength=16)
    assert counts.min() > 0.7 * counts.mean()


# ---------------------------------------------------------------------------
def _rand_reps_inputs(key, N, evs=256, bdp=4, freeze=30):
    ks = [jax.random.fold_in(key, i) for i in range(16)]
    buf_valid = jax.random.bernoulli(ks[1], 0.5, (N, 8)).astype(jnp.int32)
    return dict(
        buf_ev=jax.random.randint(ks[0], (N, 8), 0, evs, jnp.int32),
        buf_valid=buf_valid,
        head=jax.random.randint(ks[2], (N,), 0, 8, jnp.int32),
        num_valid=buf_valid.sum(1),
        explore=jax.random.randint(ks[3], (N,), 0, 3, jnp.int32),
        freezing=jax.random.bernoulli(ks[4], 0.3, (N,)).astype(jnp.int32),
        exit_freeze=jax.random.randint(ks[5], (N,), 0, 100, jnp.int32),
        n_cached=jax.random.randint(ks[6], (N,), 0, 20, jnp.int32),
        ack_mask=jax.random.bernoulli(ks[7], 0.5, (N,)).astype(jnp.int32),
        ack_ev=jax.random.randint(ks[8], (N,), 0, evs, jnp.int32),
        ack_ecn=jax.random.bernoulli(ks[9], 0.3, (N,)).astype(jnp.int32),
        timeout_mask=jax.random.bernoulli(ks[10], 0.2, (N,)).astype(jnp.int32),
        send_mask=jax.random.bernoulli(ks[11], 0.7, (N,)).astype(jnp.int32),
        rand_ev=jax.random.randint(ks[12], (N,), 0, evs, jnp.int32),
    )


@pytest.mark.parametrize("N", [1, 8, 128, 300, 515])
def test_reps_tick_sweep(N):
    inp = _rand_reps_inputs(jax.random.PRNGKey(N), N)
    args = tuple(inp.values()) + (50, 4, 30)
    got = ops.reps_tick(*args)
    want = ref.reps_tick_ref(*args)
    for i, (g, w) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=f"field {i}")


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_reps_tick_property(N, seed):
    inp = _rand_reps_inputs(jax.random.PRNGKey(seed), N)
    args = tuple(inp.values()) + (seed % 100, 4, 30)
    got = ops.reps_tick(*args)
    want = ref.reps_tick_ref(*args)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("Q,K", [(8, 16), (64, 300), (128, 128), (200, 513)])
def test_queue_tick_sweep(Q, K):
    key = jax.random.PRNGKey(Q * 7 + K)
    qlen = jax.random.randint(key, (Q,), 0, 30, jnp.int32)
    serve = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.9, (Q,)).astype(jnp.int32)
    target = jax.random.randint(jax.random.fold_in(key, 2), (K,), 0, Q + 8, jnp.int32)
    u = jax.random.uniform(jax.random.fold_in(key, 3), (K,))
    got = ops.queue_tick(target, u, qlen, serve, 32, 6, 26)
    want = ref.queue_tick_ref(np.asarray(target), np.asarray(u), qlen, serve, 32, 6, 26)
    for name, g, w in zip(["qlen", "accept", "mark"], got[:3], want[:3]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
    acc = np.asarray(got[1])
    np.testing.assert_array_equal(np.asarray(got[3])[acc], np.asarray(want[3])[acc])


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 64), st.integers(1, 260), st.integers(0, 2**31 - 1))
def test_queue_tick_property(Q, K, seed):
    key = jax.random.PRNGKey(seed)
    qlen = jax.random.randint(key, (Q,), 0, 40, jnp.int32)
    serve = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (Q,)).astype(jnp.int32)
    target = jax.random.randint(jax.random.fold_in(key, 2), (K,), 0, Q + 3, jnp.int32)
    u = jax.random.uniform(jax.random.fold_in(key, 3), (K,))
    cap, kmin, kmax = 32, 6, 26
    new_qlen, accept, mark, pos = ops.queue_tick(target, u, qlen, serve, cap, kmin, kmax)
    # invariants: capacity respected; conservation
    assert int(jnp.max(new_qlen)) <= max(cap, int(jnp.max(qlen)))
    served = np.asarray((qlen > 0) & (serve == 1)).sum()
    assert int(new_qlen.sum()) == int(qlen.sum()) - served + int(np.asarray(accept).sum())
    # marks only on accepted packets above kmin
    a, mk, p = np.asarray(accept), np.asarray(mark), np.asarray(pos)
    assert not np.any(mk & ~a)
    assert not np.any(mk & (p < kmin))
