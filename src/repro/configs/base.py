"""Architecture + shape configuration and the --arch registry."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"  # swiglu | geglu
    qkv_bias: bool = False
    rope_theta: float = 1e6
    # sliding-window pattern: (local_window, period) => layer i is LOCAL
    # unless (i+1) % period == 0 (gemma3's 5:1 local:global). None = all full.
    window_pattern: Optional[tuple[int, int]] = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity: float = 1.25
    # SSM / RWKV
    ssm_state: int = 0
    # Zamba-style shared attention block applied every `shared_attn_period`
    # backbone blocks (0 = none).
    shared_attn_period: int = 0
    shared_attn_window: int = 32768  # KV window for shared blocks at 500k
    # misc
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True
    norm_plus_one: bool = False  # gemma RMSNorm (1 + w)
    attn_strategy: str = "heads"  # heads | sequence (train-time TP choice)
    frontend: str = "none"  # none | audio_stub | vision_stub
    # full attention everywhere (=> long_500k inapplicable)?
    @property
    def pure_full_attention(self) -> bool:
        return (
            self.family not in ("ssm", "hybrid") and self.window_pattern is None
        )

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(self.n_kv_heads, 1))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
        attn += self.n_heads * self.head_dim * d
        if self.family in ("ssm",):
            attn = 0  # replaced by the mixer params below
        n_gates = 3 if self.act in ("swiglu", "geglu") else 2
        if self.n_experts:
            ffn = self.n_experts * n_gates * d * self.d_ff
        else:
            ffn = n_gates * d * self.d_ff
        mixer = 0
        if self.family == "ssm":  # rwkv6-ish: r,k,v,g,o + decay/ffn
            mixer = 5 * d * d
        if self.family == "hybrid":  # mamba2-ish in/out proj + ssm params
            mixer = 0  # counted in attn/ffn approximations below
        per_layer = attn + ffn + mixer
        router = self.n_experts * d if self.n_experts else 0
        return emb + L * (per_layer + router + 2 * d)

    def active_param_count(self) -> int:
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        n_gates = 3
        full_ffn = self.n_experts * n_gates * d * self.d_ff
        act_ffn = self.top_k * n_gates * d * self.d_ff
        return self.param_count() - L * (full_ffn - act_ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all():
    # import for registration side effects
    from repro.configs import (  # noqa: F401
        gemma3_4b,
        gemma_7b,
        llava_next_mistral_7b,
        mistral_nemo_12b,
        musicgen_large,
        phi3_5_moe_42b_a6_6b,
        qwen1_5_4b,
        qwen3_moe_235b_a22b,
        rwkv6_1_6b,
        zamba2_7b,
    )


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """The assigned shape cells for this architecture (DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        out.append("long_500k")
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        window_pattern=(64, cfg.window_pattern[1]) if cfg.window_pattern else None,
        shared_attn_period=cfg.shared_attn_period and 3,
        shared_attn_window=256,
    )
