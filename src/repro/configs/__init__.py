from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    applicable_shapes,
    get_config,
    reduced,
    register,
)

__all__ = [
    "SHAPES", "ModelConfig", "ShapeConfig", "all_configs",
    "applicable_shapes", "get_config", "reduced", "register",
]
