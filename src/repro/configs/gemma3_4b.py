"""Gemma3-4B [hf:google/gemma-3-4b-pt, unverified].

34L d_model=2560 8H (GQA kv=4, head_dim=256) d_ff=10240 vocab=262144,
5:1 local:global sliding-window pattern (window 1024), 128k context.
8 heads < 16-way model axis => sequence-parallel attention at train time."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144, act="geglu", rope_theta=1e6,
    window_pattern=(1024, 6),  # layers with (i+1)%6==0 are global
    embed_scale=True, norm_plus_one=True, tie_embeddings=True,
    attn_strategy="sequence",
))
