"""Gemma-7B [arXiv:2403.08295].

28L d_model=3072 16H (kv=16 MHA, head_dim=256) d_ff=24576 vocab=256000,
GeGLU, embeddings scaled by sqrt(d), RMSNorm(1+w)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, act="geglu", rope_theta=1e4,
    embed_scale=True, norm_plus_one=True, tie_embeddings=True,
    attn_strategy="heads",
))
