"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B family].

40L d_model=2560 20H (kv=20 MHA, head_dim=128) d_ff=6912 vocab=151936,
QKV bias.  20 heads is not divisible by the 16-way model axis, so the
train-time attention strategy is sequence-parallel (DESIGN.md §5)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab=151936, act="swiglu", qkv_bias=True, rope_theta=1e6,
    tie_embeddings=True, attn_strategy="sequence",
))
