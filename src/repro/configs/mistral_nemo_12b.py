"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072,
SwiGLU, RoPE theta=1e6, 128k context (full attention)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, act="swiglu", rope_theta=1e6,
    tie_embeddings=False, attn_strategy="heads",
))
