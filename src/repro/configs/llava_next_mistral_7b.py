"""LLaVA-Next (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Backbone: 32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=14336
vocab=32000.  The anyres vision tiling frontend is a STUB: input_specs()
provides precomputed patch embeddings concatenated with token embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, act="swiglu", rope_theta=1e6,
    tie_embeddings=False, attn_strategy="heads", frontend="vision_stub",
))
