"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks.

81 backbone blocks, d_model=3584, 32H shared attention (kv=32),
d_ff=14336, vocab=32000, ssm_state=64.  The shared attention block reuses
one parameter set at every application (Zamba's design); at 500k decode its
KV window is bounded at 32k (DESIGN.md §4)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, act="swiglu", rope_theta=1e4,
    ssm_state=64, shared_attn_period=6, shared_attn_window=32768,
    tie_embeddings=True, attn_strategy="heads",
))
