"""MusicGen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32 MHA, head_dim=64) d_ff=8192 vocab=2048.
The EnCodec modality frontend is a STUB: input_specs() provides
precomputed frame embeddings (per the assignment brief)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, act="swiglu", rope_theta=1e4,
    tie_embeddings=False, attn_strategy="heads", frontend="audio_stub",
))
