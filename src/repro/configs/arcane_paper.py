"""The paper's own simulation configurations (netsim presets, §4.1)."""
from repro.netsim.config import SimConfig

# 128-node 2-tier fat tree, 1:1 oversubscription (the paper's main config)
FATTREE_128 = SimConfig(
    n_hosts=128, hosts_per_tor=16, uplinks_per_tor=16, tiers=2,
)

# 1024-node 2-tier
FATTREE_1024 = SimConfig(
    n_hosts=1024, hosts_per_tor=32, uplinks_per_tor=32, tiers=2,
)

# 128-node 3-tier (fig 18)
FATTREE_128_3T = SimConfig(
    n_hosts=128, hosts_per_tor=16, tiers=3,
    tors_per_pod=2, aggs_per_pod=4, agg_uplinks=4,
)

# 4:1 oversubscribed variant
FATTREE_128_OVERSUB4 = SimConfig(
    n_hosts=128, hosts_per_tor=16, uplinks_per_tor=4, tiers=2,
)

# CI-scale variants (fast defaults for tests/benches on 1 CPU core)
FATTREE_64_CI = SimConfig(
    n_hosts=64, hosts_per_tor=8, uplinks_per_tor=8, tiers=2,
    evs_size=256, queue_capacity=64, init_cwnd_pkts=50, max_cwnd_pkts=100,
    rto_ticks=500, max_msg_pkts=1024,
)
FATTREE_32_CI = SimConfig(
    n_hosts=32, hosts_per_tor=8, uplinks_per_tor=8, tiers=2,
    evs_size=256, queue_capacity=48, init_cwnd_pkts=40, max_cwnd_pkts=80,
    rto_ticks=400, max_msg_pkts=512,
)
