"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-235B-A22B family].

94L d_model=4096 64H (GQA kv=4, head_dim=128), MoE 128 experts top-8 with
per-expert d_ff=1536, vocab=151936."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, act="swiglu", rope_theta=1e6,
    n_experts=128, top_k=8, tie_embeddings=False, attn_strategy="heads",
))
