"""RWKV6-1.6B "Finch" [arXiv:2404.05892] — attention-free, data-dependent
decay.  24L d_model=2048 (32 heads x 64) d_ff=7168 vocab=65536."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=0, head_dim=64,
    d_ff=7168, vocab=65536, act="rwkv_ffn", rope_theta=0.0,
    ssm_state=64, tie_embeddings=False, attn_strategy="heads",
))
