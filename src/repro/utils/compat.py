"""Version-compat shims for the JAX pinned in this container (0.4.x).

Code in this repo targets the modern public API surface; this module maps
the few newer entry points we use onto their older homes so the same source
runs on the container's jax without behavioral drift.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` (new) -> `jax.experimental.shard_map.shard_map` (old).

    The old entry point spells the replication check `check_rep`.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


@jax.custom_vjp
def grad_safe_barrier(tree):
    """`optimization_barrier` that is transparent to autodiff.

    Older jax has no differentiation rule for the barrier primitive; the
    barrier is semantically the identity, so the VJP passes cotangents
    through untouched while the primal keeps the scheduling barrier.
    """
    return jax.lax.optimization_barrier(tree)


def _barrier_fwd(tree):
    return grad_safe_barrier(tree), None


def _barrier_bwd(_, cotangent):
    return (cotangent,)


grad_safe_barrier.defvjp(_barrier_fwd, _barrier_bwd)
