"""Pytree-registered dataclasses (a tiny flax.struct analogue).

Fields are array ("data") fields by default; static configuration fields are
declared with ``static_field()`` and become part of the pytree treedef, so
they may be used in Python control flow inside jitted code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, TypeVar

import jax

_T = TypeVar("_T")

_STATIC_MARK = "__repro_static__"


def static_field(default: Any = dataclasses.MISSING, **kwargs):
    """Declare a dataclass field as static (hashable treedef metadata)."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata[_STATIC_MARK] = True
    if default is dataclasses.MISSING:
        return dataclasses.field(metadata=metadata, **kwargs)
    return dataclasses.field(default=default, metadata=metadata, **kwargs)


def pytree_dataclass(cls: type[_T]) -> type[_T]:
    """Decorator: freeze the dataclass and register it as a JAX pytree."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    data_fields = []
    meta_fields = []
    for f in dataclasses.fields(cls):
        if f.metadata.get(_STATIC_MARK, False):
            meta_fields.append(f.name)
        else:
            data_fields.append(f.name)
    jax.tree_util.register_dataclass(
        cls, data_fields=tuple(data_fields), meta_fields=tuple(meta_fields)
    )

    def replace(self: _T, **updates: Any) -> _T:
        return dataclasses.replace(self, **updates)

    cls.replace = replace  # type: ignore[attr-defined]
    return cls
