from repro.utils.compat import grad_safe_barrier, shard_map
from repro.utils.struct import pytree_dataclass, static_field

__all__ = [
    "grad_safe_barrier", "shard_map", "pytree_dataclass", "static_field",
]
