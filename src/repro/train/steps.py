"""Jittable train / prefill / decode steps.

train_step: value_and_grad over the model loss with mixed precision
(fp32 master weights cast to bf16 for fwd/bwd), optional microbatch
gradient accumulation (a lax.scan over microbatches — the standard
memory/throughput knob), AdamW update.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.common import cast_tree
from repro.utils.compat import grad_safe_barrier
from repro.models.model_zoo import Model
from repro.train.optimizer import AdamWConfig, apply_updates, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: Optional[str] = None  # None | "dots"
    microbatches: int = 1  # gradient-accumulation factor


def make_train_step(model: Model, tcfg: TrainConfig):
    def loss_of(params, batch):
        p = cast_tree(params, tcfg.compute_dtype)
        # Force the bf16 working copy to materialize ONCE per step: without
        # the barrier XLA sinks the convert into the layer scan, and every
        # layer iteration re-reads the full fp32 parameter stack (measured
        # 59.5 GB/iteration on qwen3-moe — EXPERIMENTS.md §Perf iter 2).
        p = grad_safe_barrier(p)
        b = dict(batch)
        if "embeds" in b:
            b["embeds"] = b["embeds"].astype(tcfg.compute_dtype)
        loss, metrics = model.loss_fn(
            p, b, remat=tcfg.remat, remat_policy=tcfg.remat_policy
        )
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            n = tcfg.microbatches
            split = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
            )

            def mb(carry, b):
                acc, lsum = carry
                (loss, _), g = grad_fn(params, b)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(mb, (zeros, 0.0), split)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        params, opt_state, opt_metrics = apply_updates(
            tcfg.opt, params, grads, opt_state
        )
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_serve_steps(model: Model):
    """Returns (prefill_step, decode_step) for batched serving."""

    def prefill_step(params, batch, max_len: int):
        p = cast_tree(params, jnp.bfloat16)
        return model.prefill_fn(p, batch, max_len)

    def decode_step(params, state, tokens, cache_len):
        p = cast_tree(params, jnp.bfloat16)
        logits, state = model.decode_fn(p, state, tokens, cache_len)
        return logits, state, cache_len + 1

    return prefill_step, decode_step


def init_train_state(model: Model, key, dtype=jnp.float32):
    params = model.init_params(key, dtype)
    return params, init_opt_state(params)
