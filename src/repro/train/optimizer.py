"""AdamW with global-norm clipping and fp32 moments (optax-free, explicit
pytrees so optimizer-state shardings mirror the parameter shardings)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, frac)


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def opt_state_axes(param_axes) -> dict[str, Any]:
    return {"m": param_axes, "v": param_axes, "step": ()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, opt_state):
    """One AdamW step. Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
