from repro.train import optimizer, steps
from repro.train.optimizer import AdamWConfig, apply_updates, init_opt_state, opt_state_axes
from repro.train.steps import TrainConfig, init_train_state, make_serve_steps, make_train_step

__all__ = [
    "optimizer", "steps", "AdamWConfig", "apply_updates", "init_opt_state",
    "opt_state_axes", "TrainConfig", "init_train_state", "make_serve_steps",
    "make_train_step",
]
