"""repro: REPS (Recycled Entropy Packet Spraying) reproduced as a
production-grade JAX framework.

Layers:
  repro.core      - the paper's algorithm (REPS) + baseline load balancers
                    + the recycled balls-into-bins theory models (Section 5)
  repro.kernels   - Pallas TPU kernels for the datapath hot spots
  repro.netsim    - packet-level fat-tree network simulator (htsim analogue)
  repro.models    - the 10 assigned LM-family architectures
  repro.configs   - architecture configs (--arch <id>) + paper sim configs
  repro.train     - optimizer / train_step / serve (prefill+decode) steps
  repro.data      - deterministic shard-aware data pipeline
  repro.checkpoint- sharded checkpoint save/restore + elastic resharding
  repro.ft        - fault tolerance; REPS-scheduled cross-pod channels
  repro.launch    - mesh / dry-run / roofline / train / serve entry points
"""

__version__ = "1.0.0"
