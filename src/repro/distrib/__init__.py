from repro.distrib import sharding
from repro.distrib.sharding import mesh_rules, resolve_spec, shard

__all__ = ["sharding", "mesh_rules", "resolve_spec", "shard"]
