"""Logical-axis sharding rules (the control surface for distribution).

Models annotate activations/params with *logical* axes ("batch", "seq",
"heads", "embed", "mlp", "experts", "vocab", "kv_seq", "stage", ...).  A
rule table maps logical axes to mesh axes; `shard()` applies
`with_sharding_constraint` when a mesh is active, and is a no-op otherwise
(single-device smoke tests / examples run the same code).

The rule table is deliberately swappable — §Perf hillclimbing iterates on
it without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Baseline rule set (paper-faithful starting point: pure DP over pod+data,
# TP/EP over model — the standard megatron-style mapping).
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_model": "model",  # sequence-parallel attention (low-head archs)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": "model",  # flash-decode: KV cache sharded along sequence
    "mlp": "model",
    "experts": "model",
    "expert_cap": None,
    "vocab": "model",
    "head_dim": None,  # fsdp: ("data",)
    "moe_fsdp": None,  # fsdp: ("data",)
    "qkv": None,
    "state": "model",  # SSM/RWKV channel-parallel state
    "layers": None,
}

_local = threading.local()


def _ctx():
    if not hasattr(_local, "mesh"):
        _local.mesh = None
        _local.rules = dict(DEFAULT_RULES)
    return _local


@contextlib.contextmanager
def mesh_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + rule table for `shard()` calls in this thread."""
    c = _ctx()
    prev = (c.mesh, c.rules)
    c.mesh = mesh
    c.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        yield
    finally:
        c.mesh, c.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx().mesh


def resolve_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Map logical axis names to a PartitionSpec under the active rules,
    dropping mesh axes that don't exist in the active mesh.  When `shape`
    is given, mesh axes that don't divide the dimension are dropped (e.g.
    8 KV heads can't shard 16-ways; batch=1 long-context cells can't
    data-parallel)."""
    c = _ctx()
    mesh = c.mesh
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    out = []
    used: set[str] = set()  # a mesh axis may shard at most one dim
    for i, ax in enumerate(logical_axes):
        if ax is None:
            out.append(None)
            continue
        rule = c.rules.get(ax, None)
        if rule is None:
            kept: tuple[str, ...] = ()
        elif isinstance(rule, str):
            kept = (rule,) if rule in mesh_axes else ()
        else:
            kept = tuple(r for r in rule if r in mesh_axes)
        kept = tuple(r for r in kept if r not in used)
        if shape is not None and kept:
            dim = shape[i]
            while kept:
                total = 1
                for r in kept:
                    total *= mesh.shape[r]
                if dim % total == 0:
                    break
                kept = kept[:-1]  # drop minor-most mesh axis until divisible
        used.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain `x` to the sharding implied by its logical axes."""
    c = _ctx()
    if c.mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = resolve_spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(c.mesh, spec))


def named_sharding(*logical_axes: Optional[str], shape=None) -> Optional[NamedSharding]:
    c = _ctx()
    if c.mesh is None:
        return None
    return NamedSharding(c.mesh, resolve_spec(logical_axes, shape))


# ---------------------------------------------------------------------------
# Sweep-row sharding (netsim/sweep.py): independent scenario rows sharded
# over a flat 1-D device mesh.  On CPU CI the device axis is materialized
# with XLA_FLAGS=--xla_force_host_platform_device_count=N.
# ---------------------------------------------------------------------------
SWEEP_AXIS = "rows"
# Second mesh axis for conn-sharded scale mode (SimConfig.conn_sharding):
# the *connection* axis of per-conn state shards over it under shard_map —
# see Simulator.step_scenario(conn_axis=...) and ARCHITECTURE.md §10.
CONN_AXIS = "conns"


def sweep_mesh(max_devices: Optional[int] = None) -> Optional[Mesh]:
    """1-D mesh over the available devices for row-parallel sweeps, or None
    when only one device is visible (callers then skip shard_map)."""
    import numpy as np

    devs = jax.devices()
    n = len(devs) if max_devices is None else max(1, min(max_devices, len(devs)))
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), (SWEEP_AXIS,))


def sweep_conn_mesh(
    conn_devices: int, max_devices: Optional[int] = None
) -> Mesh:
    """2-D ``(rows, conns)`` mesh for conn-sharded sweeps: row-parallel
    scenario rows on the major axis, the connection state axis sharded over
    the minor ``CONN_AXIS``.  Raises when fewer than ``conn_devices``
    devices are visible (conn sharding cannot silently degrade — results
    would still be bit-identical, but the memory contract would not hold).
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs) if max_devices is None else max(1, min(max_devices, len(devs)))
    conn_devices = int(conn_devices)
    assert conn_devices >= 1
    if conn_devices > n:
        raise ValueError(
            f"conn_devices={conn_devices} exceeds the {n} visible devices "
            "(on CPU CI materialize more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    rows = n // conn_devices
    grid = np.asarray(devs[: rows * conn_devices]).reshape(rows, conn_devices)
    return Mesh(grid, (SWEEP_AXIS, CONN_AXIS))


def pad_rows(n_rows: int, mesh: Optional[Mesh]) -> int:
    """Row count after padding to a multiple of the sweep mesh size."""
    if mesh is None:
        return n_rows
    n_dev = mesh.shape[SWEEP_AXIS]
    return ((n_rows + n_dev - 1) // n_dev) * n_dev


def mesh_platform(mesh: Optional[Mesh]) -> str:
    """Platform ("cpu" / "tpu" / "gpu") of the devices a sweep runs on:
    the mesh's devices when one is active, the default backend otherwise."""
    if mesh is not None:
        return mesh.devices.flat[0].platform
    return jax.default_backend()


def resolve_kernels_backend(backend: str, mesh: Optional[Mesh] = None) -> str:
    """THE resolution rule for ``SimConfig.kernels_backend="auto"`` —
    every consumer (Simulator at trace time, FleetRunner at construction,
    SweepEngine against its row mesh) routes through here so the choice
    can never diverge between layers: compiled Pallas kernels on TPU
    devices, the jnp formulations elsewhere (forcing ``"pallas"`` off-TPU
    runs the kernels under ``interpret=True``)."""
    assert backend in ("auto", "jnp", "pallas"), backend
    if backend == "auto":
        return "pallas" if mesh_platform(mesh) == "tpu" else "jnp"
    return backend
