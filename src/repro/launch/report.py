"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report > results/roofline_tables.md
"""
from __future__ import annotations

import glob
import json


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def load(mesh: str) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(f"results/dryrun/*__{mesh}.json")):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


MOVE_HINTS = {
    "memory": "cut HBM traffic (fuse flash chains / bf16 intermediates / "
    "chunk-size tuning / fewer resharding copies)",
    "collective": "reduce or overlap collectives (reshard once per layer, "
    "reduce-scatter instead of all-reduce, batch FSDP gathers)",
    "compute": "raise MFU (remove remat recompute via policy, larger "
    "microbatches, MXU-aligned tiles)",
}


def table(mesh: str) -> str:
    recs = load(mesh)
    out = [
        f"### Mesh {mesh} ({recs[0]['n_devices'] if recs else '?'} chips)",
        "",
        "| arch | shape | rules/mb | compile | peak GB | t_comp | t_mem "
        "(floor) | t_coll | bottleneck | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        out.append(
            "| {arch} | {shape} | {rules}/{mb} | {c:.0f}s | {peak:.1f} | {tc} "
            "| {tm} ({tmm}) | {tl} | {b} | {u:.2f} | {rf:.3f} |".format(
                arch=r["arch"],
                shape=r["shape"],
                rules=r["rules"],
                mb=r["microbatches"],
                c=r["compile_s"],
                peak=r["memory"]["peak_live_gb"],
                tc=fmt_s(r["t_compute_s"]),
                tm=fmt_s(r["t_memory_s"]),
                tmm=fmt_s(r.get("t_memory_min_s", 0.0)),
                tl=fmt_s(r["t_collective_s"]),
                b=r["bottleneck"],
                u=r["useful_flops_ratio"],
                rf=r["roofline_fraction"],
            )
        )
    out.append("")
    return "\n".join(out)


def bottleneck_notes(mesh: str) -> str:
    recs = load(mesh)
    out = ["#### Dominant-term notes (one per cell)", ""]
    for r in recs:
        out.append(
            f"- **{r['arch']} × {r['shape']}**: {r['bottleneck']}-bound "
            f"(t={fmt_s(max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s']))}); "
            f"to move it: {MOVE_HINTS[r['bottleneck']]}."
        )
    out.append("")
    return "\n".join(out)


def main():
    for mesh in ["pod16x16", "pod2x16x16"]:
        print(table(mesh))
    print(bottleneck_notes("pod16x16"))


if __name__ == "__main__":
    main()
