# NOTE: repro.launch.dryrun must be imported/run FIRST in a process when the
# 512-device dry-run is wanted (it sets XLA_FLAGS before jax init).
from repro.launch import mesh, roofline

__all__ = ["mesh", "roofline"]
