"""Trip-count-aware cost analysis over post-optimization HLO text.

Why this exists: ``compiled.cost_analysis()`` counts every computation ONCE,
so anything under a ``while`` (every ``lax.scan`` — our layer stacks, flash-
attention KV chunks, SSM chunk scans, microbatch accumulation) is
undercounted by its trip count (verified experimentally: a scan of 8
matmuls reports 1/8 of the unrolled FLOPs).  XLA *does* annotate
``known_trip_count`` on while ops, so we walk the module call graph —
ENTRY plus (transitively) while bodies/conditions, multiplying trip
counts — and accumulate per-op costs:

  * FLOPs: ``dot`` ops (2 x prod(result dims) x prod(contracted lhs dims));
    convolutions likewise.  Elementwise FLOPs are ignored (matmul-dominated
    models; documented).
  * HBM bytes: operand + result bytes of every op except free ops
    (parameter/constant/tuple/get-tuple-element/bitcast) — mirroring XLA's
    own per-op accounting.  Fusion bodies and reducer computations are NOT
    traversed (their internals live in registers); the fusion op's own
    operands/results are the HBM traffic.
  * Collective bytes: per-kind ring-model factors (see roofline.py).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_COLL_FACTORS = {
    "all-reduce": ("operand", 2.0),
    "all-gather": ("result", 1.0),
    "reduce-scatter": ("operand", 1.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPKIND_RE = re.compile(r"^((?:\([^)]*\))|(?:[\w\[\],{}]+))\s+([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*\))?\s*->[^{]*\{|^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\{")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"(?:body|condition)=%?([\w\.\-]+)")


def _shape_info(text: str) -> tuple[int, list[list[int]]]:
    """Total bytes and list of dim-lists for every shape literal in text."""
    total = 0
    dims_all = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        dims_all.append(ds)
    return total, dims_all


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0  # operands + results (prescribed; CPU-fusion UB)
    bytes_min: float = 0.0  # 2 x result bytes (perfect-fusion floor)
    coll_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(default_factory=dict)
    warnings: list = dataclasses.field(default_factory=list)


class _Op:
    __slots__ = ("name", "kind", "result_text", "rest", "line")

    def __init__(self, name, kind, result_text, rest, line):
        self.name = name
        self.kind = kind
        self.result_text = result_text
        self.rest = rest
        self.line = line


def _parse_computations(txt: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur: list[_Op] | None = None
    name = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY") or stripped.startswith("%")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
                if m:
                    name = m.group(1)
                    cur = []
            continue
        if stripped == "}" or stripped.startswith("}"):
            comps[name] = cur
            cur = None
            continue
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        opname, rhs = m.group(1), m.group(2)
        km = _OPKIND_RE.match(rhs)
        if not km:
            continue
        cur.append(_Op(opname, km.group(2), km.group(1), km.group(3), stripped))
    return comps


_CALLS_ATTR_RE = re.compile(r"calls=%?([\w\.\-]+)")


def _dus_inplace_bytes(op: _Op, table: dict, comps: dict) -> float | None:
    """In-place update traffic for dynamic-update-slice (and fusions whose
    root is a DUS): XLA aliases the target buffer, so real HBM traffic is
    ~2x the *update slice*, not the whole target.  Returns corrected bytes
    or None when the pattern doesn't apply.

    Without this, every lax.scan's per-iteration ys-stacking write counts
    the full (L, ...) stacked array each iteration — an L-fold overcount
    (measured: 155 TB -> ~10 TB on qwen3-moe train)."""
    roots: list[_Op] = []
    if op.kind == "dynamic-update-slice":
        roots = [op]
        inner_table = table
    elif op.kind == "fusion":
        m = _CALLS_ATTR_RE.search(op.line)
        if not m or m.group(1) not in comps:
            return None
        body = comps[m.group(1)]
        if not body:
            return None
        root = body[-1]
        inner_table = {o.name: o.result_text for o in body}
        if root.kind == "dynamic-update-slice":
            roots = [root]
        elif root.kind == "tuple":
            names = _OPERAND_RE.findall(root.rest)
            cand = [o for o in body if o.name in names]
            if cand and all(o.kind == "dynamic-update-slice" for o in cand):
                roots = cand
        if not roots:
            return None
    else:
        return None

    total = 0.0
    for r in roots:
        ops_ = _OPERAND_RE.findall(r.rest)
        if len(ops_) < 2:
            return None
        upd = ops_[1]  # (target, update, indices...)
        ub = _shape_info(inner_table.get(upd, ""))[0]
        if ub == 0:
            return None
        total += 2.0 * ub
    return total


def _fusion_operand_bytes(op: _Op, table: dict, comps: dict) -> float | None:
    """Operand traffic of a fusion, correcting for internal dynamic-slice:
    a fusion that takes the full stacked (L, ...) array but only reads one
    layer's slice (every lax.scan body does this for its xs) touches the
    slice, not the array.  Returns corrected operand bytes or None."""
    m = _CALLS_ATTR_RE.search(op.line)
    if not m or m.group(1) not in comps:
        return None
    body = comps[m.group(1)]
    if not body:
        return None
    inner = {o.name: o for o in body}
    # map parameter index -> param op name
    params = {}
    for o in body:
        if o.kind == "parameter":
            pm = re.search(r"parameter\((\d+)\)", o.line)
            if pm:
                params[int(pm.group(1))] = o.name
    # consumers of each param
    consumers: dict[str, list[_Op]] = {}
    for o in body:
        if o.kind == "parameter":
            continue
        for ref in _OPERAND_RE.findall(o.rest):
            if ref in inner and inner[ref].kind == "parameter":
                consumers.setdefault(ref, []).append(o)
    operands = _OPERAND_RE.findall(op.rest)
    total = 0.0
    for i, name in enumerate(operands):
        full = _shape_info(table.get(name, ""))[0]
        pname = params.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.kind == "dynamic-slice" for c in cons):
            total += sum(_shape_info(c.result_text)[0] for c in cons)
        else:
            total += full
    return total


def _dot_flops(op: _Op, shapes: dict[str, str]) -> float:
    result_bytes, result_dims = _shape_info(op.result_text)
    n_out = 1
    for ds in result_dims:
        for d in ds:
            n_out *= d
    # contracted dims from lhs shape + lhs_contracting_dims
    operands = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    lhs_shape_text = shapes.get(operands[0], "") if operands else ""
    _, lhs_dims = _shape_info(lhs_shape_text)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contracted = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx != "" and int(idx) < len(lhs_dims[0]):
                contracted *= lhs_dims[0][int(idx)]
    return 2.0 * n_out * contracted


def analyze_hlo(txt: str) -> HloCost:
    comps = _parse_computations(txt)
    # result-shape symbol table per computation
    shape_of: dict[str, dict[str, str]] = {
        cname: {op.name: op.result_text for op in ops}
        for cname, ops in comps.items()
    }

    cost = HloCost(coll_breakdown=defaultdict(float))
    entry = None
    for cname in comps:
        if cname.startswith("main") or cname == "main":
            entry = cname
    if entry is None:  # fall back: computation named ENTRY parse missed
        entry = max(comps, key=lambda c: len(comps[c]))
        cost.warnings.append(f"entry guess: {entry}")

    seen_stack = set()

    def walk(cname: str, scale: float):
        if cname not in comps or cname in seen_stack:
            return
        seen_stack.add(cname)
        table = shape_of[cname]
        for op in comps[cname]:
            kind = op.kind
            if kind == "while":
                m = _TRIP_RE.search(op.line)
                trips = float(m.group(1)) if m else 1.0
                if not m:
                    cost.warnings.append(f"no trip_count: {op.name}")
                for sub in _CALLS_RE.findall(op.line):
                    walk(sub, scale * trips)
                continue
            if kind in _FREE_OPS:
                continue
            if kind.startswith("conditional"):
                for sub in re.findall(r"%([\w\.\-]+)", op.line.split("branch_computations")[-1]):
                    if sub in comps:
                        walk(sub, scale)
            # bytes: result + operands (looked up); corrected for in-place
            # DUS writes and fusion-internal dynamic-slice reads
            inplace = _dus_inplace_bytes(op, table, comps)
            rb, _ = _shape_info(op.result_text)
            if inplace is not None:
                cost.bytes_accessed += scale * inplace
                cost.bytes_min += scale * inplace
            else:
                ob_corr = None
                if op.kind == "fusion":
                    ob_corr = _fusion_operand_bytes(op, table, comps)
                elif op.kind == "dynamic-slice":
                    ob_corr = float(rb)  # reads only the slice
                if ob_corr is None:
                    ob_corr = 0.0
                    for operand in _OPERAND_RE.findall(op.rest):
                        if operand in table:
                            ob_corr += _shape_info(table[operand])[0]
                cost.bytes_accessed += scale * (rb + ob_corr)
                cost.bytes_min += scale * 2.0 * rb
            # flops
            if kind == "dot":
                cost.flops += scale * _dot_flops(op, table)
            elif kind == "convolution":
                rb, rd = _shape_info(op.result_text)
                cost.flops += scale * 2.0 * (rb / max(_DTYPE_BYTES.get("f32", 4), 1))
            # collectives
            base_kind = kind.replace("-start", "").replace("-done", "")
            if base_kind in _COLL_FACTORS and not kind.endswith("-done"):
                side, factor = _COLL_FACTORS[base_kind]
                if side == "result":
                    cb, _ = _shape_info(op.result_text)
                else:
                    cb = 0
                    for operand in _OPERAND_RE.findall(op.rest):
                        if operand in table:
                            ob, _ = _shape_info(table[operand])
                            cb += ob
                    if cb == 0:
                        cb, _ = _shape_info(op.result_text)
                moved = scale * cb * factor
                cost.coll_bytes += moved
                cost.coll_breakdown[base_kind] += moved
        seen_stack.discard(cname)

    walk(entry, 1.0)
    cost.coll_breakdown = dict(cost.coll_breakdown)
    return cost


def breakdown(txt: str) -> list[tuple[str, float, float, float]]:
    """Per-(computation, op-kind) cost rows scaled by trip count:
    [(comp/op_kind, trips, flops, bytes)] sorted by bytes desc — the
    §Perf profiling view."""
    comps = _parse_computations(txt)
    shape_of = {
        c: {op.name: op.result_text for op in ops} for c, ops in comps.items()
    }
    entry = None
    for cname in comps:
        if cname.startswith("main"):
            entry = cname
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c]))
    rows: dict[tuple[str, str], list[float]] = {}

    def walk(cname, scale, stack=()):
        if cname not in comps or cname in stack:
            return
        table = shape_of[cname]
        for op in comps[cname]:
            if op.kind == "while":
                m = _TRIP_RE.search(op.line)
                trips = float(m.group(1)) if m else 1.0
                for sub in _CALLS_RE.findall(op.line):
                    walk(sub, scale * trips, stack + (cname,))
                continue
            if op.kind in _FREE_OPS:
                continue
            inplace = _dus_inplace_bytes(op, table, comps)
            rb, _ = _shape_info(op.result_text)
            if inplace is not None:
                b = inplace
            else:
                ob_corr = None
                if op.kind == "fusion":
                    ob_corr = _fusion_operand_bytes(op, table, comps)
                elif op.kind == "dynamic-slice":
                    ob_corr = float(rb)
                if ob_corr is None:
                    ob_corr = 0.0
                    for operand in _OPERAND_RE.findall(op.rest):
                        if operand in table:
                            ob_corr += _shape_info(table[operand])[0]
                b = rb + ob_corr
            fl = _dot_flops(op, table) if op.kind == "dot" else 0.0
            key = (cname, op.kind)
            cur = rows.setdefault(key, [scale, 0.0, 0.0])
            cur[1] += scale * fl
            cur[2] += scale * b

    walk(entry, 1.0)
    out = [(f"{c}/{k}", v[0], v[1], v[2]) for (c, k), v in rows.items()]
    return sorted(out, key=lambda r: -r[3])
