"""Roofline-term extraction from compiled dry-run artifacts.

Per the brief (EXPERIMENTS.md §Roofline):

    compute    = device_FLOPs / PEAK_FLOPS
    memory     = device_bytes / HBM_BW
    collective = device_collective_bytes_moved / LINK_BW

``compiled.cost_analysis()`` reports per-device (post-SPMD) FLOPs and bytes.
Collective bytes are NOT in cost_analysis; we parse the post-optimization
HLO and sum shape bytes of every collective op, with per-op ring-algorithm
byte-movement factors:

    all-reduce        2 x operand bytes        (reduce-scatter + all-gather)
    all-gather        1 x result bytes         ((n-1)/n ~ 1)
    reduce-scatter    1 x operand bytes
    all-to-all        1 x operand bytes
    collective-permute 1 x operand bytes

Hardware constants (TPU v5e, per the brief): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.  The pod axis actually rides DCN (slower); the
uniform 50 GB/s figure therefore *understates* the multi-pod collective
term — flagged in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re

import jax

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = {
    "all-reduce": ("operand", 2.0),
    "all-gather": ("result", 1.0),
    "reduce-scatter": ("operand", 1.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum byte-movement per collective kind from post-optimization HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        for kind, (side, factor) in _COLLECTIVES.items():
            # match "= <shape> kind(" — op use, not metadata mentions
            m = re.search(rf"=\s+(.*?)\s+{kind}(?:-start|-done)?\(", ls)
            if not m:
                continue
            if kind == "all-reduce" and re.search(r"all-reduce-done\(", ls):
                continue  # bytes counted at -start
            result_part = m.group(1)
            operand_part = ls[m.end():]
            text = result_part if side == "result" else operand_part
            b = _shape_bytes(text)
            if side == "operand" and b == 0:  # operand may be a %ref; fall back
                b = _shape_bytes(result_part)
            out[kind] += b * factor
            out["count"] += 1
            break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device HLO bytes accessed
    coll_bytes: float  # per-device collective bytes moved (factored)
    coll_breakdown: dict
    n_devices: int
    model_flops: float  # 6*N*D (global, dense/active)
    hbm_bytes_min: float = 0.0  # perfect-fusion floor (2 x result bytes)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops (remat/redundancy waste)."""
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful compute time / achievable step time (max of terms)."""
        t_useful = self.model_flops / self.n_devices / PEAK_FLOPS
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_step if t_step else 0.0

    @property
    def t_memory_min(self) -> float:
        return self.hbm_bytes_min / HBM_BW

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_memory_min_s": self.t_memory_min,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, n_devices: int, model_flops: float) -> Roofline:
    """Trip-count-aware analysis of the compiled HLO (repro.launch.hlo_cost).

    ``compiled.cost_analysis()`` counts while bodies once, undercounting
    every lax.scan by its trip count; our analyzer walks ENTRY + while
    bodies with ``known_trip_count`` scaling (validated against unrolled
    references in tests/test_roofline.py)."""
    from repro.launch.hlo_cost import analyze_hlo

    hc = analyze_hlo(compiled.as_text())
    return Roofline(
        flops=hc.flops,
        hbm_bytes=hc.bytes_accessed,
        coll_bytes=hc.coll_bytes,
        coll_breakdown=hc.coll_breakdown,
        n_devices=n_devices,
        model_flops=model_flops,
        hbm_bytes_min=hc.bytes_min,
    )


_COUNT_CACHE: dict = {}


def exact_param_counts(cfg) -> tuple[float, float, float]:
    """(matmul-active params, expert params total, shared-block params),
    counted from the real init via eval_shape (no allocation).

    "matmul-active" excludes the embedding table gather but includes the
    LM head (tied embeddings still pay the logits matmul)."""
    if cfg.name in _COUNT_CACHE:
        return _COUNT_CACHE[cfg.name]
    import numpy as np

    from repro.models.model_zoo import build_model

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init_params(k), jax.random.PRNGKey(0))
    total = expert = shared = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = float(np.prod(leaf.shape))
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if keys == "embed":
            if cfg.tie_embeddings:
                total += n  # logits matmul reuses the table
            continue
        total += n
        if "/moe/w" in keys or keys.endswith(("moe/w1", "moe/w3", "moe/w2")):
            expert += n
        if keys.startswith("shared/"):
            shared += n
    _COUNT_CACHE[cfg.name] = (total, expert, shared)
    return total, expert, shared


def model_flops_for(cfg, shape) -> float:
    """Useful model FLOPs for the cell: 2*N_active*D per forward pass
    (+ attention score/value FLOPs, which 6ND omits and which dominate at
    32k context), x3 for training (bwd ~ 2x fwd).

    MoE: only top_k/n_experts of the expert store is active per token.
    Zamba: the shared block's params are *applied* n_groups times."""
    total, expert, shared = exact_param_counts(cfg)
    n_active = total - expert * (1.0 - cfg.top_k / max(cfg.n_experts, 1)) if cfg.n_experts else total
    if cfg.shared_attn_period:
        groups = cfg.n_layers // cfg.shared_attn_period
        n_active += shared * (groups - 1)

    B, S = shape.global_batch, shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    tokens = B * S if shape.kind in ("train", "prefill") else B

    # attention score+value flops (causal ~ S/2 average context)
    attn = 0.0
    if cfg.n_kv_heads or cfg.shared_attn_period:
        H, hd = cfg.n_heads, cfg.head_dim
        if cfg.shared_attn_period:
            n_attn_layers = cfg.n_layers // cfg.shared_attn_period
        else:
            n_attn_layers = cfg.n_layers
        if shape.kind in ("train", "prefill"):
            if cfg.window_pattern:
                w, period = cfg.window_pattern
                ctx_local = min(w, S)
                n_glob = cfg.n_layers // period
                n_loc = cfg.n_layers - n_glob
                attn = 4.0 * B * H * hd * S * (
                    n_glob * (S / 2) + n_loc * ctx_local
                )
            else:
                ctx = min(S, getattr(cfg, "shared_attn_window", S)) if cfg.shared_attn_period else S
                attn = 4.0 * B * H * hd * S * (ctx / 2) * n_attn_layers
        else:  # decode: one token attends over the cache
            ctx = min(S, cfg.shared_attn_window) if cfg.shared_attn_period else S
            attn = 4.0 * B * H * hd * ctx * n_attn_layers

    return mult * (2.0 * n_active * tokens + attn)
