"""Training launcher: real training on local devices (CPU here), with
checkpoint/restart, straggler watchdog, and optional REPS channel
scheduling telemetry for the cross-pod axis.

    PYTHONPATH=src python -m repro.launch.train --arch mistral-nemo-12b \
        --reduced --steps 50 --batch 8 --seq 128 [--ckpt-dir ckpts] [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_config, reduced as reduce_cfg
from repro.data import SyntheticLM
from repro.ft import StepWatchdog
from repro.models import build_model
from repro.train import (
    AdamWConfig,
    TrainConfig,
    init_train_state,
    make_train_step,
)
from repro.train.optimizer import opt_state_axes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=5, decay_steps=max(args.steps, 10)),
        microbatches=args.microbatches,
    )
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
    if args.resume and args.ckpt_dir:
        path = ckpt.latest(args.ckpt_dir)
        if path:
            restored, start = ckpt.restore(path, {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            print(f"resumed from {path} at step {start}")

    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=17)
    watchdog = StepWatchdog()
    pending = None
    for i in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.shard_batch(i).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        if watchdog.observe(dt):
            print(f"step {i}: WATCHDOG straggling steps detected")
        if i % 5 == 0 or i == args.steps - 1:
            print(
                f"step {i:4d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
            )
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            if pending:
                pending.join()
            pending = ckpt.save_async(
                f"{args.ckpt_dir}/step_{i+1}", i + 1,
                {"params": params, "opt": opt},
            )
    if pending:
        pending.join()
    print(f"done; loss floor (markov entropy) = {data.entropy_floor():.3f}")


if __name__ == "__main__":
    main()
