"""Serving launcher: batched prefill + decode on local devices.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import build_model
from repro.train import make_serve_steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.bfloat16)
    prefill_step, decode_step = make_serve_steps(model)
    max_len = args.prompt_len + args.gen

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    logits, cache, cache_len = jax.jit(
        prefill_step, static_argnums=(2,)
    )(params, {"tokens": prompts}, max_len)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    decode = jax.jit(decode_step, donate_argnums=(1,))
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache, cache_len = decode(params, cache, toks, cache_len)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.0f}ms")
    print(
        f"decode {args.gen-1} steps: {t_decode*1e3:.0f}ms "
        f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)"
    )
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
