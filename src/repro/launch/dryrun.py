import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on placeholder devices; record memory_analysis / cost_analysis /
roofline terms.  (The two lines above MUST run before any other import —
jax locks the device count at first init.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
        --shape train_4k [--multi-pod] [--rules baseline]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results append to results/dryrun/<arch>__<shape>__<mesh>[__<rules>].json.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_configs, applicable_shapes, get_config
from repro.distrib import sharding as shd
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.train import TrainConfig, make_train_step
from repro.train.optimizer import opt_state_axes

RESULTS_DIR = "results/dryrun"

# Per-arch defaults used by --all: (rules, train microbatches).  Chosen so
# every baseline cell fits 16 GB/chip (see EXPERIMENTS.md §Dry-run).
ARCH_DEFAULTS = {
    "mistral-nemo-12b": ("fsdp", 8),
    "gemma-7b": ("fsdp", 8),
    "qwen1.5-4b": ("fsdp", 4),
    "gemma3-4b": ("fsdp", 4),
    "qwen3-moe-235b-a22b": ("fsdp", 16),
    "phi3.5-moe-42b-a6.6b": ("fsdp", 8),
    "musicgen-large": ("fsdp", 4),
    "rwkv6-1.6b": ("fsdp", 4),
    "zamba2-7b": ("fsdp", 8),
    "llava-next-mistral-7b": ("fsdp", 8),
}

# Named rule-table variants (hillclimb levers; EXPERIMENTS.md §Perf).
RULE_SETS: dict[str, dict] = {
    "baseline": {},
    # fsdp: secondary sharding of params/optimizer over the data axis
    # (ZeRO-3 style) — GSPMD all-gathers weights at use; the MoE layer
    # gathers its expert store explicitly inside shard_map.
    "fsdp": {
        "embed": ("data",),
        "head_dim": ("data",),
        "moe_fsdp": ("data",),
    },
    # seq-activations: also shard long activations along sequence between
    # attention blocks (reduces HBM term for long-context cells).
    "seq_act": {"seq": ("model",)},
}


def axes_to_shardings(mesh, axes_tree, like_tree=None, rules=None):
    """Resolve a logical-axis tree to NamedShardings; with `like_tree`
    (matching ShapeDtypeStructs) indivisible mesh axes are dropped."""
    is_ax = lambda x: isinstance(x, tuple)
    with shd.mesh_rules(mesh, rules):
        if like_tree is None:
            return jax.tree.map(
                lambda ax: jax.sharding.NamedSharding(mesh, shd.resolve_spec(ax)),
                axes_tree,
                is_leaf=is_ax,
            )
        flat_ax = jax.tree.leaves(axes_tree, is_leaf=is_ax)
        flat_like, treedef = jax.tree.flatten(like_tree)
        assert len(flat_ax) == len(flat_like), "axes/like tree mismatch"
        shards = [
            jax.sharding.NamedSharding(mesh, shd.resolve_spec(ax, l.shape))
            for ax, l in zip(flat_ax, flat_like)
        ]
        return jax.tree.unflatten(treedef, shards)


def dryrun_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    rules_name: str = "baseline",
    microbatches: int = 1,
    remat_policy=None,
    save: bool = True,
    verbose: bool = True,
):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rules = RULE_SETS[rules_name]
    t0 = time.time()

    # training keeps fp32 master weights; serving stores bf16 weights
    p_dtype = jnp.float32 if shape.kind == "train" else jnp.bfloat16
    with shd.mesh_rules(mesh, rules):
        p_axes = model.param_axes()
        params_shape = jax.eval_shape(
            lambda k: model.init_params(k, p_dtype), jax.random.PRNGKey(0)
        )
        p_shard = axes_to_shardings(mesh, p_axes, params_shape, rules)
        if shape.kind == "train":
            from repro.train.optimizer import init_opt_state

            opt_shape = jax.eval_shape(init_opt_state, params_shape)
            o_shard = axes_to_shardings(mesh, opt_state_axes(p_axes), opt_shape, rules)
            batch = model.input_specs(shape)
            b_shard = axes_to_shardings(mesh, model.batch_axes(shape), batch, rules)
            # per-microbatch batch must stay divisible by the batch shards
            batch_shards = 1
            with shd.mesh_rules(mesh, rules):
                for ax in shd.resolve_spec(("batch",)):
                    if ax is None:
                        continue
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        batch_shards *= mesh.shape[a]
            mb_cap = max(1, shape.global_batch // batch_shards)
            microbatches = min(microbatches, mb_cap)
            tcfg = TrainConfig(microbatches=microbatches, remat_policy=remat_policy)
            step = make_train_step(model, tcfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, batch)
        elif shape.kind == "prefill":
            assert model.prefill_fn is not None, f"{arch} has no prefill path"
            batch = model.input_specs(shape)
            b_shard = axes_to_shardings(mesh, model.batch_axes(shape), batch, rules)

            def prefill(params, b):
                from repro.models.common import cast_tree

                return model.prefill_fn(
                    cast_tree(params, jnp.bfloat16), b, shape.seq_len
                )

            jitted = jax.jit(prefill, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_shape, batch)
        else:  # decode
            state_spec = model.decode_state_spec(shape)
            s_shard = axes_to_shardings(mesh, model.decode_state_axes(), state_spec, rules)
            B = shape.global_batch
            tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            t_shard = axes_to_shardings(mesh, ("batch", None), tokens, rules)
            clen = jax.ShapeDtypeStruct((), jnp.int32)

            def decode(params, state, tok, cache_len):
                from repro.models.common import cast_tree

                return model.decode_fn(
                    cast_tree(params, jnp.bfloat16), state, tok, cache_len
                )

            jitted = jax.jit(
                decode,
                in_shardings=(
                    p_shard,
                    s_shard,
                    t_shard,
                    jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                ),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shape, state_spec, tokens, clen)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        roof = rl.analyze(compiled, n_dev, rl.model_flops_for(cfg, shape))
        if os.environ.get("DRYRUN_DUMP_HLO"):
            os.makedirs("results/hlo", exist_ok=True)
            mesh_tag = "mp" if multi_pod else "sp"
            with open(
                f"results/hlo/{arch}__{shape_name}__{mesh_tag}.hlo.txt", "w"
            ) as f:
                f.write(compiled.as_text())

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "rules": rules_name,
        "microbatches": microbatches,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_live_gb": (
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            )
            / 1e9,
        },
        "flops_per_device": roof.flops,
        "hbm_bytes_per_device": roof.hbm_bytes,
        "collective_bytes_per_device": roof.coll_bytes,
        "collective_breakdown": {
            k: v for k, v in roof.coll_breakdown.items() if v
        },
        "model_flops_global": roof.model_flops,
        **roof.row(),
    }
    if verbose:
        print(
            f"[{arch} x {shape_name} x {mesh_name} x {rules_name}] "
            f"compile={t_compile:.0f}s peak={rec['memory']['peak_live_gb']:.2f}GB "
            f"t_comp={roof.t_compute*1e3:.1f}ms t_mem={roof.t_memory*1e3:.1f}ms "
            f"t_coll={roof.t_collective*1e3:.1f}ms bottleneck={roof.bottleneck} "
            f"roofline_frac={roof.roofline_fraction:.3f}"
        )
        print(compiled.memory_analysis())
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = ""
        fn = f"{RESULTS_DIR}/{arch}__{shape_name}__{mesh_name}{suffix}.json"
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="baseline", choices=sorted(RULE_SETS))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat-policy", default=None)
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, cfg in sorted(all_configs().items()):
            rules_name, mb = ARCH_DEFAULTS.get(arch, ("baseline", 1))
            for shape_name in applicable_shapes(cfg):
                try:
                    dryrun_cell(
                        arch, shape_name, multi_pod=args.multi_pod,
                        rules_name=rules_name,
                        microbatches=mb if SHAPES[shape_name].kind == "train" else 1,
                    )
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape_name, str(e)[:200]))
        print(f"\n{'=' * 60}\nfailures: {len(failures)}")
        for f in failures:
            print("  FAIL:", f)
        raise SystemExit(1 if failures else 0)

    dryrun_cell(
        args.arch, args.shape, multi_pod=args.multi_pod,
        rules_name=args.rules, microbatches=args.microbatches,
        remat_policy=args.remat_policy,
    )


if __name__ == "__main__":
    main()
