"""Deterministic, shard-aware synthetic LM data pipeline.

Tokens follow a fixed random first-order Markov chain (seeded), so the
stream has learnable structure: training loss decreases toward the chain's
conditional entropy — which gives the end-to-end example a real convergence
signal without shipping a corpus.

Sharding: `shard_batch(step, shard_idx, n_shards)` generates exactly the
rows this data shard owns, from `fold_in(seed, (step, global_row))` — every
host draws identical global content without communication, and restarts at
any step are bit-reproducible (checkpoint/restart only needs `step`).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4  # out-degree of the Markov chain

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # sparse random transition structure: each state -> `branching`
        # successors with dirichlet weights
        self.succ = rng.randint(0, self.vocab, size=(self.vocab, self.branching))
        alpha = rng.dirichlet(np.ones(self.branching), size=self.vocab)
        self.cum = np.cumsum(alpha, axis=1).astype(np.float64)

    def _row(self, step: int, row: int) -> np.ndarray:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 65_537 + row) % (2**31 - 1)
        )
        out = np.empty(self.seq_len + 1, np.int32)
        s = rng.randint(self.vocab)
        u = rng.rand(self.seq_len + 1)
        for t in range(self.seq_len + 1):
            out[t] = s
            j = int(np.searchsorted(self.cum[s], u[t]))
            s = int(self.succ[s, min(j, self.branching - 1)])
        return out

    def shard_batch(self, step: int, shard_idx: int = 0, n_shards: int = 1):
        rows_per = self.global_batch // n_shards
        rows = range(shard_idx * rows_per, (shard_idx + 1) * rows_per)
        seqs = np.stack([self._row(step, r) for r in rows])
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }

    def entropy_floor(self) -> float:
        """The chain's conditional entropy (nats) — the loss floor."""
        alpha = np.diff(
            np.concatenate([np.zeros((self.vocab, 1)), self.cum], axis=1), axis=1
        )
        h = -np.sum(alpha * np.log(np.maximum(alpha, 1e-12)), axis=1)
        return float(np.mean(h))
