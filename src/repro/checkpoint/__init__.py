from repro.checkpoint.checkpoint import (
    SaveHandle, is_committed, latest, prune, read_manifest, restore, save,
    save_async,
)

__all__ = [
    "SaveHandle", "is_committed", "latest", "prune", "read_manifest",
    "restore", "save", "save_async",
]
