from repro.checkpoint.checkpoint import is_committed, latest, restore, save, save_async

__all__ = ["is_committed", "latest", "restore", "save", "save_async"]
