"""Sharded checkpointing with elastic restore and crash-safe commits.

Format: one .npz per pytree (params / opt_state / soak carries) + a JSON
manifest holding the tree structure, shapes, dtypes and *logical axes*.
Restore re-shards onto whatever mesh/rules are active — the elastic-scaling
path (restart on a different device count after failures) is therefore just
`restore()` under the new mesh.

Crash-safety contract (the soak runtime's resume path depends on it):

* **Atomic commit.**  ``save`` stages every file (npz trees, manifest,
  ``COMMITTED`` marker) into a ``<path>.tmp.<pid>`` sibling, fsyncs each
  file, then ``os.rename``s the staging dir onto ``path`` and fsyncs the
  parent directory — a reader can never observe a half-written snapshot
  under ``path``, and a crash at any byte leaves at most a stale ``.tmp``
  dir (``prune`` sweeps those).
* **Committed gating.**  ``is_committed`` / ``latest`` only ever surface
  snapshots whose marker exists *and* whose manifest parses; anything else
  (interrupted rename targets, manually truncated files) is skipped, not
  returned.
* **Transient-IO retry.**  ``save(..., retries=N)`` retries the whole
  staged commit with exponential backoff on ``OSError`` — the bounded
  retry loop long-horizon soak runs want for flaky network filesystems.
* **Async error surfacing.**  ``save_async`` snapshots device arrays to
  host synchronously, writes in a worker thread, and re-raises any worker
  exception from ``join()`` — a failed background save can no longer be
  silently swallowed.

Saves can run asynchronously (background thread over a host snapshot) so
the train/soak loop isn't blocked on I/O — the standard large-run pattern.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distrib.sharding import named_sharding

_SEP = "/"
_TMP_MARK = ".tmp."


def _flatten(tree, is_leaf=None) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def _flatten_axes(tree) -> dict[str, Any]:
    return _flatten(tree, is_leaf=lambda x: isinstance(x, (tuple, list)) or x == ())


def _fsync_path(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _save_once(
    path: str, step: int, trees: dict[str, Any], axes: Optional[dict],
    extra: Optional[dict],
):
    path = os.path.abspath(path)
    parent = os.path.dirname(path)
    os.makedirs(parent, exist_ok=True)
    tmp = f"{path}{_TMP_MARK}{os.getpid()}"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    try:
        manifest = {"step": int(step), "trees": {}}
        for name, tree in trees.items():
            flat = _flatten(
                jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            )
            fpath = os.path.join(tmp, f"{name}.npz")
            np.savez(fpath, **flat)
            _fsync_path(fpath)
            treedef = jax.tree_util.tree_structure(tree)
            manifest["trees"][name] = {
                "treedef": str(treedef),
                "keys": sorted(flat.keys()),
            }
        if axes is not None:
            manifest["axes"] = jax.tree.map(
                lambda t: list(t) if isinstance(t, tuple) else t,
                axes,
                is_leaf=lambda x: isinstance(x, tuple),
            )
        if extra:
            for k in extra:
                assert k not in manifest, f"extra manifest key {k!r} collides"
            manifest.update(extra)
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, default=str)
            f.flush()
            os.fsync(f.fileno())
        # marker written inside the staging dir: the rename below is the
        # single atomic commit point, the marker just gates readers that
        # predate atomic staging (and manual copies of snapshot dirs)
        cpath = os.path.join(tmp, "COMMITTED")
        with open(cpath, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        _fsync_path(parent)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def save(
    path: str,
    step: int,
    trees: dict[str, Any],
    axes: Optional[dict] = None,
    extra: Optional[dict] = None,
    retries: int = 0,
    backoff_s: float = 0.05,
):
    """trees: {"params": ..., "opt_state": ...}; axes: matching logical-axis
    trees (stored so restore can reshard); extra: additional JSON-able
    manifest fields (e.g. the soak runtime's plan fingerprint + injection
    log).  ``retries`` > 0 re-attempts the whole atomic commit with
    exponential backoff on transient ``OSError``s."""
    for attempt in range(retries + 1):
        try:
            _save_once(path, step, trees, axes, extra)
            return
        except OSError:
            if attempt == retries:
                raise
            time.sleep(backoff_s * (2 ** attempt))


class SaveHandle:
    """Handle on a background save: ``join()`` re-raises any worker
    exception instead of swallowing it (thread-compatible surface, so
    existing ``pending.join()`` call sites gain error propagation for
    free)."""

    def __init__(self, target, args, kwargs):
        self._exc: BaseException | None = None

        def run():
            try:
                target(*args, **kwargs)
            except BaseException as e:  # surfaced on join, never swallowed
                self._exc = e

        self._thread = threading.Thread(target=run, daemon=False)
        self._thread.start()

    def join(self, timeout: float | None = None):
        self._thread.join(timeout)
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def is_alive(self) -> bool:
        return self._thread.is_alive()

    @property
    def exception(self) -> BaseException | None:
        return self._exc


def save_async(
    path: str, step: int, trees: dict, axes=None, extra: Optional[dict] = None,
    retries: int = 0, backoff_s: float = 0.05,
) -> SaveHandle:
    """Snapshot to host synchronously (cheap, bounded by device->host
    bandwidth), write in a background thread.  The returned handle's
    ``join()`` re-raises worker exceptions — callers that previously held a
    bare ``Thread`` keep working but now see IO failures."""
    snapshot = {
        name: jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        for name, tree in trees.items()
    }
    return SaveHandle(
        save, (path, step, snapshot),
        {"axes": axes, "extra": extra, "retries": retries,
         "backoff_s": backoff_s},
    )


def is_committed(path: str) -> bool:
    return os.path.exists(os.path.join(path, "COMMITTED"))


def read_manifest(path: str) -> dict:
    """The snapshot's manifest dict (step, tree layouts, any ``extra``
    fields recorded at save time).  Raises on uncommitted snapshots."""
    assert is_committed(path), f"no committed checkpoint at {path}"
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(path: str, like: dict[str, Any], axes: Optional[dict] = None):
    """Restore trees shaped like `like` (a dict of example pytrees).  If a
    mesh is active (repro.distrib.sharding.mesh_rules) and `axes` trees are
    given, arrays are device_put with the resolved shardings — this is the
    elastic re-shard path."""
    manifest = read_manifest(path)
    out = {}
    for name, tree in like.items():
        data = np.load(os.path.join(path, f"{name}.npz"))
        flat_like = _flatten(tree)
        flat_axes = _flatten_axes(axes[name]) if axes and name in axes else {}
        restored = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            ax = flat_axes.get(key)
            sh = named_sharding(*ax) if ax is not None else None
            restored[key] = (
                jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
            )
        # rebuild tree
        treedef = jax.tree_util.tree_structure(tree)
        keys_in_order = [
            _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
        ]
        out[name] = jax.tree_util.tree_unflatten(
            treedef, [restored[k] for k in keys_in_order]
        )
    return out, manifest["step"]


def _snapshot_step(base: str, d: str) -> Optional[int]:
    """Parse + sanity-check one snapshot dir; None = not a usable snapshot
    (wrong name shape, uncommitted, or corrupt/unreadable manifest)."""
    if not d.startswith("step_") or _TMP_MARK in d:
        return None
    try:
        step = int(d.split("_", 1)[1])
    except ValueError:
        return None
    p = os.path.join(base, d)
    if not is_committed(p):
        return None
    try:
        with open(os.path.join(p, "manifest.json")) as f:
            json.load(f)
    except (OSError, ValueError):
        return None  # committed marker present but manifest unreadable
    return step


def latest(base: str) -> Optional[str]:
    """Newest *committed, readable* snapshot under ``base`` (or None).
    Uncommitted dirs, stale ``.tmp.*`` staging dirs and snapshots whose
    manifest no longer parses are skipped, never returned."""
    if not os.path.isdir(base):
        return None
    steps = []
    for d in os.listdir(base):
        step = _snapshot_step(base, d)
        if step is not None:
            steps.append((step, os.path.join(base, d)))
    return max(steps)[1] if steps else None


def prune(base: str, keep: int) -> list[str]:
    """Keep the newest ``keep`` committed snapshots under ``base``; delete
    older ones plus any stale staging (``.tmp.*``) or uncommitted dirs.
    Returns the deleted paths (for logging)."""
    assert keep >= 1, "refusing to prune every snapshot"
    if not os.path.isdir(base):
        return []
    committed: list[tuple[int, str]] = []
    doomed: list[str] = []
    for d in os.listdir(base):
        p = os.path.join(base, d)
        if not os.path.isdir(p):
            continue
        step = _snapshot_step(base, d)
        if step is not None:
            committed.append((step, p))
        elif d.startswith("step_") or _TMP_MARK in d:
            doomed.append(p)  # stale staging / interrupted save
    committed.sort()
    doomed.extend(p for _, p in committed[:-keep])
    for p in doomed:
        shutil.rmtree(p, ignore_errors=True)
    return doomed
