"""Sharded checkpointing with elastic restore.

Format: one .npz per pytree (params / opt_state) + a JSON manifest holding
the tree structure, shapes, dtypes and *logical axes*.  Restore re-shards
onto whatever mesh/rules are active — the elastic-scaling path (restart on
a different device count after failures) is therefore just `restore()`
under the new mesh.

Saves can run asynchronously (background thread over a host snapshot) so
the train loop isn't blocked on I/O — the standard large-run pattern.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distrib.sharding import named_sharding

_SEP = "/"


def _flatten(tree, is_leaf=None) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def _flatten_axes(tree) -> dict[str, Any]:
    return _flatten(tree, is_leaf=lambda x: isinstance(x, (tuple, list)) or x == ())


def save(path: str, step: int, trees: dict[str, Any], axes: Optional[dict] = None):
    """trees: {"params": ..., "opt_state": ...}; axes: matching logical-axis
    trees (stored so restore can reshard)."""
    os.makedirs(path, exist_ok=True)
    manifest = {"step": int(step), "trees": {}}
    for name, tree in trees.items():
        flat = _flatten(jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree))
        np.savez(os.path.join(path, f"{name}.npz"), **flat)
        treedef = jax.tree_util.tree_structure(tree)
        manifest["trees"][name] = {
            "treedef": str(treedef),
            "keys": sorted(flat.keys()),
        }
    if axes is not None:
        manifest["axes"] = jax.tree.map(
            lambda t: list(t) if isinstance(t, tuple) else t,
            axes,
            is_leaf=lambda x: isinstance(x, tuple),
        )
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, default=str)
    # atomic completion marker
    with open(os.path.join(path, "COMMITTED"), "w") as f:
        f.write(str(step))


def save_async(path: str, step: int, trees: dict, axes=None) -> threading.Thread:
    snapshot = {
        name: jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        for name, tree in trees.items()
    }
    t = threading.Thread(target=save, args=(path, step, snapshot, axes))
    t.start()
    return t


def is_committed(path: str) -> bool:
    return os.path.exists(os.path.join(path, "COMMITTED"))


def restore(path: str, like: dict[str, Any], axes: Optional[dict] = None):
    """Restore trees shaped like `like` (a dict of example pytrees).  If a
    mesh is active (repro.distrib.sharding.mesh_rules) and `axes` trees are
    given, arrays are device_put with the resolved shardings — this is the
    elastic re-shard path."""
    assert is_committed(path), f"no committed checkpoint at {path}"
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for name, tree in like.items():
        data = np.load(os.path.join(path, f"{name}.npz"))
        flat_like = _flatten(tree)
        flat_axes = _flatten_axes(axes[name]) if axes and name in axes else {}
        restored = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            ax = flat_axes.get(key)
            sh = named_sharding(*ax) if ax is not None else None
            restored[key] = (
                jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
            )
        # rebuild tree
        treedef = jax.tree_util.tree_structure(tree)
        keys_in_order = [
            _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
        ]
        out[name] = jax.tree_util.tree_unflatten(
            treedef, [restored[k] for k in keys_in_order]
        )
    return out, manifest["step"]


def latest(base: str) -> Optional[str]:
    if not os.path.isdir(base):
        return None
    steps = []
    for d in os.listdir(base):
        p = os.path.join(base, d)
        if d.startswith("step_") and is_committed(p):
            steps.append((int(d.split("_")[1]), p))
    return max(steps)[1] if steps else None
