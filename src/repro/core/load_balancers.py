"""Load-balancer zoo behind one interface (paper §4.1 baselines + REPS).

Every load balancer is a *static* object holding configuration; its mutable
per-connection state is a pytree threaded through the netsim engine's jitted
tick.  Interface:

    init_state(n_conns, key)                        -> state pytree
    choose_ev(state, mask, key, now)                -> (evs (N,), state)
    on_ack(state, mask, ev, ecn, now, key)          -> state
    on_timeout(state, mask, now, key)               -> state

``mask`` selects the connections that send / got an ACK / timed out this
tick (the netsim guarantees at most one such event per connection per tick,
see DESIGN.md §5).  ``switch_adaptive`` marks in-network approaches
(adaptive RoCE): the sender still stamps an EV but switches override the
port choice with a local least-queue decision.

Key-threading contract: every callback that may re-path receives a key
derived from the engine's per-tick threefry stream (``fold_in(tick_key,
2)`` for ``choose_ev``, ``fold_in(fold_in(tick_key, 4), round)`` per
feedback round for ``on_ack``, ``fold_in(tick_key, 5)`` for
``on_timeout``), so draws differ per seed, per sweep row, per tick and per
feedback round.  ``fold_in`` derives keys without consuming randomness,
so LBs that ignore the key are bit-identical to runs before the key was
threaded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import reps as reps_core
from repro.utils import pytree_dataclass, static_field

# Trace-event kinds reported by the optional LoadBalancer.trace port (one
# int32 count per kind, see trace() below).  The netsim tracer maps these to
# ring-buffer event codes; keep the numbering stable — it is serialized into
# flight-recorder part files.
TR_EV_HIT = 0  # REPS: popped the oldest *valid* cached EV
TR_EV_MISS = 1  # REPS: explored a fresh uniform EV
TR_EV_RECYCLE = 2  # REPS: freezing-mode reuse of a (possibly invalid) slot
TR_EV_FREEZE = 3  # REPS: entered freezing mode (failure detected)
TR_REPATH_ACK_ECN = 4  # re-path decided from ECN feedback on ACKs
TR_REPATH_RTO = 5  # re-path decided from a retransmission timeout
TR_REPATH_FLOWLET = 6  # re-path decided from a flowlet gap expiry
TR_REPATH_EPOCH = 7  # re-path decided at an epoch / message boundary
N_TRACE_KINDS = 8


def _trace_counts(*pairs):
    """Build a (N_TRACE_KINDS,) int32 count vector from (kind, mask) pairs.

    Every mask MUST already be gated on the site's event mask so the result
    is all-zero on quiescent ticks (the tracer carry must be a bitwise no-op
    when nothing happens, same contract as the telemetry channels).
    """
    out = jnp.zeros((N_TRACE_KINDS,), jnp.int32)
    for kind, m in pairs:
        out = out.at[kind].set(jnp.sum(m.astype(jnp.int32)))
    return out


def _rand_evs(key, n, evs_size):
    return jax.random.randint(key, (n,), 0, evs_size, jnp.int32)


def _mix32(x):
    """Cheap int32 -> uint32 avalanche hash (xorshift-multiply finalizer)."""
    u = x.astype(jnp.uint32)
    u = u ^ (u >> jnp.uint32(16))
    u = u * jnp.uint32(0x7FEB352D)
    u = u ^ (u >> jnp.uint32(15))
    u = u * jnp.uint32(0x846CA68B)
    u = u ^ (u >> jnp.uint32(16))
    return u


class LoadBalancer:
    name: str = "abstract"
    switch_adaptive: bool = False

    def __init__(self, evs_size: int = 65536):
        self.evs_size = evs_size

    def init_state(self, n_conns: int, key: jax.Array):
        raise NotImplementedError

    def choose_ev(self, state, mask, key, now):
        raise NotImplementedError

    def on_ack(self, state, mask, ev, ecn, now, key):
        return state

    def on_timeout(self, state, mask, now, key):
        return state

    def trace(self, site, prev, new, mask):
        """Optional observation-only trace port (flight recorder).

        ``site`` is a *static* string naming the engine call site just
        executed ("choose" | "ack" | "timeout"); ``prev``/``new`` are the LB
        state before/after that call and ``mask`` is the event mask the call
        received.  Returns (N_TRACE_KINDS,) int32 per-kind decision counts
        summed over connections.  Contract: pure state-diff observation (no
        RNG, no state change) and every count gated on ``mask`` so the
        result is all-zero whenever ``mask`` is — LBs whose state drifts on
        idle ticks (e.g. PLB epoch rollover) must not emit events the
        quiescence early-exit would skip.
        """
        del site, prev, new, mask
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# ECMP: one static EV per connection (per-flow hashing).  §2.2
# ---------------------------------------------------------------------------
class EcmpLB(LoadBalancer):
    name = "ecmp"

    def init_state(self, n_conns, key):
        return _rand_evs(key, n_conns, self.evs_size)

    def choose_ev(self, state, mask, key, now):
        return state, state


# ---------------------------------------------------------------------------
# OPS: uniform random EV per packet.  §2.2
# ---------------------------------------------------------------------------
class OpsLB(LoadBalancer):
    name = "ops"

    def init_state(self, n_conns, key):
        return jnp.zeros((n_conns,), jnp.int32)  # dummy (keeps pytree nonempty)

    def choose_ev(self, state, mask, key, now):
        return _rand_evs(key, state.shape[0], self.evs_size), state


# ---------------------------------------------------------------------------
# REPS (the paper).  §3
# ---------------------------------------------------------------------------
class RepsLB(LoadBalancer):
    """REPS with a switchable compute backend.

    backend="jnp"    — the vectorized repro.core.reps implementation;
    backend="pallas" — the fused repro.kernels.reps_update kernel drives
                       Algorithms 1+2 (Mosaic on TPU, interpret elsewhere);
    backend="auto"   — pallas on TPU, jnp otherwise.

    Both backends share the REPSState pytree and are bit-identical (the
    kernel is pinned to the same scalar oracle; tests assert parity), so
    flipping the backend never changes simulation results.
    """

    name = "reps"

    def __init__(
        self,
        evs_size: int = 65536,
        buffer_size: int = 8,
        num_pkts_bdp: int = 32,
        freezing_timeout: int = 1024,
        enable_freezing: bool = True,
        backend: str = "auto",
    ):
        super().__init__(evs_size)
        self.cfg = reps_core.REPSConfig(
            buffer_size=buffer_size,
            evs_size=evs_size,
            num_pkts_bdp=num_pkts_bdp,
            freezing_timeout=freezing_timeout,
        )
        self.enable_freezing = enable_freezing
        assert backend in ("auto", "jnp", "pallas"), backend
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        if backend == "pallas":
            from repro.kernels import reps_update

            assert buffer_size == reps_update.BUF, (
                f"pallas backend is compiled for buffer depth "
                f"{reps_update.BUF}, got {buffer_size}"
            )
        self.backend = backend

    def init_state(self, n_conns, key):
        return reps_core.init_state(self.cfg, n_conns)

    def _kernel_tick(self, state, ack_mask, ack_ev, ack_ecn, timeout_mask,
                     send_mask, rand_ev, now):
        """One fused Algorithm 1+2 pass through the Pallas kernel.

        Unused event classes are passed as all-zero masks, which makes the
        corresponding algorithm a no-op — so the engine's split pipeline
        stages (feedback / RTO / injection) each map onto one kernel call.
        """
        from repro.kernels import ops as kernel_ops

        n = state.head.shape[0]
        z = jnp.zeros((n,), jnp.int32)
        i = lambda x: x.astype(jnp.int32)
        out = kernel_ops.reps_tick(
            state.buf_ev, i(state.buf_valid), state.head, state.num_valid,
            state.explore_counter, i(state.is_freezing), state.exit_freezing,
            state.n_cached,
            i(ack_mask) if ack_mask is not None else z,
            ack_ev if ack_ev is not None else z,
            i(ack_ecn) if ack_ecn is not None else z,
            i(timeout_mask) if timeout_mask is not None else z,
            i(send_mask) if send_mask is not None else z,
            rand_ev if rand_ev is not None else z,
            jnp.asarray(now, jnp.int32),
            self.cfg.num_pkts_bdp,
            self.cfg.freezing_timeout,
        )
        (buf_ev, buf_valid, head, num_valid, explore, freezing, exit_freeze,
         n_cached, evs) = out
        new_state = reps_core.REPSState(
            buf_ev=buf_ev,
            buf_valid=buf_valid.astype(jnp.bool_),
            head=head,
            num_valid=num_valid,
            explore_counter=explore,
            is_freezing=freezing.astype(jnp.bool_),
            exit_freezing=exit_freeze,
            n_cached=n_cached,
        )
        return new_state, evs

    def choose_ev(self, state, mask, key, now):
        if self.backend == "pallas":
            n = state.head.shape[0]
            rand_ev = jax.random.randint(key, (n,), 0, self.cfg.evs_size, jnp.int32)
            state, evs = self._kernel_tick(
                state, None, None, None, None, mask, rand_ev, now
            )
            return evs, state
        return reps_core.choose_ev(self.cfg, state, mask, key)

    def on_ack(self, state, mask, ev, ecn, now, key):
        if self.backend == "pallas":
            state, _ = self._kernel_tick(
                state, mask, ev, ecn, None, None, None, now
            )
            return state
        return reps_core.on_ack(self.cfg, state, mask, ev, ecn, now)

    def on_timeout(self, state, mask, now, key):
        if not self.enable_freezing:
            return state
        if self.backend == "pallas":
            state, _ = self._kernel_tick(
                state, None, None, None, mask, None, None, now
            )
            return state
        return reps_core.on_failure_detection(self.cfg, state, mask, now)

    def trace(self, site, prev, new, mask):
        # Pure REPSState diffs, so both backends (jnp / pallas, bit-equal
        # states) report identical events.  choose_ev mutates num_valid only
        # via pop-oldest-valid (hit) and head only via freezing-mode reuse
        # (recycle); everything else under the mask explored fresh entropy.
        if site == "choose":
            hit = mask & (new.num_valid < prev.num_valid)
            recycle = mask & (new.head != prev.head)
            miss = mask & ~hit & ~recycle
            return _trace_counts(
                (TR_EV_HIT, hit), (TR_EV_RECYCLE, recycle), (TR_EV_MISS, miss)
            )
        if site == "timeout":
            freeze = mask & new.is_freezing & ~prev.is_freezing
            return _trace_counts((TR_EV_FREEZE, freeze))
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# PLB / FlowBender-style: per-connection EV, re-path when an epoch sees a
# high ECN fraction or on RTO.  Configured aggressively per the paper §4.1.
# ---------------------------------------------------------------------------
@pytree_dataclass
class PlbState:
    ev: jax.Array  # (N,) int32 current EV
    acks: jax.Array  # (N,) int32 ACKs this epoch
    marked: jax.Array  # (N,) int32 ECN-marked ACKs this epoch
    epoch_end: jax.Array  # (N,) int32 tick
    bad_epochs: jax.Array  # (N,) int32 consecutive congested epochs


class PlbLB(LoadBalancer):
    name = "plb"

    def __init__(
        self,
        evs_size: int = 65536,
        epoch_ticks: int = 64,
        ecn_frac_threshold: float = 0.5,
        repath_after_epochs: int = 1,  # aggressive (FlowBender-like)
    ):
        super().__init__(evs_size)
        self.epoch_ticks = epoch_ticks
        self.ecn_frac_threshold = ecn_frac_threshold
        self.repath_after_epochs = repath_after_epochs

    def init_state(self, n_conns, key):
        return PlbState(
            ev=_rand_evs(key, n_conns, self.evs_size),
            acks=jnp.zeros((n_conns,), jnp.int32),
            marked=jnp.zeros((n_conns,), jnp.int32),
            epoch_end=jnp.full((n_conns,), self.epoch_ticks, jnp.int32),
            bad_epochs=jnp.zeros((n_conns,), jnp.int32),
        )

    def choose_ev(self, state, mask, key, now):
        return state.ev, state

    def on_ack(self, state, mask, ev, ecn, now, key):
        # Reset-then-count: close out an epoch that has already ended
        # before counting this tick's ACKs.  `epoch_over` depends only on
        # `now`, so an idle gap spanning the boundary rolls the epoch over
        # on the next ACK too — the completed epoch is judged on its own
        # counters, never with the next burst's first ACK mixed in.
        epoch_over = now >= state.epoch_end
        frac_bad = state.marked > (
            jnp.ceil(state.acks.astype(jnp.float32) * self.ecn_frac_threshold)
        ).astype(jnp.int32)
        bad_epochs = jnp.where(
            epoch_over,
            jnp.where(frac_bad & (state.acks > 0), state.bad_epochs + 1, 0),
            state.bad_epochs,
        )
        acks = jnp.where(epoch_over, 0, state.acks)
        marked = jnp.where(epoch_over, 0, state.marked)
        epoch_end = jnp.where(
            epoch_over, now + self.epoch_ticks, state.epoch_end
        )
        acks = jnp.where(mask, acks + 1, acks)
        marked = jnp.where(mask & ecn, marked + 1, marked)
        repath = bad_epochs >= self.repath_after_epochs
        new_ev = jax.random.randint(
            key, state.ev.shape, 0, self.evs_size, jnp.int32
        )
        ev_out = jnp.where(repath, new_ev, state.ev)
        bad_epochs = jnp.where(repath, 0, bad_epochs)
        return PlbState(
            ev=ev_out,
            acks=acks,
            marked=marked,
            epoch_end=epoch_end,
            bad_epochs=bad_epochs,
        )

    def on_timeout(self, state, mask, now, key):
        new_ev = jax.random.randint(
            key, state.ev.shape, 0, self.evs_size, jnp.int32
        )
        return state.replace(ev=jnp.where(mask, new_ev, state.ev))

    def trace(self, site, prev, new, mask):
        # A PLB repath can technically land on a feedback round where the
        # repathing connection's own ACK mask is false (bad_epochs carried
        # from earlier rounds); the mask gate drops those so idle-tick epoch
        # rollovers never emit events — tracing is best-effort observation.
        if site == "ack":
            return _trace_counts((TR_REPATH_ACK_ECN, mask & (new.ev != prev.ev)))
        if site == "timeout":
            return _trace_counts((TR_REPATH_RTO, mask))
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# Flowlet switching: new random EV whenever the inter-send gap exceeds the
# flowlet timeout (paper sets it aggressively to RTT/2).  §4.1
# ---------------------------------------------------------------------------
@pytree_dataclass
class FlowletState:
    ev: jax.Array  # (N,) int32
    last_send: jax.Array  # (N,) int32 tick of previous send


class FlowletLB(LoadBalancer):
    name = "flowlet"

    def __init__(self, evs_size: int = 65536, gap_ticks: int = 32):
        super().__init__(evs_size)
        self.gap_ticks = gap_ticks

    def init_state(self, n_conns, key):
        return FlowletState(
            ev=_rand_evs(key, n_conns, self.evs_size),
            last_send=jnp.full((n_conns,), -(10**6), jnp.int32),
        )

    def choose_ev(self, state, mask, key, now):
        n = state.ev.shape[0]
        new_flowlet = mask & ((now - state.last_send) > self.gap_ticks)
        ev = jnp.where(new_flowlet, _rand_evs(key, n, self.evs_size), state.ev)
        return ev, FlowletState(
            ev=ev, last_send=jnp.where(mask, now, state.last_send)
        )

    def trace(self, site, prev, new, mask):
        if site == "choose":
            return _trace_counts((TR_REPATH_FLOWLET, mask & (new.ev != prev.ev)))
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# MPTCP-like: K static subflow EVs per connection, packets round-robin over
# subflows; a timeout re-hashes one subflow.  Coarse model of running K QPs
# (paper §4.1 uses K=8).  CC remains shared (documented simplification).
# ---------------------------------------------------------------------------
@pytree_dataclass
class MptcpState:
    sub_evs: jax.Array  # (N, K) int32
    rr: jax.Array  # (N,) int32 round-robin cursor


class MptcpLB(LoadBalancer):
    name = "mptcp"

    def __init__(self, evs_size: int = 65536, n_subflows: int = 8):
        super().__init__(evs_size)
        self.n_subflows = n_subflows

    def init_state(self, n_conns, key):
        return MptcpState(
            sub_evs=jax.random.randint(
                key, (n_conns, self.n_subflows), 0, self.evs_size, jnp.int32
            ),
            rr=jnp.zeros((n_conns,), jnp.int32),
        )

    def choose_ev(self, state, mask, key, now):
        idx = state.rr % self.n_subflows
        ev = jnp.take_along_axis(state.sub_evs, idx[:, None], axis=1)[:, 0]
        rr = jnp.where(mask, state.rr + 1, state.rr)
        return ev, state.replace(rr=rr)

    def on_timeout(self, state, mask, now, key):
        # Re-hash the subflow at the cursor for timed-out connections.
        idx = state.rr % self.n_subflows
        onehot = jax.nn.one_hot(idx, self.n_subflows, dtype=jnp.bool_)
        new_evs = jax.random.randint(
            key, state.sub_evs.shape, 0, self.evs_size, jnp.int32
        )
        sub_evs = jnp.where(mask[:, None] & onehot, new_evs, state.sub_evs)
        return state.replace(sub_evs=sub_evs)

    def trace(self, site, prev, new, mask):
        if site == "timeout":
            return _trace_counts((TR_REPATH_RTO, mask))
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# MPRDMA-like: per-packet spraying that avoids recently ECN-marked EVs via a
# small ring of "bad" EVs (no caching of good paths — the paper's contrast).
# ---------------------------------------------------------------------------
@pytree_dataclass
class MprdmaState:
    bad_evs: jax.Array  # (N, L) int32 recently marked EVs
    bad_ptr: jax.Array  # (N,) int32


class MprdmaLB(LoadBalancer):
    name = "mprdma"

    def __init__(self, evs_size: int = 65536, blacklist: int = 16):
        super().__init__(evs_size)
        self.blacklist = blacklist

    def init_state(self, n_conns, key):
        return MprdmaState(
            bad_evs=jnp.full((n_conns, self.blacklist), -1, jnp.int32),
            bad_ptr=jnp.zeros((n_conns,), jnp.int32),
        )

    def choose_ev(self, state, mask, key, now):
        n = state.bad_evs.shape[0]
        k1, k2 = jax.random.split(key)
        cand1 = _rand_evs(k1, n, self.evs_size)
        cand2 = _rand_evs(k2, n, self.evs_size)
        bad1 = jnp.any(state.bad_evs == cand1[:, None], axis=1)
        ev = jnp.where(bad1, cand2, cand1)  # one resample on blacklist hit
        return ev, state

    def on_ack(self, state, mask, ev, ecn, now, key):
        add = mask & ecn
        L = self.blacklist
        onehot = jax.nn.one_hot(state.bad_ptr % L, L, dtype=jnp.bool_)
        bad_evs = jnp.where(add[:, None] & onehot, ev[:, None], state.bad_evs)
        return MprdmaState(
            bad_evs=bad_evs,
            bad_ptr=jnp.where(add, state.bad_ptr + 1, state.bad_ptr),
        )


# ---------------------------------------------------------------------------
# BitMap (STrack-like): 1 bit of congestion state per EV in the whole EVS —
# the memory-expensive strawman of paper §3.3.  Marked EVs are avoided by
# resampling up to R candidates.
# ---------------------------------------------------------------------------
@pytree_dataclass
class BitmapState:
    bad: jax.Array  # (N, EVS) bool


class BitmapLB(LoadBalancer):
    name = "bitmap"

    def __init__(self, evs_size: int = 256, resamples: int = 4):
        super().__init__(evs_size)
        self.resamples = resamples

    def init_state(self, n_conns, key):
        return BitmapState(bad=jnp.zeros((n_conns, self.evs_size), jnp.bool_))

    def choose_ev(self, state, mask, key, now):
        n = state.bad.shape[0]
        keys = jax.random.split(key, self.resamples)
        ev = _rand_evs(keys[0], n, self.evs_size)
        for i in range(1, self.resamples):
            is_bad = jnp.take_along_axis(state.bad, ev[:, None], axis=1)[:, 0]
            cand = _rand_evs(keys[i], n, self.evs_size)
            ev = jnp.where(is_bad, cand, ev)
        return ev, state

    def on_ack(self, state, mask, ev, ecn, now, key):
        onehot = jax.nn.one_hot(ev, self.evs_size, dtype=jnp.bool_)
        bad = jnp.where(mask[:, None] & onehot, ecn[:, None], state.bad)
        return BitmapState(bad=bad)


# ---------------------------------------------------------------------------
# PRIME-like: multi-part entropy header (PAPERS.md).  The EV splits into a
# per-flow part hashed at connection setup and a sub-entropy field of
# ``sub_bits`` bits that rotates per packet through a hashed sequence —
# per-packet path diversity over a bounded window of EVs, so the reorder
# span stays bounded too.  An RTO re-hashes the flow part (the whole window
# moves off the failed path, via the threaded engine key); an ECN-marked
# ACK skips the rotation forward to leave the congested sub-path sooner.
# ---------------------------------------------------------------------------
@pytree_dataclass
class PrimeState:
    base: jax.Array  # (N,) int32 hashed per-flow part of the header
    ctr: jax.Array  # (N,) int32 per-packet rotation counter


class PrimeLB(LoadBalancer):
    name = "prime"

    def __init__(self, evs_size: int = 65536, sub_bits: int = 4):
        super().__init__(evs_size)
        assert 0 < (1 << sub_bits) <= evs_size, (sub_bits, evs_size)
        self.sub_bits = sub_bits

    def init_state(self, n_conns, key):
        return PrimeState(
            base=_rand_evs(key, n_conns, self.evs_size),
            ctr=jnp.zeros((n_conns,), jnp.int32),
        )

    def choose_ev(self, state, mask, key, now):
        sub = (
            _mix32(state.ctr) & jnp.uint32((1 << self.sub_bits) - 1)
        ).astype(jnp.int32)
        ev = (state.base + sub) % self.evs_size
        return ev, state.replace(
            ctr=jnp.where(mask, state.ctr + 1, state.ctr)
        )

    def on_ack(self, state, mask, ev, ecn, now, key):
        return state.replace(
            ctr=jnp.where(mask & ecn, state.ctr + 1, state.ctr)
        )

    def on_timeout(self, state, mask, now, key):
        new_base = _rand_evs(key, state.base.shape[0], self.evs_size)
        return state.replace(base=jnp.where(mask, new_base, state.base))

    def trace(self, site, prev, new, mask):
        if site == "ack":  # ECN-skip advances the sub-entropy rotation
            return _trace_counts((TR_REPATH_ACK_ECN, mask & (new.ctr != prev.ctr)))
        if site == "timeout":  # flow-part re-hash moves the whole window
            return _trace_counts((TR_REPATH_RTO, mask))
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# SeqBalance-like: reorder-free congestion-aware re-pathing (PAPERS.md).
# One EV per connection, re-drawn only at message boundaries (every
# ``msg_pkts`` sends) when the window since the last boundary saw a high
# ECN fraction — packets inside a message never straddle two paths.  An RTO
# means the message is stalled anyway (nothing left to reorder), so it
# re-paths immediately with the threaded engine key.
# ---------------------------------------------------------------------------
@pytree_dataclass
class SeqBalanceState:
    ev: jax.Array  # (N,) int32 current path
    sent: jax.Array  # (N,) int32 sends since the last boundary
    acks: jax.Array  # (N,) int32 ACKs since the last boundary
    marked: jax.Array  # (N,) int32 ECN-marked ACKs since the last boundary


class SeqBalanceLB(LoadBalancer):
    name = "seqbalance"

    def __init__(
        self,
        evs_size: int = 65536,
        msg_pkts: int = 16,
        ecn_frac_threshold: float = 0.25,
    ):
        super().__init__(evs_size)
        self.msg_pkts = msg_pkts
        self.ecn_frac_threshold = ecn_frac_threshold

    def init_state(self, n_conns, key):
        z = jnp.zeros((n_conns,), jnp.int32)
        return SeqBalanceState(
            ev=_rand_evs(key, n_conns, self.evs_size), sent=z, acks=z, marked=z
        )

    def choose_ev(self, state, mask, key, now):
        n = state.ev.shape[0]
        boundary = mask & (state.sent >= self.msg_pkts)
        congested = state.marked.astype(jnp.float32) > (
            state.acks.astype(jnp.float32) * self.ecn_frac_threshold
        )
        repath = boundary & congested
        ev = jnp.where(repath, _rand_evs(key, n, self.evs_size), state.ev)
        sent = jnp.where(
            mask, jnp.where(boundary, 1, state.sent + 1), state.sent
        )
        return ev, SeqBalanceState(
            ev=ev,
            sent=sent,
            acks=jnp.where(boundary, 0, state.acks),
            marked=jnp.where(boundary, 0, state.marked),
        )

    def on_ack(self, state, mask, ev, ecn, now, key):
        return state.replace(
            acks=jnp.where(mask, state.acks + 1, state.acks),
            marked=jnp.where(mask & ecn, state.marked + 1, state.marked),
        )

    def on_timeout(self, state, mask, now, key):
        new_ev = _rand_evs(key, state.ev.shape[0], self.evs_size)
        return state.replace(
            ev=jnp.where(mask, new_ev, state.ev),
            acks=jnp.where(mask, 0, state.acks),
            marked=jnp.where(mask, 0, state.marked),
        )

    def trace(self, site, prev, new, mask):
        if site == "choose":  # congestion-triggered message-boundary repath
            return _trace_counts((TR_REPATH_EPOCH, mask & (new.ev != prev.ev)))
        if site == "timeout":
            return _trace_counts((TR_REPATH_RTO, mask))
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# CONGA-style flowlet table: a small per-connection table of candidate EVs
# with a cached congestion score fed by ECN marks (integer EWMA).  A flowlet
# gap switches to the least-congested cached candidate instead of a uniform
# redraw; an RTO re-hashes the active candidate (threaded engine key) and
# clears its score so the fresh path starts unprejudiced.
# ---------------------------------------------------------------------------
@pytree_dataclass
class FlowletTableState:
    cand: jax.Array  # (N, T) int32 candidate EVs
    score: jax.Array  # (N, T) int32 cached congestion score
    cur: jax.Array  # (N,) int32 active candidate index
    last_send: jax.Array  # (N,) int32 tick of previous send


class FlowletTableLB(LoadBalancer):
    name = "flowlet_table"
    SCORE_MARK = 64  # score bump per ECN-marked ACK (decay is 1/4 per ACK)

    def __init__(
        self, evs_size: int = 65536, table: int = 4, gap_ticks: int = 32
    ):
        super().__init__(evs_size)
        self.table = table
        self.gap_ticks = gap_ticks

    def init_state(self, n_conns, key):
        return FlowletTableState(
            cand=jax.random.randint(
                key, (n_conns, self.table), 0, self.evs_size, jnp.int32
            ),
            score=jnp.zeros((n_conns, self.table), jnp.int32),
            cur=jnp.zeros((n_conns,), jnp.int32),
            last_send=jnp.full((n_conns,), -(10**6), jnp.int32),
        )

    def choose_ev(self, state, mask, key, now):
        new_flowlet = mask & ((now - state.last_send) > self.gap_ticks)
        best = jnp.argmin(state.score, axis=1).astype(jnp.int32)
        cur = jnp.where(new_flowlet, best, state.cur)
        ev = jnp.take_along_axis(state.cand, cur[:, None], axis=1)[:, 0]
        return ev, state.replace(
            cur=cur, last_send=jnp.where(mask, now, state.last_send)
        )

    def on_ack(self, state, mask, ev, ecn, now, key):
        hit = mask[:, None] & (state.cand == ev[:, None])
        decayed = (
            state.score
            - state.score // 4
            + jnp.where(ecn, self.SCORE_MARK, 0)[:, None]
        )
        return state.replace(score=jnp.where(hit, decayed, state.score))

    def on_timeout(self, state, mask, now, key):
        onehot = jax.nn.one_hot(state.cur, self.table, dtype=jnp.bool_)
        sel = mask[:, None] & onehot
        new_cand = jax.random.randint(
            key, state.cand.shape, 0, self.evs_size, jnp.int32
        )
        return state.replace(
            cand=jnp.where(sel, new_cand, state.cand),
            score=jnp.where(sel, 0, state.score),
        )

    def trace(self, site, prev, new, mask):
        if site == "choose":  # flowlet gap switched to another candidate
            return _trace_counts((TR_REPATH_FLOWLET, mask & (new.cur != prev.cur)))
        if site == "timeout":  # active candidate re-hashed + score cleared
            return _trace_counts((TR_REPATH_RTO, mask))
        return jnp.zeros((N_TRACE_KINDS,), jnp.int32)


# ---------------------------------------------------------------------------
# SwitchLB: N variants behind one lax.switch branch index, so scenarios that
# differ only in their load balancer share a single compilation (the sweep
# engine's LB dispatch, repro.netsim.sweep).  State is (branch_idx, tuple of
# every variant's state); each callback switches into the active variant,
# passing the *same* key/mask the variant would see serially and rewriting
# only its own state slot — so the active branch's stream is bit-identical
# to a serial run with the plain variant.  Under vmap the switch lowers to
# run-all-branches + select, which is the price of one compilation for the
# whole LB column.
# ---------------------------------------------------------------------------
class SwitchLB(LoadBalancer):
    name = "switch"

    def __init__(self, variants):
        variants = tuple(variants)
        assert variants, "need at least one variant"
        flags = {v.switch_adaptive for v in variants}
        assert len(flags) == 1, (
            "SwitchLB variants must agree on switch_adaptive (in-network "
            "adaptive LBs change the routing function, a static property); "
            "bucket them separately"
        )
        sizes = {int(v.evs_size) for v in variants}
        if len(sizes) != 1:
            raise ValueError(
                "SwitchLB variants must share one evs_size (every branch "
                "samples the same entropy space; a smaller variant would "
                "silently draw out-of-range EVs): got "
                + ", ".join(f"{v.name}={v.evs_size}" for v in variants)
                + ".  Pass evs_size explicitly to each variant — note "
                "BitmapLB defaults to 256 while the rest of the zoo "
                "defaults to 65536."
            )
        super().__init__(sizes.pop())
        self.variants = variants
        self.switch_adaptive = flags.pop()
        self.name = "switch(" + "+".join(v.name for v in variants) + ")"

    def _dispatch(self, bidx, states, fn, out_proto=None):
        """lax.switch over per-variant callbacks; branch i rewrites state
        slot i only.  fn(i, state_i) -> (aux_i, new_state_i)."""

        def mk(i):
            def br(sts):
                aux, si = fn(i, sts[i])
                return aux, tuple(
                    si if j == i else sts[j] for j in range(len(sts))
                )

            return br

        return jax.lax.switch(bidx, [mk(i) for i in range(len(self.variants))], states)

    def init_state(self, n_conns, key):
        # every variant is seeded with the same key it would get serially
        return (
            jnp.zeros((), jnp.int32),
            tuple(v.init_state(n_conns, key) for v in self.variants),
        )

    def with_branch(self, state, branch_idx):
        """Rebind the branch index (the sweep sets it per scenario row)."""
        return (jnp.asarray(branch_idx, jnp.int32), state[1])

    def choose_ev(self, state, mask, key, now):
        bidx, states = state
        evs, states = self._dispatch(
            bidx, states,
            lambda i, s: self.variants[i].choose_ev(s, mask, key, now),
        )
        return evs, (bidx, states)

    def on_ack(self, state, mask, ev, ecn, now, key):
        bidx, states = state
        _, states = self._dispatch(
            bidx, states,
            lambda i, s: (
                jnp.zeros((), jnp.int32),
                self.variants[i].on_ack(s, mask, ev, ecn, now, key),
            ),
        )
        return (bidx, states)

    def on_timeout(self, state, mask, now, key):
        bidx, states = state
        _, states = self._dispatch(
            bidx, states,
            lambda i, s: (
                jnp.zeros((), jnp.int32),
                self.variants[i].on_timeout(s, mask, now, key),
            ),
        )
        return (bidx, states)

    def trace(self, site, prev, new, mask):
        # Only the active branch mutated its state slot, so only its trace
        # port sees a diff — the switch picks exactly that variant's counts.
        bidx = new[0]

        def mk(i):
            def br(_):
                return self.variants[i].trace(site, prev[1][i], new[1][i], mask)

            return br

        return jax.lax.switch(
            bidx,
            [mk(i) for i in range(len(self.variants))],
            jnp.zeros((), jnp.int32),
        )


# ---------------------------------------------------------------------------
# Adaptive RoCE (NVIDIA Spectrum-X style): in-network per-packet adaptive
# routing — switches pick the least-loaded valid uplink.  The sender sprays
# (EV is ignored by adaptive switches).
# ---------------------------------------------------------------------------
class AdaptiveRoceLB(OpsLB):
    name = "adaptive_roce"
    switch_adaptive = True


REGISTRY = {
    cls.name: cls
    for cls in [
        EcmpLB,
        OpsLB,
        RepsLB,
        PlbLB,
        FlowletLB,
        MptcpLB,
        MprdmaLB,
        BitmapLB,
        AdaptiveRoceLB,
        PrimeLB,
        SeqBalanceLB,
        FlowletTableLB,
    ]
}


def make_lb(name: str, **kwargs) -> LoadBalancer:
    return REGISTRY[name](**kwargs)
