"""Section 5 theory models: batched balls-into-bins (OPS) and the paper's
*recycled* balls-into-bins process (Theorem 5.1), plus the Appendix B EVS
load-imbalance model (Fig. 16) and Appendix D.1 coalesced recycling
(Fig. 17).

All processes are implemented as jitted ``lax.scan`` loops so the
benchmarks (fig13/fig14/fig16/fig17) and the Theorem 5.1 property tests run
fast on CPU.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# OPS model: each step every non-empty bin removes one ball, then ~lam*n new
# balls are thrown uniformly at random (paper §5.1, Fig. 13 top curves).
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(1, 3))
def simulate_ops_bins(
    key: jax.Array, n_bins: int, lam: float, steps: int
) -> jax.Array:
    """Returns (steps,) max bin load over time."""

    def step(carry, key_t):
        loads = carry
        loads = jnp.maximum(loads - 1, 0)  # each non-empty bin serves one
        arrivals = jax.random.bernoulli(
            jax.random.fold_in(key_t, 0), lam, (n_bins,)
        )  # Binomial thinning: expected lam*n arrivals
        targets = jax.random.randint(
            jax.random.fold_in(key_t, 1), (n_bins,), 0, n_bins
        )
        add = jnp.zeros((n_bins,), jnp.int32).at[targets].add(
            arrivals.astype(jnp.int32)
        )
        loads = loads + add
        return loads, jnp.max(loads)

    keys = jax.random.split(key, steps)
    _, max_loads = jax.lax.scan(step, jnp.zeros((n_bins,), jnp.int32), keys)
    return max_loads


# ---------------------------------------------------------------------------
# Recycled balls-into-bins (paper §5.1, Theorem 5.1; Fig. 13/14 bottom).
#
#   * b*n colors cycled round-robin in batches of n.
#   * Each step every non-empty bin removes its FIFO-oldest ball.  If the
#     bin's load (pre-removal) is <= tau the removed ball's color remembers
#     the bin (unless it already remembers one); if > tau the color forgets.
#   * Each color of the current batch throws one ball into its remembered
#     bin, or uniformly at random if it has no memory.
#
# Coalesced recycling (Appendix D.1): with ratio r only every r-th removal
# feeds back into color memory; skipped removals lose their memory (their
# "ACK" never returns), modelling n:1 ACK coalescing.
# ---------------------------------------------------------------------------
class RecycledTrace(NamedTuple):
    max_load: jax.Array  # (steps,) int32
    frac_remember: jax.Array  # (steps,) float32 fraction of colors w/ memory
    loads_final: jax.Array  # (n_bins,) int32


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5, 6))
def simulate_recycled_bins(
    key: jax.Array,
    n_bins: int,
    b: int,
    tau: int,
    steps: int,
    queue_cap: int = 0,
    coalesce: int = 1,
) -> RecycledTrace:
    n = n_bins
    n_colors = b * n
    cap = queue_cap if queue_cap > 0 else max(8 * tau, 64)

    # Per-bin FIFO of color ids (circular).
    queue = jnp.zeros((n, cap), jnp.int32)
    q_head = jnp.zeros((n,), jnp.int32)
    q_len = jnp.zeros((n,), jnp.int32)
    color_bin = jnp.full((n_colors,), -1, jnp.int32)  # -1 = no memory
    removal_seq = jnp.zeros((), jnp.int32)  # global removal counter

    def step(carry, inp):
        queue, q_head, q_len, color_bin, removal_seq = carry
        t, key_t = inp

        # --- removal phase -------------------------------------------------
        nonempty = q_len > 0
        removed_color = jnp.take_along_axis(
            queue, (q_head % cap)[:, None], axis=1
        )[:, 0]
        load_pre = q_len
        q_head = jnp.where(nonempty, q_head + 1, q_head)
        q_len = jnp.where(nonempty, q_len - 1, q_len)

        # Coalescing: only every `coalesce`-th removal (per global sequence)
        # feeds memory; others forget.
        seq_ids = removal_seq + jnp.cumsum(nonempty.astype(jnp.int32)) - 1
        feeds = nonempty & (seq_ids % coalesce == 0)
        removal_seq = removal_seq + jnp.sum(nonempty.astype(jnp.int32))

        remembers = jnp.take(color_bin, removed_color)  # (n,)
        bin_ids = jnp.arange(n, dtype=jnp.int32)
        new_mem = jnp.where(
            load_pre > tau,
            -1,  # overloaded bin: forget
            jnp.where(remembers < 0, bin_ids, remembers),  # remember if free
        )
        # Scatter memory updates for removed colors.  At most one removal per
        # bin per step, and a color currently in only one bin's head slot, so
        # collisions are benign (last-write-wins matches the model).
        color_bin = color_bin.at[removed_color].set(
            jnp.where(nonempty & feeds, new_mem, jnp.take(color_bin, removed_color)),
            mode="drop",
        )

        # --- arrival phase: batch of n colors, round-robin -----------------
        batch_colors = (t * n + jnp.arange(n, dtype=jnp.int32)) % n_colors
        mem = jnp.take(color_bin, batch_colors)
        rand_bins = jax.random.randint(key_t, (n,), 0, n)
        targets = jnp.where(mem >= 0, mem, rand_bins)

        # Multi-enqueue with intra-step FIFO ranking (one-hot cumsum).
        onehot = (targets[:, None] == jnp.arange(n)[None, :]).astype(jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) - 1  # rank within target bin
        rank_of_ball = jnp.take_along_axis(rank, targets[:, None], axis=1)[:, 0]
        slot = (jnp.take(q_head + q_len, targets) + rank_of_ball) % cap
        queue = queue.at[targets, slot].set(batch_colors)
        q_len = q_len + jnp.sum(onehot, axis=0)

        stats = (jnp.max(q_len), jnp.mean((color_bin >= 0).astype(jnp.float32)))
        return (queue, q_head, q_len, color_bin, removal_seq), stats

    keys = jax.random.split(key, steps)
    ts = jnp.arange(steps, dtype=jnp.int32)
    carry, (max_load, frac_remember) = jax.lax.scan(
        step, (queue, q_head, q_len, color_bin, removal_seq), (ts, keys)
    )
    return RecycledTrace(
        max_load=max_load, frac_remember=frac_remember, loads_final=carry[2]
    )


# ---------------------------------------------------------------------------
# Appendix B (Fig. 16): EVS load imbalance under uniform hashing.
# m = flows * evs_size distinct (flow, EV) pairs hashed onto n uplinks.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def evs_load_imbalance(
    key: jax.Array, n_ports: int, evs_size: int, n_flows: int, n_trials: int
) -> jax.Array:
    """Returns (n_trials,) load imbalance lambda = max_load/(m/n) - 1."""

    def trial(key_i):
        m = evs_size * n_flows
        ports = jax.random.randint(key_i, (m,), 0, n_ports)
        loads = jnp.zeros((n_ports,), jnp.int32).at[ports].add(1)
        return jnp.max(loads).astype(jnp.float32) / (m / n_ports) - 1.0

    return jax.vmap(trial)(jax.random.split(key, n_trials))
