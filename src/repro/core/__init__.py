from repro.core import balls_bins, load_balancers, reps
from repro.core.load_balancers import REGISTRY, LoadBalancer, SwitchLB, make_lb
from repro.core.reps import REPSConfig, REPSOracle, REPSState

__all__ = [
    "balls_bins",
    "load_balancers",
    "reps",
    "REGISTRY",
    "LoadBalancer",
    "SwitchLB",
    "make_lb",
    "REPSConfig",
    "REPSOracle",
    "REPSState",
]
