"""REPS — Recycled Entropy Packet Spraying (the paper's core algorithm).

Faithful, vectorized implementation of the paper's Algorithms 1 and 2
("ARCANE" in the supplied text = REPS; see DESIGN.md §0).

Per-connection state (paper Table 1, ~25 bytes with an 8-deep buffer):

  * circular buffer of ``buffer_size`` cached entropy values (EVs), each
    with a validity bit,
  * ``head`` pointer, ``num_valid`` counter,
  * ``explore_counter`` (initialized to one BDP worth of packets),
  * freezing-mode flag and exit-freezing deadline.

All procedures are branch-free (``jnp.where``) updates over an arbitrary
batch of connections so they vectorize on TPU/CPU, can be driven by the
netsim engine one tick at a time, and are bit-identical to the scalar
pseudocode (tests assert this against a pure-Python oracle).

Semantics notes, tied to the paper's pseudocode:
  * ``on_ack`` (Alg. 1): ECN-marked ACKs are discarded entirely.  A clean
    ACK's EV is written at ``head`` (overwriting), validity set, head
    advanced.  Freezing mode is exited when ``now > exit_freezing`` and, on
    exit, ``explore_counter`` is re-armed to one BDP so the sender re-probes
    the network.
  * ``on_failure_detection`` (Alg. 1): enter freezing mode only when not
    already freezing and not in the warm-up explore phase.
  * ``choose_ev`` (Alg. 2 onSend + getNextEV): explore a uniform EV when the
    buffer has never been written, when there are no valid EVs and we are
    not freezing, or while ``explore_counter > 0``; otherwise pop the
    *oldest valid* EV (offset ``head - num_valid``) and invalidate it — or,
    in freezing mode with no valid EVs, recycle entries at ``head`` even if
    invalid, advancing ``head``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field

DEFAULT_BUFFER_SIZE = 8  # paper §3.1: chosen from Theorem 5.1 bounds


@pytree_dataclass
class REPSConfig:
    buffer_size: int = static_field(default=DEFAULT_BUFFER_SIZE)
    evs_size: int = static_field(default=65536)  # 16-bit EV space (§2.2)
    num_pkts_bdp: int = static_field(default=32)  # warm-up explore budget
    freezing_timeout: int = static_field(default=1024)  # ticks (§3.2)


@pytree_dataclass
class REPSState:
    """Structure-of-arrays over N connections."""

    buf_ev: jax.Array  # (N, B) int32 cached EVs
    buf_valid: jax.Array  # (N, B) bool validity bits
    head: jax.Array  # (N,) int32
    num_valid: jax.Array  # (N,) int32
    explore_counter: jax.Array  # (N,) int32
    is_freezing: jax.Array  # (N,) bool
    exit_freezing: jax.Array  # (N,) int32 tick deadline
    n_cached: jax.Array  # (N,) int32 total EVs ever cached (isEmpty check)


def init_state(cfg: REPSConfig, n_conns: int) -> REPSState:
    B = cfg.buffer_size
    return REPSState(
        buf_ev=jnp.zeros((n_conns, B), jnp.int32),
        buf_valid=jnp.zeros((n_conns, B), jnp.bool_),
        head=jnp.zeros((n_conns,), jnp.int32),
        num_valid=jnp.zeros((n_conns,), jnp.int32),
        explore_counter=jnp.full((n_conns,), cfg.num_pkts_bdp, jnp.int32),
        is_freezing=jnp.zeros((n_conns,), jnp.bool_),
        exit_freezing=jnp.zeros((n_conns,), jnp.int32),
        n_cached=jnp.zeros((n_conns,), jnp.int32),
    )


def on_ack(
    cfg: REPSConfig,
    state: REPSState,
    mask: jax.Array,  # (N,) bool: connection received an ACK this tick
    ev: jax.Array,  # (N,) int32: EV echoed in the ACK
    ecn: jax.Array,  # (N,) bool: ACK is ECN-marked
    now: jax.Array,  # scalar int32 tick
) -> REPSState:
    """Paper Algorithm 1, onAck — vectorized over connections."""
    B = cfg.buffer_size
    cache = mask & ~ecn  # ECN-marked ACKs are discarded (Alg.1 l.6-8)

    head_onehot = jax.nn.one_hot(state.head, B, dtype=jnp.bool_)  # (N,B)
    slot_was_valid = jnp.take_along_axis(
        state.buf_valid, state.head[:, None], axis=1
    )[:, 0]
    num_valid = jnp.where(
        cache & ~slot_was_valid, state.num_valid + 1, state.num_valid
    )
    write = cache[:, None] & head_onehot
    buf_ev = jnp.where(write, ev[:, None], state.buf_ev)
    buf_valid = jnp.where(write, True, state.buf_valid)
    head = jnp.where(cache, (state.head + 1) % B, state.head)
    n_cached = jnp.where(cache, state.n_cached + 1, state.n_cached)

    # Freezing-mode exit check (Alg.1 l.15-18). The pseudocode reaches this
    # only on a clean cached ACK; we keep that gating.
    exit_now = cache & state.is_freezing & (now > state.exit_freezing)
    is_freezing = jnp.where(exit_now, False, state.is_freezing)
    explore_counter = jnp.where(
        exit_now, jnp.int32(cfg.num_pkts_bdp), state.explore_counter
    )
    return REPSState(
        buf_ev=buf_ev,
        buf_valid=buf_valid,
        head=head,
        num_valid=num_valid,
        explore_counter=explore_counter,
        is_freezing=is_freezing,
        exit_freezing=state.exit_freezing,
        n_cached=n_cached,
    )


def on_failure_detection(
    cfg: REPSConfig,
    state: REPSState,
    mask: jax.Array,  # (N,) bool: failure (timeout) detected this tick
    now: jax.Array,
) -> REPSState:
    """Paper Algorithm 1, onFailureDetection — enter freezing mode."""
    enter = mask & ~state.is_freezing & (state.explore_counter == 0)
    return state.replace(
        is_freezing=jnp.where(enter, True, state.is_freezing),
        exit_freezing=jnp.where(
            enter, now + jnp.int32(cfg.freezing_timeout), state.exit_freezing
        ),
    )


def choose_ev(
    cfg: REPSConfig,
    state: REPSState,
    mask: jax.Array,  # (N,) bool: connection sends a data packet this tick
    key: jax.Array,
) -> tuple[jax.Array, REPSState]:
    """Paper Algorithm 2 (onSend + getNextEV) — vectorized.

    Returns (evs, new_state); ``evs[i]`` is only meaningful where
    ``mask[i]``.
    """
    N, B = state.buf_ev.shape
    rand_ev = jax.random.randint(key, (N,), 0, cfg.evs_size, jnp.int32)

    is_empty = state.n_cached == 0
    explore = mask & (
        is_empty
        | ((state.num_valid == 0) & ~state.is_freezing)
        | (state.explore_counter > 0)
    )
    recycle = mask & ~explore  # take from the buffer

    # getNextEV branch 1: pop oldest valid entry.
    pop_valid = recycle & (state.num_valid > 0)
    offset_valid = jnp.mod(state.head - state.num_valid, B)
    # getNextEV branch 2 (freezing, nothing valid): reuse entry at head,
    # advance head.
    reuse = recycle & (state.num_valid == 0)
    offset = jnp.where(pop_valid, offset_valid, state.head)

    picked_ev = jnp.take_along_axis(state.buf_ev, offset[:, None], axis=1)[:, 0]
    evs = jnp.where(recycle, picked_ev, rand_ev)

    offset_onehot = jax.nn.one_hot(offset, B, dtype=jnp.bool_)
    buf_valid = jnp.where(
        pop_valid[:, None] & offset_onehot, False, state.buf_valid
    )
    num_valid = jnp.where(pop_valid, state.num_valid - 1, state.num_valid)
    head = jnp.where(reuse, (state.head + 1) % B, state.head)
    explore_counter = jnp.where(
        explore, jnp.maximum(state.explore_counter - 1, 0), state.explore_counter
    )
    new_state = state.replace(
        buf_valid=buf_valid,
        num_valid=num_valid,
        head=head,
        explore_counter=explore_counter,
    )
    return evs, new_state


def state_footprint_bits(cfg: REPSConfig) -> dict[str, int]:
    """Paper Table 1: per-connection memory footprint in bits."""
    per_element = 16 + 1  # cachedEV + isValid
    globals_bits = {
        "head": 8,
        "numberOfValidEVs": 8,
        "exitFreezingMode": 32,
        "isFreezingMode": 1,
        "exploreCounter": 8,
    }
    total = per_element * cfg.buffer_size + sum(globals_bits.values())
    return {
        "per_buffer_element_bits": per_element,
        "buffer_elements": cfg.buffer_size,
        **{f"global_{k}_bits": v for k, v in globals_bits.items()},
        "total_bits": total,
        "total_bytes_ceil": (total + 7) // 8,
    }


def pack_state(cfg: REPSConfig, state: REPSState) -> "np.ndarray":
    """Bit-pack a REPSState into the paper's Table 1 layout: one
    ``(N, total_bytes_ceil)`` uint8 row per connection — 25 bytes at the
    default 8-deep buffer.  This is the *measured* counterpart of
    ``state_footprint_bits``: ``pack_state(...).nbytes / N`` is the
    footprint the Table 1 scale benchmark and tests/test_scale_mode.py
    assert on, and ``unpack_state`` round-trips it losslessly, so the
    layout provably holds the full algorithmic state.

    Field widths (per conn): ``buffer_size`` × (16-bit EV + 1 validity
    bit), 8-bit head, 8-bit num_valid, 32-bit exit_freezing, 1-bit
    is_freezing, 8-bit explore_counter, plus ONE extra bit beyond Table 1:
    ``ever_cached`` — the implementation's monotone ``n_cached`` counter is
    only ever read as ``n_cached == 0`` (the Alg. 2 isEmpty check), so the
    packed form stores that single bit and ``unpack_state`` reconstructs
    ``n_cached`` as the indicator (0 or 1): exact on every
    algorithm-visible field, 194 bits total, same 25-byte ceiling.
    Requires ``evs_size <= 2**16`` and ``buffer_size``/``num_pkts_bdp``
    < 256 (asserted).
    """
    import numpy as np

    B = cfg.buffer_size
    assert cfg.evs_size <= 1 << 16, "EV does not fit the 16-bit field"
    assert B < 256 and cfg.num_pkts_bdp < 256, "8-bit counters overflow"
    n = int(state.head.shape[0])

    def bits(vals, width):  # (N,) uint -> (N, width) little-endian bits
        v = np.asarray(vals, np.uint32)
        return (v[:, None] >> np.arange(width, dtype=np.uint32)) & 1

    cols = []
    ev = np.asarray(state.buf_ev, np.uint32)
    valid = np.asarray(state.buf_valid)
    for b in range(B):
        cols.append(bits(ev[:, b], 16))
        cols.append(valid[:, b : b + 1].astype(np.uint32))
    cols += [
        bits(state.head, 8),
        bits(state.num_valid, 8),
        bits(np.asarray(state.exit_freezing, np.int64) & 0xFFFFFFFF, 32),
        np.asarray(state.is_freezing).astype(np.uint32).reshape(n, 1),
        bits(state.explore_counter, 8),
        (np.asarray(state.n_cached) > 0).astype(np.uint32).reshape(n, 1),
    ]
    stream = np.concatenate(cols, axis=1).astype(np.uint8)
    assert stream.shape[1] == state_footprint_bits(cfg)["total_bits"] + 1
    return np.packbits(stream, axis=1, bitorder="little")


def unpack_state(cfg: REPSConfig, packed: "np.ndarray") -> REPSState:
    """Inverse of ``pack_state``: exact on every algorithm-visible field
    (``n_cached`` comes back as its 0/1 isEmpty indicator — see
    ``pack_state``)."""
    import numpy as np

    B = cfg.buffer_size
    n = packed.shape[0]
    total = state_footprint_bits(cfg)["total_bits"] + 1
    stream = np.unpackbits(packed, axis=1, bitorder="little")[:, :total]

    pos = 0

    def take(width):
        nonlocal pos
        chunk = stream[:, pos : pos + width].astype(np.uint32)
        pos += width
        return (chunk << np.arange(width, dtype=np.uint32)).sum(
            axis=1, dtype=np.uint32
        )

    buf_ev = np.empty((n, B), np.int32)
    buf_valid = np.empty((n, B), bool)
    for b in range(B):
        buf_ev[:, b] = take(16).astype(np.int32)
        buf_valid[:, b] = take(1).astype(bool)
    head = take(8).astype(np.int32)
    num_valid = take(8).astype(np.int32)
    exit_freezing = take(32).astype(np.int32)
    is_freezing = take(1).astype(bool)
    explore_counter = take(8).astype(np.int32)
    ever_cached = take(1).astype(np.int32)
    return REPSState(
        buf_ev=jnp.asarray(buf_ev),
        buf_valid=jnp.asarray(buf_valid),
        head=jnp.asarray(head),
        num_valid=jnp.asarray(num_valid),
        explore_counter=jnp.asarray(explore_counter),
        is_freezing=jnp.asarray(is_freezing),
        exit_freezing=jnp.asarray(exit_freezing),
        n_cached=jnp.asarray(ever_cached),
    )


class REPSOracle:
    """Scalar pure-Python oracle transcribing the paper's pseudocode
    literally (used by tests to pin the vectorized version's semantics)."""

    def __init__(self, cfg: REPSConfig):
        self.cfg = cfg
        B = cfg.buffer_size
        self.buf_ev = [0] * B
        self.buf_valid = [False] * B
        self.head = 0
        self.num_valid = 0
        self.explore_counter = cfg.num_pkts_bdp
        self.is_freezing = False
        self.exit_freezing = 0
        self.n_cached = 0

    def on_ack(self, ev: int, ecn: bool, now: int) -> None:
        if ecn:
            return
        if not self.buf_valid[self.head]:
            self.num_valid += 1
        self.buf_ev[self.head] = ev
        self.buf_valid[self.head] = True
        self.head = (self.head + 1) % self.cfg.buffer_size
        self.n_cached += 1
        if self.is_freezing and now > self.exit_freezing:
            self.is_freezing = False
            self.explore_counter = self.cfg.num_pkts_bdp

    def on_failure_detection(self, now: int) -> None:
        if not self.is_freezing and self.explore_counter == 0:
            self.is_freezing = True
            self.exit_freezing = now + self.cfg.freezing_timeout

    def _get_next_ev(self) -> int:
        B = self.cfg.buffer_size
        if self.num_valid > 0:
            offset = (self.head - self.num_valid) % B
            self.buf_valid[offset] = False
            self.num_valid -= 1
        else:  # must be in freezing mode
            offset = self.head
            self.head = (self.head + 1) % B
        return self.buf_ev[offset]

    def on_send(self, rand_ev: int) -> int:
        is_empty = self.n_cached == 0
        if (
            is_empty
            or (self.num_valid == 0 and not self.is_freezing)
            or self.explore_counter > 0
        ):
            self.explore_counter = max(self.explore_counter - 1, 0)
            return rand_ev
        return self._get_next_ev()
