"""Pallas TPU kernel: batched FIFO segment rank (tiled histogram scan).

``rank_i = #{j < i : seg_j == seg_i}`` — the stable within-segment rank the
netsim engine uses twice per tick: ranking same-connection ACK events for
the exact ``feedback_rounds`` replay, and ranking same-target arrivals for
FIFO enqueue positions (engine.py §1/§4).

The pure-jnp engine formulation is the O(K²) pairwise compare+reduce; this
kernel is the O(K·S) *tiled sort-free scan*: a running per-segment
histogram block stays resident in VMEM while K streams through in
``K_TILE``-sized chunks — each element's rank is the histogram count of its
segment so far plus its within-tile prefix count (a cumulative sum over the
one-hot tile, lane-parallel over the S segment lanes).  The histogram is
the scan carry, accumulated across the sequential K grid axis exactly like
``queue_tick``'s running occupancy block.

Batching: the kernel body is written per row; under ``jax.vmap`` (the
sweep/fleet (scenario, seed) row axis) the ``pallas_call`` batching rule
prepends a row grid dimension, so one launch covers the whole bucket.

Out-of-range segment ids (``seg >= S``, the engine's sentinel/padding
convention) get rank 0 and never touch the histogram.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_TILE = 128


def _seg_rank_kernel(
    seg_ref,  # (K_TILE, 1) int32 segment id (or >= S: padding, rank 0)
    o_hist_ref,  # (1, S) int32 running per-segment counts (scan carry)
    o_rank_ref,  # (K_TILE, 1) int32
):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_hist_ref[...] = jnp.zeros_like(o_hist_ref)

    hist = o_hist_ref[...]  # (1, S)
    S = hist.shape[1]
    seg = seg_ref[...]  # (T, 1)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], S), 1) == seg
    ).astype(jnp.int32)  # (T, S); all-zero rows for out-of-range ids
    within = jnp.cumsum(onehot, axis=0) - onehot  # same-seg earlier in tile
    base = jnp.sum(hist * onehot, axis=1, keepdims=True)  # count before tile
    my_rank = jnp.sum(within * onehot, axis=1, keepdims=True)
    o_rank_ref[...] = base + my_rank
    o_hist_ref[...] = hist + jnp.sum(onehot, axis=0, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("n_segments", "interpret")
)
def seg_rank_pallas(
    seg: jax.Array,  # (K,) int32; entries >= n_segments rank as 0
    n_segments: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """FIFO rank of each element within its segment, stable in input order.

    Bit-identical to ``repro.kernels.ref.seg_rank_ref`` (and to the
    engine's pairwise/sort jnp formulations) for every ``seg`` in
    ``[0, 2**30)``; ``n_segments`` only has to bound the ids whose ranks
    are consumed.
    """
    K = seg.shape[0]
    S = int(n_segments)
    KP = pl.cdiv(K, K_TILE) * K_TILE
    seg_p = jnp.full((KP,), S, jnp.int32).at[:K].set(seg.astype(jnp.int32))
    grid = (KP // K_TILE,)
    kcol = pl.BlockSpec((K_TILE, 1), lambda i: (i, 0))
    srow = pl.BlockSpec((1, S), lambda i: (0, 0))
    _, rank = pl.pallas_call(
        _seg_rank_kernel,
        grid=grid,
        in_specs=[kcol],
        out_specs=(srow, kcol),
        out_shape=(
            jax.ShapeDtypeStruct((1, S), jnp.int32),
            jax.ShapeDtypeStruct((KP, 1), jnp.int32),
        ),
        interpret=interpret,
    )(seg_p.reshape(KP, 1))
    return rank.reshape(KP)[:K]
