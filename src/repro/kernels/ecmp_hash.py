"""Pallas TPU kernel: batched ECMP mixing hash (flow, EV, salt) -> port.

The switch datapath hashes every packet header; in the vectorized simulator
this is a wide elementwise u32 mix — a pure VPU kernel.  Inputs are tiled
(ROWS x 128) int32 blocks resident in VMEM; lanes are the 128-wide vector
dimension of the TPU VPU, rows are sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
ROW_TILE = 8  # one (8, 128) VREG per block step


def _mix_kernel(flow_ref, ev_ref, salt_ref, nports_ref, out_ref):
    flow = flow_ref[...].astype(jnp.uint32)
    ev = ev_ref[...].astype(jnp.uint32)
    salt = salt_ref[...].astype(jnp.uint32)
    x = (
        flow * jnp.uint32(0x9E3779B1)
        ^ ev * jnp.uint32(0x85EBCA77)
        ^ salt * jnp.uint32(0xC2B2AE3D)
    )
    # murmur3 finalizer
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    nports = nports_ref[0].astype(jnp.uint32)
    out_ref[...] = (x % nports).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ecmp_hash_pallas(
    flow: jax.Array,  # (R, 128) int32
    ev: jax.Array,
    salt: jax.Array,
    nports: jax.Array,  # () int32
    *,
    interpret: bool = True,
) -> jax.Array:
    R = flow.shape[0]
    assert flow.shape[1] == LANES and flow.shape == ev.shape == salt.shape
    grid = (pl.cdiv(R, ROW_TILE),)
    spec = pl.BlockSpec((ROW_TILE, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((R, LANES), jnp.int32),
        interpret=interpret,
    )(flow, ev, salt, nports.reshape(1))
