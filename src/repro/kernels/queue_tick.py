"""Pallas TPU kernel: one switch tick (serve + multi-enqueue + RED/ECN).

The recycled balls-into-bins inner loop (§5.1) and the netsim's
service/arrival steps fused for a single switch: every non-empty served
queue drains one packet, then a batch of K arrivals is enqueued with FIFO
ranking, tail-drop and RED marking.

TPU mapping (DESIGN.md §3.2): the per-arrival "which queue" histogram is a
one-hot (K_TILE x Q) comparison reduced with cumulative sums — lane-parallel
over Q (queues on the 128-lane axis), sequential-grid-accumulated over K
tiles so arbitrarily large arrival batches stream through VMEM while the
running queue-occupancy block stays resident.

Outputs: new queue lengths, per-arrival accept flag, RED mark flag, and the
insert position (used by callers to place payload slots).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_TILE = 128


def _queue_tick_kernel(
    target_ref,  # (K_TILE, 1) int32 arrival target queue (or >= Q: no-op)
    u_ref,  # (K_TILE, 1) float32 uniform for RED
    qlen_ref,  # (1, Q) int32 lengths at tick start
    serve_ref,  # (1, Q) int32 0/1 service mask
    params_ref,  # (4,): [capacity, kmin, kmax, Q]
    o_qlen_ref,  # (1, Q) int32 running lengths (accumulated over K tiles)
    o_accept_ref,  # (K_TILE, 1) int32
    o_mark_ref,  # (K_TILE, 1) int32
    o_pos_ref,  # (K_TILE, 1) int32
):
    cap = params_ref[0]
    kmin = params_ref[1]
    kmax = params_ref[2]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        q0 = qlen_ref[...]
        served = jnp.where((q0 > 0) & (serve_ref[...] == 1), 1, 0)
        o_qlen_ref[...] = q0 - served

    qlen = o_qlen_ref[...]  # (1, Q) running occupancy
    Q = qlen.shape[1]
    target = target_ref[...]  # (T, 1)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (target.shape[0], Q), 1)
        == target
    ).astype(jnp.int32)  # (T, Q)
    rank = jnp.cumsum(onehot, axis=0) - onehot  # arrivals before me, same q
    base = jnp.sum(qlen * onehot, axis=1, keepdims=True)  # qlen[target]
    my_rank = jnp.sum(rank * onehot, axis=1, keepdims=True)
    pos = base + my_rank
    is_real = jnp.sum(onehot, axis=1, keepdims=True) > 0  # target < Q
    accept = is_real & (pos < cap)
    ramp = (pos - kmin).astype(jnp.float32) / jnp.maximum(
        (kmax - kmin).astype(jnp.float32), 1.0
    )
    mark = accept & (u_ref[...] < jnp.clip(ramp, 0.0, 1.0))

    counts = jnp.sum(jnp.where(accept, onehot, 0), axis=0, keepdims=True)
    o_qlen_ref[...] = qlen + counts
    o_accept_ref[...] = accept.astype(jnp.int32)
    o_mark_ref[...] = mark.astype(jnp.int32)
    o_pos_ref[...] = pos


@functools.partial(jax.jit, static_argnames=("interpret",))
def queue_tick_pallas(
    target: jax.Array,  # (K,) int32; entries >= Q are padding no-ops
    u: jax.Array,  # (K,) float32
    qlen: jax.Array,  # (Q,) int32
    serve: jax.Array,  # (Q,) int32/bool
    capacity,
    kmin,
    kmax,
    *,
    interpret: bool = True,
):
    K = target.shape[0]
    Q = qlen.shape[0]
    params = jnp.stack(
        [
            jnp.asarray(capacity, jnp.int32),
            jnp.asarray(kmin, jnp.int32),
            jnp.asarray(kmax, jnp.int32),
            jnp.asarray(Q, jnp.int32),
        ]
    )
    grid = (pl.cdiv(K, K_TILE),)
    kcol = pl.BlockSpec((K_TILE, 1), lambda i: (i, 0))
    qrow = pl.BlockSpec((1, Q), lambda i: (0, 0))
    out = pl.pallas_call(
        _queue_tick_kernel,
        grid=grid,
        in_specs=[kcol, kcol, qrow, qrow, pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=(qrow, kcol, kcol, kcol),
        out_shape=(
            jax.ShapeDtypeStruct((1, Q), jnp.int32),
            jax.ShapeDtypeStruct((K, 1), jnp.int32),
            jax.ShapeDtypeStruct((K, 1), jnp.int32),
            jax.ShapeDtypeStruct((K, 1), jnp.int32),
        ),
        interpret=interpret,
    )(
        target.reshape(K, 1).astype(jnp.int32),
        u.reshape(K, 1).astype(jnp.float32),
        qlen.reshape(1, Q).astype(jnp.int32),
        serve.reshape(1, Q).astype(jnp.int32),
        params,
    )
    new_qlen, accept, mark, pos = out
    return (
        new_qlen.reshape(Q),
        accept.reshape(K).astype(jnp.bool_),
        mark.reshape(K).astype(jnp.bool_),
        pos.reshape(K),
    )
