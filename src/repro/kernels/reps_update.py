"""Pallas TPU kernel: fused per-tick REPS connection-state update.

This is the NIC datapath hot spot of the paper, restructured for a vector
machine (DESIGN.md §3.2): one kernel invocation applies, for a tile of
connections at once, the paper's Algorithm 1 (onAck + onFailureDetection)
followed by Algorithm 2 (onSend/getNextEV) — branch-free selects over the
8-lane circular buffers held in VMEM.

Layout: per grid step a (CONN_TILE, 8) int32 block of buffer state plus
(CONN_TILE, 1) per-connection scalars.  8 is the buffer depth (paper §3.1);
CONN_TILE=128 keeps a step's working set « VMEM while filling VREG lanes.

The pure-jnp oracle is `repro.kernels.ref.reps_tick_ref`, itself pinned to
`repro.core.reps` (which tests pin to the paper's scalar pseudocode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CONN_TILE = 128
BUF = 8  # paper buffer depth


def _reps_tick_kernel(
    # state
    buf_ev_ref, buf_valid_ref, head_ref, num_valid_ref, explore_ref,
    freezing_ref, exit_freeze_ref, n_cached_ref,
    # events
    ack_mask_ref, ack_ev_ref, ack_ecn_ref, timeout_mask_ref, send_mask_ref,
    rand_ev_ref,
    # scalars
    params_ref,  # (3,): [now, num_pkts_bdp, freezing_timeout]
    # outputs
    o_buf_ev_ref, o_buf_valid_ref, o_head_ref, o_num_valid_ref,
    o_explore_ref, o_freezing_ref, o_exit_freeze_ref, o_n_cached_ref,
    o_ev_ref,
):
    now = params_ref[0]
    bdp = params_ref[1]
    freeze_to = params_ref[2]

    buf_ev = buf_ev_ref[...]
    buf_valid = buf_valid_ref[...]  # int32 0/1
    head = head_ref[...]  # (T,1)
    num_valid = num_valid_ref[...]
    explore_ctr = explore_ref[...]
    freezing = freezing_ref[...]  # int32 0/1
    exit_freeze = exit_freeze_ref[...]
    n_cached = n_cached_ref[...]

    lane = jax.lax.broadcasted_iota(jnp.int32, buf_ev.shape, 1)  # (T,8)

    # ---- Algorithm 1: onAck -------------------------------------------
    ack = ack_mask_ref[...]
    cache = (ack == 1) & (ack_ecn_ref[...] == 0)
    at_head = lane == head  # (T,8)
    slot_valid = jnp.sum(jnp.where(at_head, buf_valid, 0), axis=1, keepdims=True)
    num_valid = jnp.where(cache & (slot_valid == 0), num_valid + 1, num_valid)
    wr = cache & at_head
    buf_ev = jnp.where(wr, ack_ev_ref[...], buf_ev)
    buf_valid = jnp.where(wr, 1, buf_valid)
    head = jnp.where(cache, (head + 1) % BUF, head)
    n_cached = jnp.where(cache, n_cached + 1, n_cached)
    exit_now = cache & (freezing == 1) & (now > exit_freeze)
    freezing = jnp.where(exit_now, 0, freezing)
    explore_ctr = jnp.where(exit_now, bdp, explore_ctr)

    # ---- Algorithm 1: onFailureDetection -------------------------------
    enter = (timeout_mask_ref[...] == 1) & (freezing == 0) & (explore_ctr == 0)
    freezing = jnp.where(enter, 1, freezing)
    exit_freeze = jnp.where(enter, now + freeze_to, exit_freeze)

    # ---- Algorithm 2: onSend / getNextEV --------------------------------
    send = send_mask_ref[...] == 1
    is_empty = n_cached == 0
    explore = send & (
        is_empty | ((num_valid == 0) & (freezing == 0)) | (explore_ctr > 0)
    )
    recycle = send & ~explore
    pop_valid = recycle & (num_valid > 0)
    reuse = recycle & (num_valid == 0)
    offset = jnp.where(pop_valid, (head - num_valid) % BUF, head)  # (T,1)
    at_off = lane == offset
    picked = jnp.sum(jnp.where(at_off, buf_ev, 0), axis=1, keepdims=True)
    ev = jnp.where(recycle, picked, rand_ev_ref[...])
    buf_valid = jnp.where(pop_valid & at_off, 0, buf_valid)
    num_valid = jnp.where(pop_valid, num_valid - 1, num_valid)
    head = jnp.where(reuse, (head + 1) % BUF, head)
    explore_ctr = jnp.where(
        explore, jnp.maximum(explore_ctr - 1, 0), explore_ctr
    )

    o_buf_ev_ref[...] = buf_ev
    o_buf_valid_ref[...] = buf_valid
    o_head_ref[...] = head
    o_num_valid_ref[...] = num_valid
    o_explore_ref[...] = explore_ctr
    o_freezing_ref[...] = freezing
    o_exit_freeze_ref[...] = exit_freeze
    o_n_cached_ref[...] = n_cached
    o_ev_ref[...] = ev


@functools.partial(jax.jit, static_argnames=("interpret",))
def reps_tick_pallas(
    buf_ev, buf_valid, head, num_valid, explore, freezing, exit_freeze,
    n_cached, ack_mask, ack_ev, ack_ecn, timeout_mask, send_mask, rand_ev,
    now, num_pkts_bdp, freezing_timeout, *, interpret: bool = True,
):
    """All per-conn inputs are (N,) int32 (masks 0/1); buffers (N, 8) int32.

    Returns the updated state tuple + chosen EVs, same shapes.
    """
    N = buf_ev.shape[0]
    assert buf_ev.shape == (N, BUF)
    col = lambda x: x.reshape(N, 1).astype(jnp.int32)
    params = jnp.stack(
        [
            jnp.asarray(now, jnp.int32),
            jnp.asarray(num_pkts_bdp, jnp.int32),
            jnp.asarray(freezing_timeout, jnp.int32),
        ]
    )

    grid = (pl.cdiv(N, CONN_TILE),)
    buf_spec = pl.BlockSpec((CONN_TILE, BUF), lambda i: (i, 0))
    col_spec = pl.BlockSpec((CONN_TILE, 1), lambda i: (i, 0))
    par_spec = pl.BlockSpec((3,), lambda i: (0,))
    out_shapes = (
        jax.ShapeDtypeStruct((N, BUF), jnp.int32),  # buf_ev
        jax.ShapeDtypeStruct((N, BUF), jnp.int32),  # buf_valid
        *[jax.ShapeDtypeStruct((N, 1), jnp.int32) for _ in range(7)],
    )
    outs = pl.pallas_call(
        _reps_tick_kernel,
        grid=grid,
        in_specs=[buf_spec, buf_spec] + [col_spec] * 12 + [par_spec],
        out_specs=(buf_spec, buf_spec) + (col_spec,) * 7,
        out_shape=out_shapes,
        interpret=interpret,
    )(
        buf_ev.astype(jnp.int32),
        buf_valid.astype(jnp.int32),
        col(head), col(num_valid), col(explore), col(freezing),
        col(exit_freeze), col(n_cached),
        col(ack_mask), col(ack_ev), col(ack_ecn), col(timeout_mask),
        col(send_mask), col(rand_ev),
        params,
    )
    (
        o_buf_ev, o_buf_valid, o_head, o_num_valid, o_explore, o_freezing,
        o_exit_freeze, o_n_cached, o_ev,
    ) = outs
    flat = lambda x: x.reshape(N)
    return (
        o_buf_ev,
        o_buf_valid,
        flat(o_head),
        flat(o_num_valid),
        flat(o_explore),
        flat(o_freezing),
        flat(o_exit_freeze),
        flat(o_n_cached),
        flat(o_ev),
    )
