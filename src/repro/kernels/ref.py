"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each `<name>_ref` computes exactly what `repro.kernels.<name>` must produce;
tests sweep shapes/dtypes and assert allclose/array_equal between the two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import reps as reps_core
from repro.netsim.topology import ecmp_hash as _ecmp_hash_jnp


# ---------------------------------------------------------------------------
def ecmp_hash_ref(flow, ev, salt, nports):
    return _ecmp_hash_jnp(flow, ev, salt, nports)


# ---------------------------------------------------------------------------
def reps_tick_ref(
    buf_ev, buf_valid, head, num_valid, explore, freezing, exit_freeze,
    n_cached, ack_mask, ack_ev, ack_ecn, timeout_mask, send_mask, rand_ev,
    now, num_pkts_bdp, freezing_timeout,
):
    """Fused tick = on_ack -> on_failure_detection -> choose_ev, delegating
    to repro.core.reps (itself pinned to the paper's pseudocode)."""
    cfg = reps_core.REPSConfig(
        buffer_size=buf_ev.shape[1],
        evs_size=2**31 - 1,  # rand_ev supplied externally here
        num_pkts_bdp=int(num_pkts_bdp),
        freezing_timeout=int(freezing_timeout),
    )
    state = reps_core.REPSState(
        buf_ev=jnp.asarray(buf_ev, jnp.int32),
        buf_valid=jnp.asarray(buf_valid).astype(jnp.bool_),
        head=jnp.asarray(head, jnp.int32),
        num_valid=jnp.asarray(num_valid, jnp.int32),
        explore_counter=jnp.asarray(explore, jnp.int32),
        is_freezing=jnp.asarray(freezing).astype(jnp.bool_),
        exit_freezing=jnp.asarray(exit_freeze, jnp.int32),
        n_cached=jnp.asarray(n_cached, jnp.int32),
    )
    now = jnp.asarray(now, jnp.int32)
    state = reps_core.on_ack(
        cfg,
        state,
        jnp.asarray(ack_mask).astype(jnp.bool_),
        jnp.asarray(ack_ev, jnp.int32),
        jnp.asarray(ack_ecn).astype(jnp.bool_),
        now,
    )
    state = reps_core.on_failure_detection(
        cfg, state, jnp.asarray(timeout_mask).astype(jnp.bool_), now
    )
    # choose_ev with externally-supplied uniform EVs: replicate its logic
    # but substitute rand_ev for the drawn randomness.
    send = jnp.asarray(send_mask).astype(jnp.bool_)
    B = cfg.buffer_size
    is_empty = state.n_cached == 0
    explore_m = send & (
        is_empty
        | ((state.num_valid == 0) & ~state.is_freezing)
        | (state.explore_counter > 0)
    )
    recycle = send & ~explore_m
    pop_valid = recycle & (state.num_valid > 0)
    reuse = recycle & (state.num_valid == 0)
    offset = jnp.where(
        pop_valid, jnp.mod(state.head - state.num_valid, B), state.head
    )
    picked = jnp.take_along_axis(state.buf_ev, offset[:, None], axis=1)[:, 0]
    ev = jnp.where(recycle, picked, jnp.asarray(rand_ev, jnp.int32))
    oh = jax.nn.one_hot(offset, B, dtype=jnp.bool_)
    buf_valid2 = jnp.where(pop_valid[:, None] & oh, False, state.buf_valid)
    num_valid2 = jnp.where(pop_valid, state.num_valid - 1, state.num_valid)
    head2 = jnp.where(reuse, (state.head + 1) % B, state.head)
    explore2 = jnp.where(
        explore_m,
        jnp.maximum(state.explore_counter - 1, 0),
        state.explore_counter,
    )
    return (
        state.buf_ev,
        buf_valid2.astype(jnp.int32),
        head2,
        num_valid2,
        explore2,
        state.is_freezing.astype(jnp.int32),
        state.exit_freezing,
        state.n_cached,
        ev,
    )


# ---------------------------------------------------------------------------
def seg_rank_ref(seg, n_segments):
    """Stable FIFO rank within each segment: rank_i = #{j < i : seg_j ==
    seg_i}, computed with the O(K^2) pairwise compare+reduce.  Out-of-range
    ids (>= n_segments) still rank against their own kind here — the kernel
    returns 0 for them instead, so compare only in-range lanes (callers
    never consume out-of-range ranks)."""
    del n_segments  # rank is well-defined without the bound
    seg = jnp.asarray(seg, jnp.int32)
    K = seg.shape[0]
    earlier = jnp.tril(jnp.ones((K, K), jnp.bool_), k=-1)
    same = seg[None, :] == seg[:, None]
    return jnp.sum(same & earlier, axis=1, dtype=jnp.int32)


def seg_sum_ref(seg, vals, n_segments):
    """Dense one-hot masked reduction: out[f, s] = sum_k vals[f, k] *
    (seg[k] == s).  Ids >= n_segments fall outside every bucket."""
    seg = jnp.asarray(seg, jnp.int32)
    vals = jnp.asarray(vals, jnp.int32)
    oh = seg[:, None] == jnp.arange(n_segments, dtype=jnp.int32)[None, :]
    return jnp.sum(
        jnp.where(oh[None, :, :], vals[:, :, None], 0), axis=1,
        dtype=jnp.int32,
    )


# ---------------------------------------------------------------------------
def queue_tick_ref(target, u, qlen, serve, capacity, kmin, kmax, tile=128):
    """Serve-then-enqueue with FIFO ranking, tail drop and RED marking.

    Mirrors the kernel's tile-streaming semantics: arrivals are processed in
    `tile`-sized chunks; each chunk's insert positions are computed against
    the running occupancy (initial lengths minus service plus previously
    accepted arrivals)."""
    Q = qlen.shape[0]
    K = target.shape[0]
    served = jnp.where((jnp.asarray(qlen) > 0) & (jnp.asarray(serve) == 1), 1, 0)
    run = jnp.asarray(qlen, jnp.int32) - served
    accepts, marks, poss = [], [], []
    for s in range(0, K, tile):
        t = jnp.asarray(target[s : s + tile], jnp.int32)
        uu = jnp.asarray(u[s : s + tile], jnp.float32)
        onehot = (t[:, None] == jnp.arange(Q)[None, :]).astype(jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) - onehot
        base = (run[None, :] * onehot).sum(axis=1)
        my_rank = (rank * onehot).sum(axis=1)
        pos = base + my_rank
        is_real = onehot.sum(axis=1) > 0
        accept = is_real & (pos < capacity)
        ramp = jnp.clip(
            (pos - kmin).astype(jnp.float32)
            / jnp.maximum(jnp.float32(kmax - kmin), 1.0),
            0.0,
            1.0,
        )
        mark = accept & (uu < ramp)
        run = run + jnp.where(accept[:, None], onehot, 0).sum(axis=0)
        accepts.append(accept)
        marks.append(mark)
        poss.append(pos)
    return (
        run,
        jnp.concatenate(accepts),
        jnp.concatenate(marks),
        jnp.concatenate(poss),
    )
