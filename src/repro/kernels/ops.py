"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the
kernel body runs in Python on the CPU backend, which is what the tests
validate against the pure-jnp oracles in ``repro.kernels.ref``.  On a real
TPU backend the same ``pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels import ecmp_hash as _eh
from repro.kernels import queue_tick as _qt
from repro.kernels import reps_update as _ru
from repro.kernels import seg_rank as _sr
from repro.kernels import seg_sum as _ss


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ecmp_hash(flow, ev, salt, nports):
    """(R,128) int32 tiles -> ECMP port choice per element."""
    return _eh.ecmp_hash_pallas(flow, ev, salt, nports, interpret=_interpret())


def reps_tick(*args, **kwargs):
    """Fused REPS per-tick update; see repro.kernels.reps_update."""
    return _ru.reps_tick_pallas(*args, interpret=_interpret(), **kwargs)


def queue_tick(*args, **kwargs):
    """One switch tick: serve + enqueue + RED; see repro.kernels.queue_tick."""
    return _qt.queue_tick_pallas(*args, interpret=_interpret(), **kwargs)


def seg_rank(seg, n_segments):
    """(K,) int32 -> stable FIFO rank within each segment; see
    repro.kernels.seg_rank (batched over sweep rows via vmap)."""
    return _sr.seg_rank_pallas(seg, n_segments, interpret=_interpret())


def seg_sum(seg, vals, n_segments):
    """(K,), (F, K) int32 -> (F, n_segments) stacked segment sums; see
    repro.kernels.seg_sum (batched over sweep rows via vmap)."""
    return _ss.seg_sum_pallas(seg, vals, n_segments, interpret=_interpret())
