"""Pallas TPU kernel: batched multi-field segment sum.

``out[f, s] = sum_k vals[f, k] * (seg[k] == s)`` — the per-connection /
per-round event aggregation the netsim tick is built on (inflight and
retransmit accounting, NACK counts, delivery/coalescing bookkeeping,
injection window updates: engine.py §1/§2/§3/§5).  The engine's jnp
formulation is a stacked scatter-add; the seed formulation this replaces
was a dense ``(K, S)`` one-hot masked reduction per field.

Kernel shape: the ``(F, S)`` accumulator block stays resident in VMEM
(scan carry, like ``queue_tick``'s occupancy row) while the K event axis
streams through in ``K_TILE`` chunks; each chunk reduces its one-hot
``(T, S)`` against all F value rows — lane-parallel over the S segment
lanes, sequential-grid-accumulated over K tiles, so arbitrarily large
event batches never materialize a ``(K, S)`` intermediate.

Batching: written per row; under ``jax.vmap`` (the sweep/fleet
(scenario, seed) row axis) the ``pallas_call`` batching rule prepends a
row grid dimension — one launch per bucket tick, not one per row.

Out-of-range segment ids (``seg >= S``) contribute to no bucket — the
engine's sentinel convention (events of padded rows aggregate to the
``NC`` sentinel column, which callers slice off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_TILE = 128


def _seg_sum_kernel(
    seg_ref,  # (K_TILE, 1) int32 segment id (or >= S: no-op)
    vals_ref,  # (F, K_TILE) int32
    o_sum_ref,  # (F, S) int32 accumulator (carried across K tiles)
):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_sum_ref[...] = jnp.zeros_like(o_sum_ref)

    S = o_sum_ref.shape[1]
    F = o_sum_ref.shape[0]
    seg = seg_ref[...]  # (T, 1)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], S), 1) == seg
    )  # (T, S) bool; all-false rows for out-of-range ids
    vals = vals_ref[...]  # (F, T)
    acc = o_sum_ref[...]
    # per-field masked reduce keeps the live intermediate at (T, S) — F is
    # a handful of stacked counters, S is the segment axis on the lanes
    for f in range(F):
        acc = acc.at[f].add(
            jnp.sum(jnp.where(onehot, vals[f][:, None], 0), axis=0)
        )
    o_sum_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("n_segments", "interpret")
)
def seg_sum_pallas(
    seg: jax.Array,  # (K,) int32; entries >= n_segments are dropped
    vals: jax.Array,  # (F, K) int32 stacked fields
    n_segments: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Segment-sum ``F`` stacked int32 fields into ``n_segments`` buckets.

    Returns ``(F, n_segments)`` int32.  Integer addition is associative and
    commutative, so the result is bit-identical to the dense one-hot
    reduction (``repro.kernels.ref.seg_sum_ref``) and to the engine's jnp
    scatter-add for any accumulation order.
    """
    K = seg.shape[0]
    F = vals.shape[0]
    S = int(n_segments)
    KP = pl.cdiv(K, K_TILE) * K_TILE
    seg_p = jnp.full((KP,), S, jnp.int32).at[:K].set(seg.astype(jnp.int32))
    vals_p = jnp.zeros((F, KP), jnp.int32).at[:, :K].set(
        vals.astype(jnp.int32)
    )
    grid = (KP // K_TILE,)
    out = pl.pallas_call(
        _seg_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((F, K_TILE), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((F, S), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, S), jnp.int32),
        interpret=interpret,
    )(seg_p.reshape(KP, 1), vals_p)
    return out
