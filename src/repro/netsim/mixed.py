"""MixedLB: run two load balancers side by side in one simulation
(foreground vs background traffic, paper Fig. 5 / incremental deployment).

Each connection is statically assigned to cohort A or B; state for both LBs
is kept and events are routed by the cohort mask.  The cohort is specified
either as a boolean mask over the workload's connections or as a tuple of
background conn indices (``bg_conns``) — the mask itself is materialized in
``init_state`` at the engine's conn-table width, so padded sweep rows
(extra inert conns) default to the foreground cohort and the serial/sweep
streams stay bit-identical.

Registered as ``make_lb("mixed", fg=..., bg=..., bg_conns=(...))`` so sweep
cells (repro.netsim.sweep) can carry mixed cohorts through the hashable
``lb_kwargs`` spec.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.load_balancers import REGISTRY, LoadBalancer, make_lb


class MixedLB(LoadBalancer):
    name = "mixed"

    def __init__(
        self,
        lb_a: LoadBalancer,
        lb_b: LoadBalancer,
        b_mask: np.ndarray | None = None,
        bg_conns: tuple[int, ...] | None = None,
    ):
        super().__init__(lb_a.evs_size)
        assert not (lb_a.switch_adaptive or lb_b.switch_adaptive), (
            "mixed mode supports endpoint LBs only"
        )
        assert (b_mask is None) != (bg_conns is None), (
            "pass exactly one of b_mask / bg_conns"
        )
        if b_mask is not None:
            bg_conns = tuple(
                int(i) for i in np.nonzero(np.asarray(b_mask, bool))[0]
            )
        self.lb_a, self.lb_b = lb_a, lb_b
        self.bg_conns = tuple(int(i) for i in bg_conns)
        self.name = f"mixed({lb_a.name}+{lb_b.name})"

    def _mask(self, n_conns: int) -> np.ndarray:
        bm = np.zeros((n_conns,), bool)
        if self.bg_conns:
            bm[list(self.bg_conns)] = True
        return bm

    def init_state(self, n_conns, key):
        import jax

        ka, kb = jax.random.split(key)
        return (
            self.lb_a.init_state(n_conns, ka),
            self.lb_b.init_state(n_conns, kb),
            jnp.asarray(self._mask(n_conns)),
        )

    def choose_ev(self, state, mask, key, now):
        import jax

        sa, sb, bm = state
        ka, kb = jax.random.split(key)
        ev_a, sa = self.lb_a.choose_ev(sa, mask & ~bm, ka, now)
        ev_b, sb = self.lb_b.choose_ev(sb, mask & bm, kb, now)
        return jnp.where(bm, ev_b, ev_a), (sa, sb, bm)

    def on_ack(self, state, mask, ev, ecn, now, key):
        import jax

        sa, sb, bm = state
        ka, kb = jax.random.split(key)
        sa = self.lb_a.on_ack(sa, mask & ~bm, ev, ecn, now, ka)
        sb = self.lb_b.on_ack(sb, mask & bm, ev, ecn, now, kb)
        return (sa, sb, bm)

    def on_timeout(self, state, mask, now, key):
        import jax

        sa, sb, bm = state
        ka, kb = jax.random.split(key)
        sa = self.lb_a.on_timeout(sa, mask & ~bm, now, ka)
        sb = self.lb_b.on_timeout(sb, mask & bm, now, kb)
        return (sa, sb, bm)

    def trace(self, site, prev, new, mask):
        bm = new[2]
        return self.lb_a.trace(site, prev[0], new[0], mask & ~bm) + self.lb_b.trace(
            site, prev[1], new[1], mask & bm
        )


def _make_mixed(
    fg: str = "ops",
    bg: str = "ecmp",
    bg_conns: tuple[int, ...] = (),
    evs_size: int = 65536,
) -> MixedLB:
    """Registry entry: a hashable-kwargs constructor for sweep cells."""
    return MixedLB(
        make_lb(fg, evs_size=evs_size),
        make_lb(bg, evs_size=evs_size),
        bg_conns=tuple(bg_conns),
    )


REGISTRY["mixed"] = _make_mixed
