"""MixedLB: run two load balancers side by side in one simulation
(foreground vs background traffic, paper Fig. 5 / incremental deployment).

Each connection is statically assigned to cohort A or B; state for both LBs
is kept and events are routed by the cohort mask.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.load_balancers import LoadBalancer


class MixedLB(LoadBalancer):
    name = "mixed"

    def __init__(self, lb_a: LoadBalancer, lb_b: LoadBalancer, b_mask: np.ndarray):
        super().__init__(lb_a.evs_size)
        assert not (lb_a.switch_adaptive or lb_b.switch_adaptive), (
            "mixed mode supports endpoint LBs only"
        )
        self.lb_a, self.lb_b = lb_a, lb_b
        self.b_mask_np = np.asarray(b_mask, bool)
        self.name = f"mixed({lb_a.name}+{lb_b.name})"

    def init_state(self, n_conns, key):
        import jax

        ka, kb = jax.random.split(key)
        return (
            self.lb_a.init_state(n_conns, ka),
            self.lb_b.init_state(n_conns, kb),
            jnp.asarray(self.b_mask_np),
        )

    def choose_ev(self, state, mask, key, now):
        import jax

        sa, sb, bm = state
        ka, kb = jax.random.split(key)
        ev_a, sa = self.lb_a.choose_ev(sa, mask & ~bm, ka, now)
        ev_b, sb = self.lb_b.choose_ev(sb, mask & bm, kb, now)
        return jnp.where(bm, ev_b, ev_a), (sa, sb, bm)

    def on_ack(self, state, mask, ev, ecn, now):
        sa, sb, bm = state
        sa = self.lb_a.on_ack(sa, mask & ~bm, ev, ecn, now)
        sb = self.lb_b.on_ack(sb, mask & bm, ev, ecn, now)
        return (sa, sb, bm)

    def on_timeout(self, state, mask, now):
        sa, sb, bm = state
        sa = self.lb_a.on_timeout(sa, mask & ~bm, now)
        sb = self.lb_b.on_timeout(sb, mask & bm, now)
        return (sa, sb, bm)
