"""Simulator configuration.

Time is discretized at Δt = one MTU serialization time on the reference
link (4 KiB @ 400 Gb/s ≈ 82 ns — DESIGN.md §3).  All latencies/timeouts are
expressed in ticks; helpers convert from the paper's physical constants.

The paper's defaults (§4.1): 4 KiB MTU, 400 Gb/s links, 500 ns switch
traversal + 500 ns link latency (≈ 1 µs ≈ 12 ticks per hop), RTO = 70 µs
(≈ 854 ticks), queue size = 1 BDP with RED thresholds Kmin = 20 % and
Kmax = 80 % of it.
"""
from __future__ import annotations

import dataclasses

TICK_NS = 81.92  # 4 KiB at 400 Gb/s


def ns_to_ticks(ns: float) -> int:
    return max(1, int(round(ns / TICK_NS)))


def us_to_ticks(us: float) -> int:
    return ns_to_ticks(us * 1000.0)


INT32_MAX = 2**31 - 1


def checked_auto_pkt_slots(
    n_conns: int, max_cwnd_pkts: int, n_hosts: int, pin: int = 0
) -> int:
    """THE packet-slot auto-sizing rule (``pkt_slots = n_conns * max_cwnd
    + slack``, rounded to a power of two), computed in python ints and
    validated against the engine's int32 slot namespace.

    The packet table, the free list and every slot index the engine
    scatters through are int32; near 10⁶ connections the raw product
    ``n_conns * max_cwnd_pkts`` crosses 2³¹ long before any array is
    allocated, and an unchecked ``np.int32`` cast would wrap silently.
    Raises ``ValueError`` naming the inputs instead.
    """
    raw = int(n_conns) * int(max_cwnd_pkts) + 4 * int(n_hosts) + 64
    if pin:
        slots = int(pin)
    else:
        import math

        slots = 1 << max(1, math.ceil(math.log2(max(raw, 2))))
    if slots > INT32_MAX:
        raise ValueError(
            f"pkt_slots auto-sizing overflows int32: n_conns={n_conns} * "
            f"max_cwnd_pkts={max_cwnd_pkts} + slack -> {raw} pkt slots "
            f"(pow2 {slots}), but slot indices are int32 (max {INT32_MAX}). "
            "Pin SimConfig.pkt_slots to an explicit budget (e.g. with "
            "conn_sharding=True, where the active-set cap bounds live "
            "packets) or reduce n_conns/max_cwnd_pkts."
        )
    return slots


@dataclasses.dataclass(frozen=True)
class SimConfig:
    # --- topology ---------------------------------------------------------
    n_hosts: int = 128
    hosts_per_tor: int = 16
    tiers: int = 2  # 2 or 3
    uplinks_per_tor: int = 16  # 2-tier: == number of spines
    # 3-tier only:
    tors_per_pod: int = 4
    aggs_per_pod: int = 4
    agg_uplinks: int = 4  # cores per agg
    # Generated fabric (netsim/topogen.py): empty string = the built-in
    # arithmetic fat-tree above; otherwise a deterministic generator spec
    # like "clos3:pods=4,tors=2,hosts=4,aggs=2,up=2", "rail:..." or
    # "mesh:...".  The spec string (not the generated tables) lives on the
    # config so SimConfig stays frozen/hashable and `replace()`-able; the
    # generator is pure, so equal strings always build identical fabrics.
    fabric: str = ""

    # --- timing -----------------------------------------------------------
    hop_latency_ticks: int = 12  # 500 ns link + 500 ns switch
    ack_delay_ticks: int = 24  # ACK return latency (unqueued, 64 B)
    rto_ticks: int = 854  # 70 us
    nack_delay_ticks: int = 24  # trimmed-header return latency

    # --- queues / ECN (RED) -------------------------------------------------
    queue_capacity: int = 85  # ~1 BDP in packets
    kmin_frac: float = 0.2
    kmax_frac: float = 0.8
    pmax: float = 1.0  # RED marking prob at kmax

    # --- transport ----------------------------------------------------------
    max_msg_pkts: int = 4096  # bitmap width (max message size in packets)
    ack_coalesce: int = 1  # n:1 ACK coalescing (paper §4.5.1)
    trimming: bool = False  # paper's main runs use RTO only (App. A)
    max_cwnd_pkts: int = 170  # 2 BDP
    init_cwnd_pkts: int = 85  # 1 BDP

    # --- congestion control --------------------------------------------------
    cc: str = "dctcp"  # dctcp | eqds | delay
    dctcp_g: float = 1.0 / 16.0
    delay_target_ticks: int = 64
    delay_beta: float = 0.5

    # --- load balancing -------------------------------------------------------
    evs_size: int = 65536

    # --- engine sizing ---------------------------------------------------------
    pkt_slots: int = 0  # 0 = auto (n_conns * max_cwnd + slack)
    # --- conn-scale mode --------------------------------------------------
    # Opt-in million-connection mode (ARCHITECTURE.md §10).  When True the
    # engine (a) iterates the packet table through a sparse active-slot set
    # so per-tick cost tracks live traffic instead of pkt_slots width, and
    # (b) accepts a conn-axis mesh (distrib.sharding.CONN_AXIS) that shards
    # per-connection state storage across devices under shard_map.  Off by
    # default: at figure scales every committed BENCH row and parity test
    # runs the dense path byte-for-byte.  With the active-set cap at its
    # auto size the sparse path is itself bit-identical to the dense path
    # whenever the cap does not bind (tests/test_scale_mode.py locks this).
    conn_sharding: bool = False
    # Sparse active-set capacity (conn_sharding only): max packet slots
    # live at once.  0 = auto — min(pkt_slots, pow2 of the slot-lifetime
    # bound NH * (rto + drain + ack slack)); injection beyond the cap
    # alloc-fails (counted in s_alloc_fail) exactly like free-list
    # exhaustion, and never silently drops an allocated slot.
    active_slots: int = 0
    # Shape pins for the sweep engine's bucketing (netsim/sweep.py): padding
    # two scenarios to one compiled shape requires the *derived* static sizes
    # (per-conn bitmap width, host conn-table width) to match too, or the
    # round-robin / RNG streams diverge from the serial reference.  0 = auto
    # (derive from the workload, the seed behavior).
    msg_slots: int = 0  # 0 = auto (pow2 of the workload's max message)
    conns_per_host: int = 0  # 0 = auto (max conns sharing one source host)
    # Failure-schedule row pin: pad the schedule with inert rows to this
    # length at Simulator build (0 = use the schedule as given).  The sweep
    # packer sets it on bucket configs so a serial reference built from the
    # *raw* schedule still shares the bucket's (F,) shape; pad semantics
    # (never resurrect a link) live on FailureSchedule.pad_to/validate.
    failure_slots: int = 0
    feedback_rounds: int = 2  # exact per-conn events applied per tick
    n_watch_queues: int = 16  # queues traced per tick for micro figures
    # arrivals enqueue backend: "jnp" (segment-cumsum in the tick body),
    # "pallas" (fused repro.kernels.queue_tick; interpret mode off-TPU), or
    # "auto" (pallas on TPU, jnp elsewhere).
    arrivals_backend: str = "auto"
    # tick hot-spot kernel backend for the batched segment-rank and
    # segment-sum primitives (repro.kernels.seg_rank / seg_sum) the engine's
    # feedback/RTO/delivery/injection accounting is built on: "jnp" (scatter
    # formulations in the tick body), "pallas" (the tiled kernels; Mosaic on
    # TPU, interpret mode elsewhere — parity-tested bit-identical), or
    # "auto" (pallas on TPU, jnp elsewhere).  Because the kernels sit inside
    # the vmapped ``step_scenario``, the sweep/fleet row axis batches them
    # into one launch per tick (grid over rows x tiles), not one per row.
    kernels_backend: str = "auto"

    def __post_init__(self):
        assert self.arrivals_backend in ("auto", "jnp", "pallas"), (
            f"unknown arrivals_backend {self.arrivals_backend!r}"
        )
        assert self.kernels_backend in ("auto", "jnp", "pallas"), (
            f"unknown kernels_backend {self.kernels_backend!r}"
        )

    # Derived topology ---------------------------------------------------------
    @property
    def n_tors(self) -> int:
        return self.n_hosts // self.hosts_per_tor

    @property
    def n_pods(self) -> int:
        assert self.tiers == 3
        return self.n_tors // self.tors_per_pod

    @property
    def n_spines(self) -> int:
        assert self.tiers == 2
        return self.uplinks_per_tor

    @property
    def n_cores(self) -> int:
        assert self.tiers == 3
        return self.aggs_per_pod * self.agg_uplinks

    @property
    def kmin(self) -> int:
        return max(1, int(self.queue_capacity * self.kmin_frac))

    @property
    def kmax(self) -> int:
        return max(2, int(self.queue_capacity * self.kmax_frac))

    def replace(self, **kw) -> "SimConfig":
        return dataclasses.replace(self, **kw)
