"""Result summarization for simulation runs.

Two paths build a ``RunSummary``:

* ``summarize``        — from a run's final ``SimState`` (host-side).
* ``summarize_sketch`` — from on-device telemetry sketches
  (``repro.netsim.telemetry``, ``collect="summary"``): counters, completion
  counts, runtime and mean FCT are **bit-identical** to the state path
  (running sums/maxes are exact); p99 FCT comes from the log-spaced
  histogram and is exact to bin resolution.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.config import TICK_NS


@dataclasses.dataclass
class RunSummary:
    name: str
    lb: str
    n_conns: int
    completed: int
    runtime_ticks: int  # max FCT over completed conns (the paper's metric)
    runtime_us: float
    mean_fct_ticks: float
    p99_fct_ticks: float
    drops_cong: int
    drops_fail: int
    timeouts: int
    delivered: int
    injected: int
    ecn_marks: int
    unprocessed_events: int
    alloc_fails: int

    def row(self) -> str:
        return (
            f"{self.name},{self.lb},{self.completed}/{self.n_conns},"
            f"{self.runtime_us:.1f},{self.mean_fct_ticks:.0f},"
            f"{self.p99_fct_ticks:.0f},{self.drops_cong},{self.drops_fail},"
            f"{self.timeouts}"
        )


def summarize(
    sim,
    state,
    name: str | None = None,
    lb_name: str | None = None,
    n_conns: int | None = None,
    conn_start=None,
) -> RunSummary:
    """Summarize one run's final state.

    The overrides exist for sweep cells (netsim/sweep.py): the hosting
    bucket simulator carries a SwitchLB and a shape-padded conn table, so
    the cell's true LB name, original conn count, and its own start ticks
    are passed explicitly.  Padded conns never start, so they are invisible
    to every completion/FCT statistic.
    """
    done = np.asarray(state.c_done)
    done_tick = np.asarray(state.c_done_tick)
    start = np.asarray(conn_start if conn_start is not None else sim.conn_start)
    fct = (done_tick - start)[done]
    runtime = int(done_tick[done].max()) if done.any() else -1
    return RunSummary(
        name=name or sim.wl.name,
        lb=lb_name or sim.lb.name,
        n_conns=n_conns if n_conns is not None else sim.wl.n_conns,
        completed=int(done.sum()),
        runtime_ticks=runtime,
        runtime_us=runtime * TICK_NS / 1000.0,
        mean_fct_ticks=float(fct.mean()) if len(fct) else float("nan"),
        p99_fct_ticks=float(np.percentile(fct, 99)) if len(fct) else float("nan"),
        drops_cong=int(state.s_drops_cong),
        drops_fail=int(state.s_drops_fail),
        timeouts=int(state.s_timeouts),
        delivered=int(state.s_delivered),
        injected=int(state.s_injected),
        ecn_marks=int(state.s_ecn_marks),
        unprocessed_events=int(state.s_unprocessed),
        alloc_fails=int(state.s_alloc_fail),
    )


def summarize_sketch(
    tel: dict,
    name: str,
    lb_name: str,
    n_conns: int,
) -> RunSummary:
    """Build a ``RunSummary`` from finalized telemetry channels
    (``TelemetryProgram.finalize_row`` output).

    Requires the ``counters``, ``scalars`` and ``fct_hist`` channels (all in
    ``TelemetrySpec.default()``).  Counter totals telescope to the final
    ``s_stats`` and the scalar channel tracks exact sums/maxes, so every
    field except ``p99_fct_ticks`` is bit-identical to ``summarize`` on the
    run's final state; p99 is the sketch percentile (bin resolution).
    """
    from repro.netsim.telemetry import sketch_percentile

    missing = {"counters", "scalars", "fct_hist"} - set(tel)
    if missing:
        raise ValueError(
            f"summarize_sketch needs channels {sorted(missing)}; "
            "include them in the TelemetrySpec (TelemetrySpec.default() does)"
        )
    c, s, h = tel["counters"], tel["scalars"], tel["fct_hist"]
    completed = s["fct_count"]
    runtime = s["done_tick_max"]
    return RunSummary(
        name=name,
        lb=lb_name,
        n_conns=n_conns,
        completed=completed,
        runtime_ticks=runtime,
        runtime_us=runtime * TICK_NS / 1000.0,
        mean_fct_ticks=s["mean_fct_ticks"],
        p99_fct_ticks=(
            sketch_percentile(h["counts"], h["edges"], 99, zeros=h["zeros"])
            if completed
            else float("nan")
        ),
        drops_cong=c["drops_cong"],
        drops_fail=c["drops_fail"],
        timeouts=c["timeouts"],
        delivered=c["delivered"],
        injected=c["injected"],
        ecn_marks=c["ecn_marks"],
        unprocessed_events=c["unprocessed"],
        alloc_fails=c["alloc_fails"],
    )
