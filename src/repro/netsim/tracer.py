"""On-device flight recorder: typed decision events in a ring-buffer carry.

The telemetry sketches (repro.netsim.telemetry) answer *aggregate*
questions; this module answers *provenance* ones — "why did this connection
keep spraying into the degraded spine", "when exactly did the first
re-routed delivery land".  A ``TracerProgram`` compiles a ``TraceSpec``
into one flat ``(size,)`` int32 carry per sweep row holding a fixed-size
ring of ``(tick, code, value)`` event triples plus a monotone push cursor,
carried through the scanned tick loop under the exact contract the
telemetry carry already obeys: donated, vmapped over rows, sharded by
``shard_map``, frozen per-row past the horizon, and **bitwise no-op on
quiescent ticks** (every push condition derives from the tick's
``Probe``/``TickEvents``, both all-zero at a fixed point) so tracing
composes with quiescence early exit.

Event sources, per tick (engine ``step_events``):

* LB decision counts from the optional ``LoadBalancer.trace`` port —
  REPS EV-cache hit / miss / freezing-recycle / freeze-entry, and re-path
  decisions with cause codes (ACK-ECN, RTO, flowlet gap, epoch boundary) —
  observed as pure state diffs around the three LB call sites, threaded
  through the ``SwitchLB`` dispatch.
* Failure edges: schedule-window activation, the first failure drop, and
  the first re-routed delivery after it.  The first-drop / re-delivery
  logic mirrors ``telemetry.RecoveryTracker`` **exactly** (same
  new-first-drop-then-compare ordering, same same-tick exclusion), so a
  recovery span decoded from the ring has precisely the tracker's
  ``recovery_ticks`` duration.
* Periodic ``MARK`` heartbeat rows (total backlog) on active ticks, so
  long quiet-but-busy stretches keep landmarks in the ring.

Events are *observation-only*: ``update`` never touches simulation or
telemetry state, and the engine stages no trace-port calls at all when
tracing is off — carries are bit-identical to an untraced build either way.

Draining is incremental: ``SoakRunner.advance`` decodes each row's ring
segment ``[last_flushed_cursor, cursor)`` at every chunk boundary and
appends it to atomic ``flight_*.npz`` part files (kill/resume-safe), so a
bounded ring loses events only if more than ``ring`` pushes land within
one chunk (the decoder reports the overwritten count as ``lost``).
Consumers: ``tools/trace_export.py`` (Chrome/Perfetto JSON) and
``benchmarks/soak_dashboard.py`` (live view).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.load_balancers import (
    N_TRACE_KINDS, TR_EV_FREEZE, TR_EV_HIT, TR_EV_MISS, TR_EV_RECYCLE,
    TR_REPATH_ACK_ECN, TR_REPATH_EPOCH, TR_REPATH_FLOWLET, TR_REPATH_RTO,
)
from repro.netsim.engine import BIG, ST_DELIVERED, ST_DROPS_FAIL

# Ring event codes (serialized into flight part files — keep stable).
MARK = 1  # heartbeat on active ticks; value = total queue backlog
EV_HIT = 2  # REPS popped a valid cached EV; value = count this tick
EV_MISS = 3  # REPS explored fresh entropy
EV_RECYCLE = 4  # REPS freezing-mode slot reuse
EV_FREEZE = 5  # REPS entered freezing mode
REPATH_ACK_ECN = 6  # re-path from ECN feedback
REPATH_RTO = 7  # re-path from a timeout
REPATH_FLOWLET = 8  # re-path from a flowlet gap
REPATH_EPOCH = 9  # re-path at an epoch / message boundary
FAIL_ACTIVE = 10  # failure window opened; value = queues affected
FAIL_FIRST_DROP = 11  # first failure drop; value = drops this tick
FAIL_REROUTED = 12  # first delivery after it; value = recovery ticks

CODE_NAMES = {
    MARK: "mark",
    EV_HIT: "ev_hit",
    EV_MISS: "ev_miss",
    EV_RECYCLE: "ev_recycle",
    EV_FREEZE: "ev_freeze",
    REPATH_ACK_ECN: "repath_ack_ecn",
    REPATH_RTO: "repath_rto",
    REPATH_FLOWLET: "repath_flowlet",
    REPATH_EPOCH: "repath_epoch",
    FAIL_ACTIVE: "fail_active",
    FAIL_FIRST_DROP: "fail_first_drop",
    FAIL_REROUTED: "fail_rerouted",
}

# (trace-port kind, ring code) in the static push order — one conditional
# push per kind per tick, so the ring stays deterministic under any chunk
# tiling (pushes depend only on (probe, events), never on wall time).
_LB_CODES = (
    (TR_EV_HIT, EV_HIT),
    (TR_EV_MISS, EV_MISS),
    (TR_EV_RECYCLE, EV_RECYCLE),
    (TR_EV_FREEZE, EV_FREEZE),
    (TR_REPATH_ACK_ECN, REPATH_ACK_ECN),
    (TR_REPATH_RTO, REPATH_RTO),
    (TR_REPATH_FLOWLET, REPATH_FLOWLET),
    (TR_REPATH_EPOCH, REPATH_EPOCH),
)
assert len(_LB_CODES) == N_TRACE_KINDS


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Declarative, hashable flight-recorder knobs.

    ``ring`` bounds the carry (and the per-chunk host transfer) at
    ``3 + 3 × ring`` int32 per row; at most 12 events push per tick, so a
    ring of 256 absorbs ≥ 21 fully-eventful ticks before overwriting —
    soak chunks flush far more often than that in practice, and overwrites
    are *reported* (``lost``), never silent.  ``marker_every`` spaces the
    heartbeat MARK rows (in ticks)."""

    ring: int = 256
    marker_every: int = 256

    def build(self, sim, ticks: int) -> "TracerProgram":
        return TracerProgram(self, sim, ticks)


class TracerProgram:
    """A ``TraceSpec`` compiled against one simulator program.

    Flat per-row carry layout (all int32)::

        [0]                cursor — total pushes ever (monotone)
        [1]                first failure-drop tick (BIG until seen)
        [2]                first re-routed delivery tick (BIG until seen)
        [3        : 3+R ]  ring: event tick
        [3 +   R  : 3+2R]  ring: event code
        [3 + 2R   : 3+3R]  ring: event value

    Event ``k`` (0-based push index) lives at ring slot ``k % R``; the
    host-side ``decode_row`` walks ``[since, cursor)`` in push order.
    """

    def __init__(self, spec: TraceSpec, sim, ticks: int):
        if spec.ring < 16:
            raise ValueError(f"TraceSpec.ring must be >= 16, got {spec.ring}")
        if spec.marker_every < 1:
            raise ValueError(
                f"TraceSpec.marker_every must be >= 1, got {spec.marker_every}"
            )
        self.spec = spec
        self.ring = int(spec.ring)
        self.ticks = int(ticks)
        self.size = 3 + 3 * self.ring

    @property
    def nbytes(self) -> int:
        return self.size * 4

    def init(self) -> jnp.ndarray:
        flat = np.zeros((self.size,), np.int32)
        flat[1] = BIG  # first_drop sentinel
        flat[2] = BIG  # first_redeliver sentinel
        return jnp.asarray(flat)

    def update(self, flat: jnp.ndarray, probe, events) -> jnp.ndarray:
        """One recorder step (pure; vmap over rows).

        Every push condition is False on an all-zero (probe, events) pair,
        so the whole update is a bitwise no-op on quiescent ticks."""
        R = self.ring
        cursor = flat[0]
        first_drop = flat[1]
        first_red = flat[2]
        ticks = flat[3 : 3 + R]
        codes = flat[3 + R : 3 + 2 * R]
        vals = flat[3 + 2 * R : 3 + 3 * R]
        now = probe.now
        sd = probe.stats_delta
        lane = jnp.arange(R, dtype=jnp.int32)

        def push(carry, cond, code, value):
            cursor, ticks, codes, vals = carry
            sel = (lane == cursor % R) & cond
            return (
                cursor + cond.astype(jnp.int32),
                jnp.where(sel, now, ticks),
                jnp.where(sel, jnp.int32(code), codes),
                jnp.where(sel, value.astype(jnp.int32), vals),
            )

        carry = (cursor, ticks, codes, vals)
        for kind, code in _LB_CODES:
            n = events.lb[kind]
            carry = push(carry, n > 0, code, n)
        carry = push(carry, events.fail_start > 0, FAIL_ACTIVE, events.fail_start)

        # First-drop / re-routed-delivery edges: mirror RecoveryTracker
        # bit-exactly (new first_drop computed first; same-tick deliveries
        # excluded by the strict `now > first_drop`), so the decoded span
        # duration equals the tracker's recovery_ticks.
        drops = sd[ST_DROPS_FAIL]
        new_first_drop = jnp.minimum(
            first_drop, jnp.where(drops > 0, now, BIG)
        )
        carry = push(
            carry, (drops > 0) & (first_drop >= BIG), FAIL_FIRST_DROP, drops
        )
        redeliver = (
            (sd[ST_DELIVERED] > 0) & (now > new_first_drop) & (first_red >= BIG)
        )
        carry = push(carry, redeliver, FAIL_REROUTED, now - new_first_drop)
        new_first_red = jnp.minimum(
            first_red,
            jnp.where(
                (sd[ST_DELIVERED] > 0) & (now > new_first_drop), now, BIG
            ),
        )

        # Heartbeat: only on active ticks (a quiescent tick must not push),
        # spaced on the absolute tick so chunk tilings agree.
        active = (
            jnp.any(sd != 0) | jnp.any(probe.q_len > 0) | jnp.any(events.lb != 0)
        )
        marker = active & (now % self.spec.marker_every == 0)
        carry = push(carry, marker, MARK, jnp.sum(probe.q_len))

        cursor, ticks, codes, vals = carry
        return jnp.concatenate([
            cursor[None],
            new_first_drop[None],
            new_first_red[None],
            ticks,
            codes,
            vals,
        ])

    # -- host side ----------------------------------------------------------
    def decode_row(self, flat: np.ndarray, since: int = 0) -> dict:
        """Decode one host-side row's events in push order.

        Returns events ``[max(since, cursor - ring), cursor)`` — ``seq`` is
        the global push index, ``lost`` counts events in ``[since, cursor)``
        already overwritten by ring wrap-around (0 when drained at least
        every ``ring`` pushes)."""
        flat = np.asarray(flat)
        assert flat.shape == (self.size,), (flat.shape, self.size)
        R = self.ring
        cursor = int(flat[0])
        start = max(int(since), cursor - R)
        lost = max(0, start - int(since))
        seq = np.arange(start, cursor, dtype=np.int64)
        idx = (seq % R).astype(np.int64)
        first_drop = int(flat[1])
        first_red = int(flat[2])
        return {
            "seq": seq,
            "tick": flat[3 : 3 + R][idx],
            "code": flat[3 + R : 3 + 2 * R][idx],
            "value": flat[3 + 2 * R : 3 + 3 * R][idx],
            "cursor": cursor,
            "lost": lost,
            "first_drop_tick": -1 if first_drop >= BIG else first_drop,
            "first_redeliver_tick": -1 if first_red >= BIG else first_red,
        }


def run_serial(sim, n_ticks: int, spec: TraceSpec):
    """Serial reference: scan one plain ``Simulator`` with the recorder
    folded in.  Returns ``(final_state, trace_carry)`` — the carry is
    bit-identical to the same scenario's sweep-row carry (tests pin this),
    because pushes depend only on (probe, events) and both are pure in
    (state, tick, key, scenario)."""
    prog = spec.build(sim, n_ticks)
    state0 = sim.init_state()

    def body(carry, t):
        st, trc = carry
        new, probe, ev = sim.step_events(st, t, sim.base_key, sim.scn)
        return (new, prog.update(trc, probe, ev)), None

    (state, trc), _ = jax.lax.scan(
        body, (state0, prog.init()), jnp.arange(n_ticks, dtype=jnp.int32)
    )
    return state, trc
