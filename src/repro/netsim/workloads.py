"""Workload generators (paper §4.2).

All generators return a ``Workload`` (static numpy connection table) for the
engine.  Message sizes are in packets (MTU = 4 KiB default).
"""
from __future__ import annotations

import numpy as np

from repro.netsim.engine import Workload

KIB = 1024


def pkts(nbytes: float, mtu: int = 4 * KIB) -> int:
    return max(1, int(np.ceil(nbytes / mtu)))


# ---------------------------------------------------------------------------
# Synthetic benchmarks: incast / permutation / tornado (§4.2)
# ---------------------------------------------------------------------------
def incast(n_hosts: int, degree: int, msg_pkts: int, receiver: int = 0) -> Workload:
    senders = [h for h in range(n_hosts) if h != receiver][:degree]
    n = len(senders)
    return Workload(
        src=np.asarray(senders, np.int32),
        dst=np.full((n,), receiver, np.int32),
        msg_pkts=np.full((n,), msg_pkts, np.int32),
        start=np.zeros((n,), np.int32),
        dep=np.full((n,), -1, np.int32),
        name=f"incast{degree}",
    )


def permutation(n_hosts: int, msg_pkts: int, seed: int = 0) -> Workload:
    """Random derangement: each host sends to and receives from exactly one."""
    rng = np.random.RandomState(seed)
    while True:
        perm = rng.permutation(n_hosts)
        if not np.any(perm == np.arange(n_hosts)):
            break
    return Workload(
        src=np.arange(n_hosts, dtype=np.int32),
        dst=perm.astype(np.int32),
        msg_pkts=np.full((n_hosts,), msg_pkts, np.int32),
        start=np.zeros((n_hosts,), np.int32),
        dep=np.full((n_hosts,), -1, np.int32),
        name="permutation",
    )


def tornado(n_hosts: int, msg_pkts: int) -> Workload:
    """Each node sends to its twin in the other half of the tree (§4.2)."""
    dst = (np.arange(n_hosts) + n_hosts // 2) % n_hosts
    return Workload(
        src=np.arange(n_hosts, dtype=np.int32),
        dst=dst.astype(np.int32),
        msg_pkts=np.full((n_hosts,), msg_pkts, np.int32),
        start=np.zeros((n_hosts,), np.int32),
        dep=np.full((n_hosts,), -1, np.int32),
        name="tornado",
    )


# ---------------------------------------------------------------------------
# Datacenter traces: websearch flow-size CDF (DCTCP-style; Appendix E),
# Poisson arrivals at a target load, random receivers.
# ---------------------------------------------------------------------------
WEBSEARCH_KB = np.array(
    [1, 2, 3, 5, 7, 10, 15, 30, 50, 80, 200, 1000, 2000, 5000, 10000, 30000],
    np.float64,
)
WEBSEARCH_CDF = np.array(
    [0.10, 0.15, 0.20, 0.30, 0.40, 0.53, 0.60, 0.70, 0.80, 0.90, 0.95, 0.97,
     0.98, 0.99, 0.997, 1.0],
    np.float64,
)


def sample_websearch_kb(rng: np.random.RandomState, n: int) -> np.ndarray:
    u = rng.rand(n)
    idx = np.searchsorted(WEBSEARCH_CDF, u)
    idx = np.clip(idx, 0, len(WEBSEARCH_KB) - 1)
    lo = np.where(idx > 0, WEBSEARCH_KB[idx - 1], 0.5)
    hi = WEBSEARCH_KB[idx]
    return lo + (hi - lo) * rng.rand(n)  # interpolate within the bucket


def websearch_trace(
    n_hosts: int,
    load: float,
    duration_ticks: int,
    seed: int = 0,
    mtu: int = 4 * KIB,
    max_pkts: int = 0,
) -> Workload:
    """Per-host Poisson flow arrivals at `load` of the host link capacity.
    `max_pkts` > 0 truncates the flow-size tail (CI-scale engine caps)."""
    rng = np.random.RandomState(seed)
    mean_pkts = float(np.mean([pkts(kb * KIB, mtu) for kb in sample_websearch_kb(rng, 4096)]))
    rate = load / mean_pkts  # flows per tick per host (1 pkt/tick links)
    src, dst, msg, start = [], [], [], []
    for h in range(n_hosts):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= duration_ticks:
                break
            d = rng.randint(n_hosts - 1)
            d = d + (d >= h)
            src.append(h)
            dst.append(d)
            size = pkts(sample_websearch_kb(rng, 1)[0] * KIB, mtu)
            msg.append(min(size, max_pkts) if max_pkts else size)
            start.append(int(t))
    n = len(src)
    return Workload(
        src=np.asarray(src, np.int32),
        dst=np.asarray(dst, np.int32),
        msg_pkts=np.asarray(msg, np.int32),
        start=np.asarray(start, np.int32),
        dep=np.full((n,), -1, np.int32),
        name=f"websearch{int(load * 100)}",
    )


# ---------------------------------------------------------------------------
# AI collectives (§4.2): ring / butterfly AllReduce, windowed AllToAll.
# Dependencies are expressed as conn -> prerequisite conn (engine starts a
# connection once its prerequisite completes).
# ---------------------------------------------------------------------------
def ring_allreduce(n_hosts: int, total_msg_pkts: int) -> Workload:
    """2(p-1) rounds; round r of node i depends on node i-1 finishing round
    r-1 (the chunk it forwards must have arrived)."""
    p = n_hosts
    chunk = max(1, total_msg_pkts // p)
    rounds = 2 * (p - 1)
    src, dst, msg, start, dep = [], [], [], [], []
    conn_id = {}
    for r in range(rounds):
        for i in range(p):
            conn_id[(i, r)] = len(src)
            src.append(i)
            dst.append((i + 1) % p)
            msg.append(chunk)
            start.append(0)
            dep.append(-1 if r == 0 else conn_id[((i - 1) % p, r - 1)])
    return Workload(
        src=np.asarray(src, np.int32),
        dst=np.asarray(dst, np.int32),
        msg_pkts=np.asarray(msg, np.int32),
        start=np.asarray(start, np.int32),
        dep=np.asarray(dep, np.int32),
        name="ring_allreduce",
    )


def butterfly_allreduce(n_hosts: int, total_msg_pkts: int) -> Workload:
    """log2(p) exchange rounds (recursive doubling); round r of node i
    depends on receiving its partner's round r-1 data."""
    p = n_hosts
    assert p & (p - 1) == 0, "butterfly needs a power-of-two host count"
    rounds = int(np.log2(p))
    per_round = max(1, total_msg_pkts // rounds)
    src, dst, msg, start, dep = [], [], [], [], []
    conn_id = {}
    for r in range(rounds):
        for i in range(p):
            partner = i ^ (1 << r)
            conn_id[(i, r)] = len(src)
            src.append(i)
            dst.append(partner)
            msg.append(per_round)
            start.append(0)
            prev_partner = i ^ (1 << (r - 1)) if r > 0 else 0
            dep.append(-1 if r == 0 else conn_id[(prev_partner, r - 1)])
    return Workload(
        src=np.asarray(src, np.int32),
        dst=np.asarray(dst, np.int32),
        msg_pkts=np.asarray(msg, np.int32),
        start=np.asarray(start, np.int32),
        dep=np.asarray(dep, np.int32),
        name="butterfly_allreduce",
    )


def alltoall(n_hosts: int, per_pair_pkts: int, window: int = 4, seed: int = 0) -> Workload:
    """Windowed AllToAll: each host sends to every other host in a rotated
    order with at most `window` of its connections active at once (§4.2)."""
    rng = np.random.RandomState(seed)
    src, dst, msg, start, dep = [], [], [], [], []
    for h in range(n_hosts):
        order = [(h + 1 + k) % n_hosts for k in range(n_hosts - 1)]
        rng.shuffle(order)
        ids = []
        for k, d in enumerate(order):
            ids.append(len(src))
            src.append(h)
            dst.append(d)
            msg.append(per_pair_pkts)
            start.append(0)
            dep.append(-1 if k < window else ids[k - window])
    return Workload(
        src=np.asarray(src, np.int32),
        dst=np.asarray(dst, np.int32),
        msg_pkts=np.asarray(msg, np.int32),
        start=np.asarray(start, np.int32),
        dep=np.asarray(dep, np.int32),
        name=f"alltoall_w{window}",
    )


# ---------------------------------------------------------------------------
# Mixed traffic (fig 5): a fraction of hosts run background ECMP flows.
# Returned as (foreground_workload, background_host_mask) — the benchmark
# builds two simulators sharing the topology... in our engine both cohorts
# live in one conn table; the benchmark assigns LB "ecmp" to background conns
# via the MixedLB wrapper in repro.netsim.mixed.
# ---------------------------------------------------------------------------
def permutation_with_background(
    n_hosts: int, msg_pkts: int, bg_fraction: float = 0.1, seed: int = 0
) -> tuple[Workload, np.ndarray]:
    wl = permutation(n_hosts, msg_pkts, seed)
    rng = np.random.RandomState(seed + 1)
    n_bg = max(1, int(round(bg_fraction * wl.n_conns)))
    bg = np.zeros((wl.n_conns,), bool)
    bg[rng.choice(wl.n_conns, n_bg, replace=False)] = True
    return wl, bg
