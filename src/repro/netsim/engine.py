"""Discrete-time packet-level fat-tree simulator (the htsim analogue).

One jitted ``tick`` stepped under ``lax.scan``.  Within a tick (order is
part of the model, DESIGN.md §3):

  1. feedback  — ACK/NACK events due now update transport (inflight, rtx),
                 CC and the load balancer;
  2. RTO       — sender-side per-packet timeouts → retransmit marks,
                 timeout events (REPS freezing), window reduction;
  3. service   — every queue dequeues ≤1 packet (degraded links serve every
                 other tick; failed links blackhole); final-hop dequeues
                 deliver to the receiver, which dedupes via a SACK bitmap,
                 coalesces ACKs, and schedules the ACK return;
  4. arrivals  — in-flight packets due now are enqueued at their next hop
                 (ECMP hash or adaptive least-queue choice), with RED/ECN
                 marking and tail-drop (→ trim NACK or silent loss);
  5. injection — each host injects ≤1 packet (round-robin over its eligible
                 connections, window-limited); the load balancer stamps the
                 EV (REPS Algorithm 2 lives here).

Invariants the engine maintains (tested):
  * a connection sees at most one delivery per tick (host downlink serves
    1 pkt/tick), so per-connection LB/CC updates are exact with
    ``feedback_rounds=2``;
  * packet slots are conserved (ring free-list; alloc failures counted);
  * ``inflight`` accounting is exact (ACK count / NACK / RTO each decrement
    exactly once; orphans never double-decrement).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.load_balancers import LoadBalancer
from repro.netsim.config import SimConfig
from repro.netsim.topology import Topology

# packet states
FREE, FLYING, QUEUED, IN_ACK, IN_NACK, LOST_WAIT = 0, 1, 2, 3, 4, 5

BIG = 2**30  # python int: usable both as jnp operand and as static fill_value


@dataclasses.dataclass(frozen=True)
class Workload:
    """Static connection table (built by repro.netsim.workloads)."""

    src: np.ndarray  # (NC,) int32 source host
    dst: np.ndarray  # (NC,) int32 destination host
    msg_pkts: np.ndarray  # (NC,) int32 message size in packets
    start: np.ndarray  # (NC,) int32 start tick
    dep: np.ndarray  # (NC,) int32 index of prerequisite conn or -1
    name: str = "custom"

    @property
    def n_conns(self) -> int:
        return len(self.src)


@dataclasses.dataclass(frozen=True)
class FailureSchedule:
    """Link events: kind 0 = down (blackhole), 1 = degraded to half rate."""

    queue: np.ndarray  # (F,) int32 queue id
    start: np.ndarray  # (F,) int32 tick
    end: np.ndarray  # (F,) int32 tick
    kind: np.ndarray  # (F,) int32

    @staticmethod
    def none() -> "FailureSchedule":
        z = np.zeros((0,), np.int32)
        return FailureSchedule(z, z, z, z)

    @staticmethod
    def concat(*scheds: "FailureSchedule") -> "FailureSchedule":
        return FailureSchedule(
            np.concatenate([s.queue for s in scheds]).astype(np.int32),
            np.concatenate([s.start for s in scheds]).astype(np.int32),
            np.concatenate([s.end for s in scheds]).astype(np.int32),
            np.concatenate([s.kind for s in scheds]).astype(np.int32),
        )


class SimState(NamedTuple):
    # packet table (NP,)
    p_state: jax.Array
    p_conn: jax.Array
    p_ev: jax.Array
    p_seq: jax.Array
    p_hop: jax.Array
    p_cur_queue: jax.Array
    p_send_tick: jax.Array
    p_event_tick: jax.Array
    p_ecn: jax.Array
    p_orphan: jax.Array
    p_ack_count: jax.Array
    # queues
    qbuf: jax.Array  # (NQ, QCAP)
    q_head: jax.Array
    q_len: jax.Array
    q_served: jax.Array  # cumulative serve count per queue
    # connections
    c_inflight: jax.Array
    c_next_new: jax.Array
    c_delivered: jax.Array
    c_rx_pending: jax.Array
    c_done: jax.Array
    c_done_tick: jax.Array
    c_rtx_count: jax.Array
    c_rtx: jax.Array  # (NC, MSG) bool
    c_rcv: jax.Array  # (NC, MSG) bool
    c_cwnd: jax.Array  # float32
    c_alpha: jax.Array  # float32
    # hosts
    h_rr: jax.Array
    # LB state
    lb_state: Any
    # free list
    fl: jax.Array
    fl_head: jax.Array
    fl_count: jax.Array
    # cumulative stats
    s_drops_cong: jax.Array
    s_drops_fail: jax.Array
    s_timeouts: jax.Array
    s_delivered: jax.Array
    s_ecn_marks: jax.Array
    s_injected: jax.Array
    s_unprocessed: jax.Array
    s_alloc_fail: jax.Array


class TickTrace(NamedTuple):
    max_qlen: jax.Array
    sum_qlen: jax.Array
    drops: jax.Array
    timeouts: jax.Array
    delivered: jax.Array
    injected: jax.Array
    watch_qlen: jax.Array  # (W,)
    watch_served: jax.Array  # (W,) int32 0/1


class Simulator:
    """Builds and runs one simulation scenario (static: cfg/topo/workload/
    failures/LB; dynamic: SimState)."""

    def __init__(
        self,
        cfg: SimConfig,
        workload: Workload,
        lb: LoadBalancer,
        failures: FailureSchedule | None = None,
        watch_queues: np.ndarray | None = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.topo = Topology.build(cfg)
        self.wl = workload
        self.lb = lb
        self.failures = failures or FailureSchedule.none()
        self.seed = seed

        NC = workload.n_conns
        msg_max = int(workload.msg_pkts.max()) if NC else 1
        assert msg_max <= cfg.max_msg_pkts, (
            f"message of {msg_max} pkts exceeds max_msg_pkts={cfg.max_msg_pkts}"
        )
        self.MSG = int(min(cfg.max_msg_pkts, max(int(2 ** np.ceil(np.log2(max(msg_max, 2)))), 2)))
        self.NQ = self.topo.n_queues
        self.NH = cfg.n_hosts
        self.NP = cfg.pkt_slots or int(
            2 ** np.ceil(np.log2(NC * cfg.max_cwnd_pkts + 4 * self.NH + 64))
        )
        self.MAX_ARR = self.NQ + self.NH
        self.MAX_EV = self.NQ + 2 * self.NH
        self.MAX_FREE = self.MAX_EV + self.NQ + self.MAX_ARR + self.NH

        # host -> local conn table
        by_host: list[list[int]] = [[] for _ in range(self.NH)]
        for c in range(NC):
            by_host[int(workload.src[c])].append(c)
        self.CPH = max(1, max(len(v) for v in by_host) if NC else 1)
        hc = np.full((self.NH, self.CPH), -1, np.int32)
        for h, v in enumerate(by_host):
            hc[h, : len(v)] = v
        self.host_conns = jnp.asarray(hc)

        self.conn_src = jnp.asarray(workload.src.astype(np.int32))
        self.conn_dst = jnp.asarray(workload.dst.astype(np.int32))
        self.conn_msg = jnp.asarray(workload.msg_pkts.astype(np.int32))
        self.conn_start = jnp.asarray(workload.start.astype(np.int32))
        self.conn_dep = jnp.asarray(workload.dep.astype(np.int32))

        if watch_queues is None:
            watch_queues = self.topo.t0_up_queues(0)[: cfg.n_watch_queues]
        self.watch = jnp.asarray(np.asarray(watch_queues, np.int32))

        self.f_queue = jnp.asarray(self.failures.queue)
        self.f_start = jnp.asarray(self.failures.start)
        self.f_end = jnp.asarray(self.failures.end)
        self.f_kind = jnp.asarray(self.failures.kind)

        self.base_key = jax.random.PRNGKey(seed)

    # ------------------------------------------------------------------
    def init_state(self) -> SimState:
        NP, NQ, NC, NH = self.NP, self.NQ, self.wl.n_conns, self.NH
        cfg = self.cfg
        i32 = jnp.int32
        return SimState(
            p_state=jnp.zeros((NP,), i32),
            p_conn=jnp.zeros((NP,), i32),
            p_ev=jnp.zeros((NP,), i32),
            p_seq=jnp.zeros((NP,), i32),
            p_hop=jnp.zeros((NP,), i32),
            p_cur_queue=jnp.zeros((NP,), i32),
            p_send_tick=jnp.zeros((NP,), i32),
            p_event_tick=jnp.zeros((NP,), i32),
            p_ecn=jnp.zeros((NP,), jnp.bool_),
            p_orphan=jnp.zeros((NP,), jnp.bool_),
            p_ack_count=jnp.zeros((NP,), i32),
            qbuf=jnp.zeros((NQ, cfg.queue_capacity), i32),
            q_head=jnp.zeros((NQ,), i32),
            q_len=jnp.zeros((NQ,), i32),
            q_served=jnp.zeros((NQ,), i32),
            c_inflight=jnp.zeros((NC,), i32),
            c_next_new=jnp.zeros((NC,), i32),
            c_delivered=jnp.zeros((NC,), i32),
            c_rx_pending=jnp.zeros((NC,), i32),
            c_done=jnp.zeros((NC,), jnp.bool_),
            c_done_tick=jnp.full((NC,), -1, i32),
            c_rtx_count=jnp.zeros((NC,), i32),
            c_rtx=jnp.zeros((NC, self.MSG), jnp.bool_),
            c_rcv=jnp.zeros((NC, self.MSG), jnp.bool_),
            c_cwnd=jnp.full((NC,), float(cfg.init_cwnd_pkts), jnp.float32),
            c_alpha=jnp.zeros((NC,), jnp.float32),
            h_rr=jnp.zeros((NH,), i32),
            lb_state=self.lb.init_state(NC, jax.random.fold_in(self.base_key, 777)),
            fl=jnp.arange(NP, dtype=i32),
            fl_head=jnp.zeros((), i32),
            fl_count=jnp.asarray(NP, i32),
            s_drops_cong=jnp.zeros((), i32),
            s_drops_fail=jnp.zeros((), i32),
            s_timeouts=jnp.zeros((), i32),
            s_delivered=jnp.zeros((), i32),
            s_ecn_marks=jnp.zeros((), i32),
            s_injected=jnp.zeros((), i32),
            s_unprocessed=jnp.zeros((), i32),
            s_alloc_fail=jnp.zeros((), i32),
        )

    # ------------------------------------------------------------------
    def _cc_on_ack(self, cwnd, alpha, mask, ecn, rtt):
        """Per-ACK CC update (DCTCP-variant per §4.1 / MPRDMA)."""
        cfg = self.cfg
        if cfg.cc == "dctcp":
            g = cfg.dctcp_g
            alpha = jnp.where(
                mask, (1 - g) * alpha + g * ecn.astype(jnp.float32), alpha
            )
            up = cwnd + 1.0 / jnp.maximum(cwnd, 1.0)
            down = cwnd - alpha / 2.0
            cwnd = jnp.where(mask, jnp.where(ecn, down, up), cwnd)
        elif cfg.cc == "eqds":
            # receiver-credit approximation: fast additive increase toward a
            # hard BDP cap; ECN halves toward the cap floor.
            up = cwnd + 4.0 / jnp.maximum(cwnd, 1.0)
            down = cwnd - 0.5
            cwnd = jnp.where(mask, jnp.where(ecn, down, up), cwnd)
            cwnd = jnp.minimum(cwnd, float(self.cfg.init_cwnd_pkts))
        elif cfg.cc == "delay":
            t = float(cfg.delay_target_ticks)
            over = (rtt.astype(jnp.float32) - t) / t
            up = cwnd + 1.0 / jnp.maximum(cwnd, 1.0)
            down = cwnd - cfg.delay_beta * jnp.clip(over, 0.0, 1.0)
            cwnd = jnp.where(mask, jnp.where(over > 0, down, up), cwnd)
        else:
            raise ValueError(cfg.cc)
        cwnd = jnp.clip(cwnd, 1.0, float(cfg.max_cwnd_pkts))
        return cwnd, alpha

    # ------------------------------------------------------------------
    def tick_fn(self, state: SimState, tick: jax.Array) -> tuple[SimState, TickTrace]:
        cfg, topo = self.cfg, self.topo
        NP, NQ, NH = self.NP, self.NQ, self.NH
        NC = self.wl.n_conns
        QCAP = cfg.queue_capacity
        now = tick.astype(jnp.int32)
        key = jax.random.fold_in(self.base_key, tick)
        state_at_entry = state.p_state

        (
            p_state, p_conn, p_ev, p_seq, p_hop, p_cur_queue, p_send_tick,
            p_event_tick, p_ecn, p_orphan, p_ack_count,
            qbuf, q_head, q_len, q_served,
            c_inflight, c_next_new, c_delivered, c_rx_pending, c_done,
            c_done_tick, c_rtx_count, c_rtx, c_rcv, c_cwnd, c_alpha,
            h_rr, lb_state, fl, fl_head, fl_count,
            s_drops_cong, s_drops_fail, s_timeouts, s_delivered, s_ecn_marks,
            s_injected, s_unprocessed, s_alloc_fail,
        ) = state

        # =============== 1. feedback (ACK / NACK) =====================
        due = ((p_state == IN_ACK) | (p_state == IN_NACK)) & (p_event_tick == now)
        e_idx = jnp.nonzero(due, size=self.MAX_EV, fill_value=NP)[0]
        e_valid = e_idx < NP
        eg = lambda arr, fill: jnp.where(e_valid, arr[jnp.minimum(e_idx, NP - 1)], fill)
        e_conn = eg(p_conn, NC)  # NC = sentinel row for scatters (mode drop)
        e_is_nack = eg(p_state, 0) == IN_NACK
        e_ev = eg(p_ev, 0)
        e_ecn = eg(p_ecn, False)
        e_cnt = eg(p_ack_count, 0)
        e_seq = eg(p_seq, 0)
        e_rtt = jnp.where(e_valid, now - eg(p_send_tick, 0), 0)

        # exact inflight accounting over ALL events
        dec = jnp.where(e_is_nack, 1, e_cnt)
        c_inflight = c_inflight.at[e_conn].add(-dec, mode="drop")
        # NACK: mark retransmission, window -1 MTU (congestion drop signal)
        nack_mask = e_valid & e_is_nack
        already = c_rcv.at[e_conn, e_seq].get(mode="fill", fill_value=True)
        need_rtx = nack_mask & ~already
        prev_rtx = c_rtx.at[e_conn, e_seq].get(mode="fill", fill_value=True)
        c_rtx = c_rtx.at[e_conn, e_seq].max(need_rtx, mode="drop")
        c_rtx_count = c_rtx_count.at[e_conn].add(
            (need_rtx & ~prev_rtx).astype(jnp.int32), mode="drop"
        )
        nacks_per_conn = (
            jnp.zeros((NC + 1,), jnp.int32).at[e_conn].add(nack_mask, mode="drop")[:NC]
        )
        c_cwnd = jnp.clip(
            c_cwnd - nacks_per_conn.astype(jnp.float32),
            1.0,
            float(cfg.max_cwnd_pkts),
        )

        # LB + CC updates: up to `feedback_rounds` exact rounds of one ACK
        # event per connection.
        processed = ~(e_valid & ~e_is_nack)
        ev_order = jnp.arange(self.MAX_EV, dtype=jnp.int32)
        for _ in range(cfg.feedback_rounds):
            slot = (
                jnp.full((NC + 1,), self.MAX_EV, jnp.int32)
                .at[e_conn]
                .min(jnp.where(processed, self.MAX_EV, ev_order), mode="drop")
            )
            win = (~processed) & (slot.at[e_conn].get(mode="fill", fill_value=self.MAX_EV) == ev_order)
            w_conn = jnp.where(win, e_conn, NC)
            conn_mask = (
                jnp.zeros((NC + 1,), jnp.bool_).at[w_conn].max(win, mode="drop")[:NC]
            )
            conn_ev = (
                jnp.zeros((NC + 1,), jnp.int32).at[w_conn].max(jnp.where(win, e_ev, 0), mode="drop")[:NC]
            )
            conn_ecn = (
                jnp.zeros((NC + 1,), jnp.bool_).at[w_conn].max(win & e_ecn, mode="drop")[:NC]
            )
            conn_rtt = (
                jnp.zeros((NC + 1,), jnp.int32).at[w_conn].max(jnp.where(win, e_rtt, 0), mode="drop")[:NC]
            )
            c_cwnd, c_alpha = self._cc_on_ack(c_cwnd, c_alpha, conn_mask, conn_ecn, conn_rtt)
            lb_state = self.lb.on_ack(lb_state, conn_mask, conn_ev, conn_ecn, now)
            processed = processed | win
        s_unprocessed = s_unprocessed + jnp.sum((~processed).astype(jnp.int32))

        # free all feedback slots
        p_state = jnp.where(due, FREE, p_state)

        # =============== 2. RTO ========================================
        active_data = (p_state == FLYING) | (p_state == QUEUED) | (p_state == LOST_WAIT)
        conn_done_of_pkt = c_done[jnp.clip(p_conn, 0, NC - 1)]
        rto = (
            active_data
            & ~p_orphan
            & ((now - p_send_tick) >= cfg.rto_ticks)
            & ~conn_done_of_pkt
        )
        rcv_already = c_rcv.at[p_conn, p_seq].get(mode="fill", fill_value=True)
        rto_need = rto & ~rcv_already
        prev_rtx_p = c_rtx.at[p_conn, p_seq].get(mode="fill", fill_value=True)
        c_rtx = c_rtx.at[jnp.where(rto_need, p_conn, NC), p_seq].max(rto_need, mode="drop")
        c_rtx_count = c_rtx_count.at[jnp.where(rto_need & ~prev_rtx_p, p_conn, NC)].add(
            1, mode="drop"
        )
        rto_per_conn = (
            jnp.zeros((NC + 1,), jnp.int32)
            .at[jnp.where(rto, p_conn, NC)]
            .add(1, mode="drop")[:NC]
        )
        c_inflight = c_inflight - rto_per_conn
        c_cwnd = jnp.clip(
            c_cwnd - rto_per_conn.astype(jnp.float32), 1.0, float(cfg.max_cwnd_pkts)
        )
        lb_state = self.lb.on_timeout(lb_state, rto_per_conn > 0, now)
        s_timeouts = s_timeouts + jnp.sum(rto.astype(jnp.int32))
        # orphan in-network packets; free LOST_WAIT ones
        p_orphan = p_orphan | rto
        p_state = jnp.where(rto & (p_state == LOST_WAIT), FREE, p_state)

        # =============== 3. service / dequeue ===========================
        f_active = (now >= self.f_start) & (now < self.f_end)
        failed_q = (
            jnp.zeros((NQ + 1,), jnp.bool_)
            .at[jnp.where(f_active & (self.f_kind == 0), self.f_queue, NQ)]
            .max(True, mode="drop")[:NQ]
        )
        degraded_q = (
            jnp.zeros((NQ + 1,), jnp.bool_)
            .at[jnp.where(f_active & (self.f_kind == 1), self.f_queue, NQ)]
            .max(True, mode="drop")[:NQ]
        )
        service_ok = ~(degraded_q & (now % 2 == 1))
        serve = (q_len > 0) & service_ok
        head_pid = qbuf[jnp.arange(NQ), q_head % QCAP]
        q_head = jnp.where(serve, q_head + 1, q_head)
        q_len = jnp.where(serve, q_len - 1, q_len)
        q_served = q_served + serve.astype(jnp.int32)

        pid = jnp.where(serve, head_pid, NP)  # NP = drop sentinel
        qid = jnp.arange(NQ, dtype=jnp.int32)
        blackhole = serve & failed_q
        is_final = serve & ~blackhole & (qid >= topo.t0_down_base)
        mid = serve & ~blackhole & ~is_final

        d_orph = p_orphan.at[pid].get(mode="fill", fill_value=False)
        # blackholed: silent loss (failure — no trim); orphans are freed
        s_drops_fail = s_drops_fail + jnp.sum((blackhole & ~d_orph).astype(jnp.int32))
        p_state = p_state.at[jnp.where(blackhole, pid, NP)].set(
            jnp.where(d_orph, FREE, LOST_WAIT), mode="drop"
        )
        # mid-path: fly to next hop
        p_state = p_state.at[jnp.where(mid, pid, NP)].set(FLYING, mode="drop")
        p_event_tick = p_event_tick.at[jnp.where(mid, pid, NP)].set(
            now + cfg.hop_latency_ticks, mode="drop"
        )
        p_hop = p_hop.at[jnp.where(mid, pid, NP)].add(1, mode="drop")
        p_cur_queue = p_cur_queue.at[jnp.where(mid, pid, NP)].set(qid, mode="drop")

        # deliveries (≤ 1 per connection per tick — host downlink serves 1)
        dconn = jnp.where(is_final, p_conn.at[pid].get(mode="fill", fill_value=0), NC)
        dseq = p_seq.at[pid].get(mode="fill", fill_value=0)
        was_done = c_done.at[dconn].get(mode="fill", fill_value=True)
        newly = is_final & ~c_rcv.at[dconn, dseq].get(mode="fill", fill_value=True)
        c_rcv = c_rcv.at[dconn, dseq].max(is_final, mode="drop")
        c_delivered = c_delivered.at[jnp.where(newly, dconn, NC)].add(1, mode="drop")
        s_delivered = s_delivered + jnp.sum(newly.astype(jnp.int32))
        deliver_ackable = is_final & ~d_orph & ~was_done
        c_rx_pending = c_rx_pending.at[jnp.where(deliver_ackable, dconn, NC)].add(
            1, mode="drop"
        )
        msg_of = self.conn_msg.at[dconn].get(mode="fill", fill_value=BIG)
        now_done = c_delivered.at[dconn].get(mode="fill", fill_value=0) >= msg_of
        rxp = c_rx_pending.at[dconn].get(mode="fill", fill_value=0)
        emit = deliver_ackable & ((rxp >= cfg.ack_coalesce) | now_done)
        # emitted ACK reuses the packet slot
        p_state = p_state.at[jnp.where(is_final, pid, NP)].set(
            jnp.where(emit, IN_ACK, FREE), mode="drop"
        )
        p_event_tick = p_event_tick.at[jnp.where(emit, pid, NP)].set(
            now + cfg.ack_delay_ticks, mode="drop"
        )
        p_ack_count = p_ack_count.at[jnp.where(emit, pid, NP)].set(rxp, mode="drop")
        c_rx_pending = c_rx_pending.at[jnp.where(emit, dconn, NC)].set(0, mode="drop")
        # completion bookkeeping
        first_done = is_final & now_done & ~was_done
        c_done = c_done.at[jnp.where(first_done, dconn, NC)].set(True, mode="drop")
        c_done_tick = c_done_tick.at[jnp.where(first_done, dconn, NC)].set(
            now, mode="drop"
        )

        # =============== 4. arrivals / enqueue ==========================
        arr = (p_state == FLYING) & (p_event_tick == now)
        a_idx = jnp.nonzero(arr, size=self.MAX_ARR, fill_value=NP)[0]
        a_valid = a_idx < NP
        ag = lambda arr_, fill: jnp.where(
            a_valid, arr_[jnp.minimum(a_idx, NP - 1)], fill
        )
        a_conn = ag(p_conn, 0)
        a_ev = ag(p_ev, 0)
        a_inj = ag(p_hop, 1) == 0
        a_cur = ag(p_cur_queue, 0)
        a_src = self.conn_src[jnp.clip(a_conn, 0, NC - 1)]
        a_dst = self.conn_dst[jnp.clip(a_conn, 0, NC - 1)]
        # adaptive switches exclude locally-known failed ports (link down is
        # visible at the switch); hashing LBs ignore q_len entirely.
        q_len_eff = q_len + failed_q.astype(jnp.int32) * jnp.int32(4 * QCAP)
        target = topo.next_queue(
            a_inj, a_cur, a_conn, a_ev, a_src, a_dst, q_len_eff,
            adaptive=self.lb.switch_adaptive,
        )
        target = jnp.where(a_valid, target, NQ)
        # FIFO rank among same-target arrivals (stable in slot order)
        skey = target * jnp.int32(self.MAX_ARR) + jnp.arange(self.MAX_ARR, dtype=jnp.int32)
        order = jnp.argsort(skey)
        tsorted = target[order]
        run_start = jnp.concatenate(
            [jnp.ones((1,), jnp.bool_), tsorted[1:] != tsorted[:-1]]
        )
        pos_in_run = jnp.arange(self.MAX_ARR) - jnp.maximum.accumulate(
            jnp.where(run_start, jnp.arange(self.MAX_ARR), 0)
        )
        rank = jnp.zeros((self.MAX_ARR,), jnp.int32).at[order].set(pos_in_run)
        room = QCAP - q_len.at[target].get(mode="fill", fill_value=0)
        accept = a_valid & (rank < room)
        dropd = a_valid & ~accept
        pos = q_len.at[target].get(mode="fill", fill_value=0) + rank
        mark_p = (
            jnp.clip(
                (pos.astype(jnp.float32) - cfg.kmin) / float(cfg.kmax - cfg.kmin),
                0.0,
                1.0,
            )
            * cfg.pmax
        )
        mark = accept & (
            jax.random.uniform(jax.random.fold_in(key, 1), (self.MAX_ARR,)) < mark_p
        )
        s_ecn_marks = s_ecn_marks + jnp.sum(mark.astype(jnp.int32))
        slot = (q_head.at[target].get(mode="fill", fill_value=0) + pos) % QCAP
        qbuf = qbuf.at[jnp.where(accept, target, NQ), slot].set(
            a_idx, mode="drop"
        )
        q_len = q_len.at[jnp.where(accept, target, NQ)].add(1, mode="drop")
        p_ecn = p_ecn.at[jnp.where(mark, a_idx, NP)].max(True, mode="drop")
        p_state = p_state.at[jnp.where(accept, a_idx, NP)].set(QUEUED, mode="drop")
        p_cur_queue = p_cur_queue.at[jnp.where(accept, a_idx, NP)].set(
            target, mode="drop"
        )
        # congestion drops: trim → NACK; else silent (await RTO); orphans free
        a_orph = ag(p_orphan, False)
        s_drops_cong = s_drops_cong + jnp.sum((dropd & ~a_orph).astype(jnp.int32))
        if cfg.trimming:
            dstate = jnp.where(a_orph, FREE, IN_NACK)
        else:
            dstate = jnp.where(a_orph, FREE, LOST_WAIT)
        p_state = p_state.at[jnp.where(dropd, a_idx, NP)].set(dstate, mode="drop")
        if cfg.trimming:
            p_event_tick = p_event_tick.at[jnp.where(dropd & ~a_orph, a_idx, NP)].set(
                now + cfg.nack_delay_ticks, mode="drop"
            )

        # =============== 5. injection ===================================
        started = (now >= self.conn_start) & (
            (self.conn_dep < 0) | c_done[jnp.clip(self.conn_dep, 0, NC - 1)]
        )
        has_work = (c_rtx_count > 0) | (c_next_new < self.conn_msg)
        can = (
            started
            & ~c_done
            & has_work
            & (c_inflight < jnp.floor(c_cwnd).astype(jnp.int32))
        )
        hc = self.host_conns  # (NH, CPH)
        elig = can[jnp.clip(hc, 0, NC - 1)] & (hc >= 0)
        ordr = (jnp.arange(self.CPH)[None, :] - h_rr[:, None]) % self.CPH
        score = jnp.where(elig, ordr, BIG)
        pick_local = jnp.argmin(score, axis=1).astype(jnp.int32)
        any_pick = jnp.min(score, axis=1) < BIG
        # free-slot allocation (ring pop)
        srank = jnp.cumsum(any_pick.astype(jnp.int32)) - 1
        can_alloc = srank < fl_count
        sendh = any_pick & can_alloc
        s_alloc_fail = s_alloc_fail + jnp.sum((any_pick & ~can_alloc).astype(jnp.int32))
        n_alloc = jnp.sum(sendh.astype(jnp.int32))
        slot_p = fl[(fl_head + srank) % NP]
        fl_head = (fl_head + n_alloc) % NP
        fl_count = fl_count - n_alloc

        pick_conn = jnp.where(
            sendh, hc[jnp.arange(NH), pick_local], NC
        )  # NC sentinel
        h_rr = jnp.where(sendh, (pick_local + 1) % self.CPH, h_rr)
        send_mask = (
            jnp.zeros((NC + 1,), jnp.bool_).at[pick_conn].max(sendh, mode="drop")[:NC]
        )
        # seq selection: retransmissions first
        use_rtx = c_rtx_count[jnp.clip(pick_conn, 0, NC - 1)] > 0
        rtx_rows = c_rtx[jnp.clip(pick_conn, 0, NC - 1)]  # (NH, MSG)
        rtx_seq = jnp.argmax(rtx_rows, axis=1).astype(jnp.int32)
        new_seq = c_next_new[jnp.clip(pick_conn, 0, NC - 1)]
        seq = jnp.where(use_rtx, rtx_seq, new_seq)
        c_rtx = c_rtx.at[jnp.where(sendh & use_rtx, pick_conn, NC), rtx_seq].set(
            False, mode="drop"
        )
        c_rtx_count = c_rtx_count.at[jnp.where(sendh & use_rtx, pick_conn, NC)].add(
            -1, mode="drop"
        )
        c_next_new = c_next_new.at[jnp.where(sendh & ~use_rtx, pick_conn, NC)].add(
            1, mode="drop"
        )
        c_inflight = c_inflight.at[jnp.where(sendh, pick_conn, NC)].add(1, mode="drop")
        s_injected = s_injected + n_alloc

        # the load balancer stamps the EV (REPS Algorithm 2)
        evs, lb_state = self.lb.choose_ev(
            lb_state, send_mask, jax.random.fold_in(key, 2), now
        )
        pkt_ev = evs[jnp.clip(pick_conn, 0, NC - 1)]

        wslot = jnp.where(sendh, slot_p, NP)
        p_state = p_state.at[wslot].set(FLYING, mode="drop")
        p_conn = p_conn.at[wslot].set(pick_conn, mode="drop")
        p_ev = p_ev.at[wslot].set(pkt_ev, mode="drop")
        p_seq = p_seq.at[wslot].set(seq, mode="drop")
        p_hop = p_hop.at[wslot].set(0, mode="drop")
        p_cur_queue = p_cur_queue.at[wslot].set(-1, mode="drop")
        p_send_tick = p_send_tick.at[wslot].set(now, mode="drop")
        p_event_tick = p_event_tick.at[wslot].set(
            now + cfg.hop_latency_ticks, mode="drop"
        )
        p_ecn = p_ecn.at[wslot].set(False, mode="drop")
        p_orphan = p_orphan.at[wslot].set(False, mode="drop")
        p_ack_count = p_ack_count.at[wslot].set(0, mode="drop")

        # =============== 6. free-list push ==============================
        freed = (p_state == FREE) & (state_at_entry != FREE)
        # exclude slots that were popped and re-used this tick (state FLYING
        # now, so they are not FREE — no conflict).
        f_idx2 = jnp.nonzero(freed, size=self.MAX_FREE, fill_value=NP)[0]
        f_val = f_idx2 < NP
        frank = jnp.cumsum(f_val.astype(jnp.int32)) - 1
        n_freed = jnp.sum(f_val.astype(jnp.int32))
        fpos = (fl_head + fl_count + frank) % NP
        fl = fl.at[jnp.where(f_val, fpos, NP)].set(f_idx2, mode="drop")
        fl_count = fl_count + n_freed

        new_state = SimState(
            p_state, p_conn, p_ev, p_seq, p_hop, p_cur_queue, p_send_tick,
            p_event_tick, p_ecn, p_orphan, p_ack_count,
            qbuf, q_head, q_len, q_served,
            c_inflight, c_next_new, c_delivered, c_rx_pending, c_done,
            c_done_tick, c_rtx_count, c_rtx, c_rcv, c_cwnd, c_alpha,
            h_rr, lb_state, fl, fl_head, fl_count,
            s_drops_cong, s_drops_fail, s_timeouts, s_delivered, s_ecn_marks,
            s_injected, s_unprocessed, s_alloc_fail,
        )
        trace = TickTrace(
            max_qlen=jnp.max(q_len),
            sum_qlen=jnp.sum(q_len),
            drops=s_drops_cong + s_drops_fail,
            timeouts=s_timeouts,
            delivered=s_delivered,
            injected=s_injected,
            watch_qlen=q_len[self.watch],
            watch_served=serve[self.watch].astype(jnp.int32),
        )
        return new_state, trace

    # ------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=(0, 1))
    def _run(self, n_ticks: int, state: SimState):
        ticks = jnp.arange(n_ticks, dtype=jnp.int32)
        return jax.lax.scan(self.tick_fn, state, ticks)

    def run(self, n_ticks: int, state: SimState | None = None):
        """Run the simulation for n_ticks; returns (final_state, trace)."""
        if state is None:
            state = self.init_state()
        return self._run(n_ticks, state)
