"""Discrete-time packet-level fat-tree simulator (the htsim analogue).

One jitted ``tick`` stepped under ``lax.scan``.  Within a tick (order is
part of the model, DESIGN.md §3):

  1. feedback  — ACK/NACK events due now update transport (inflight, rtx),
                 CC and the load balancer;
  2. RTO       — sender-side per-packet timeouts → retransmit marks,
                 timeout events (REPS freezing), window reduction;
  3. service   — every queue dequeues ≤1 packet (degraded links serve every
                 other tick; failed links blackhole); final-hop dequeues
                 deliver to the receiver, which dedupes via a SACK bitmap,
                 coalesces ACKs, and schedules the ACK return;
  4. arrivals  — in-flight packets due now are enqueued at their next hop
                 (ECMP hash or adaptive least-queue choice), with RED/ECN
                 marking and tail-drop (→ trim NACK or silent loss);
  5. injection — each host injects ≤1 packet (round-robin over its eligible
                 connections, window-limited); the load balancer stamps the
                 EV (REPS Algorithm 2 lives here).

Invariants the engine maintains (tested):
  * a connection sees at most one delivery per tick (host downlink serves
    1 pkt/tick), so per-connection LB/CC updates are exact with
    ``feedback_rounds=2``;
  * packet slots are conserved (ring free-list; alloc failures counted);
  * ``inflight`` accounting is exact (ACK count / NACK / RTO each decrement
    exactly once; orphans never double-decrement).

Hot-path layout (this file's perf model — see README "Performance &
execution model"):

  * The per-packet table is ONE packed ``(PF, NP)`` int32 array.  Each
    pipeline stage gathers the rows it touches once, rewrites whole packet
    columns densely, and scatters back once — on the CPU/TPU backends the
    per-tick cost is dominated by the number of non-fusable gather/scatter/
    sort kernels, not FLOPs, so stages budget one gather + one scatter each
    instead of ~10 per-field ops.
  * FIFO ranking of same-target arrivals (and of same-connection ACK
    events for the exact ``feedback_rounds`` replay) and every
    per-connection event aggregation (inflight / NACK / delivery /
    injection accounting) go through two backend-switchable segment
    primitives — ``_seg_rank_b`` and ``_seg_sum_b``
    (``SimConfig.kernels_backend``): the jnp formulations are a pairwise
    compare+reduce rank and stacked scatter-adds (one narrow scatter per
    stage, replacing the dense one-hot masked reductions that used to
    dominate the tick); the pallas formulations are the tiled
    histogram-scan kernels in ``repro.kernels.seg_rank``/``seg_sum``,
    which batch across the vmapped sweep/fleet row axis via the
    ``pallas_call`` vmap rule.  The ACK feedback rounds scatter once into a
    ``(round, conn)`` table instead of building a ``(K, NC)`` selection
    mask per round.
  * Scalar stat counters live in a single ``(N_STATS,)`` vector updated
    once per tick with a stacked delta.
  * ``_step`` is a pure function of (state, tick, base_key); the
    ``FleetRunner`` vmaps it over per-seed keys to batch whole sweeps
    (repro.netsim.fleet).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.load_balancers import LoadBalancer
from repro.netsim.config import INT32_MAX, SimConfig, checked_auto_pkt_slots
from repro.netsim.topology import Topology

# packet states
FREE, FLYING, QUEUED, IN_ACK, IN_NACK, LOST_WAIT = 0, 1, 2, 3, 4, 5

BIG = 2**30  # python int: usable both as jnp operand and as static fill_value

# Packed packet-table rows: pkt[field, slot].  Everything int32 (bools 0/1).
PS, PCONN, PEV, PSEQ, PHOP, PCURQ, PSEND, PEVT, PECN, PORPH, PACK = range(11)
PF = 11

# Fused stats vector indices.
(
    ST_DROPS_CONG, ST_DROPS_FAIL, ST_TIMEOUTS, ST_DELIVERED, ST_ECN,
    ST_INJECTED, ST_UNPROC, ST_ALLOC_FAIL,
) = range(8)
N_STATS = 8


@dataclasses.dataclass(frozen=True)
class Workload:
    """Static connection table (built by repro.netsim.workloads)."""

    src: np.ndarray  # (NC,) int32 source host
    dst: np.ndarray  # (NC,) int32 destination host
    msg_pkts: np.ndarray  # (NC,) int32 message size in packets
    start: np.ndarray  # (NC,) int32 start tick
    dep: np.ndarray  # (NC,) int32 index of prerequisite conn or -1
    name: str = "custom"

    @property
    def n_conns(self) -> int:
        return len(self.src)


# failure kind codes (FailureSchedule.kind); names for error messages/docs
K_DOWN, K_DEGRADED, K_GRAY = 0, 1, 2
KNOWN_KINDS = {
    K_DOWN: "down",
    K_DEGRADED: "degraded",
    K_GRAY: "gray_loss",
}
# gray-loss drop probability is fixed-point: param / GRAY_SCALE
GRAY_SCALE = 65536


@dataclasses.dataclass(frozen=True)
class FailureSchedule:
    """Link events: kind 0 = down (blackhole), 1 = degraded to half rate,
    2 = gray loss (silent per-packet drop with probability
    ``param / GRAY_SCALE``, drawn through the engine's threefry key so
    runs stay bit-reproducible; invisible to adaptive switch routing —
    that is the defining "gray" property).

    A row is *active* at tick ``t`` iff ``start <= t < end``.  Two row
    shapes are legal (``validate``): real windows (``end > start``) and
    inert pads (``start == end == 0``).  Padding/truncation must preserve
    the active-set at every tick — in particular a permanent failure
    (``end = failures.FOREVER``) may never have its ``end`` clipped to a
    pad/bucket boundary, which would silently resurrect the link there.
    ``pad_to`` only ever appends inert rows; dropping rows is the job of
    ``failures.truncate_dead`` (which refuses to drop live events).

    ``param`` is the per-row kind parameter (gray-loss drop rate); it is
    optional at construction (defaults to zeros) so the long-standing
    4-array call sites stay valid.
    """

    queue: np.ndarray  # (F,) int32 queue id
    start: np.ndarray  # (F,) int32 tick
    end: np.ndarray  # (F,) int32 tick
    kind: np.ndarray  # (F,) int32
    param: np.ndarray | None = None  # (F,) int32 kind parameter

    def __post_init__(self) -> None:
        if self.param is None:
            object.__setattr__(
                self, "param", np.zeros((len(self.queue),), np.int32)
            )

    def __len__(self) -> int:
        return len(self.queue)

    @staticmethod
    def none() -> "FailureSchedule":
        z = np.zeros((0,), np.int32)
        return FailureSchedule(z, z, z, z, z)

    @staticmethod
    def concat(*scheds: "FailureSchedule") -> "FailureSchedule":
        return FailureSchedule(
            np.concatenate([s.queue for s in scheds]).astype(np.int32),
            np.concatenate([s.start for s in scheds]).astype(np.int32),
            np.concatenate([s.end for s in scheds]).astype(np.int32),
            np.concatenate([s.kind for s in scheds]).astype(np.int32),
            np.concatenate([s.param for s in scheds]).astype(np.int32),
        )

    def pad_to(self, f: int) -> "FailureSchedule":
        """Append inert rows (start == end == 0: never active for any
        ``now >= 0``) up to ``f`` total.  Existing rows are bit-unchanged —
        padding can therefore never alter the active-set of any tick."""
        extra = f - len(self.queue)
        assert extra >= 0, (
            f"cannot pad a {len(self.queue)}-event schedule down to {f} "
            "rows; drop provably-dead events first (failures.truncate_dead)"
        )
        if extra == 0:
            return self
        z = np.zeros((extra,), np.int32)
        return FailureSchedule(
            queue=np.concatenate([self.queue.astype(np.int32), z]),
            start=np.concatenate([self.start.astype(np.int32), z]),
            end=np.concatenate([self.end.astype(np.int32), z]),
            kind=np.concatenate([self.kind.astype(np.int32), z]),
            param=np.concatenate([self.param.astype(np.int32), z]),
        )

    def validate(self, n_queues: int | None = None) -> None:
        """Reject rows that are neither real windows nor inert pads — each
        violation raises ``ValueError`` naming the offending rows.  The
        dangerous in-between (``end <= start`` but not all-zero) is what a
        buggy pad/truncate produces when it clips ``end`` instead of
        keeping the original window — at the clip boundary the link would
        come back up even though the builder scheduled it down forever.
        Unknown ``kind`` codes are rejected too: an out-of-range kind
        would silently fall through the engine's active-set arithmetic
        (matching none of the per-kind masks) and the row would be a
        no-op instead of the fault the caller asked for."""
        s = np.asarray(self.start)
        e = np.asarray(self.end)
        q = np.asarray(self.queue)
        k = np.asarray(self.kind)
        p = np.asarray(self.param)
        live = e > s
        inert = (s == 0) & (e == 0) & (q == 0) & (k == 0) & (p == 0)
        bad = ~(live | inert)
        if bad.any():
            i = int(np.nonzero(bad)[0][0])
            raise ValueError(
                "failure rows must be real windows (end > start) or inert "
                "pads (queue == start == end == kind == param == 0); "
                f"offending rows {np.nonzero(bad)[0].tolist()} (first: row "
                f"{i} queue={int(q[i])} start={int(s[i])} end={int(e[i])} "
                f"kind={int(k[i])}) look like a clipped/truncated schedule, "
                "which would resurrect the link at the clip boundary"
            )
        if (s < 0).any():
            i = int(np.nonzero(s < 0)[0][0])
            raise ValueError(
                f"failure row {i} (queue {int(q[i])}) starts at tick "
                f"{int(s[i])}: windows cannot start before tick 0"
            )
        unknown = live & ~np.isin(k, list(KNOWN_KINDS))
        if unknown.any():
            i = int(np.nonzero(unknown)[0][0])
            raise ValueError(
                f"failure row {i} (queue {int(q[i])}, "
                f"[{int(s[i])}, {int(e[i])})) has unknown kind "
                f"{int(k[i])}; known kinds: "
                + ", ".join(f"{c}={n}" for c, n in sorted(KNOWN_KINDS.items()))
            )
        bad_p = live & (
            ((k == K_GRAY) & ((p <= 0) | (p > GRAY_SCALE)))
            | ((k != K_GRAY) & (p != 0))
        )
        if bad_p.any():
            i = int(np.nonzero(bad_p)[0][0])
            raise ValueError(
                f"failure row {i} (queue {int(q[i])}, kind {int(k[i])}) has "
                f"param {int(p[i])}: gray-loss rows need 0 < param <= "
                f"{GRAY_SCALE} (drop probability = param/{GRAY_SCALE}); "
                "other kinds take param == 0"
            )
        if n_queues is not None:
            bad_q = live & ((q < 0) | (q >= n_queues))
            if bad_q.any():
                i = int(np.nonzero(bad_q)[0][0])
                raise ValueError(
                    f"failure row {i} targets queue {int(q[i])}, outside "
                    f"the topology's [0, {n_queues}) queue range"
                )

    def merge(
        self,
        delta: "FailureSchedule",
        at_tick: int = 0,
        n_queues: int | None = None,
    ) -> "FailureSchedule":
        """Merge an event ``delta`` into this schedule — the ONE code path
        shared by statically declared composites and the soak runtime's
        live mid-run injection (``SoakRunner.inject`` calls this with
        ``at_tick`` = the current tick cursor).

        Validation (each violation raises ``ValueError``):

        * every delta row must be a real window starting at or after
          ``at_tick`` — an event injected into the already-simulated past
          could never equal the statically-scheduled run it claims to be;
        * a delta row may not overlap an existing *down* window on the
          same queue: the link is already dead there, and the delta's own
          ``end`` would imply a resurrection that pad/truncate semantics
          forbid (the no-resurrect invariant of ``validate``);
        * a delta row may not overlap an existing same-kind window on the
          same queue (a double-scheduled event is a bug, not a request) —
          a *down* delta over an existing *degraded* window stays legal,
          exactly like the statically-declared down+degraded composites.

        Rows of ``self`` (including inert pads) are kept bit-unchanged and
        the delta's live rows are appended, so for any valid delta
        ``base.merge(delta)`` is ``concat(base, delta_live)`` — an injected
        run and the equivalent pre-declared schedule produce identical
        active-sets at every tick.
        """
        delta.validate(n_queues)
        self.validate(n_queues)
        d_s = np.asarray(delta.start, np.int64)
        d_e = np.asarray(delta.end, np.int64)
        d_live = d_e > d_s
        if not np.all(d_s[d_live] >= at_tick):
            bad = np.nonzero(d_live & (d_s < at_tick))[0].tolist()
            raise ValueError(
                f"delta rows {bad} start before tick {at_tick}: events "
                "cannot be injected into the already-simulated past"
            )
        b_q = np.asarray(self.queue, np.int64)
        b_s = np.asarray(self.start, np.int64)
        b_e = np.asarray(self.end, np.int64)
        b_k = np.asarray(self.kind, np.int64)
        b_live = b_e > b_s
        d_q = np.asarray(delta.queue, np.int64)
        d_k = np.asarray(delta.kind, np.int64)
        for i in np.nonzero(d_live)[0]:
            same_q = b_live & (b_q == d_q[i])
            overlap = same_q & (b_s < d_e[i]) & (d_s[i] < b_e)
            if np.any(overlap & (b_k == 0)):
                j = np.nonzero(overlap & (b_k == 0))[0].tolist()
                raise ValueError(
                    f"delta row {int(i)} (queue {int(d_q[i])}, "
                    f"[{int(d_s[i])}, {int(d_e[i])})) overlaps existing "
                    f"down window(s) {j}: the link is already dead there, "
                    "and the delta's end tick would resurrect it"
                )
            if np.any(overlap & (b_k == d_k[i])):
                j = np.nonzero(overlap & (b_k == d_k[i]))[0].tolist()
                raise ValueError(
                    f"delta row {int(i)} (queue {int(d_q[i])}) overlaps "
                    f"same-kind window(s) {j}: double-scheduled event"
                )
            # accepted rows join the base for subsequent delta-row checks,
            # so a delta overlapping itself is rejected the same way
            b_q = np.append(b_q, d_q[i])
            b_s = np.append(b_s, d_s[i])
            b_e = np.append(b_e, d_e[i])
            b_k = np.append(b_k, d_k[i])
            b_live = np.append(b_live, True)
        live_delta = FailureSchedule(
            queue=np.asarray(delta.queue, np.int32)[d_live],
            start=np.asarray(delta.start, np.int32)[d_live],
            end=np.asarray(delta.end, np.int32)[d_live],
            kind=np.asarray(delta.kind, np.int32)[d_live],
            param=np.asarray(delta.param, np.int32)[d_live],
        )
        merged = FailureSchedule.concat(self, live_delta)
        merged.validate(n_queues)
        return merged


class ScenarioArrays(NamedTuple):
    """Per-scenario dynamic arrays, split out of the Simulator so the sweep
    engine can batch *heterogeneous* scenarios: ``step_scenario`` is pure in
    (state, tick, key, scenario), and scenarios sharing static shapes vmap
    together on a leading row axis (repro.netsim.sweep)."""

    conn_src: jax.Array  # (NC,) int32
    conn_dst: jax.Array  # (NC,) int32
    conn_msg: jax.Array  # (NC,) int32
    conn_start: jax.Array  # (NC,) int32
    conn_dep: jax.Array  # (NC,) int32
    host_conns: jax.Array  # (NH, CPH) int32, -1 padded
    watch: jax.Array  # (W,) int32 queue ids traced per tick
    f_queue: jax.Array  # (F,) int32
    f_start: jax.Array  # (F,) int32
    f_end: jax.Array  # (F,) int32
    f_kind: jax.Array  # (F,) int32
    f_param: jax.Array  # (F,) int32


class SimState(NamedTuple):
    # packed packet table (PF, NP) int32 — see field constants above
    pkt: jax.Array
    # queues
    qbuf: jax.Array  # (NQ, QCAP)
    q_head: jax.Array
    q_len: jax.Array
    q_served: jax.Array  # cumulative serve count per queue
    # connections
    c_inflight: jax.Array
    c_next_new: jax.Array
    c_delivered: jax.Array
    c_rx_pending: jax.Array
    c_done: jax.Array
    c_done_tick: jax.Array
    c_rtx_count: jax.Array
    c_rtx: jax.Array  # (NC, MSG) bool
    c_rcv: jax.Array  # (NC, MSG) bool
    c_cwnd: jax.Array  # float32
    c_alpha: jax.Array  # float32
    # hosts
    h_rr: jax.Array
    # LB state
    lb_state: Any
    # free list
    fl: jax.Array
    fl_head: jax.Array
    fl_count: jax.Array
    # cumulative stats, fused into one vector (N_STATS,)
    s_stats: jax.Array
    # sparse active-slot set (conn-scale mode, ARCHITECTURE.md §10):
    # as_idx is the ascending, NP-padded list of currently allocated packet
    # slots and as_count the number of real entries.  Dense mode carries
    # the empty placeholder ((0,) / scalar 0) so the pytree structure —
    # and therefore every compiled sweep shape — is mode-independent.
    as_idx: jax.Array  # (A,) int32, sorted, NP-padded (dense: (0,))
    as_count: jax.Array  # () int32

    # ---- unpacked views (read-only compat accessors) ---------------------
    @property
    def p_state(self):
        return self.pkt[PS]

    @property
    def p_conn(self):
        return self.pkt[PCONN]

    @property
    def p_ev(self):
        return self.pkt[PEV]

    @property
    def p_seq(self):
        return self.pkt[PSEQ]

    @property
    def p_hop(self):
        return self.pkt[PHOP]

    @property
    def p_cur_queue(self):
        return self.pkt[PCURQ]

    @property
    def p_send_tick(self):
        return self.pkt[PSEND]

    @property
    def p_event_tick(self):
        return self.pkt[PEVT]

    @property
    def p_ecn(self):
        return self.pkt[PECN].astype(jnp.bool_)

    @property
    def p_orphan(self):
        return self.pkt[PORPH].astype(jnp.bool_)

    @property
    def p_ack_count(self):
        return self.pkt[PACK]

    @property
    def s_drops_cong(self):
        return self.s_stats[ST_DROPS_CONG]

    @property
    def s_drops_fail(self):
        return self.s_stats[ST_DROPS_FAIL]

    @property
    def s_timeouts(self):
        return self.s_stats[ST_TIMEOUTS]

    @property
    def s_delivered(self):
        return self.s_stats[ST_DELIVERED]

    @property
    def s_ecn_marks(self):
        return self.s_stats[ST_ECN]

    @property
    def s_injected(self):
        return self.s_stats[ST_INJECTED]

    @property
    def s_unprocessed(self):
        return self.s_stats[ST_UNPROC]

    @property
    def s_alloc_fail(self):
        return self.s_stats[ST_ALLOC_FAIL]


class TickTrace(NamedTuple):
    max_qlen: jax.Array
    sum_qlen: jax.Array
    drops: jax.Array
    timeouts: jax.Array
    delivered: jax.Array
    injected: jax.Array
    watch_qlen: jax.Array  # (W,)
    watch_served: jax.Array  # (W,) int32 0/1


class Probe(NamedTuple):
    """Per-tick observables the telemetry channels reduce over
    (repro.netsim.telemetry).

    Unlike ``TickTrace`` (a raw stream destined for the host), a ``Probe``
    never leaves the device: it is consumed on the spot by the pure
    ``(carry, probe) -> carry`` channel reducers folded inside the scanned
    tick loop.  Every field is a *delta or instantaneous* view of the tick,
    so a quiescent tick (no packets, no startable work) produces an
    all-zero probe and channel updates become no-ops — which is what makes
    summary collection compatible with quiescence early exit.
    """

    now: jax.Array  # () int32 — the tick just executed
    q_len: jax.Array  # (NQ,) int32 occupancy after the tick
    served: jax.Array  # (NQ,) int32 0/1 — dequeued this tick
    watch_qlen: jax.Array  # (W,) int32 occupancy of watched queues
    watch_served: jax.Array  # (W,) int32 0/1 for watched queues
    stats_delta: jax.Array  # (N_STATS,) int32 counter increments this tick
    done_now: jax.Array  # (NC,) bool — conns that completed this tick
    fct: jax.Array  # (NC,) int32 — done tick - start where done_now, else 0


class TickEvents(NamedTuple):
    """Per-tick decision-event counts for the flight recorder
    (repro.netsim.tracer).

    Observation-only companions to ``Probe``: derived from state diffs
    around the LB call sites (the optional ``LoadBalancer.trace`` port) and
    the scenario's failure windows, never fed back into the simulation.
    Same quiescence contract as ``Probe`` — all-zero on a quiescent tick —
    so the tracer carry stays compatible with early exit and per-row
    horizon freezing.
    """

    lb: jax.Array  # (N_TRACE_KINDS,) int32 LB decision counts this tick
    fail_start: jax.Array  # () int32 — queues whose failure window opens now


class Simulator:
    """Builds and runs one simulation scenario.

    Static scenario structure (cfg / topo / workload tables / failures /
    watch list) lives on the instance; per-run dynamic state is the
    ``SimState`` pytree plus the PRNG base key, both explicit arguments of
    the pure ``_step`` — which is what lets ``FleetRunner`` vmap one
    compiled scenario over many seeds.
    """

    def __init__(
        self,
        cfg: SimConfig,
        workload: Workload,
        lb: LoadBalancer,
        failures: FailureSchedule | None = None,
        watch_queues: np.ndarray | None = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.topo = Topology.build(cfg)
        self.wl = workload
        self.lb = lb
        self.failures = failures or FailureSchedule.none()
        if cfg.failure_slots:
            # shape pin (sweep bucketing): pad with inert rows so a serial
            # reference built from the raw schedule shares the sweep row's
            # (F,) shape — semantics of pad rows are FailureSchedule's.
            self.failures = self.failures.pad_to(cfg.failure_slots)
        self.failures.validate(self.topo.n_queues)
        self.seed = seed

        NC = workload.n_conns
        msg_max = int(workload.msg_pkts.max()) if NC else 1
        assert msg_max <= cfg.max_msg_pkts, (
            f"message of {msg_max} pkts exceeds max_msg_pkts={cfg.max_msg_pkts}"
        )
        auto_msg = int(min(cfg.max_msg_pkts, max(int(2 ** np.ceil(np.log2(max(msg_max, 2)))), 2)))
        if cfg.msg_slots:
            assert cfg.msg_slots >= auto_msg, (
                f"msg_slots={cfg.msg_slots} < required bitmap width {auto_msg}"
            )
            self.MSG = int(cfg.msg_slots)
        else:
            self.MSG = auto_msg
        self.NQ = self.topo.n_queues
        self.NH = cfg.n_hosts
        if cfg.conn_sharding:
            # Scale mode: live packet slots are bounded by slot *lifetime*
            # (injection admits ≤ NH/tick and every slot frees within
            # rto + drain + feedback latency of its send), not by
            # NC * max_cwnd — so the auto size caps at the lifetime bound
            # and a million-conn run no longer allocates a 2^28-slot table.
            # At figure scales the conn-based size is the smaller of the
            # two, so the auto rule (and every result) is unchanged there.
            bound = self._active_bound()
            conn_auto = int(
                2 ** np.ceil(np.log2(NC * cfg.max_cwnd_pkts + 4 * self.NH + 64))
            )
            self.NP = int(cfg.pkt_slots) if cfg.pkt_slots else min(conn_auto, bound)
            if self.NP > INT32_MAX:
                raise ValueError(
                    f"pkt_slots={self.NP} exceeds the int32 slot namespace "
                    f"(max {INT32_MAX})"
                )
            self.A = min(int(cfg.active_slots) if cfg.active_slots else bound, self.NP)
        else:
            # dense mode: THE auto rule, python-int checked against int32
            # (near 10**6 conns the raw product wraps silently otherwise)
            self.NP = checked_auto_pkt_slots(
                NC, cfg.max_cwnd_pkts, self.NH, pin=cfg.pkt_slots
            )
            self.A = 0
        # MAX_ARR is RNG-visible (the per-arrival RED uniform draw has
        # shape (MAX_ARR,), and jax threefry draws are not prefix-stable),
        # so it keeps the seed engine's generous bound for bit-parity.
        self.MAX_ARR = self.NQ + self.NH
        # MAX_EV / MAX_FREE are pure compaction sizes — no RNG shape
        # derives from them — so they use tight per-tick bounds (every K
        # beyond a bound is provably unreachable, making the shrink
        # bit-invisible while directly narrowing the hot-path rank /
        # segment-sum / scatter widths):
        #  * feedback: ACKs are emitted only by final-hop dequeues (the NH
        #    host downlinks, queues ≥ t0_down_base) with a fixed ack delay
        #    → ≤ NH due per tick; trim NACKs (≤ MAX_ARR, fixed nack delay)
        #    exist only when cfg.trimming;
        #  * frees: feedback slots (≤ MAX_EV) + RTO LOST_WAIT expiries
        #    (≤ NH) + service frees (≤ NQ serves) + arrival drops
        #    (≤ MAX_ARR).
        self.MAX_EV = self.NH + (self.MAX_ARR if cfg.trimming else 0)
        self.MAX_FREE = self.MAX_EV + self.NQ + self.MAX_ARR + self.NH

        # int32 audit: the widest flattened segment-id / sort-key spaces the
        # tick builds (feedback (round, conn) table; seg-rank's
        # seg * K + iota sort keys).  Computed in python ints — near 10**6
        # conns these cross 2**31 long before any array exists, and a
        # wrapped id would scatter into the wrong connection silently.
        widest = max(
            (cfg.feedback_rounds + 1) * (NC + 1),
            (NC + 1) * (self.MAX_EV + 1),
            (self.NQ + 1) * (self.MAX_ARR + 1),
        )
        if widest > INT32_MAX:
            raise ValueError(
                f"per-tick segment-id space overflows int32: n_conns={NC}, "
                f"n_queues={self.NQ}, max events/tick {self.MAX_EV}, "
                f"max arrivals/tick {self.MAX_ARR} -> widest id {widest} > "
                f"{INT32_MAX}. Reduce the topology/connection count."
            )

        # host -> local conn table (vectorized — the per-conn python loop
        # this replaces dominated build time near 10**6 conns)
        src = np.asarray(workload.src, np.int64)
        counts = (
            np.bincount(src, minlength=self.NH)
            if NC
            else np.zeros(self.NH, np.int64)
        )
        auto_cph = int(max(1, counts.max())) if NC else 1
        if cfg.conns_per_host:
            assert cfg.conns_per_host >= auto_cph, (
                f"conns_per_host={cfg.conns_per_host} < required {auto_cph}"
            )
            self.CPH = int(cfg.conns_per_host)
        else:
            self.CPH = auto_cph
        hc = np.full((self.NH, self.CPH), -1, np.int32)
        if NC:
            # stable sort by host keeps conn-id order within each host —
            # identical fill to the per-host append loop it replaces
            order = np.argsort(src, kind="stable")
            starts = np.zeros(self.NH, np.int64)
            starts[1:] = np.cumsum(counts)[:-1]
            rank = np.arange(NC, dtype=np.int64) - starts[src[order]]
            hc[src[order], rank] = order
        self.host_conns = jnp.asarray(hc)

        self.conn_src = jnp.asarray(workload.src.astype(np.int32))
        self.conn_dst = jnp.asarray(workload.dst.astype(np.int32))
        self.conn_msg = jnp.asarray(workload.msg_pkts.astype(np.int32))
        self.conn_start = jnp.asarray(workload.start.astype(np.int32))
        self.conn_dep = jnp.asarray(workload.dep.astype(np.int32))

        if watch_queues is None:
            watch_queues = self.topo.t0_up_queues(0)[: cfg.n_watch_queues]
        self.watch = jnp.asarray(np.asarray(watch_queues, np.int32))

        self.f_queue = jnp.asarray(self.failures.queue)
        self.f_start = jnp.asarray(self.failures.start)
        self.f_end = jnp.asarray(self.failures.end)
        self.f_kind = jnp.asarray(self.failures.kind)
        self.f_param = jnp.asarray(self.failures.param)

        # the pure-step view of this scenario's dynamic arrays
        self.scn = ScenarioArrays(
            conn_src=self.conn_src,
            conn_dst=self.conn_dst,
            conn_msg=self.conn_msg,
            conn_start=self.conn_start,
            conn_dep=self.conn_dep,
            host_conns=self.host_conns,
            watch=self.watch,
            f_queue=self.f_queue,
            f_start=self.f_start,
            f_end=self.f_end,
            f_kind=self.f_kind,
            f_param=self.f_param,
        )

        self.base_key = jax.random.PRNGKey(seed)

    # ------------------------------------------------------------------
    def _active_bound(self) -> int:
        """Pow2 bound on simultaneously-allocated packet slots (conn-scale
        mode): injection admits ≤ NH packets per tick and every slot frees
        within one lifetime of its send — worst-case path drain
        (diameter hops, each ≤ hop latency + a full queue at degraded
        half-rate) plus the feedback return delay, with RTO as the hard
        backstop for silent losses.  LOST_WAIT slots of already-completed
        connections leak past this bound (their RTO never fires — same as
        dense mode, where NP slack absorbs them); if a long lossy soak
        fills the cap, injection alloc-fails *visibly* (s_alloc_fail)
        rather than corrupting state.
        """
        cfg = self.cfg
        lifetime = (
            cfg.rto_ticks
            + cfg.ack_delay_ticks
            + cfg.nack_delay_ticks
            + self.topo.diameter * (cfg.hop_latency_ticks + 2 * cfg.queue_capacity)
        )
        raw = self.NH * lifetime + 4 * self.NH + 64
        return int(2 ** np.ceil(np.log2(max(raw, 2))))

    # ------------------------------------------------------------------
    def init_state(self, key: jax.Array | None = None) -> SimState:
        NP, NQ, NC, NH = self.NP, self.NQ, self.wl.n_conns, self.NH
        cfg = self.cfg
        i32 = jnp.int32
        if key is None:
            key = self.base_key
        return SimState(
            pkt=jnp.zeros((PF, NP), i32),
            qbuf=jnp.zeros((NQ, cfg.queue_capacity), i32),
            q_head=jnp.zeros((NQ,), i32),
            q_len=jnp.zeros((NQ,), i32),
            q_served=jnp.zeros((NQ,), i32),
            c_inflight=jnp.zeros((NC,), i32),
            c_next_new=jnp.zeros((NC,), i32),
            c_delivered=jnp.zeros((NC,), i32),
            c_rx_pending=jnp.zeros((NC,), i32),
            c_done=jnp.zeros((NC,), jnp.bool_),
            c_done_tick=jnp.full((NC,), -1, i32),
            c_rtx_count=jnp.zeros((NC,), i32),
            c_rtx=jnp.zeros((NC, self.MSG), jnp.bool_),
            c_rcv=jnp.zeros((NC, self.MSG), jnp.bool_),
            c_cwnd=jnp.full((NC,), float(cfg.init_cwnd_pkts), jnp.float32),
            c_alpha=jnp.zeros((NC,), jnp.float32),
            h_rr=jnp.zeros((NH,), i32),
            lb_state=self.lb.init_state(NC, jax.random.fold_in(key, 777)),
            fl=jnp.arange(NP, dtype=i32),
            fl_head=jnp.zeros((), i32),
            fl_count=jnp.asarray(NP, i32),
            s_stats=jnp.zeros((N_STATS,), i32),
            as_idx=jnp.full((self.A,), NP, i32),
            as_count=jnp.zeros((), i32),
        )

    # ------------------------------------------------------------------
    def _cc_on_ack(self, cwnd, alpha, mask, ecn, rtt):
        """Per-ACK CC update (DCTCP-variant per §4.1 / MPRDMA)."""
        cfg = self.cfg
        if cfg.cc == "dctcp":
            g = cfg.dctcp_g
            alpha = jnp.where(
                mask, (1 - g) * alpha + g * ecn.astype(jnp.float32), alpha
            )
            up = cwnd + 1.0 / jnp.maximum(cwnd, 1.0)
            down = cwnd - alpha / 2.0
            cwnd = jnp.where(mask, jnp.where(ecn, down, up), cwnd)
        elif cfg.cc == "eqds":
            # receiver-credit approximation: fast additive increase toward a
            # hard BDP cap; ECN halves toward the cap floor.
            up = cwnd + 4.0 / jnp.maximum(cwnd, 1.0)
            down = cwnd - 0.5
            cwnd = jnp.where(mask, jnp.where(ecn, down, up), cwnd)
            cwnd = jnp.minimum(cwnd, float(self.cfg.init_cwnd_pkts))
        elif cfg.cc == "delay":
            t = float(cfg.delay_target_ticks)
            over = (rtt.astype(jnp.float32) - t) / t
            up = cwnd + 1.0 / jnp.maximum(cwnd, 1.0)
            down = cwnd - cfg.delay_beta * jnp.clip(over, 0.0, 1.0)
            cwnd = jnp.where(mask, jnp.where(over > 0, down, up), cwnd)
        else:
            raise ValueError(cfg.cc)
        cwnd = jnp.clip(cwnd, 1.0, float(cfg.max_cwnd_pkts))
        return cwnd, alpha

    # ------------------------------------------------------------------
    @staticmethod
    def _compact(mask: jax.Array, size: int) -> jax.Array:
        """Indices of set bits in ascending order, padded with len(mask).

        Bit-equivalent to ``jnp.nonzero(mask, size=size, fill_value=N)[0]``
        but ~15x cheaper on the CPU backend: the j-th set bit is found by a
        vectorized binary search over the running popcount instead of the
        full-width scatter nonzero lowers to.
        """
        cs = jnp.cumsum(mask.astype(jnp.int32))
        targets = jnp.arange(1, size + 1, dtype=jnp.int32)
        return jnp.searchsorted(cs, targets, side="left").astype(jnp.int32)

    @staticmethod
    def _seg_rank(seg: jax.Array) -> jax.Array:
        """FIFO rank of each element within its segment (stable in input
        order): rank_i = #{j < i : seg_j == seg_i}.

        For the K used at CI scale (a few hundred) the O(K^2) pairwise
        comparison is a single fused compare+reduce — cheaper than both
        argsort and a segment-cumsum over the one-hot histogram, whose
        K x n_segs scan dominates the arrivals step on CPU/TPU.  Past ~1k
        elements the quadratic mask loses to the O(K log K) sort, so large
        fleets fall back to the sort-based run-length rank.
        """
        K = seg.shape[0]
        if K <= 1024:
            earlier = jnp.tril(jnp.ones((K, K), jnp.bool_), k=-1)  # j < i
            same = seg[None, :] == seg[:, None]
            return jnp.sum(same & earlier, axis=1, dtype=jnp.int32)
        iota = jnp.arange(K, dtype=jnp.int32)
        order = jnp.argsort(seg * jnp.int32(K) + iota)  # stable in input order
        ts = seg[order]
        run_start = jnp.concatenate(
            [jnp.ones((1,), jnp.bool_), ts[1:] != ts[:-1]]
        )
        pos_in_run = iota - jax.lax.cummax(jnp.where(run_start, iota, 0))
        return jnp.zeros((K,), jnp.int32).at[order].set(pos_in_run)

    # ------------------------------------------------------------------
    # Backend-switchable segment primitives (SimConfig.kernels_backend).
    # "auto" resolves at trace time: the tiled Pallas kernels on TPU, the
    # jnp formulations elsewhere.  Both are bit-identical (int32 adds are
    # order-free; ranks are exact), so flipping the backend never changes
    # simulation results — tests/test_kernel_parity.py locks this across
    # multi-bucket sweeps.
    def _kb(self) -> str:
        from repro.distrib.sharding import resolve_kernels_backend

        return resolve_kernels_backend(self.cfg.kernels_backend)

    def _seg_rank_b(self, seg: jax.Array, n_segments: int) -> jax.Array:
        """FIFO rank within segment; ids >= n_segments are sentinels whose
        ranks are never consumed (the pallas kernel returns 0 for them)."""
        if self._kb() == "pallas":
            from repro.kernels import ops as kernel_ops

            return kernel_ops.seg_rank(seg, n_segments)
        return self._seg_rank(seg)

    def _seg_sum_b(
        self, seg: jax.Array, vals: jax.Array, n_segments: int
    ) -> jax.Array:
        """Stacked (F, K) int32 fields segment-summed to (F, n_segments);
        ids >= n_segments drop.  One narrow scatter-add on the jnp path —
        the replacement for the dense per-field one-hot reductions."""
        if self._kb() == "pallas":
            from repro.kernels import ops as kernel_ops

            return kernel_ops.seg_sum(seg, vals, n_segments)
        return jnp.zeros((vals.shape[0], n_segments), jnp.int32).at[
            :, seg
        ].add(vals, mode="drop")

    # ------------------------------------------------------------------
    # Conn-sharded bitmap indirection (scale mode).  Under a conn-axis mesh
    # the (NC, MSG) rtx/rcv bitmaps are the only per-conn state too large
    # to replicate, so they stay device-local and every access goes through
    # these four helpers: each device answers for the conn rows it owns and
    # a psum-OR reconstructs the full-shape value the tick body expects
    # (scatters simply drop on non-owners).  With conn_axis=None each
    # helper IS the dense expression it replaces, byte-for-byte.
    def _bm_local(self, bmap, conns, conn_axis):
        NCd = bmap.shape[0]
        off = jax.lax.axis_index(conn_axis) * NCd
        loc = conns - off
        inr = (loc >= 0) & (loc < NCd)
        return jnp.where(inr, loc, NCd), inr

    def _bm_get(self, bmap, conns, seqs, conn_axis):
        """``bmap.at[conns, seqs].get(mode="fill", fill_value=True)``."""
        if conn_axis is None:
            return bmap.at[conns, seqs].get(mode="fill", fill_value=True)
        loc, inr = self._bm_local(bmap, conns, conn_axis)
        got = bmap.at[loc, seqs].get(mode="fill", fill_value=False)
        hit = jax.lax.psum((inr & got).astype(jnp.int32), conn_axis) > 0
        return hit | (conns >= self.wl.n_conns) | (conns < 0)

    def _bm_max(self, bmap, conns, seqs, vals, conn_axis):
        """``bmap.at[conns, seqs].max(vals, mode="drop")``."""
        if conn_axis is None:
            return bmap.at[conns, seqs].max(vals, mode="drop")
        loc, _ = self._bm_local(bmap, conns, conn_axis)
        return bmap.at[loc, seqs].max(vals, mode="drop")

    def _bm_set_false(self, bmap, conns, seqs, conn_axis):
        """``bmap.at[conns, seqs].set(False, mode="drop")``."""
        if conn_axis is None:
            return bmap.at[conns, seqs].set(False, mode="drop")
        loc, _ = self._bm_local(bmap, conns, conn_axis)
        return bmap.at[loc, seqs].set(False, mode="drop")

    def _bm_rows(self, bmap, conns, conn_axis):
        """``bmap[conns]`` — full (K, MSG) bool rows; callers pass in-range
        conn ids only."""
        if conn_axis is None:
            return bmap[conns]
        loc, inr = self._bm_local(bmap, conns, conn_axis)
        rows = bmap.at[loc].get(mode="fill", fill_value=False)
        rows = jnp.where(inr[:, None], rows, False)
        return jax.lax.psum(rows.astype(jnp.int32), conn_axis) > 0

    # ------------------------------------------------------------------
    def tick_fn(self, state: SimState, tick: jax.Array) -> tuple[SimState, TickTrace]:
        return self._step(state, tick, self.base_key)

    def _step(
        self, state: SimState, tick: jax.Array, base_key: jax.Array
    ) -> tuple[SimState, TickTrace]:
        return self.step_scenario(state, tick, base_key, self.scn)

    def step_scenario(
        self,
        state: SimState,
        tick: jax.Array,
        base_key: jax.Array,
        scn: ScenarioArrays,
        emit_events: bool = False,
        conn_axis: str | None = None,
    ) -> tuple:
        """One tick, pure in (state, tick, key, scenario arrays).

        Static structure (cfg, topology, shapes, LB object) still lives on
        the instance; everything a scenario can vary *without changing
        shapes* arrives via ``scn`` — which is what the sweep engine vmaps
        over to batch heterogeneous (workload, lb, failures) cells into one
        compiled scan (repro.netsim.sweep).

        ``emit_events`` is a *static* flag: when False (the default) the
        compiled computation is byte-for-byte today's — no trace-port calls
        are staged at all.  When True the return grows a third element, a
        ``TickEvents`` of observation-only decision counts gathered from
        LB-state diffs around the three LB call sites (``fold_in`` key
        derivation consumes no randomness and the trace port draws none, so
        the (state, trace) pair is bit-identical either way).

        ``conn_axis`` (static) names the mesh axis the *connection* state
        axis is sharded over (scale mode, inside ``shard_map``): small
        (NC,) per-conn vectors and the scn conn tables arrive as local
        shards, are all_gathered to full shape at entry and sliced back at
        exit — so every RNG draw keeps its full, shard-count-independent
        shape and results stay bit-identical to the unsharded run — while
        the (NC, MSG) rtx/rcv bitmaps (the dominant per-conn storage) stay
        device-local behind the ``_bm_*`` psum indirection.  ``lb_state``
        is replicated: LBs draw (NC,)-shaped randoms internally, so
        sharding it would change draw shapes and break parity.
        """
        cfg, topo = self.cfg, self.topo
        NP, NQ, NH = self.NP, self.NQ, self.NH
        NC = self.wl.n_conns
        QCAP = cfg.queue_capacity
        now = tick.astype(jnp.int32)
        key = jax.random.fold_in(base_key, tick)

        pkt = state.pkt
        (
            qbuf, q_head, q_len, q_served,
            c_inflight, c_next_new, c_delivered, c_rx_pending, c_done,
            c_done_tick, c_rtx_count, c_rtx, c_rcv, c_cwnd, c_alpha,
            h_rr, lb_state, fl, fl_head, fl_count, s_stats,
            as_idx, as_count,
        ) = state[1:]

        if conn_axis is not None:
            # conn-sharded entry: gather the small per-conn leaves to full
            # shape (collective cost O(NC) scalars/tick; the (NC, MSG)
            # bitmaps stay local).  NCd/coff identify this device's block.
            NCd = c_inflight.shape[0]
            coff = jax.lax.axis_index(conn_axis) * NCd

            def cgather(x):
                return jax.lax.all_gather(x, conn_axis, axis=0, tiled=True)

            (c_inflight, c_next_new, c_delivered, c_rx_pending, c_done,
             c_done_tick, c_rtx_count, c_cwnd, c_alpha) = (
                cgather(c_inflight), cgather(c_next_new),
                cgather(c_delivered), cgather(c_rx_pending),
                cgather(c_done), cgather(c_done_tick),
                cgather(c_rtx_count), cgather(c_cwnd), cgather(c_alpha),
            )
            scn = scn._replace(
                conn_src=cgather(scn.conn_src),
                conn_dst=cgather(scn.conn_dst),
                conn_msg=cgather(scn.conn_msg),
                conn_start=cgather(scn.conn_start),
                conn_dep=cgather(scn.conn_dep),
            )

        sparse = bool(cfg.conn_sharding)
        if sparse:
            # scale mode: stages 1/2/4/6 iterate the packet table through
            # the sorted active-slot set (A entries) instead of dense (NP,)
            # masks — per-tick cost tracks live traffic, not table width.
            # Compaction works on positions-within-as_idx, then maps back
            # through as_idx; because as_idx is kept ascending, the
            # compacted slot sequences are identical to the dense path's,
            # and with A == NP the whole mode is bit-identical to dense.
            asx = jnp.minimum(as_idx, NP - 1)
            as_valid = as_idx < NP
            asg = jnp.where(as_valid, as_idx, NP)  # scatter-drop form
            entry_ps_a = jnp.where(as_valid, pkt[PS, asx], FREE)
        else:
            state_at_entry = pkt[PS]

        if emit_events:
            from repro.core.load_balancers import N_TRACE_KINDS

            lb_counts = jnp.zeros((N_TRACE_KINDS,), jnp.int32)

        # =============== 1. feedback (ACK / NACK) =====================
        if sparse:
            ps_a = entry_ps_a
            evt_a = pkt[PEVT, asx]
            due_a = as_valid & ((ps_a == IN_ACK) | (ps_a == IN_NACK)) & (evt_a == now)
            e_pos = self._compact(due_a, self.MAX_EV)
            e_idx = jnp.where(
                e_pos < self.A, as_idx[jnp.minimum(e_pos, self.A - 1)], NP
            )
        else:
            p_state = pkt[PS]
            due = ((p_state == IN_ACK) | (p_state == IN_NACK)) & (pkt[PEVT] == now)
            e_idx = self._compact(due, self.MAX_EV)
        e_valid = e_idx < NP
        E = pkt[:, jnp.minimum(e_idx, NP - 1)]  # (PF, MAX_EV) one gather
        e_conn = jnp.where(e_valid, E[PCONN], NC)  # NC = sentinel segment
        e_is_nack = e_valid & (E[PS] == IN_NACK)
        e_is_ack = e_valid & ~e_is_nack
        e_ev = jnp.where(e_valid, E[PEV], 0)
        e_ecn = e_valid & (E[PECN] == 1)
        e_cnt = jnp.where(e_valid, E[PACK], 0)
        e_seq = jnp.where(e_valid, E[PSEQ], 0)
        e_rtt = jnp.where(e_valid, now - E[PSEND], 0)

        # ONE stacked segment-sum covers the whole feedback stage.  Index =
        # (ACK round, conn): an ACK's round is its FIFO rank among
        # same-connection ACKs (slot order, unique per conn — computed once
        # by the segment-rank primitive, no per-round scatter-min
        # selection); non-ACK/pad events land via their real conn (rank
        # within the NC sentinel segment picks an arbitrary row, summed
        # out) so the round-summed leading fields still aggregate ALL
        # events, while the ACK-masked trailing fields keep the per-round
        # table clean.  Without trimming no packet can ever be IN_NACK
        # (only the arrivals trim branch creates them), so the NACK
        # bookkeeping — rtx marking, the cwnd decrement, two table fields
        # and a bitmap scatter — is statically compiled out.
        R_fb = cfg.feedback_rounds
        ack_seg = jnp.where(e_is_ack, e_conn, NC)
        e_rank = self._seg_rank_b(ack_seg, NC + 1)
        ridx = jnp.minimum(e_rank, R_fb) * (NC + 1) + e_conn
        fields = [
            jnp.where(e_is_nack, 1, e_cnt) if cfg.trimming else e_cnt,  # dec
            e_is_ack.astype(jnp.int32),
            jnp.where(e_is_ack, e_ev, 0),
            (e_ecn & e_is_ack).astype(jnp.int32),
            jnp.where(e_is_ack, e_rtt, 0),
        ]
        if cfg.trimming:
            already = self._bm_get(c_rcv, e_conn, e_seq, conn_axis)
            need_rtx = e_is_nack & ~already
            prev_rtx = self._bm_get(c_rtx, e_conn, e_seq, conn_axis)
            c_rtx = self._bm_max(c_rtx, e_conn, e_seq, need_rtx, conn_axis)
            fields += [
                (need_rtx & ~prev_rtx).astype(jnp.int32),
                e_is_nack.astype(jnp.int32),
            ]
        tbl = self._seg_sum_b(
            ridx, jnp.stack(fields), (R_fb + 1) * (NC + 1)
        ).reshape(len(fields), R_fb + 1, NC + 1)
        fb = jnp.sum(tbl, axis=1)  # rank-independent totals per conn
        c_inflight = c_inflight - fb[0, :NC]
        if cfg.trimming:
            c_rtx_count = c_rtx_count + fb[5, :NC]
            nacks_per_conn = fb[6, :NC]
            c_cwnd = jnp.clip(
                c_cwnd - nacks_per_conn.astype(jnp.float32),
                1.0,
                float(cfg.max_cwnd_pkts),
            )

        # LB + CC updates: up to `feedback_rounds` exact rounds of one ACK
        # event per connection — round r's per-conn event is table row r.
        # Each round gets its own key off the tick stream (fold 4) so
        # repath draws differ per seed / row / tick / round; key-ignoring
        # LBs are bit-identical (fold_in consumes no randomness).
        k_ack = jax.random.fold_in(key, 4)
        for r in range(R_fb):
            conn_mask = tbl[1, r, :NC] > 0
            conn_ev = tbl[2, r, :NC]
            conn_ecn = tbl[3, r, :NC] > 0
            conn_rtt = tbl[4, r, :NC]
            c_cwnd, c_alpha = self._cc_on_ack(c_cwnd, c_alpha, conn_mask, conn_ecn, conn_rtt)
            prev_lb = lb_state
            lb_state = self.lb.on_ack(
                lb_state, conn_mask, conn_ev, conn_ecn, now,
                jax.random.fold_in(k_ack, r),
            )
            if emit_events:
                lb_counts = lb_counts + self.lb.trace(
                    "ack", prev_lb, lb_state, conn_mask
                )
        unprocessed = jnp.sum(
            (e_is_ack & (e_rank >= R_fb)).astype(jnp.int32)
        )

        # =============== 2. RTO ========================================
        # A packet fires its RTO exactly at send_tick + rto_ticks (send_tick
        # is set once at injection and eligibility blockers — orphan, conn
        # done — are permanent), and injection admits ≤ 1 packet per host
        # per tick, so ≤ NH packets fire per tick: compact to NH rows and
        # keep every scatter narrow instead of full packet-table width.
        if sparse:
            ps_a = jnp.where(due_a, FREE, ps_a)  # free feedback slots
            porph_a = pkt[PORPH, asx] == 1
            active_a = (ps_a == FLYING) | (ps_a == QUEUED) | (ps_a == LOST_WAIT)
            cdone_a = c_done[jnp.clip(pkt[PCONN, asx], 0, NC - 1)]
            rto_a = (
                active_a
                & ~porph_a
                & ((now - pkt[PSEND, asx]) >= cfg.rto_ticks)
                & ~cdone_a
                & as_valid
            )
            r_pos = self._compact(rto_a, NH)
            r_idx = jnp.where(
                r_pos < self.A, as_idx[jnp.minimum(r_pos, self.A - 1)], NP
            )
            timeouts_d = jnp.sum(rto_a.astype(jnp.int32))
        else:
            # free all feedback slots
            p_state = jnp.where(due, FREE, p_state)
            p_conn = pkt[PCONN]
            p_orphan = pkt[PORPH] == 1
            active_data = (p_state == FLYING) | (p_state == QUEUED) | (p_state == LOST_WAIT)
            conn_done_of_pkt = c_done[jnp.clip(p_conn, 0, NC - 1)]
            rto = (
                active_data
                & ~p_orphan
                & ((now - pkt[PSEND]) >= cfg.rto_ticks)
                & ~conn_done_of_pkt
            )
            r_idx = self._compact(rto, NH)
            timeouts_d = jnp.sum(rto.astype(jnp.int32))
        r_valid = r_idx < NP
        Rp = pkt[:, jnp.minimum(r_idx, NP - 1)]  # (PF, NH)
        r_conn = jnp.where(r_valid, Rp[PCONN], NC)
        r_seq = jnp.where(r_valid, Rp[PSEQ], 0)
        rcv_already = self._bm_get(c_rcv, r_conn, r_seq, conn_axis)
        rto_need = r_valid & ~rcv_already
        prev_rtx_p = self._bm_get(c_rtx, r_conn, r_seq, conn_axis)
        c_rtx = self._bm_max(
            c_rtx, jnp.where(rto_need, r_conn, NC), r_seq, rto_need, conn_axis
        )
        rsum_rto = self._seg_sum_b(
            r_conn,
            jnp.stack([
                (rto_need & ~prev_rtx_p).astype(jnp.int32),
                r_valid.astype(jnp.int32),
            ]),
            NC + 1,
        )
        c_rtx_count = c_rtx_count + rsum_rto[0, :NC]
        rto_per_conn = rsum_rto[1, :NC]
        c_inflight = c_inflight - rto_per_conn
        c_cwnd = jnp.clip(
            c_cwnd - rto_per_conn.astype(jnp.float32), 1.0, float(cfg.max_cwnd_pkts)
        )
        prev_lb = lb_state
        lb_state = self.lb.on_timeout(
            lb_state, rto_per_conn > 0, now, jax.random.fold_in(key, 5)
        )
        if emit_events:
            lb_counts = lb_counts + self.lb.trace(
                "timeout", prev_lb, lb_state, rto_per_conn > 0
            )
        # orphan in-network packets; free LOST_WAIT ones — write the two
        # packet columns (state / orphan) back once (active rows only in
        # sparse mode; untracked slots are FREE and untouched either way)
        if sparse:
            porph_a = porph_a | rto_a
            ps_a = jnp.where(rto_a & (ps_a == LOST_WAIT), FREE, ps_a)
            pkt = pkt.at[PS, asg].set(ps_a, mode="drop")
            pkt = pkt.at[PORPH, asg].set(porph_a.astype(jnp.int32), mode="drop")
        else:
            p_orphan = p_orphan | rto
            p_state = jnp.where(rto & (p_state == LOST_WAIT), FREE, p_state)
            pkt = pkt.at[PS].set(p_state)
            pkt = pkt.at[PORPH].set(p_orphan.astype(jnp.int32))

        # =============== 3. service / dequeue ===========================
        f_active = (now >= scn.f_start) & (now < scn.f_end)
        failed_q = (
            jnp.zeros((NQ + 1,), jnp.bool_)
            .at[jnp.where(f_active & (scn.f_kind == K_DOWN), scn.f_queue, NQ)]
            .max(True, mode="drop")[:NQ]
        )
        degraded_q = (
            jnp.zeros((NQ + 1,), jnp.bool_)
            .at[jnp.where(f_active & (scn.f_kind == K_DEGRADED), scn.f_queue, NQ)]
            .max(True, mode="drop")[:NQ]
        )
        # gray loss: per-queue fixed-point drop probability (param/GRAY_SCALE)
        # scatter-maxed from active kind-2 rows, compared against a uniform
        # draw on its own fold (3) of the tick key — independent of the RED
        # (1) and LB (2) streams, so schedules with no gray rows stay
        # bit-identical to runs predating the gray fault model.
        gray_p = (
            jnp.zeros((NQ + 1,), jnp.int32)
            .at[jnp.where(f_active & (scn.f_kind == K_GRAY), scn.f_queue, NQ)]
            .max(scn.f_param, mode="drop")[:NQ]
        )
        u_gray = jax.random.uniform(jax.random.fold_in(key, 3), (NQ,))
        gray_hit = (u_gray * GRAY_SCALE).astype(jnp.int32) < gray_p
        service_ok = ~(degraded_q & (now % 2 == 1))
        serve = (q_len > 0) & service_ok
        head_pid = qbuf[jnp.arange(NQ), q_head % QCAP]
        q_head = jnp.where(serve, q_head + 1, q_head)
        q_len = jnp.where(serve, q_len - 1, q_len)
        q_served = q_served + serve.astype(jnp.int32)

        pid = jnp.where(serve, head_pid, NP)  # NP = drop sentinel
        qid = jnp.arange(NQ, dtype=jnp.int32)
        # gray-dropped serves share the blackhole path (silent loss →
        # ST_DROPS_FAIL, LOST_WAIT awaiting RTO) but NOT the q_len_eff
        # routing penalty below: gray loss is invisible to the switches.
        blackhole = serve & (failed_q | gray_hit)
        is_final = serve & ~blackhole & (qid >= topo.t0_down_base)
        mid = serve & ~blackhole & ~is_final

        D = pkt[:, jnp.minimum(pid, NP - 1)]  # (PF, NQ) served-packet rows
        d_orph = serve & (D[PORPH] == 1)

        # blackholed: silent loss (failure — no trim); orphans are freed
        drops_fail_d = jnp.sum((blackhole & ~d_orph).astype(jnp.int32))

        # deliveries (≤ 1 per connection per tick — host downlink serves 1)
        dconn = jnp.where(is_final, D[PCONN], NC)
        dseq = jnp.where(is_final, D[PSEQ], 0)
        # deliveries only happen at the final-hop queues — the STATIC tail
        # [t0_down_base, NQ) of the queue axis (NH host downlinks) — so the
        # delivery-side scatters restrict to that slice: the dropped rows
        # are all sentinel/False no-ops, and scatter cost is rows × K
        fin = slice(topo.t0_down_base, NQ)
        was_done = c_done.at[dconn].get(mode="fill", fill_value=True)
        newly = is_final & ~self._bm_get(c_rcv, dconn, dseq, conn_axis)
        c_rcv = self._bm_max(
            c_rcv, dconn[fin], dseq[fin], is_final[fin], conn_axis
        )
        delivered_d = jnp.sum(newly.astype(jnp.int32))
        deliver_ackable = is_final & ~d_orph & ~was_done
        msg_of = scn.conn_msg.at[dconn].get(mode="fill", fill_value=BIG)
        # ≤1 delivery per conn per tick ⇒ the post-update per-conn counters
        # equal the pre-update gathers plus this queue's own contribution —
        # so `emit`/`first_done` are computable BEFORE the scatter and the
        # whole stage needs ONE stacked segment-sum.
        del_of = (
            c_delivered.at[dconn].get(mode="fill", fill_value=0)
            + newly.astype(jnp.int32)
        )
        now_done = del_of >= msg_of
        rxp = (
            c_rx_pending.at[dconn].get(mode="fill", fill_value=0)
            + deliver_ackable.astype(jnp.int32)
        )
        emit = deliver_ackable & ((rxp >= cfg.ack_coalesce) | now_done)
        first_done = is_final & now_done & ~was_done
        dsum = self._seg_sum_b(
            dconn[fin],
            jnp.stack([
                newly.astype(jnp.int32)[fin],
                deliver_ackable.astype(jnp.int32)[fin],
                emit.astype(jnp.int32)[fin],
                first_done.astype(jnp.int32)[fin],
            ]),
            NC + 1,
        )
        c_delivered = c_delivered + dsum[0, :NC]
        c_rx_pending = jnp.where(
            dsum[2, :NC] > 0, 0, c_rx_pending + dsum[1, :NC]
        )
        # completion bookkeeping
        first_done_c = dsum[3, :NC] > 0
        c_done = c_done | first_done_c
        c_done_tick = jnp.where(first_done_c, now, c_done_tick)

        # served-packet row rewrite (one scatter): blackhole / mid / final
        d_state = jnp.where(
            blackhole,
            jnp.where(d_orph, FREE, LOST_WAIT),
            jnp.where(
                mid,
                FLYING,
                jnp.where(emit, IN_ACK, FREE),  # final hop: emitted ACK reuses slot
            ),
        )
        d_evt = jnp.where(
            mid,
            now + cfg.hop_latency_ticks,
            jnp.where(emit, now + cfg.ack_delay_ticks, D[PEVT]),
        )
        Dn = D.at[PS].set(d_state)
        Dn = Dn.at[PEVT].set(d_evt)
        Dn = Dn.at[PHOP].set(jnp.where(mid, D[PHOP] + 1, D[PHOP]))
        Dn = Dn.at[PCURQ].set(jnp.where(mid, qid, D[PCURQ]))
        Dn = Dn.at[PACK].set(jnp.where(emit, rxp, D[PACK]))
        pkt = pkt.at[:, pid].set(Dn, mode="drop")

        # =============== 4. arrivals / enqueue ==========================
        if sparse:
            arr_a = (
                as_valid
                & (pkt[PS, asx] == FLYING)
                & (pkt[PEVT, asx] == now)
            )
            a_pos = self._compact(arr_a, self.MAX_ARR)
            a_idx = jnp.where(
                a_pos < self.A, as_idx[jnp.minimum(a_pos, self.A - 1)], NP
            )
        else:
            p_state = pkt[PS]
            arr = (p_state == FLYING) & (pkt[PEVT] == now)
            a_idx = self._compact(arr, self.MAX_ARR)
        a_valid = a_idx < NP
        A = pkt[:, jnp.minimum(a_idx, NP - 1)]  # (PF, MAX_ARR)
        a_conn = jnp.where(a_valid, A[PCONN], 0)
        a_ev = jnp.where(a_valid, A[PEV], 0)
        a_inj = jnp.where(a_valid, A[PHOP], 1) == 0
        a_cur = jnp.where(a_valid, A[PCURQ], 0)
        a_src = scn.conn_src[jnp.clip(a_conn, 0, NC - 1)]
        a_dst = scn.conn_dst[jnp.clip(a_conn, 0, NC - 1)]
        # adaptive switches exclude locally-known failed ports (link down is
        # visible at the switch); hashing LBs ignore q_len entirely.
        q_len_eff = q_len + failed_q.astype(jnp.int32) * jnp.int32(4 * QCAP)
        target = topo.next_queue(
            a_inj, a_cur, a_conn, a_ev, a_src, a_dst, q_len_eff,
            adaptive=self.lb.switch_adaptive,
        )
        target = jnp.where(a_valid, target, NQ)
        u_red = jax.random.uniform(jax.random.fold_in(key, 1), (self.MAX_ARR,))

        arrivals_backend = cfg.arrivals_backend
        if arrivals_backend == "auto":
            arrivals_backend = (
                "pallas" if jax.default_backend() == "tpu" else "jnp"
            )
        if arrivals_backend == "pallas":
            # fused serve+rank+accept kernel (repro.kernels.queue_tick);
            # service already happened, so serve mask is all-zero here.
            from repro.kernels import ops as kernel_ops

            new_qlen, k_accept, _, pos = kernel_ops.queue_tick(
                target, u_red, q_len, jnp.zeros((NQ,), jnp.int32),
                QCAP, cfg.kmin, cfg.kmax,
            )
            accept = a_valid & k_accept
            q_len = new_qlen
        else:
            # FIFO rank among same-target arrivals (stable in slot order)
            rank = self._seg_rank_b(target, NQ + 1)
            qlen_t = q_len.at[target].get(mode="fill", fill_value=0)
            accept = a_valid & (rank < QCAP - qlen_t)
            pos = qlen_t + rank
            q_len = q_len.at[jnp.where(accept, target, NQ)].add(1, mode="drop")
        dropd = a_valid & ~accept
        mark_p = (
            jnp.clip(
                (pos.astype(jnp.float32) - cfg.kmin) / float(cfg.kmax - cfg.kmin),
                0.0,
                1.0,
            )
            * cfg.pmax
        )
        mark = accept & (u_red < mark_p)
        ecn_marks_d = jnp.sum(mark.astype(jnp.int32))
        slot = (q_head.at[target].get(mode="fill", fill_value=0) + pos) % QCAP
        qbuf = qbuf.at[jnp.where(accept, target, NQ), slot].set(
            a_idx, mode="drop"
        )
        # congestion drops: trim → NACK; else silent (await RTO); orphans free
        a_orph = a_valid & (A[PORPH] == 1)
        drops_cong_d = jnp.sum((dropd & ~a_orph).astype(jnp.int32))
        if cfg.trimming:
            dstate = jnp.where(a_orph, FREE, IN_NACK)
        else:
            dstate = jnp.where(a_orph, FREE, LOST_WAIT)
        An = A.at[PS].set(jnp.where(accept, QUEUED, dstate))
        An = An.at[PCURQ].set(jnp.where(accept, target, A[PCURQ]))
        An = An.at[PECN].set(A[PECN] | mark.astype(jnp.int32))
        if cfg.trimming:
            An = An.at[PEVT].set(
                jnp.where(dropd & ~a_orph, now + cfg.nack_delay_ticks, A[PEVT])
            )
        pkt = pkt.at[:, a_idx].set(An, mode="drop")

        # =============== 5. injection ===================================
        started = (now >= scn.conn_start) & (
            (scn.conn_dep < 0) | c_done[jnp.clip(scn.conn_dep, 0, NC - 1)]
        )
        has_work = (c_rtx_count > 0) | (c_next_new < scn.conn_msg)
        can = (
            started
            & ~c_done
            & has_work
            & (c_inflight < jnp.floor(c_cwnd).astype(jnp.int32))
        )
        hc = scn.host_conns  # (NH, CPH)
        elig = can[jnp.clip(hc, 0, NC - 1)] & (hc >= 0)
        ordr = (jnp.arange(self.CPH)[None, :] - h_rr[:, None]) % self.CPH
        score = jnp.where(elig, ordr, BIG)
        pick_local = jnp.argmin(score, axis=1).astype(jnp.int32)
        any_pick = jnp.min(score, axis=1) < BIG
        # free-slot allocation (ring pop)
        srank = jnp.cumsum(any_pick.astype(jnp.int32)) - 1
        can_alloc = srank < fl_count
        if sparse:
            # active-set capacity gate.  Since every non-FREE slot is
            # tracked, as_count + fl_count == NP always — so with A == NP
            # this conjunct is exactly `srank < fl_count` again and the
            # sparse path stays bit-identical to dense; when A binds, the
            # overflow surfaces as counted alloc-fails, never lost slots.
            can_alloc = can_alloc & (as_count + srank < self.A)
        sendh = any_pick & can_alloc
        alloc_fail_d = jnp.sum((any_pick & ~can_alloc).astype(jnp.int32))
        n_alloc = jnp.sum(sendh.astype(jnp.int32))
        slot_p = fl[(fl_head + srank) % NP]
        fl_head = (fl_head + n_alloc) % NP
        fl_count = fl_count - n_alloc

        pick_conn = jnp.where(
            sendh, hc[jnp.arange(NH), pick_local], NC
        )  # NC sentinel
        h_rr = jnp.where(sendh, (pick_local + 1) % self.CPH, h_rr)
        # seq selection: retransmissions first
        pick_cc = jnp.clip(pick_conn, 0, NC - 1)
        use_rtx = c_rtx_count[pick_cc] > 0
        rtx_rows = self._bm_rows(c_rtx, pick_cc, conn_axis)  # (NH, MSG)
        rtx_seq = jnp.argmax(rtx_rows, axis=1).astype(jnp.int32)
        new_seq = c_next_new[pick_cc]
        seq = jnp.where(use_rtx, rtx_seq, new_seq)
        c_rtx = self._bm_set_false(
            c_rtx, jnp.where(sendh & use_rtx, pick_conn, NC), rtx_seq, conn_axis
        )
        # each host picks <= 1 conn and a conn lives on one host, so
        # per-conn injection counts are 0/1: one stacked segment-sum covers
        # the send mask, rtx/new splits and the inflight increment
        isum = self._seg_sum_b(
            pick_conn,
            jnp.stack([
                sendh.astype(jnp.int32),
                (sendh & use_rtx).astype(jnp.int32),
            ]),
            NC + 1,
        )
        send_mask = isum[0, :NC] > 0
        c_rtx_count = c_rtx_count - isum[1, :NC]
        c_next_new = c_next_new + (isum[0] - isum[1])[:NC]
        c_inflight = c_inflight + isum[0, :NC]
        injected_d = n_alloc

        # the load balancer stamps the EV (REPS Algorithm 2)
        prev_lb = lb_state
        evs, lb_state = self.lb.choose_ev(
            lb_state, send_mask, jax.random.fold_in(key, 2), now
        )
        if emit_events:
            lb_counts = lb_counts + self.lb.trace(
                "choose", prev_lb, lb_state, send_mask
            )
        pkt_ev = evs[pick_cc]

        wslot = jnp.where(sendh, slot_p, NP)
        W = jnp.stack([
            jnp.full((NH,), FLYING, jnp.int32),  # PS
            pick_conn,  # PCONN
            pkt_ev,  # PEV
            seq,  # PSEQ
            jnp.zeros((NH,), jnp.int32),  # PHOP
            jnp.full((NH,), -1, jnp.int32),  # PCURQ
            jnp.full((NH,), now, jnp.int32),  # PSEND
            jnp.full((NH,), now + cfg.hop_latency_ticks, jnp.int32),  # PEVT
            jnp.zeros((NH,), jnp.int32),  # PECN
            jnp.zeros((NH,), jnp.int32),  # PORPH
            jnp.zeros((NH,), jnp.int32),  # PACK
        ])
        # one (PF, NH) block scatter writes the whole new-packet rows
        pkt = pkt.at[:, wslot].set(W, mode="drop")

        # =============== 6. free-list push ==============================
        # slots popped and re-used this tick are FLYING now, not FREE — no
        # conflict with the push below.
        if sparse:
            fs_a = jnp.where(as_valid, pkt[PS, asx], FREE)  # post-tick states
            freed_a = as_valid & (fs_a == FREE) & (entry_ps_a != FREE)
            f_pos = self._compact(freed_a, self.MAX_FREE)
            f_idx2 = jnp.where(
                f_pos < self.A, as_idx[jnp.minimum(f_pos, self.A - 1)], NP
            )
        else:
            freed = (pkt[PS] == FREE) & (state_at_entry != FREE)
            f_idx2 = self._compact(freed, self.MAX_FREE)
        f_val = f_idx2 < NP
        n_freed = jnp.sum(f_val.astype(jnp.int32))
        if self.MAX_FREE <= NP and not sparse:
            # the push targets a contiguous (mod NP) ring segment, so it is
            # a rotate + static-slice blend + rotate back — a scatter here
            # would serialize over MAX_FREE rows per sweep lane on CPU/TPU
            start = (fl_head + fl_count) % NP
            rot = jnp.roll(fl, -start)
            head = jnp.where(
                jnp.arange(self.MAX_FREE, dtype=jnp.int32) < n_freed,
                f_idx2,
                rot[: self.MAX_FREE],
            )
            fl = jnp.roll(rot.at[: self.MAX_FREE].set(head), start)
        else:
            # positional scatter: O(MAX_FREE) instead of the O(NP) roll —
            # always in sparse mode (that roll is exactly the dense cost
            # the active set removes), or under a tiny pkt_slots pin.
            # Both branches write identical fl contents.
            frank = jnp.cumsum(f_val.astype(jnp.int32)) - 1
            fpos = (fl_head + fl_count + frank) % NP
            fl = fl.at[jnp.where(f_val, fpos, NP)].set(f_idx2, mode="drop")
        fl_count = fl_count + n_freed

        if sparse:
            # active-set maintenance: drop freed slots, add this tick's
            # allocations (wslot), re-sort ascending.  Real entries ≤ A by
            # the injection gate; NP sentinels sort to the tail.
            alive = as_valid & (fs_a != FREE)
            cand = jnp.concatenate([jnp.where(alive, as_idx, NP), wslot])
            as_idx = jnp.sort(cand)[: self.A]
            as_count = jnp.sum(alive.astype(jnp.int32)) + n_alloc

        # =============== 7. fused stats update ==========================
        s_stats = s_stats + jnp.stack([
            drops_cong_d, drops_fail_d, timeouts_d, delivered_d,
            ecn_marks_d, injected_d, unprocessed, alloc_fail_d,
        ])

        if conn_axis is not None:
            # conn-sharded exit: hand back only this device's block of the
            # gathered per-conn vectors (inverse of the entry all_gather —
            # every device computed the identical full-shape values).
            def cslice(x):
                return jax.lax.dynamic_slice_in_dim(x, coff, NCd, axis=0)

            (c_inflight, c_next_new, c_delivered, c_rx_pending, c_done,
             c_done_tick, c_rtx_count, c_cwnd, c_alpha) = (
                cslice(c_inflight), cslice(c_next_new),
                cslice(c_delivered), cslice(c_rx_pending),
                cslice(c_done), cslice(c_done_tick),
                cslice(c_rtx_count), cslice(c_cwnd), cslice(c_alpha),
            )

        new_state = SimState(
            pkt,
            qbuf, q_head, q_len, q_served,
            c_inflight, c_next_new, c_delivered, c_rx_pending, c_done,
            c_done_tick, c_rtx_count, c_rtx, c_rcv, c_cwnd, c_alpha,
            h_rr, lb_state, fl, fl_head, fl_count, s_stats,
            as_idx, as_count,
        )
        trace = TickTrace(
            max_qlen=jnp.max(q_len),
            sum_qlen=jnp.sum(q_len),
            drops=s_stats[ST_DROPS_CONG] + s_stats[ST_DROPS_FAIL],
            timeouts=s_stats[ST_TIMEOUTS],
            delivered=s_stats[ST_DELIVERED],
            injected=s_stats[ST_INJECTED],
            watch_qlen=q_len[scn.watch],
            watch_served=serve[scn.watch].astype(jnp.int32),
        )
        if emit_events:
            # failure-window activation edge, deduped per queue exactly like
            # the service stage's scatter-max (pad rows repeat row 0 and
            # union away, so counts match the declared schedule).
            f_on = (scn.f_start == now) & (now < scn.f_end)
            fail_q = (
                jnp.zeros((NQ + 1,), jnp.bool_)
                .at[jnp.where(f_on, scn.f_queue, NQ)]
                .max(True, mode="drop")[:NQ]
            )
            events = TickEvents(
                lb=lb_counts,
                fail_start=jnp.sum(fail_q.astype(jnp.int32)),
            )
            return new_state, trace, events
        return new_state, trace

    # ------------------------------------------------------------------
    def probe(
        self,
        prev: SimState,
        new: SimState,
        tick: jax.Array,
        scn: ScenarioArrays,
    ) -> Probe:
        """Derive the tick's ``Probe`` from the states around it.

        Pure in (prev, new, tick, scn) like ``step_scenario`` itself, so the
        sweep engine can vmap it over heterogeneous rows.  Deltas telescope:
        summing ``stats_delta`` over any tick range reproduces the final
        ``s_stats`` of that range bit-exactly.
        """
        now = tick.astype(jnp.int32)
        done_now = new.c_done & ~prev.c_done
        served = new.q_served - prev.q_served
        return Probe(
            now=now,
            q_len=new.q_len,
            served=served,
            watch_qlen=new.q_len[scn.watch],
            watch_served=served[scn.watch],
            stats_delta=new.s_stats - prev.s_stats,
            done_now=done_now,
            fct=jnp.where(done_now, now - scn.conn_start, 0).astype(jnp.int32),
        )

    def step_probe(
        self,
        state: SimState,
        tick: jax.Array,
        base_key: jax.Array,
        scn: ScenarioArrays,
        conn_axis: str | None = None,
    ) -> tuple[SimState, Probe]:
        """One tick that emits a ``Probe`` instead of a host-bound trace —
        the summary-collection analogue of ``step_scenario`` (the unused
        ``TickTrace`` is dead code XLA eliminates).  Under a conn mesh the
        probe's (NC,) fields (done_now / fct) are per-device conn shards,
        consistent with the sharded carry."""
        new, _ = self.step_scenario(state, tick, base_key, scn, conn_axis=conn_axis)
        return new, self.probe(state, new, tick, scn)

    def step_events(
        self,
        state: SimState,
        tick: jax.Array,
        base_key: jax.Array,
        scn: ScenarioArrays,
        conn_axis: str | None = None,
    ) -> tuple[SimState, Probe, "TickEvents"]:
        """``step_probe`` plus the flight recorder's ``TickEvents`` — the
        tick body the sweep engine scans when a ``TraceSpec`` is active."""
        new, _, events = self.step_scenario(
            state, tick, base_key, scn, emit_events=True, conn_axis=conn_axis
        )
        return new, self.probe(state, new, tick, scn), events

    # ------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=(0, 1))
    def _run(self, n_ticks: int, state: SimState):
        ticks = jnp.arange(n_ticks, dtype=jnp.int32)
        return jax.lax.scan(self.tick_fn, state, ticks)

    def run(self, n_ticks: int, state: SimState | None = None):
        """Run the simulation for n_ticks; returns (final_state, trace)."""
        if state is None:
            state = self.init_state()
        return self._run(n_ticks, state)
