from repro.netsim import failures, metrics, telemetry, tracer, workloads
from repro.netsim.chaos import (
    ChaosCampaign, ChaosFault, ChaosInvariants, ChaosScenario, Violation,
    known_bad_scenario,
)
from repro.netsim.config import TICK_NS, SimConfig, ns_to_ticks, us_to_ticks
from repro.netsim.engine import (
    FailureSchedule, Probe, ScenarioArrays, SimState, Simulator, Workload,
)
from repro.netsim.fleet import FleetRunner, FleetTelemetry
from repro.netsim.metrics import RunSummary, summarize, summarize_sketch
from repro.netsim.mixed import MixedLB
from repro.netsim.soak import SoakConfig, SoakRunner
from repro.netsim.sweep import (
    BucketPlan, CellShape, PackerConfig, PackPlan, SweepCase, SweepEngine,
    SweepResult, est_row_tick_cost, measured_costs_from_bench, pack,
)
from repro.netsim.telemetry import (
    CounterTotals, Histogram, RecoveryTracker, RunningScalars,
    TelemetryProgram, TelemetrySpec, WindowedSeries, sketch_bin_index,
    sketch_percentile,
)
from repro.netsim.topology import Topology, ecmp_hash, mix32
from repro.netsim.tracer import TracerProgram, TraceSpec

__all__ = [
    "failures", "metrics", "telemetry", "tracer", "workloads",
    "ChaosCampaign", "ChaosFault", "ChaosInvariants", "ChaosScenario",
    "Violation", "known_bad_scenario",
    "TICK_NS", "SimConfig", "ns_to_ticks", "us_to_ticks",
    "FailureSchedule", "Probe", "ScenarioArrays", "SimState", "Simulator",
    "Workload",
    "FleetRunner", "FleetTelemetry", "RunSummary", "summarize",
    "summarize_sketch", "MixedLB",
    "SoakConfig", "SoakRunner",
    "SweepCase", "SweepEngine", "SweepResult",
    "BucketPlan", "CellShape", "PackerConfig", "PackPlan",
    "est_row_tick_cost", "measured_costs_from_bench", "pack",
    "CounterTotals", "Histogram", "RecoveryTracker", "RunningScalars",
    "TelemetryProgram", "TelemetrySpec", "WindowedSeries",
    "sketch_bin_index", "sketch_percentile",
    "Topology", "ecmp_hash", "mix32",
    "TracerProgram", "TraceSpec",
]
