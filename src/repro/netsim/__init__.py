from repro.netsim import failures, metrics, workloads
from repro.netsim.config import TICK_NS, SimConfig, ns_to_ticks, us_to_ticks
from repro.netsim.engine import (
    FailureSchedule, ScenarioArrays, SimState, Simulator, Workload,
)
from repro.netsim.fleet import FleetRunner
from repro.netsim.metrics import RunSummary, summarize
from repro.netsim.mixed import MixedLB
from repro.netsim.sweep import (
    BucketPlan, CellShape, PackerConfig, PackPlan, SweepCase, SweepEngine,
    SweepResult, est_row_tick_cost, pack,
)
from repro.netsim.topology import Topology, ecmp_hash, mix32

__all__ = [
    "failures", "metrics", "workloads",
    "TICK_NS", "SimConfig", "ns_to_ticks", "us_to_ticks",
    "FailureSchedule", "ScenarioArrays", "SimState", "Simulator", "Workload",
    "FleetRunner", "RunSummary", "summarize", "MixedLB",
    "SweepCase", "SweepEngine", "SweepResult",
    "BucketPlan", "CellShape", "PackerConfig", "PackPlan",
    "est_row_tick_cost", "pack",
    "Topology", "ecmp_hash", "mix32",
]
