"""Chaos engine: randomized gray-failure campaigns with machine-checked
invariants and automatic scenario shrinking.

The paper's headline claim is failure *mitigation* — REPS re-routes around
a failure within a handful of RTTs — but curated figures only exercise two
clean fault kinds on hand-written schedules.  Real fabrics fail uglier:
flapping links, gray loss, fail-slow switches, correlated switch-level
outages.  This module turns the mitigation claim into a continuously
fuzzed property, in three layers:

1. **Fault archetypes** (``failures.py`` builders + engine kind codes):
   ``link_down`` / ``link_degraded`` / ``link_flapping`` (explicit kind-0
   window stacks) / ``gray_loss`` (kind 2, threefry-drawn per-packet drop)
   / ``switch_down`` / ``switch_degraded`` / ``spine_down`` — applicable
   statically or injected mid-run through ``SoakRunner.inject``.
2. **Invariant checker** (``ChaosInvariants``): pure per-chunk and
   post-hoc checks evaluated from soak snapshots and telemetry sketches —
   packet-slot conservation, delivered-bitmap consistency, monotone
   counters, bounded-window delivery progress (no-livelock), completion,
   recovery-latency bound via ``RecoveryTracker``, and kill/resume
   bit-parity under active chaos.  No extra host traffic: the checks read
   the carries the soak runtime already snapshots.
3. **Campaign runner** (``ChaosCampaign``): seeded random scenarios over
   the archetype space, driven through ``SoakRunner`` grids with mid-run
   injection.  On any violation the scenario is deterministically
   *shrunk* — drop faults one at a time, halve conns, halve the horizon,
   re-check — to a minimal repro, emitted as a replayable JSON artifact
   with a one-line repro command (``benchmarks/chaos_campaign.py``).

The known-bad fixture needs no artificial broken LB: ``ecmp`` under a
permanent spine outage is the paper's own counter-example — static
per-conn paths never re-route, so the affected connections livelock and
the no-livelock / completion / recovery invariants all fire.  The same
scenario under ``reps`` passes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.netsim import failures, workloads
from repro.netsim.engine import (
    FREE, K_DOWN, PS, ST_DELIVERED, FailureSchedule,
)
from repro.netsim.soak import SoakConfig, SoakRunner
from repro.netsim.sweep import SweepCase, SweepEngine
from repro.netsim.topology import Topology

ARCHETYPES = (
    "link_down", "link_degraded", "link_flapping", "gray_loss", "switch",
)


# ---------------------------------------------------------------------------
# Scenario description — plain data, JSON round-trippable.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChaosFault:
    """One fault in a scenario, addressed by fabric coordinates (ToR /
    spine index) rather than raw queue ids so a shrunken scenario stays
    meaningful when re-materialized.  ``inject_at >= 0`` makes it a live
    mid-run injection through ``SoakRunner.inject`` at that tick instead
    of a statically-declared row."""

    archetype: str  # one of ARCHETYPES | "switch_down" | ... (see _build)
    tor: int = 0
    spine: int = 0
    start: int = 0
    end: int = 0
    period: int = 0  # link_flapping
    down_ticks: int = 0  # link_flapping
    rate: float = 0.0  # gray_loss
    inject_at: int = -1

    def build(self, cfg) -> FailureSchedule:
        topo = Topology.build(cfg)
        q = int(topo.t0_up_queues(self.tor)[self.spine])
        if self.archetype == "link_down":
            return failures.link_down([q], self.start, self.end)
        if self.archetype == "link_degraded":
            return failures.link_degraded([q], self.start, self.end)
        if self.archetype == "link_flapping":
            return failures.link_flapping(
                [q], self.start, self.end, self.period, self.down_ticks
            )
        if self.archetype == "gray_loss":
            return failures.gray_loss([q], self.start, self.end, self.rate)
        if self.archetype == "switch_down":
            return failures.switch_down(cfg, self.tor, self.start, self.end)
        if self.archetype == "switch_degraded":
            return failures.switch_degraded(
                cfg, self.tor, self.start, self.end
            )
        if self.archetype == "spine_down":
            return failures.spine_down(cfg, self.spine, self.start, self.end)
        raise ValueError(f"unknown fault archetype {self.archetype!r}")


@dataclasses.dataclass(frozen=True)
class ChaosScenario:
    """One runnable chaos scenario: a seeded workload + LB + fault set.
    Everything is plain data so violations serialize to a replayable JSON
    artifact; ``n_conns = 0`` means the full permutation."""

    name: str
    seed: int
    lb: str
    msg_pkts: int
    ticks: int
    chunk: int
    faults: tuple[ChaosFault, ...] = ()
    n_conns: int = 0
    resume_check: bool = False

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["faults"] = [dataclasses.asdict(f) for f in self.faults]
        return d

    @staticmethod
    def from_dict(d: dict) -> "ChaosScenario":
        d = dict(d)
        d["faults"] = tuple(ChaosFault(**f) for f in d.get("faults", ()))
        return ChaosScenario(**d)

    def static_schedule(self, cfg) -> FailureSchedule:
        parts = [f.build(cfg) for f in self.faults if f.inject_at < 0]
        return FailureSchedule.concat(*parts) if parts else FailureSchedule.none()

    def injected(self) -> list[ChaosFault]:
        return sorted(
            (f for f in self.faults if f.inject_at >= 0),
            key=lambda f: f.inject_at,
        )

    def workload(self, cfg):
        wl = workloads.permutation(cfg.n_hosts, self.msg_pkts, seed=self.seed)
        if self.n_conns and self.n_conns < wl.n_conns:
            k = self.n_conns
            wl = dataclasses.replace(
                wl, src=wl.src[:k], dst=wl.dst[:k], msg_pkts=wl.msg_pkts[:k],
                start=wl.start[:k], dep=wl.dep[:k],
            )
        return wl


# ---------------------------------------------------------------------------
# Invariants.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Violation:
    invariant: str
    cell: str
    tick: int
    detail: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ChaosInvariants:
    """Declarative invariant suite evaluated against a running
    ``SoakRunner`` (per-chunk, from the device carries it already holds)
    and its finished result (post-hoc, from telemetry sketches).

    * ``conservation`` — packet-slot conservation: every one of the NP
      packet slots is either on the free list or holds a non-FREE packet
      (injected == delivered + dropped + in-flight, in slot form — exact,
      unlike a stats-side identity which double-counts retransmits).
    * ``delivered_bitmap`` — per-conn delivered counters equal the
      popcount of the received-seq bitmap.
    * ``monotone`` — cumulative stats, per-conn delivery counters and
      completion flags never move backwards between chunk boundaries.
    * ``no_livelock`` — a row that is not quiescent and not past its own
      horizon must make *delivery* progress within
      ``no_progress_window`` ticks.  The window must exceed the longest
      legitimate stall (longest down window + one RTO + chunk rounding);
      ``ChaosCampaign`` sizes it per scenario.
    * ``completion`` — every connection completes by the horizon
      (asserted only for survivable scenarios: all service-stopping
      windows end early enough for retransmissions to land).
    * ``recovery`` — if a failure drop was observed, a post-drop delivery
      (the paper's re-route proxy) happened within
      ``recovery_bound_ticks``.
    * kill/resume bit-parity is campaign-level (it needs a second run):
      ``ChaosCampaign`` checks it on scenarios with ``resume_check``.
    """

    no_progress_window: int = 2048
    recovery_bound_ticks: int = 2048
    require_completion: bool = True
    check_recovery: bool = True

    def monitor(self, runner: SoakRunner) -> "InvariantMonitor":
        return InvariantMonitor(runner, self)


class InvariantMonitor:
    """Stateful evaluation of a ``ChaosInvariants`` suite over one soak
    run: call ``boundary()`` after each ``advance`` (chunk snapshot
    checks), ``final(result)`` after ``runner.result()``."""

    def __init__(self, runner: SoakRunner, inv: ChaosInvariants):
        self.runner = runner
        self.inv = inv
        self._scn_host = [
            jax.device_get(b.scn) for b in runner.engine.buckets
        ]
        self._prev: list[Optional[dict]] = [None] * len(runner.engine.buckets)
        self._last_progress: list[np.ndarray] = [
            np.zeros((b.plan.n_padded_rows,), np.int64)
            for b in runner.engine.buckets
        ]

    # -- helpers --------------------------------------------------------
    def _states(self, bi: int):
        carry = self.runner.carries[bi]
        states = carry[0] if self.runner.config.collect == "summary" else carry
        return jax.device_get(states)

    def _rows(self, bucket):
        for c in bucket.cells:
            for si, row in enumerate(c.rows):
                yield c.case.name, si, row

    @staticmethod
    def _quiet_rows(states, scn, horizons, NP: int) -> np.ndarray:
        """Host-side mirror of the engine's per-row quiescence predicate."""
        no_pkts = np.asarray(states.fl_count) == NP
        conn_dep = np.asarray(scn.conn_dep)
        dep = np.clip(conn_dep, 0, conn_dep.shape[-1] - 1)
        dep_ok = (conn_dep < 0) | np.take_along_axis(
            np.asarray(states.c_done), dep, axis=-1
        )
        startable = (np.asarray(scn.conn_start) < horizons[:, None]) & dep_ok
        has_work = (np.asarray(states.c_rtx_count) > 0) | (
            np.asarray(states.c_next_new) < np.asarray(scn.conn_msg)
        )
        active = startable & ~np.asarray(states.c_done) & has_work
        return no_pkts & ~active.any(axis=-1)

    # -- per-chunk checks -----------------------------------------------
    def boundary(self) -> list[Violation]:
        out: list[Violation] = []
        cursor = self.runner.cursor
        for bi, bucket in enumerate(self.runner.engine.buckets):
            NP = bucket.program.sim.NP
            st = self._states(bi)
            scn = self._scn_host[bi]
            horizons = np.asarray(bucket.horizons, np.int64)
            alloc = (np.asarray(st.pkt)[:, PS, :] != FREE).sum(axis=-1)
            fl_count = np.asarray(st.fl_count, np.int64)
            delivered_map = np.asarray(st.c_rcv).sum(axis=-1)
            c_delivered = np.asarray(st.c_delivered, np.int64)
            s_stats = np.asarray(st.s_stats, np.int64)
            c_done = np.asarray(st.c_done)
            quiet = self._quiet_rows(st, scn, horizons, NP)
            prev = self._prev[bi]
            for name, si, row in self._rows(bucket):
                cell = f"{name}[seed {si}]"
                if fl_count[row] + alloc[row] != NP:
                    out.append(Violation(
                        "conservation", cell, cursor,
                        f"free {int(fl_count[row])} + allocated "
                        f"{int(alloc[row])} != {NP} packet slots",
                    ))
                bad = np.nonzero(c_delivered[row] != delivered_map[row])[0]
                if len(bad):
                    out.append(Violation(
                        "delivered_bitmap", cell, cursor,
                        f"conn {int(bad[0])}: c_delivered "
                        f"{int(c_delivered[row][bad[0]])} != bitmap popcount "
                        f"{int(delivered_map[row][bad[0]])}",
                    ))
                if prev is not None:
                    if (s_stats[row] < prev["s_stats"][row]).any():
                        out.append(Violation(
                            "monotone", cell, cursor,
                            f"cumulative stats decreased: "
                            f"{prev['s_stats'][row].tolist()} -> "
                            f"{s_stats[row].tolist()}",
                        ))
                    if (c_delivered[row] < prev["c_delivered"][row]).any():
                        out.append(Violation(
                            "monotone", cell, cursor,
                            "per-conn delivered counter decreased",
                        ))
                    if (prev["c_done"][row] & ~c_done[row]).any():
                        out.append(Violation(
                            "monotone", cell, cursor,
                            "completed connection un-completed",
                        ))
                # delivery progress (no-livelock)
                d = int(s_stats[row][ST_DELIVERED])
                d0 = (
                    int(prev["s_stats"][row][ST_DELIVERED])
                    if prev is not None else -1
                )
                if d != d0:
                    self._last_progress[bi][row] = cursor
                stalled = cursor - int(self._last_progress[bi][row])
                if (
                    not quiet[row]
                    and cursor < int(horizons[row])
                    and stalled > self.inv.no_progress_window
                ):
                    out.append(Violation(
                        "no_livelock", cell, cursor,
                        f"no delivery progress for {stalled} ticks "
                        f"(window {self.inv.no_progress_window}) with "
                        "unfinished work pending",
                    ))
            self._prev[bi] = {
                "s_stats": s_stats, "c_delivered": c_delivered,
                "c_done": c_done,
            }
        return out

    # -- post-hoc checks ------------------------------------------------
    def final(self, result) -> list[Violation]:
        out: list[Violation] = []
        summaries = result.summaries()
        for name, per_seed in summaries.items():
            for si, s in enumerate(per_seed):
                cell = f"{name}[seed {si}]"
                horizon = None
                if self.inv.require_completion and s.completed < s.n_conns:
                    out.append(Violation(
                        "completion", cell, -1,
                        f"{s.completed}/{s.n_conns} connections completed "
                        "by the horizon",
                    ))
                if not self.inv.check_recovery:
                    continue
                tel = result.telemetry_for(name, si)
                rec = tel.get("recovery")
                if rec is None:
                    continue
                drop = rec["first_drop_tick"]
                rticks = rec["recovery_ticks"]
                if drop >= 0 and rticks < 0:
                    out.append(Violation(
                        "recovery", cell, drop,
                        f"failure drop at tick {drop} but no delivery "
                        "afterwards (no re-route)",
                    ))
                elif drop >= 0 and rticks > self.inv.recovery_bound_ticks:
                    out.append(Violation(
                        "recovery", cell, drop,
                        f"recovery took {rticks} ticks "
                        f"(bound {self.inv.recovery_bound_ticks})",
                    ))
        return out


# ---------------------------------------------------------------------------
# Campaign runner with shrinking.
# ---------------------------------------------------------------------------


def scenario_record(result) -> dict:
    """Canonical record of a finished run: every RunSummary field plus a
    sha256 of every telemetry sketch row — the bit-parity unit used by
    kill/resume checks and artifact replays (same shape as the soak-smoke
    CI gate)."""
    record: dict[str, Any] = {"summaries": {}, "telemetry_sha": {}}
    summaries = result.summaries()
    for name in sorted(summaries):
        record["summaries"][name] = [
            dataclasses.asdict(s) for s in summaries[name]
        ]
    for b in result.buckets:
        if b.telemetry is None:
            continue
        for c in b.cells:
            record["telemetry_sha"][c.case.name] = [
                hashlib.sha256(
                    np.ascontiguousarray(b.telemetry[row]).tobytes()
                ).hexdigest()
                for row in c.rows
            ]
    return record


def record_digest(record: dict) -> str:
    return hashlib.sha256(
        json.dumps(record, sort_keys=True).encode()
    ).hexdigest()


class ChaosCampaign:
    """Seeded random chaos campaign: generate scenarios over the fault
    archetype space, drive each through a checkpointable ``SoakRunner``
    grid with mid-run injection, check ``ChaosInvariants`` at every chunk
    boundary and post-hoc, and shrink any violating scenario to a minimal
    replayable repro.

    ``budget_s`` bounds wall clock (checked between scenarios);
    ``min_scenarios`` runs at least that many regardless, which with the
    default generator guarantees every archetype is covered.  All
    randomness flows from ``np.random.RandomState(seed + index)`` — the
    same seed always produces the same campaign.
    """

    # sizing knobs for generated scenarios (CI scale); messages are sized
    # so delivery is still in flight when fault windows open (REPS drains
    # a 24-pkt permutation in ~70 ticks — faults after that are vacuous)
    TICKS = 1280
    CHUNK = 160
    MSG_PKTS = 64

    def __init__(
        self,
        seed: int,
        budget_s: float = 180.0,
        min_scenarios: int = len(ARCHETYPES),
        max_scenarios: int | None = None,
        cfg=None,
        lb: str = "reps",
        min_failure_slots: int = 32,
        invariants: ChaosInvariants | None = None,
    ):
        if cfg is None:
            from repro.configs.arcane_paper import FATTREE_32_CI

            cfg = FATTREE_32_CI
        self.seed = int(seed)
        self.budget_s = float(budget_s)
        self.min_scenarios = int(min_scenarios)
        self.max_scenarios = max_scenarios
        self.cfg = cfg
        self.lb = lb
        self.min_failure_slots = int(min_failure_slots)
        self.invariants = invariants

    # -- scenario generation --------------------------------------------
    def _slack(self) -> int:
        """Ticks a service-stopping window must leave before the horizon
        so every blackholed packet gets retransmitted and delivered."""
        return self.cfg.rto_ticks + 2 * self.CHUNK + 128

    def generate(self, index: int) -> ChaosScenario:
        """Deterministic scenario #``index``: the primary fault cycles the
        archetype list (coverage), a second non-conflicting fault rides
        along half the time, and some primaries arrive as live mid-run
        injections instead of static schedule rows."""
        rng = np.random.RandomState(self.seed * 100003 + index)
        cfg = self.cfg
        ticks, chunk = self.TICKS, self.CHUNK
        slack = self._slack()
        down_end_max = ticks - slack

        tors = rng.permutation(cfg.n_tors)
        spines = rng.permutation(cfg.uplinks_per_tor)

        def make_fault(archetype, tor, spine):
            # fault windows open early (traffic is still in flight) and
            # service-stopping windows close `slack` before the horizon so
            # every blackholed packet can still be retransmitted/delivered
            if archetype == "link_down":
                start = int(rng.randint(8, 160))
                end = int(rng.randint(start + 64, down_end_max))
                return ChaosFault("link_down", tor, spine, start, end)
            if archetype == "link_degraded":
                start = int(rng.randint(0, 160))
                end = failures.FOREVER if rng.rand() < 0.5 else int(
                    rng.randint(start + 64, ticks)
                )
                return ChaosFault("link_degraded", tor, spine, start, end)
            if archetype == "link_flapping":
                down = int(rng.randint(48, 128))
                period = down + cfg.rto_ticks + int(rng.randint(64, 192))
                start = int(rng.randint(8, 96))
                end = max(start + 1, down_end_max - down)
                return ChaosFault(
                    "link_flapping", tor, spine, start, end,
                    period=period, down_ticks=down,
                )
            if archetype == "gray_loss":
                start = int(rng.randint(0, 160))
                end = int(rng.randint(start + 128, down_end_max))
                rate = float(rng.uniform(0.05, 0.4))
                return ChaosFault(
                    "gray_loss", tor, spine, start, end, rate=round(rate, 4)
                )
            assert archetype == "switch"
            start = int(rng.randint(8, 160))
            if rng.rand() < 0.34:
                end = int(rng.randint(start + 64, down_end_max))
                return ChaosFault("switch_down", tor, spine, start, end)
            if rng.rand() < 0.5:
                end = int(rng.randint(start + 64, down_end_max))
                return ChaosFault("spine_down", tor, spine, start, end)
            return ChaosFault(
                "switch_degraded", tor, spine, start,
                int(rng.randint(start + 64, ticks)),
            )

        primary = make_fault(
            ARCHETYPES[index % len(ARCHETYPES)], int(tors[0]), int(spines[0])
        )
        flist = [primary]
        if rng.rand() < 0.5:
            extra_kind = ARCHETYPES[int(rng.randint(len(ARCHETYPES)))]
            # distinct ToR AND distinct spine: disjoint queues under every
            # combination of link-, spine- and switch-level faults, so the
            # merge path's overlap rejection can never fire
            flist.append(make_fault(extra_kind, int(tors[1]), int(spines[1])))
        if rng.rand() < 0.4:
            # live injection: the merge/inject path must behave exactly
            # like the static declaration (tests assert parity).  The
            # fault is pushed past the first chunk boundary so the
            # injection lands before its window opens.
            shifted = max(primary.start, chunk + 8)
            horizon_end = min(primary.end, ticks)
            if shifted + 64 <= horizon_end:
                flist[0] = dataclasses.replace(
                    primary, start=shifted, inject_at=chunk
                )
        return ChaosScenario(
            name=f"chaos/{self.lb}/s{self.seed}i{index}",
            seed=self.seed * 7919 + index,
            lb=self.lb,
            msg_pkts=self.MSG_PKTS,
            ticks=ticks,
            chunk=chunk,
            faults=tuple(flist),
            resume_check=(index == 0),
        )

    # -- scenario execution ---------------------------------------------
    def _invariants_for(self, scenario: ChaosScenario) -> ChaosInvariants:
        if self.invariants is not None:
            return self.invariants
        # longest legitimate delivery stall: the longest service-stopping
        # window (a lone unfinished conn can sit blackholed through it),
        # plus one RTO for the retransmit, plus chunk rounding
        longest_down = 0
        for f in scenario.faults:
            if f.archetype in ("link_down", "switch_down", "spine_down"):
                end = min(f.end, scenario.ticks)
                longest_down = max(longest_down, end - f.start)
            elif f.archetype == "link_flapping":
                longest_down = max(longest_down, f.down_ticks)
        window = longest_down + self.cfg.rto_ticks + 2 * scenario.chunk + 64
        return ChaosInvariants(
            no_progress_window=window,
            recovery_bound_ticks=self.cfg.rto_ticks + scenario.ticks // 2,
        )

    def _runner(
        self, scenario: ChaosScenario, ckpt_dir: str | None = None
    ) -> SoakRunner:
        case = SweepCase(
            name=scenario.name,
            workload=scenario.workload(self.cfg),
            lb=scenario.lb,
            ticks=scenario.ticks,
            failures=scenario.static_schedule(self.cfg),
            seeds=(scenario.seed,),
        )
        engine = SweepEngine(
            self.cfg, [case], min_failure_slots=self.min_failure_slots
        )
        return SoakRunner(
            engine,
            SoakConfig(chunk=scenario.chunk, ckpt_dir=ckpt_dir,
                       collect="summary"),
        )

    def _drive(
        self, runner: SoakRunner, scenario: ChaosScenario,
        monitor: InvariantMonitor | None, stop_at: int | None = None,
    ) -> list[Violation]:
        """Advance to the horizon (or ``stop_at``) chunk by chunk,
        injecting scheduled faults and checking invariants at every
        boundary."""
        violations: list[Violation] = []
        # a resumed runner replays logged injections from the snapshot, so
        # only faults strictly past its cursor are still ours to apply
        pending = [
            f for f in scenario.injected() if f.inject_at > runner.cursor
        ]
        target = scenario.ticks if stop_at is None else stop_at
        while runner.cursor < target:
            nxt = min(
                runner.cursor + scenario.chunk,
                target,
                *[f.inject_at for f in pending if f.inject_at > runner.cursor],
            )
            runner.advance(nxt - runner.cursor)
            while pending and pending[0].inject_at <= runner.cursor:
                runner.inject(pending.pop(0).build(self.cfg))
            if monitor is not None:
                violations.extend(monitor.boundary())
        return violations

    def run_scenario(
        self, scenario: ChaosScenario
    ) -> tuple[list[Violation], dict]:
        """One scenario end to end.  Returns (violations, record); the
        record's digest is the scenario's bit-parity identity."""
        inv = self._invariants_for(scenario)
        runner = self._runner(scenario)
        monitor = inv.monitor(runner)
        violations = self._drive(runner, scenario, monitor)
        result = runner.result()
        violations.extend(monitor.final(result))
        record = scenario_record(result)
        if scenario.resume_check:
            violations.extend(self._check_resume_parity(scenario, record))
        return violations, record

    def _check_resume_parity(
        self, scenario: ChaosScenario, straight_record: dict
    ) -> list[Violation]:
        """Kill/resume bit-parity under active chaos: checkpoint, abandon
        the runner mid-run, resume from disk in a *fresh* engine, finish,
        and require a byte-identical record."""
        kill_at = (scenario.ticks // 2 // scenario.chunk) * scenario.chunk
        with tempfile.TemporaryDirectory(prefix="chaos_ck_") as ck:
            first = self._runner(scenario, ckpt_dir=ck)
            self._drive(first, scenario, None, stop_at=kill_at)
            del first  # hard-kill analogue: no finalize, no further saves
            resumed = self._runner(scenario, ckpt_dir=ck)
            resumed.resume()
            self._drive(resumed, scenario, None)
            record = scenario_record(resumed.result())
        if record_digest(record) != record_digest(straight_record):
            return [Violation(
                "resume_parity", scenario.name, kill_at,
                "kill/resume record differs from the uninterrupted run "
                f"({record_digest(record)[:12]} != "
                f"{record_digest(straight_record)[:12]})",
            )]
        return []

    # -- shrinking -------------------------------------------------------
    def _reductions(self, s: ChaosScenario) -> list[ChaosScenario]:
        """Candidate simplifications, most aggressive first; each keeps
        the scenario well-formed (faults fitting the shrunk horizon)."""
        out: list[ChaosScenario] = []
        base = dataclasses.replace(s, resume_check=False)
        for i in range(len(s.faults)):
            kept = tuple(f for j, f in enumerate(s.faults) if j != i)
            if kept:
                out.append(dataclasses.replace(base, faults=kept))
        nc = s.n_conns or self.cfg.n_hosts
        if nc > 4:
            out.append(dataclasses.replace(base, n_conns=nc // 2))
        if s.ticks // 2 >= 2 * s.chunk:
            half = (s.ticks // 2 // s.chunk) * s.chunk
            kept = tuple(
                f for f in s.faults
                if f.start < half and (f.inject_at < 0 or f.inject_at < half)
            )
            if kept:
                out.append(
                    dataclasses.replace(base, ticks=half, faults=kept)
                )
        if s.msg_pkts > 4:
            out.append(dataclasses.replace(base, msg_pkts=s.msg_pkts // 2))
        return out

    def shrink(
        self, scenario: ChaosScenario
    ) -> tuple[ChaosScenario, list[Violation], dict]:
        """Greedy deterministic shrink to a local minimum: try each
        reduction in order, keep the first that still violates, repeat to
        fixpoint.  Returns (minimal scenario, its violations, record)."""
        current = dataclasses.replace(scenario, resume_check=False)
        violations, record = self.run_scenario(current)
        assert violations, "shrink() needs a violating scenario"
        progress = True
        while progress:
            progress = False
            for cand in self._reductions(current):
                v, rec = self.run_scenario(cand)
                if v:
                    current, violations, record = cand, v, rec
                    progress = True
                    break
        return current, violations, record

    def make_artifact(
        self, scenario: ChaosScenario, violations: list[Violation],
        record: dict,
    ) -> dict:
        return {
            "schema": 1,
            "campaign_seed": self.seed,
            "lb": self.lb,
            "scenario": scenario.to_dict(),
            "violations": [v.to_dict() for v in violations],
            "record_digest": record_digest(record),
            "repro": (
                "PYTHONPATH=src python -m benchmarks.chaos_campaign "
                "--replay <this file>"
            ),
        }

    def replay(self, artifact: dict) -> tuple[list[Violation], bool]:
        """Re-run an artifact's scenario.  Returns (violations,
        bit_exact) — ``bit_exact`` is digest equality with the recorded
        run, the artifact's reproducibility contract."""
        scenario = ChaosScenario.from_dict(artifact["scenario"])
        violations, record = self.run_scenario(scenario)
        return violations, record_digest(record) == artifact["record_digest"]

    # -- the campaign loop ----------------------------------------------
    def run(
        self, artifact_dir: str | None = None, log=print
    ) -> dict:
        """Run scenarios until the budget (but at least
        ``min_scenarios``).  On the first violation: shrink, write the
        artifact (when ``artifact_dir`` is given), and stop.  Returns a
        report dict (``violations`` empty on a clean campaign)."""
        t0 = time.time()
        report: dict[str, Any] = {
            "seed": self.seed, "lb": self.lb, "scenarios": [],
            "violations": [], "artifact": None,
        }
        index = 0
        while True:
            over_budget = time.time() - t0 > self.budget_s
            if index >= self.min_scenarios and over_budget:
                break
            if self.max_scenarios is not None and index >= self.max_scenarios:
                break
            scenario = self.generate(index)
            log(f"[chaos] scenario {index}: "
                + ", ".join(f.archetype for f in scenario.faults)
                + (" (+resume check)" if scenario.resume_check else ""))
            violations, record = self.run_scenario(scenario)
            report["scenarios"].append({
                "name": scenario.name,
                "faults": [f.archetype for f in scenario.faults],
                "violations": len(violations),
            })
            if violations:
                log(f"[chaos] VIOLATION in {scenario.name}: "
                    f"{violations[0].invariant} — shrinking")
                minimal, mv, mrec = self.shrink(scenario)
                artifact = self.make_artifact(minimal, mv, mrec)
                report["violations"] = [v.to_dict() for v in mv]
                report["artifact"] = artifact
                if artifact_dir:
                    os.makedirs(artifact_dir, exist_ok=True)
                    path = os.path.join(
                        artifact_dir, f"chaos_repro_s{self.seed}i{index}.json"
                    )
                    with open(path, "w") as fh:
                        json.dump(artifact, fh, indent=2, sort_keys=True)
                    report["artifact_path"] = path
                    log(f"[chaos] minimal repro written to {path}")
                break
            index += 1
        report["elapsed_s"] = round(time.time() - t0, 2)
        report["n_scenarios"] = index + (1 if report["violations"] else 0)
        return report


def known_bad_scenario(
    cfg=None, ticks: int = 1280, chunk: int = 160
) -> ChaosScenario:
    """The seeded known-bad fixture: ``ecmp`` under a permanent outage of
    half the spines.  Static per-conn paths never re-route, so the
    connections hashed onto dead spines livelock and the completion
    invariant fires deterministically.  The same faults under ``reps``
    pass (that asymmetry *is* the paper's claim)."""
    return ChaosScenario(
        name="chaos/known_bad/ecmp_half_fabric",
        seed=7,
        lb="ecmp",
        msg_pkts=24,
        ticks=ticks,
        chunk=chunk,
        faults=tuple(
            ChaosFault("spine_down", tor=0, spine=sp, start=8,
                       end=failures.FOREVER)
            for sp in range(4)
        ),
    )
