"""Sweep engine: shape-bucketed multi-scenario fleets in a few compiled calls.

The paper's headline figures sweep workloads × load balancers × seeds ×
failure schedules; serially that costs one trace + compile + scan per cell.
This module batches *heterogeneous* cells instead:

  1. **Quantization** — cells are described by their padded static shapes
     ``(ticks, adaptive, NC, MSG, F, W)``: conn counts and message-bitmap
     widths round up to powers of two, failure schedules drop events that
     are provably dead before the horizon (``failures.truncate_dead``) and
     pad to the bucket max, watch lists pad to the bucket max.  Within a
     bucket every cell compiles to the *same* jaxpr, so the whole bucket is
     one ``lax.scan``.
  2. **Cost-aware packing** (``pack``) — pure, host-side, inspectable:

     * *merge*: shape groups whose padded union costs at most
       ``PackerConfig.waste_budget`` more than the sum of their native
       costs fuse into one bucket (greedy lowest-waste pair first).  The
       cost model (``est_row_tick_cost``) is a gather/scatter footprint
       proxy: packet-table slots + per-conn bitmaps + event one-hots +
       schedule/watch rows, times the tick horizon.  Merging may fuse
       *different tick horizons*: the bucket scans to the max and each row
       freezes bit-exactly at its own horizon (see 4).
     * *split*: groups larger than ``max_rows_per_bucket`` rows split into
       equal-capacity sub-buckets (cells stay atomic).  Sub-buckets of one
       group share padded shapes *and* padded row count, so they reuse one
       compiled program — splitting bounds device memory, not compiles.
     * *device alignment*: bucket rows pad to a multiple of the sweep mesh
       so ``shard_map`` assigns every device the same row count (rows of a
       bucket cost the same, so equal rows ⇒ balanced cost).

     The resulting ``PackPlan`` (→ ``SweepEngine.plan``) is a dataclass
     tree that tests and benchmarks assert on: cell→bucket coverage,
     per-bucket ``merge_waste``, pad rows, device row assignment.
  3. **Neutral padding** — padded conns never start (start tick 2^29),
     padded failure rows are inert (start == end == 0; semantics and the
     never-resurrect invariant live on ``FailureSchedule``), and the
     derived static sizes a padded table would perturb are pinned via
     ``SimConfig.msg_slots`` / ``conns_per_host`` / ``failure_slots`` so
     the *serial reference* (``serial_sim``) builds bit-identical shapes.
     Every sweep row is bit-identical to ``Simulator.run`` on that
     reference (tests/test_sweep.py, tests/test_figure_parity.py).
  4. **Per-row horizons** — when a bucket fuses cells with different tick
     horizons, each row carries its own horizon and the scan body freezes
     the row's carry once ``tick >= horizon`` (a ``where`` on every state
     leaf; skipped entirely for homogeneous buckets).  A frozen row is
     bit-identical to stopping its serial run at that tick.
  5. **LB dispatch** — cells that differ only in load balancer share the
     bucket through ``SwitchLB``: one ``lax.switch`` branch index per row
     selects the variant, so ECMP/OPS/REPS columns cost one compilation.
     In-network adaptive LBs change the routing function (a static
     property) and never merge with endpoint LBs.
  6. **(scenario, seed) vmap + device sharding** — rows are the product of
     cells and seeds; ``Simulator.step_scenario`` vmaps over the row axis
     and, when more than one device is visible, rows shard across a 1-D
     ``shard_map`` mesh (CPU CI materializes devices with
     ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
  7. **Donated chunked execution** — the scan carry is donated per chunk
     and trace chunks stream to the host; ``collect="none"`` drops trace
     emission entirely (the fast path benchmarks use), and quiescence
     early exit skips post-fixed-point chunks without changing any
     reported metric.
  8. **Telemetry sketch channels** — ``collect="summary"`` folds a
     ``TelemetrySpec`` (repro.netsim.telemetry) into the scan: each row
     carries ONE stacked int32 sketch vector (FCT/qlen histograms,
     windowed link utilization, recovery trackers, exact counters) updated
     by pure ``(carry, probe) -> carry`` reducers.  Host traffic drops
     from O(rows × ticks) to O(rows × bins), and — because reducers are
     no-ops on quiescent ticks — summary collection composes with
     ``early_exit=True``, which raw trace streaming cannot.

Example (one compiled call per shape bucket, not per cell):

    cases = [SweepCase(f"fig02/{w}/{lb}", wl, lb, ticks=4000)
             for w, wl in wls.items() for lb in ("ecmp", "ops", "reps")]
    eng = SweepEngine(cfg, cases)
    print(eng.plan.describe())
    result = eng.run()
    for name, summaries in result.summaries().items(): ...
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.load_balancers import SwitchLB, make_lb
from repro.distrib.sharding import (
    CONN_AXIS, SWEEP_AXIS, resolve_kernels_backend, sweep_conn_mesh,
    sweep_mesh,
)
from repro.netsim.config import SimConfig
from repro.netsim.engine import (
    FailureSchedule, ScenarioArrays, Simulator, SimState, Workload,
)
from repro.netsim.failures import truncate_dead
from repro.netsim.metrics import RunSummary, summarize, summarize_sketch
from repro.netsim.telemetry import TelemetrySpec
from repro.netsim.tracer import TraceSpec
from repro.utils import compat

# padded conns start here: far beyond any sweep horizon, still well inside
# int32 so `now >= start` arithmetic cannot wrap.
NEVER_TICK = 2**29


def _pow2(n: int) -> int:
    return int(2 ** np.ceil(np.log2(max(int(n), 1))))


@dataclasses.dataclass(frozen=True)
class SweepCase:
    """One cell of a sweep grid: a scenario structure plus its seeds."""

    name: str
    workload: Workload
    lb: str  # load-balancer registry name
    ticks: int
    lb_kwargs: dict = dataclasses.field(default_factory=dict)
    failures: FailureSchedule | None = None
    watch_queues: Any = None  # None = topology default
    seeds: tuple[int, ...] = (0,)


# ---------------------------------------------------------------------------
# Cost-aware bucket packer.  Pure host-side planning over quantized cell
# shapes — no jax, no Simulator construction — so property tests can hammer
# it with random grids (tests/test_sweep.py).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackerConfig:
    """Knobs for ``pack``.

    * ``max_rows_per_bucket`` — split threshold: a bucket's (cells × seeds)
      row count beyond this splits into sub-buckets sharing one compiled
      program.  A single cell larger than the threshold stays atomic (one
      oversized bucket).
    * ``waste_budget`` — max fractional padded-cost overhead a merged
      bucket may carry over the sum of its members' native costs
      (``BucketPlan.merge_waste``).  0 disables all padding-for-merging
      but still fuses bit-identical shapes.
    * ``merge`` — disable to reproduce pure shape quantization (one bucket
      per distinct quantized shape, the pre-packer behavior).
    """

    max_rows_per_bucket: int = 1024
    waste_budget: float = 0.25
    merge: bool = True


@dataclasses.dataclass(frozen=True)
class CellShape:
    """What the packer sees of a cell: quantized static shapes + row count.

    ``nc``/``msg``/``f``/``w`` are the cell's *own* padded sizes (pow2
    conns, pow2 message bitmap, live failure rows, watch rows); ``rows`` is
    its seed count.  Merging never mutates a CellShape — native costs are
    always measured on these original shapes.

    ``nc_exact`` is the unquantized conn count.  Grouping and cost compare
    the pow2 ``nc`` (so near-sized cells land together), but the bucket is
    finally sized to the *max exact* conn count of its members: conn
    padding is visible to spraying LBs through their per-conn random draw
    shapes (jax threefry pairs counter i with i + n/2, so a (480,) draw and
    a (512,) draw differ everywhere), and shrink-to-fit keeps the largest
    cell of every bucket — and any solo-shape figure column — bit-identical
    to a *raw* unpadded serial run, not just to the padded reference.
    """

    name: str
    ticks: int
    adaptive: bool
    nc: int
    msg: int
    f: int
    w: int
    rows: int
    nc_exact: int = 0  # 0 = same as nc

    @property
    def key(self) -> tuple:
        return (self.ticks, self.adaptive, self.nc, self.msg, self.f, self.w)


def est_row_tick_cost(
    cfg: SimConfig, nc: int, msg: int, f: int, w: int
) -> float:
    """Estimated cost of one row-tick at the given padded shapes.

    The tick body is gather/scatter-bound (engine.py header), so the proxy
    counts array footprint touched per tick rather than FLOPs: the packed
    packet table (NP slots, pow2 of conns × max cwnd + host slack), the
    per-conn message bitmaps (NC × MSG, touched via event scatters at ~1/8
    density), the feedback/delivery segment tables (MAX_EV ≈ 3·NH events ×
    NC+1 segments), and the linear schedule/watch rows.  Only *relative*
    cost matters — the packer compares merged vs native sums of this
    estimate (or of the measured-cost model, see ``measured_costs_from_bench``).
    """
    np_slots = _pow2(nc * cfg.max_cwnd_pkts + 4 * cfg.n_hosts + 64)
    max_ev = 3 * cfg.n_hosts
    return float(np_slots + nc * msg / 8.0 + max_ev * (nc + 1) / 8.0 + f + w)


def measured_costs_from_bench(path_or_rows) -> dict:
    """Harvest the packer's measured-cost feedback from a benchmark file.

    Args:
        path_or_rows: path to a ``BENCH_netsim.json`` (or its already-loaded
            ``rows`` dict).  The PackPlan-keyed ``{fig}/bucket/*`` rows that
            ``benchmarks/common.figure_grid`` emits carry ``bucket_key =
            [ticks, adaptive, nc, msg, f, w]`` next to the *measured*
            ``measured_row_tick_us`` wall-clock of that bucket's scan.

    Returns:
        ``{(adaptive, pow2(nc), msg, f, w): mean measured_row_tick_us}`` —
        the per-row-tick cost is horizon-independent, so ``ticks`` is
        dropped; ``nc`` quantizes to the pow2 grouping grid because bucket
        keys record the shrink-to-fit *exact* conn count while the packer's
        merge decisions compare pow2-quantized shapes.  Multiple samples of
        one shape (several figures / sub-buckets) average.  Missing or
        malformed files yield ``{}`` (the packer then falls back to
        ``est_row_tick_cost`` everywhere).
    """
    rows = path_or_rows
    if not isinstance(rows, dict):
        import json

        try:
            with open(path_or_rows) as fh:
                rows = json.load(fh).get("rows", {})
        except (OSError, ValueError, AttributeError):
            return {}
    acc: dict[tuple, list] = {}
    if not isinstance(rows, dict):
        return {}
    for name, rec in rows.items():
        if "/bucket/" not in str(name) or not isinstance(rec, dict):
            continue
        key = rec.get("bucket_key")
        us = rec.get("measured_row_tick_us")
        try:
            _t, ad, nc, msg, f, w = key
            k = (bool(ad), _pow2(nc), int(msg), int(f), int(w))
            us = float(us)
        except (TypeError, ValueError):  # malformed row: skip, don't abort
            continue
        if us > 0:
            acc.setdefault(k, []).append(us)
    return {k: sum(v) / len(v) for k, v in acc.items()}


def _cost_model(cfg: SimConfig, measured: dict | None):
    """Per-row-tick cost function for ``pack``: measured µs where a shape
    was benchmarked, the footprint estimate *calibrated to µs* elsewhere
    (scale = median measured/estimate ratio over the measured keys, so
    mixing the two inside one merge comparison stays unit-consistent).
    Deterministic: pure arithmetic over the sorted measured dict."""
    if not measured:
        return lambda ad, nc, msg, f, w: est_row_tick_cost(cfg, nc, msg, f, w)
    ratios = sorted(
        us / max(est_row_tick_cost(cfg, *k[1:]), 1e-9)
        for k, us in measured.items()
    )
    scale = ratios[len(ratios) // 2]

    def cost(ad, nc, msg, f, w):
        hit = measured.get((ad, nc, msg, f, w))
        if hit is None:
            hit = measured.get((ad, _pow2(nc), msg, f, w))
        if hit is not None:
            return hit
        return scale * est_row_tick_cost(cfg, nc, msg, f, w)

    return cost


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """One planned bucket: a set of cells sharing padded shapes + horizon.

    ``key = (ticks, adaptive, nc, msg, f, w)`` is the padded union shape;
    ``group`` identifies the split family — buckets with equal ``group``
    share padded shapes *and* ``n_padded_rows`` and therefore one compiled
    program.  ``native_cost`` sums the members' costs at their own
    quantized shapes/horizons, so ``merge_waste`` isolates the padding
    overhead the packer accepted to fuse them.
    """

    key: tuple
    cells: tuple[str, ...]
    group: int
    n_rows: int
    n_padded_rows: int
    n_devices: int
    est_row_cost: float  # one padded row over the full bucket horizon
    native_cost: float

    @property
    def ticks(self) -> int:
        return self.key[0]

    @property
    def est_cost(self) -> float:
        return self.n_rows * self.est_row_cost

    @property
    def merge_waste(self) -> float:
        """Fractional padded-cost overhead from shape/horizon merging
        (row padding excluded — see ``pad_rows``)."""
        return self.est_cost / max(self.native_cost, 1e-9) - 1.0

    @property
    def pad_rows(self) -> int:
        return self.n_padded_rows - self.n_rows

    @property
    def device_rows(self) -> tuple[int, ...]:
        """Rows per mesh device (shard_map splits the padded row axis
        evenly; rows of one bucket cost the same, so equal rows ⇒ balanced
        estimated tick cost)."""
        per = self.n_padded_rows // self.n_devices
        return (per,) * self.n_devices


@dataclasses.dataclass(frozen=True)
class PackPlan:
    """The packer's full output — inspect via ``SweepEngine.plan``.

    A pure host-side dataclass tree (no jax arrays): ``buckets`` is the
    ordered tuple of :class:`BucketPlan` rows the engine will materialize,
    ``n_devices`` the mesh width every bucket's rows were padded for, and
    ``packer`` the :class:`PackerConfig` that produced the plan.

    Invariants (property-tested): cells covered exactly once across
    ``buckets``; per split-group aggregate ``merge_waste`` ≤ the packer's
    budget (``group_merge_waste()``); every ``n_padded_rows`` divisible by
    ``n_devices``.  Plans are deterministic in (cfg, shapes, packer,
    n_devices, measured_costs) — replanning with identical inputs yields
    an identical (``==``) plan, which is what lets benchmark files key
    rows by plan shape.  ``describe()`` renders the human-readable form.
    """

    buckets: tuple[BucketPlan, ...]
    n_devices: int
    packer: PackerConfig

    @property
    def n_cells(self) -> int:
        return sum(len(b.cells) for b in self.buckets)

    @property
    def n_rows(self) -> int:
        return sum(b.n_rows for b in self.buckets)

    @property
    def n_padded_rows(self) -> int:
        return sum(b.n_padded_rows for b in self.buckets)

    @property
    def n_groups(self) -> int:
        return len({b.group for b in self.buckets})

    @property
    def merge_waste(self) -> float:
        native = sum(b.native_cost for b in self.buckets)
        est = sum(b.est_cost for b in self.buckets)
        return est / max(native, 1e-9) - 1.0

    def group_merge_waste(self) -> dict[int, float]:
        """Per split-group aggregate waste — the level the budget is
        enforced at (an individual sub-bucket holding only the group's
        shortest-horizon cells can sit above it)."""
        est: dict[int, float] = {}
        native: dict[int, float] = {}
        for b in self.buckets:
            est[b.group] = est.get(b.group, 0.0) + b.est_cost
            native[b.group] = native.get(b.group, 0.0) + b.native_cost
        return {
            g: est[g] / max(native[g], 1e-9) - 1.0 for g in est
        }

    def describe(self) -> str:
        lines = [
            f"PackPlan: {self.n_cells} cells -> {len(self.buckets)} buckets "
            f"({self.n_groups} compiled programs, {self.n_devices} devices, "
            f"waste {self.merge_waste:+.1%})"
        ]
        for b in self.buckets:
            t, ad, nc, msg, f, w = b.key
            lines.append(
                f"  g{b.group} ticks={t} adaptive={int(ad)} NC={nc} MSG={msg} "
                f"F={f} W={w} rows={b.n_rows}+{b.pad_rows}pad "
                f"waste={b.merge_waste:+.1%} cells={list(b.cells)}"
            )
        return "\n".join(lines)


@dataclasses.dataclass
class _Group:
    shapes: list[CellShape]

    def key(self) -> tuple:
        ks = [s.key for s in self.shapes]
        return (
            max(k[0] for k in ks), ks[0][1], max(k[2] for k in ks),
            max(k[3] for k in ks), max(k[4] for k in ks),
            max(k[5] for k in ks),
        )

    def fit_key(self) -> tuple:
        """The bucket's final key: NC shrunk to the members' max *exact*
        conn count (see CellShape.nc_exact) — quantized NC is a grouping /
        cost artifact, not a shape the scan has to pay (or perturb RNG
        streams) for."""
        k = self.key()
        nc_fit = max(max(s.nc_exact or s.nc, 1) for s in self.shapes)
        return (k[0], k[1], nc_fit, *k[3:])

    def rows(self) -> int:
        return sum(s.rows for s in self.shapes)


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def pack(
    cfg: SimConfig,
    shapes: Sequence[CellShape],
    packer: PackerConfig = PackerConfig(),
    n_devices: int = 1,
    measured_costs: dict | None = None,
) -> PackPlan:
    """Plan buckets for quantized cell shapes (pure; deterministic).

    Args:
        cfg: the sweep's base :class:`SimConfig` (only static sizing fields
            feed the cost model).
        shapes: one :class:`CellShape` per cell — quantized padded shapes
            plus the cell's seed-row count.  Names must be unique.
        packer: merge/split knobs, see :class:`PackerConfig`.
        n_devices: sweep mesh size; bucket rows pad to a multiple of it.
        measured_costs: optional ``{(adaptive, nc, msg, f, w): µs}`` map of
            *measured* per-row-tick wall-clock (the PackPlan-keyed
            ``{fig}/bucket/*`` rows of ``BENCH_netsim.json`` — build it with
            :func:`measured_costs_from_bench`).  Where a candidate shape was
            benchmarked its measured cost replaces the footprint estimate in
            every merge comparison; unbenchmarked shapes fall back to the
            estimate calibrated to µs (median measured/estimate ratio), so
            the two are unit-compatible.  ``None``/``{}`` = pure estimate.

    Returns:
        A :class:`PackPlan` — a pure dataclass tree (no jax arrays) that
        ``SweepEngine`` materializes and that tests/benchmarks assert on.

    Invariants (property-tested in tests/test_sweep.py):
      * every cell lands in exactly one bucket;
      * ``n_rows <= max(max_rows_per_bucket, largest cell) + n_devices - 1``
        for every bucket (cells are atomic; capacities are device-rounded);
      * aggregate ``merge_waste <= waste_budget`` for every split group
        (``PackPlan.group_merge_waste`` — the merge decision's level; a
        single sub-bucket of a heterogeneous group can sit above it) under
        whichever cost model (estimated or measured) planned it;
      * ``n_padded_rows`` is a multiple of ``n_devices`` and every device
        is assigned exactly ``n_padded_rows / n_devices`` rows;
      * planning is deterministic: identical inputs (including the
        ``measured_costs`` dict) reproduce the identical plan.

    Note on bit-parity: the plan decides each bucket's padded conn count
    (shrink-to-fit to its members' max *exact* conn count).  Conn padding
    is RNG-visible to spraying load balancers — jax threefry draws are
    **not prefix-stable** (a ``(480,)`` uniform draw shares no prefix with
    a ``(512,)`` draw), so two plans that bucket a cell differently can
    both be *self*-consistent yet produce different per-cell streams.
    Every plan is bit-identical to its own ``serial_sim`` reference; only
    cells whose exact conn count equals their bucket's fit size are
    additionally bit-identical to a *raw* unpadded run.
    """
    assert n_devices >= 1
    assert shapes, "need at least one cell"
    names = [s.name for s in shapes]
    assert len(set(names)) == len(names), "cell names must be unique"
    cost_fn = _cost_model(cfg, measured_costs)

    def _cell_cost(s: CellShape) -> float:
        return s.rows * s.ticks * cost_fn(s.adaptive, s.nc, s.msg, s.f, s.w)

    # 1. exact-shape grouping (insertion order kept for determinism)
    by_key: dict[tuple, _Group] = {}
    for s in shapes:
        by_key.setdefault(s.key, _Group(shapes=[])).shapes.append(s)
    groups = list(by_key.values())

    def native(g: _Group) -> float:
        return sum(_cell_cost(s) for s in g.shapes)

    def est(key: tuple, rows: int) -> float:
        t, ad, nc, msg, f, w = key
        return rows * t * cost_fn(ad, nc, msg, f, w)

    # 2. greedy lowest-waste pairwise merging under the budget.  Group
    #    key/rows/native are additive under merge, so they are memoized and
    #    updated incrementally — the pair search is O(1) per pair instead
    #    of re-summing per-cell costs.
    keys = [g.key() for g in groups]
    rows = [g.rows() for g in groups]
    natives = [native(g) for g in groups]

    def merged_key(a: tuple, b: tuple) -> tuple:
        return (
            max(a[0], b[0]), a[1], max(a[2], b[2]), max(a[3], b[3]),
            max(a[4], b[4]), max(a[5], b[5]),
        )

    while packer.merge and len(groups) > 1:
        best = None  # (waste, i, j)
        for i in range(len(groups)):
            for j in range(i + 1, len(groups)):
                if keys[i][1] != keys[j][1]:
                    continue  # adaptive routing is a static property
                k = merged_key(keys[i], keys[j])
                waste = est(k, rows[i] + rows[j]) / max(
                    natives[i] + natives[j], 1e-9
                ) - 1.0
                if waste <= packer.waste_budget and (
                    best is None or waste < best[0] - 1e-12
                ):
                    best = (waste, i, j)
        if best is None:
            break
        _, i, j = best
        groups[i] = _Group(shapes=groups[i].shapes + groups[j].shapes)
        keys[i] = merged_key(keys[i], keys[j])
        rows[i] += rows[j]
        natives[i] += natives[j]
        del groups[j], keys[j], rows[j], natives[j]

    # 3. split oversized groups into equal-capacity sub-buckets that share
    #    one compiled program (same shapes AND same padded row count)
    buckets: list[BucketPlan] = []
    for gid, g in enumerate(groups):
        key = g.fit_key()
        total = g.rows()
        max_cell = max(s.rows for s in g.shapes)
        threshold = max(packer.max_rows_per_bucket, max_cell)
        n_sub = -(-total // threshold)
        target = max(-(-total // n_sub), max_cell)
        cap = _pad_to(target, n_devices)
        if n_sub == 1:
            order = list(g.shapes)  # keep submission order
        else:
            order = sorted(g.shapes, key=lambda s: (-s.rows, s.name))
        bins: list[list[CellShape]] = []
        fill: list[int] = []
        for s in order:
            for b_i, used in enumerate(fill):
                if used + s.rows <= cap:
                    bins[b_i].append(s)
                    fill[b_i] += s.rows
                    break
            else:
                bins.append([s])
                fill.append(s.rows)
        shared_pad = (
            _pad_to(max(fill), n_devices) if len(bins) > 1 else None
        )
        row_cost = key[0] * cost_fn(key[1], *key[2:])
        for cells, used in zip(bins, fill):
            buckets.append(
                BucketPlan(
                    key=key,
                    cells=tuple(s.name for s in cells),
                    group=gid,
                    n_rows=used,
                    n_padded_rows=(
                        shared_pad
                        if shared_pad is not None
                        else _pad_to(used, n_devices)
                    ),
                    n_devices=n_devices,
                    est_row_cost=row_cost,
                    native_cost=sum(_cell_cost(s) for s in cells),
                )
            )
    return PackPlan(
        buckets=tuple(buckets), n_devices=n_devices, packer=packer
    )


# ---------------------------------------------------------------------------
# Engine-side materialization of a plan.
# ---------------------------------------------------------------------------


def _canon_lb_kwargs(case: SweepCase, cfg: SimConfig) -> dict:
    """LB kwargs with harness defaults resolved — keying on the raw kwargs
    would give `{}` and `{"evs_size": cfg.evs_size}` distinct SwitchLB
    branches, and every redundant branch costs a full extra LB evaluation
    per tick under the vmapped switch."""
    kw = dict(case.lb_kwargs)
    kw.setdefault("evs_size", cfg.evs_size)
    return kw


def _variant_key(case: SweepCase, cfg: SimConfig) -> tuple:
    return (case.lb, tuple(sorted(_canon_lb_kwargs(case, cfg).items())))


def _pad_workload(wl: Workload, nc: int, n_hosts: int) -> Workload:
    """Pad the conn table to ``nc`` rows with inert connections: they never
    start and depend on nothing.  Pad conns fill the *least-loaded* hosts
    first, so whenever the padding fits into existing per-host slack the
    conns_per_host pin equals the unpadded auto width — and the padded row
    stays bit-identical to a raw (unpinned) serial run, not just to the
    pinned serial reference."""
    extra = nc - wl.n_conns
    if extra == 0:
        return wl
    assert extra > 0
    counts = np.bincount(
        wl.src.astype(np.int64), minlength=n_hosts
    ).astype(np.int64)
    pad_src = np.empty((extra,), np.int32)
    for i in range(extra):
        h = int(np.argmin(counts))  # stable: lowest host id wins ties
        pad_src[i] = h
        counts[h] += 1
    return Workload(
        src=np.concatenate([wl.src.astype(np.int32), pad_src]),
        dst=np.concatenate(
            [wl.dst.astype(np.int32), (pad_src + 1) % n_hosts]
        ).astype(np.int32),
        msg_pkts=np.concatenate(
            [wl.msg_pkts.astype(np.int32), np.ones((extra,), np.int32)]
        ),
        start=np.concatenate(
            [wl.start.astype(np.int32), np.full((extra,), NEVER_TICK, np.int32)]
        ),
        dep=np.concatenate(
            [wl.dep.astype(np.int32), np.full((extra,), -1, np.int32)]
        ),
        name=wl.name,
    )


def _host_conns(wl: Workload, n_hosts: int, cph: int) -> np.ndarray:
    """host -> local conn table, same layout the engine builds (-1 padded)."""
    hc = np.full((n_hosts, cph), -1, np.int32)
    fill = np.zeros((n_hosts,), np.int32)
    for c in range(wl.n_conns):
        h = int(wl.src[c])
        hc[h, fill[h]] = c
        fill[h] += 1
    return hc


def _pad_watch(watch: np.ndarray, w: int) -> np.ndarray:
    watch = np.asarray(watch, np.int32)
    extra = w - len(watch)
    assert extra >= 0
    if extra == 0:
        return watch
    fill = watch[-1] if len(watch) else 0
    return np.concatenate([watch, np.full((extra,), fill, np.int32)])


@dataclasses.dataclass
class _Cell:
    case: SweepCase
    padded_wl: Workload
    padded_fs: FailureSchedule
    padded_watch: np.ndarray
    branch: int
    rows: list[int] = dataclasses.field(default_factory=list)  # per seed


@dataclasses.dataclass
class _Program:
    """One compiled scan family: all sub-buckets of a split group share it
    (identical padded shapes, padded row count, SwitchLB variant set)."""

    group: int
    cfg: SimConfig  # shape-pinned bucket config
    lb: SwitchLB
    sim: Simulator
    sim_ticks: int  # the group's bucket horizon (max member horizon)
    masked: bool  # rows carry heterogeneous horizons
    variant_order: list  # one (lb, kwargs) key per SwitchLB branch
    padded_wls: dict  # cell name -> group-padded Workload
    chunk_fns: dict = dataclasses.field(default_factory=dict)
    quiescent_fn: Any = None
    tel_progs: dict = dataclasses.field(default_factory=dict)  # spec -> prog
    trc_progs: dict = dataclasses.field(default_factory=dict)  # TraceSpec -> prog


@dataclasses.dataclass
class _Bucket:
    plan: BucketPlan
    program: _Program
    cells: list[_Cell]
    n_rows: int
    # stacked per-row inputs
    keys: jax.Array  # (R, key)
    scn: ScenarioArrays  # leaves (R, ...)
    branch_idx: np.ndarray  # (R,)
    horizons: np.ndarray  # (R,) per-row tick horizon
    # filled by run()
    final_state: Any = None  # host-side SimState, leaves (R, ...)
    traces: Any = None  # host-side TickTrace, leaves (ticks, R, ...) or None
    telemetry: Any = None  # host-side (R, size) int32 sketch carries or None
    tel_prog: Any = None  # TelemetryProgram that owns `telemetry`'s layout
    trace_rows: Any = None  # host-side (R, size) int32 flight-ring carries
    trc_prog: Any = None  # TracerProgram that owns `trace_rows`'s layout
    exec_wall_s: float = 0.0
    compile_wall_s: float = 0.0
    ticks_run: int = 0  # == ticks unless early exit fired sooner

    # compat accessors (benchmarks read these off result buckets)
    @property
    def key(self) -> tuple:
        return self.plan.key

    @property
    def ticks(self) -> int:
        return self.plan.ticks

    @property
    def cfg(self) -> SimConfig:
        return self.program.cfg

    @property
    def lb(self) -> SwitchLB:
        return self.program.lb

    @property
    def sim(self) -> Simulator:
        return self.program.sim


class SweepResult:
    """Per-cell access to a finished sweep (all arrays already on host)."""

    def __init__(self, engine: "SweepEngine"):
        self._engine = engine
        self.buckets = engine.buckets
        self.plan = engine.plan
        self.exec_wall_s = sum(b.exec_wall_s for b in self.buckets)
        self.compile_wall_s = sum(b.compile_wall_s for b in self.buckets)

    def _find(self, name: str) -> tuple[_Bucket, _Cell]:
        for b in self.buckets:
            for c in b.cells:
                if c.case.name == name:
                    return b, c
        raise KeyError(name)

    def state_for(self, name: str, seed_idx: int = 0) -> SimState:
        b, c = self._find(name)
        row = c.rows[seed_idx]
        return jax.tree_util.tree_map(lambda x: x[row], b.final_state)

    def trace_for(self, name: str, seed_idx: int = 0):
        b, c = self._find(name)
        assert b.traces is not None, "run with collect='full' to keep traces"
        row = c.rows[seed_idx]
        # rows of a horizon-merged bucket freeze at their own horizon; the
        # trace past it is that frozen state re-observed, so expose only
        # the cell's own window.
        return jax.tree_util.tree_map(
            lambda x: x[: c.case.ticks, row], b.traces
        )

    def flight_for(self, name: str, seed_idx: int = 0, since: int = 0) -> dict:
        """Decoded flight-recorder events for one cell row (run with a
        ``trace=TraceSpec(...)``): ``{seq, tick, code, value, cursor, lost,
        first_drop_tick, first_redeliver_tick}`` in push order — see
        ``tracer.TracerProgram.decode_row``."""
        b, c = self._find(name)
        if b.trace_rows is None:
            raise ValueError(
                "no flight-recorder events were collected for this sweep; "
                "run with trace=TraceSpec(...)"
            )
        return b.trc_prog.decode_row(b.trace_rows[c.rows[seed_idx]], since)

    def telemetry_for(self, name: str, seed_idx: int = 0) -> dict:
        """Finalized sketch channels for one cell row.

        Args:
            name: the cell's ``SweepCase.name``.
            seed_idx: index into the cell's ``seeds`` tuple (not the seed
                value itself).

        Returns:
            ``{channel key: finalized dict}`` as produced by each channel's
            ``finalize`` — e.g. ``["fct"]["counts"]``,
            ``["recovery"]["recovery_us"]`` for the default spec.
            Finalization uses the cell's *own* horizon (rows of a
            horizon-merged bucket froze bit-exactly there), so the result
            is identical whether or not the cell shared its bucket.

        Raises:
            ValueError: if the sweep did not run with
                ``collect="summary"`` (no sketches were carried).
            KeyError: unknown cell name.
        """
        b, c = self._find(name)
        if b.telemetry is None:
            raise ValueError(
                "no telemetry sketches were collected for this sweep; "
                "run with collect='summary'"
            )
        return b.tel_prog.finalize_row(
            b.telemetry[c.rows[seed_idx]], c.case.ticks
        )

    def summaries(self, source: str = "auto") -> dict[str, list[RunSummary]]:
        """Per-cell summaries (one per seed).

        ``source="state"`` builds them from each bucket's host-side final
        state; ``"sketch"`` from the telemetry sketches (summary mode) —
        bit-identical counters/completions/runtime/mean, p99 to bin
        resolution; ``"auto"`` prefers sketches when they were collected
        with the channels a RunSummary needs (custom specs without them
        fall back to the state path, which is always available).
        """
        from repro.netsim.telemetry import SUMMARY_CHANNEL_KEYS

        assert source in ("auto", "state", "sketch"), source
        out: dict[str, list[RunSummary]] = {}
        for b in self.buckets:
            sketch = (
                b.telemetry is not None
                and SUMMARY_CHANNEL_KEYS <= b.tel_prog.channel_keys
                if source == "auto"
                else source == "sketch"
            )
            for c in b.cells:
                variant = b.lb.variants[c.branch]
                if sketch:
                    if b.telemetry is None:
                        raise ValueError(
                            "no telemetry sketches were collected; run "
                            "with collect='summary' for sketch summaries"
                        )
                    out[c.case.name] = [
                        summarize_sketch(
                            b.tel_prog.finalize_row(
                                b.telemetry[row], c.case.ticks
                            ),
                            name=c.case.name,
                            lb_name=variant.name,
                            n_conns=c.case.workload.n_conns,
                        )
                        for row in c.rows
                    ]
                else:
                    out[c.case.name] = [
                        summarize(
                            b.sim,
                            jax.tree_util.tree_map(
                                lambda x, r=row: x[r], b.final_state
                            ),
                            name=c.case.name,
                            lb_name=variant.name,
                            n_conns=c.case.workload.n_conns,
                            conn_start=c.padded_wl.start,
                        )
                        for row in c.rows
                    ]
        return out


class SweepEngine:
    """Packs a list of SweepCases into cost-aware buckets and runs each as
    one compiled, row-sharded, donated-carry scan.

    ``kernels_backend`` pins the engine's segment-rank/segment-sum hot-spot
    backend (``SimConfig.kernels_backend``) for every bucket program:
    ``None`` keeps the config's own setting, ``"auto"`` resolves against
    the sweep mesh's platform (compiled Pallas kernels on TPU, the jnp
    formulations elsewhere), ``"pallas"`` forces the kernels — compiled on
    TPU, ``interpret=True`` elsewhere (slow; the bit-parity reference CI
    runs).  ``measured_costs`` feeds the packer's measured-cost model, see
    ``pack``/``measured_costs_from_bench``.
    """

    def __init__(
        self,
        cfg: SimConfig,
        cases: Sequence[SweepCase],
        devices: int | str | None = "auto",
        min_conn_bucket: int = 8,
        packer: PackerConfig | None = None,
        kernels_backend: str | None = None,
        measured_costs: dict | None = None,
        min_failure_slots: int = 0,
        conn_devices: int = 1,
    ):
        # ``min_failure_slots`` floors every cell's quantized failure-row
        # count (pow2-rounded like the natural size): headroom for the soak
        # runtime's live injection (SoakRunner.inject re-materializes the
        # padded schedule into the reserved inert rows without a shape
        # change), and the knob that makes an injected run and its
        # statically-scheduled equivalent plan identical buckets.
        #
        # ``conn_devices`` > 1 (scale mode) shards the *connection* state
        # axis over the minor axis of a 2-D (rows, conns) mesh — requires
        # the cfg to opt in via ``conn_sharding=True``; ``devices`` then
        # bounds the total device count and rows take the rest.  Bit-parity
        # contract: a conn-sharded row is bit-identical to its unsharded
        # ``serial_sim`` reference (tests/test_scale_mode.py).
        self.min_failure_slots = int(min_failure_slots)
        self.cfg = cfg
        self.cases = list(cases)
        assert self.cases, "need at least one case"
        self.conn_devices = max(1, int(conn_devices))
        if self.conn_devices > 1:
            if not cfg.conn_sharding:
                raise ValueError(
                    "conn_devices > 1 requires SimConfig.conn_sharding=True "
                    "(the scale mode is opt-in; see ARCHITECTURE.md §10)"
                )
            self.mesh = sweep_conn_mesh(
                self.conn_devices,
                None if devices in ("auto", None) else int(devices),
            )
        elif devices == "auto":
            self.mesh = sweep_mesh()
        elif devices in (None, 1):
            self.mesh = None
        else:
            self.mesh = sweep_mesh(int(devices))
        self.n_devices = (
            self.mesh.shape[SWEEP_AXIS] if self.mesh is not None else 1
        )
        # resolve the backend (incl. the config's own "auto") against the
        # row mesh's platform, ONE shared rule for every layer
        resolved = resolve_kernels_backend(
            kernels_backend or cfg.kernels_backend, self.mesh
        )
        if resolved != cfg.kernels_backend:
            self.cfg = cfg = cfg.replace(kernels_backend=resolved)
        self.kernels_backend = resolved
        self.min_conn_bucket = min_conn_bucket
        self.packer = packer or PackerConfig()
        self._default_watch_arr = self._default_watch()
        self.plan = pack(
            cfg,
            [self._quantize(c) for c in self.cases],
            self.packer,
            self.n_devices,
            measured_costs=measured_costs,
        )
        self.programs: dict[int, _Program] = {}
        self.buckets = self._build_buckets()

    # ------------------------------------------------------------------
    def _default_watch(self) -> np.ndarray:
        from repro.netsim.topology import Topology

        topo = Topology.build(self.cfg)
        return np.asarray(
            topo.t0_up_queues(0)[: self.cfg.n_watch_queues], np.int32
        )

    def _watch_for(self, case: SweepCase) -> np.ndarray:
        if case.watch_queues is None:
            return self._default_watch_arr
        return np.asarray(case.watch_queues, np.int32)

    def _live_failures(self, case: SweepCase) -> FailureSchedule:
        return truncate_dead(
            case.failures or FailureSchedule.none(), case.ticks
        )

    def _quantize(self, case: SweepCase) -> CellShape:
        cfg = self.cfg
        variant = make_lb(case.lb, **_canon_lb_kwargs(case, cfg))
        wl = case.workload
        msg_max = int(wl.msg_pkts.max()) if wl.n_conns else 1
        # conn-sharded buckets need conn counts divisible by the conn mesh
        # axis, so the shrink-to-fit exact size rounds up to it (inert pad
        # conns, same neutral-padding contract as bucket-size padding)
        nc_exact = _pad_to(max(wl.n_conns, 1), self.conn_devices)
        return CellShape(
            name=case.name,
            ticks=case.ticks,
            adaptive=variant.switch_adaptive,
            nc=_pow2(max(wl.n_conns, self.min_conn_bucket)),
            msg=int(min(cfg.max_msg_pkts, max(_pow2(max(msg_max, 2)), 2))),
            f=_pow2(
                max(len(self._live_failures(case)), 1, self.min_failure_slots)
            ),
            w=_pow2(max(len(self._watch_for(case)), 1)),
            rows=len(case.seeds),
            nc_exact=nc_exact,
        )

    # ------------------------------------------------------------------
    def _build_buckets(self) -> list[_Bucket]:
        cfg = self.cfg
        by_name = {c.name: c for c in self.cases}
        # group-level shape/variant context (shared by all sub-buckets)
        group_cases: dict[int, list[SweepCase]] = {}
        for bp in self.plan.buckets:
            group_cases.setdefault(bp.group, []).extend(
                by_name[n] for n in bp.cells
            )
        for gid, members in group_cases.items():
            self.programs[gid] = self._build_program(
                gid,
                next(bp for bp in self.plan.buckets if bp.group == gid),
                members,
            )
        return [self._build_bucket(bp, by_name) for bp in self.plan.buckets]

    def _build_program(
        self, gid: int, bp: BucketPlan, members: list[SweepCase]
    ) -> _Program:
        ticks_b, _adaptive, nc_b, msg_b, f_b, _w_b = bp.key
        cfg = self.cfg

        # one SwitchLB branch per distinct (lb name, kwargs) spec
        variant_order: list[tuple] = []
        variants = []
        for case in members:
            vk = _variant_key(case, cfg)
            if vk not in variant_order:
                variant_order.append(vk)
                variants.append(
                    make_lb(case.lb, **_canon_lb_kwargs(case, cfg))
                )

        # pin the derived static sizes the padded tables would otherwise
        # perturb, so serial references share bit-identical shapes
        cph_b = 1
        padded_wls = {}
        for case in members:
            pwl = _pad_workload(case.workload, nc_b, cfg.n_hosts)
            padded_wls[case.name] = pwl
            counts = np.bincount(pwl.src, minlength=cfg.n_hosts)
            cph_b = max(cph_b, int(counts.max()))
        cfg_b = cfg.replace(
            msg_slots=msg_b, conns_per_host=cph_b, failure_slots=f_b
        )

        lb = SwitchLB(variants)
        first = members[0]
        sim = Simulator(
            cfg_b,
            padded_wls[first.name],
            lb,
            failures=self._live_failures(first).pad_to(f_b),
            watch_queues=_pad_watch(self._watch_for(first), bp.key[5]),
            seed=int(first.seeds[0]),
        )
        return _Program(
            group=gid,
            cfg=cfg_b,
            lb=lb,
            sim=sim,
            sim_ticks=ticks_b,
            masked=any(case.ticks < ticks_b for case in members),
            variant_order=variant_order,
            padded_wls=padded_wls,
        )

    def _build_bucket(
        self, bp: BucketPlan, by_name: dict[str, SweepCase]
    ) -> _Bucket:
        f_b, w_b = bp.key[4], bp.key[5]
        prog = self.programs[bp.group]
        cfg = self.cfg

        cells: list[_Cell] = []
        for name in bp.cells:
            case = by_name[name]
            cells.append(
                _Cell(
                    case=case,
                    padded_wl=prog.padded_wls[name],
                    padded_fs=self._live_failures(case).pad_to(f_b),
                    padded_watch=_pad_watch(self._watch_for(case), w_b),
                    branch=prog.variant_order.index(
                        _variant_key(case, cfg)
                    ),
                )
            )

        # rows = cells × seeds, padded to the planned row count by
        # repeating row 0 (discarded on output)
        row_cells: list[tuple[_Cell, int]] = []
        for c in cells:
            for s in c.case.seeds:
                c.rows.append(len(row_cells))
                row_cells.append((c, int(s)))
        n_rows = len(row_cells)
        assert n_rows == bp.n_rows, (n_rows, bp)
        row_cells += [row_cells[0]] * (bp.n_padded_rows - n_rows)

        cph_b = prog.cfg.conns_per_host

        def stack(field_of):
            return jnp.asarray(np.stack([field_of(c, s) for c, s in row_cells]))

        scn = ScenarioArrays(
            conn_src=stack(lambda c, s: c.padded_wl.src.astype(np.int32)),
            conn_dst=stack(lambda c, s: c.padded_wl.dst.astype(np.int32)),
            conn_msg=stack(lambda c, s: c.padded_wl.msg_pkts.astype(np.int32)),
            conn_start=stack(lambda c, s: c.padded_wl.start.astype(np.int32)),
            conn_dep=stack(lambda c, s: c.padded_wl.dep.astype(np.int32)),
            host_conns=stack(
                lambda c, s: _host_conns(c.padded_wl, cfg.n_hosts, cph_b)
            ),
            watch=stack(lambda c, s: c.padded_watch),
            f_queue=stack(lambda c, s: c.padded_fs.queue.astype(np.int32)),
            f_start=stack(lambda c, s: c.padded_fs.start.astype(np.int32)),
            f_end=stack(lambda c, s: c.padded_fs.end.astype(np.int32)),
            f_kind=stack(lambda c, s: c.padded_fs.kind.astype(np.int32)),
            f_param=stack(lambda c, s: c.padded_fs.param.astype(np.int32)),
        )
        keys = jnp.stack([jax.random.PRNGKey(s) for _, s in row_cells])
        branch_idx = np.asarray([c.branch for c, _ in row_cells], np.int32)
        horizons = np.asarray(
            [c.case.ticks for c, _ in row_cells], np.int32
        )
        return _Bucket(
            plan=bp, program=prog, cells=cells, n_rows=n_rows,
            keys=keys, scn=scn, branch_idx=branch_idx, horizons=horizons,
        )

    # ------------------------------------------------------------------
    def serial_sim(self, name: str, seed: int | None = None) -> Simulator:
        """The serial reference for a cell: a plain Simulator built on the
        same padded scenario and shape-pinned config the sweep row ran —
        ``serial_sim(name).run(case.ticks)`` is bit-identical to the sweep
        row (which froze at exactly that horizon in a merged bucket)."""
        for b in self.buckets:
            for c in b.cells:
                if c.case.name == name:
                    lb = make_lb(
                        c.case.lb, **_canon_lb_kwargs(c.case, self.cfg)
                    )
                    return Simulator(
                        b.cfg,
                        c.padded_wl,
                        lb,
                        failures=c.padded_fs,
                        watch_queues=c.padded_watch,
                        seed=int(c.case.seeds[0] if seed is None else seed),
                    )
        raise KeyError(name)

    # ------------------------------------------------------------------
    def _init_states(self, bucket: _Bucket) -> SimState:
        states = jax.vmap(bucket.sim.init_state)(bucket.keys)
        _, variant_states = states.lb_state
        return states._replace(
            lb_state=(jnp.asarray(bucket.branch_idx), variant_states)
        )

    def _tel_prog(self, prog: _Program, spec: TelemetrySpec):
        """The program's TelemetryProgram for a spec (built once; shapes and
        window strides derive from the group's bucket horizon)."""
        if spec not in prog.tel_progs:
            prog.tel_progs[spec] = spec.build(prog.sim, prog.sim_ticks)
        return prog.tel_progs[spec]

    def _trc_prog(self, prog: _Program, trace: TraceSpec):
        """The program's TracerProgram for a TraceSpec (built once)."""
        if trace not in prog.trc_progs:
            prog.trc_progs[trace] = trace.build(prog.sim, prog.sim_ticks)
        return prog.trc_progs[trace]

    def _make_chunk_fn(
        self, prog: _Program, n: int, collect: str,
        spec: TelemetrySpec | None = None, trace: TraceSpec | None = None,
    ):
        """Compiled runner for one chunk of ``n`` ticks: carries donated
        states (plus the stacked telemetry sketches in summary mode, plus
        the flight-recorder rings when tracing), returns (carry,
        traces-or-None).  Shared by every bucket of the program's split
        group (same shapes, same padded rows)."""
        sim = prog.sim
        full = collect == "full"
        summary = collect == "summary"
        masked = prog.masked
        ca = CONN_AXIS if self.conn_devices > 1 else None
        if ca is not None and summary:
            raise ValueError(
                "collect='summary' is incompatible with conn_devices > 1: "
                "telemetry reducers consume full-width per-conn probe "
                "vectors (done_now, fct), which are shard-local under conn "
                "sharding.  Use collect='none' or 'full'."
            )
        if summary and trace is not None:
            vstep = jax.vmap(
                lambda st, t, k, sc: sim.step_events(st, t, k, sc, conn_axis=ca),
                in_axes=(0, None, 0, 0),
            )
            tel_update = jax.vmap(self._tel_prog(prog, spec).update)
            trc_update = jax.vmap(self._trc_prog(prog, trace).update)
        elif summary:
            vstep = jax.vmap(
                lambda st, t, k, sc: sim.step_probe(st, t, k, sc, conn_axis=ca),
                in_axes=(0, None, 0, 0),
            )
            tel_update = jax.vmap(self._tel_prog(prog, spec).update)
        else:
            vstep = jax.vmap(
                lambda st, t, k, sc: sim.step_scenario(st, t, k, sc, conn_axis=ca),
                in_axes=(0, None, 0, 0),
            )

        def freeze(live, new, old):
            # freeze rows past their own horizon: bit-identical to stopping
            # that row's serial run at `horizon` ticks
            return jax.tree_util.tree_map(
                lambda nw, od: jnp.where(
                    live.reshape((-1,) + (1,) * (nw.ndim - 1)), nw, od
                ),
                new,
                old,
            )

        def body(carry, keys, scn, horizon, t0):
            def tick(carry, t):
                if summary and trace is not None:
                    states, tel, trc = carry
                    new_states, probe, events = vstep(states, t, keys, scn)
                    new_carry = (
                        new_states,
                        tel_update(tel, probe),
                        trc_update(trc, probe, events),
                    )
                    tr = None
                elif summary:
                    states, tel = carry
                    new_states, probe = vstep(states, t, keys, scn)
                    new_carry = (new_states, tel_update(tel, probe))
                    tr = None
                else:
                    new_carry, tr = vstep(carry, t, keys, scn)
                if masked:
                    new_carry = freeze(t < horizon, new_carry, carry)
                return new_carry, (tr if full else None)

            ticks = t0 + jnp.arange(n, dtype=jnp.int32)
            return jax.lax.scan(tick, carry, ticks)

        if self.mesh is not None:
            if ca is None:
                carry_spec = P(SWEEP_AXIS)
                scn_spec = P(SWEEP_AXIS)
            else:
                # per-leaf specs: per-conn leaves shard (rows, conns), the
                # rest (packet table, queues, LB state, stats) replicate
                # over the conn axis — matching step_scenario's conn_axis
                # contract (gather at entry / slice at exit keeps them
                # device-invariant along CONN_AXIS).
                carry_spec = self._conn_state_specs()
                scn_spec = self._conn_scn_specs()
            body = compat.shard_map(
                body,
                self.mesh,
                in_specs=(
                    carry_spec, P(SWEEP_AXIS), scn_spec,
                    P(SWEEP_AXIS), P(),
                ),
                out_specs=(
                    carry_spec, P(None, SWEEP_AXIS) if full else P()
                ),
                check_vma=False,
            )
        return jax.jit(body, donate_argnums=(0,))

    def _conn_state_specs(self) -> SimState:
        row, conn = P(SWEEP_AXIS), P(SWEEP_AXIS, CONN_AXIS)
        return SimState(
            pkt=row, qbuf=row, q_head=row, q_len=row, q_served=row,
            c_inflight=conn, c_next_new=conn, c_delivered=conn,
            c_rx_pending=conn, c_done=conn, c_done_tick=conn,
            c_rtx_count=conn, c_rtx=conn, c_rcv=conn, c_cwnd=conn,
            c_alpha=conn, h_rr=row, lb_state=row, fl=row, fl_head=row,
            fl_count=row, s_stats=row, as_idx=row, as_count=row,
        )

    def _conn_scn_specs(self) -> ScenarioArrays:
        row, conn = P(SWEEP_AXIS), P(SWEEP_AXIS, CONN_AXIS)
        return ScenarioArrays(
            conn_src=conn, conn_dst=conn, conn_msg=conn, conn_start=conn,
            conn_dep=conn, host_conns=row, watch=row, f_queue=row,
            f_start=row, f_end=row, f_kind=row, f_param=row,
        )

    def _make_quiescent_fn(self, prog: _Program):
        """Per-row fixed-point detector.  A row is quiescent when no packet
        slot is allocated (covers FLYING/QUEUED/ACK/NACK/LOST_WAIT — every
        live state holds a slot until consumed) and no connection that can
        still start within the row's horizon has work left — or when the
        row is already past its horizon (frozen).  Once all rows hold,
        every later tick is a no-op for packet/conn/stat state, so the
        remaining scan chunks can be skipped without changing any reported
        result (only time-keeping LB internals, e.g. PLB epoch clocks,
        would have kept advancing).
        """
        NP = prog.sim.NP

        def f(states: SimState, scn: ScenarioArrays, horizon, offset):
            no_pkts = states.fl_count == NP  # (R,)
            dep = jnp.clip(scn.conn_dep, 0, scn.conn_src.shape[-1] - 1)
            dep_ok = (scn.conn_dep < 0) | jnp.take_along_axis(
                states.c_done, dep, axis=-1
            )
            startable = (scn.conn_start < horizon[:, None]) & dep_ok
            has_work = (states.c_rtx_count > 0) | (
                states.c_next_new < scn.conn_msg
            )
            active = startable & ~states.c_done & has_work
            quiet = no_pkts & ~jnp.any(active, axis=-1)
            return jnp.all(quiet | (offset >= horizon))

        return jax.jit(f)

    def run(
        self,
        collect: str = "none",
        chunk: int | None = None,
        early_exit: bool = False,
        telemetry: TelemetrySpec | None = None,
        trace: TraceSpec | None = None,
    ) -> SweepResult:
        """Execute every bucket.  The three-mode ``collect`` contract:

        * ``"none"``    — no per-tick output (fastest; state summaries
          only).  Early-exit compatible.
        * ``"summary"`` — on-device sketch channels (``telemetry`` spec,
          default ``TelemetrySpec.default()``) reduced inside the scan;
          O(bins) host bytes per row.  Early-exit compatible: reducers are
          no-ops on quiescent ticks, so skipping them is bit-invisible.
        * ``"full"``    — raw TickTrace streams fetched chunk-by-chunk;
          O(ticks) host bytes per row.  Incompatible with ``early_exit``.

        ``chunk`` bounds how many ticks of trace live on device at once
        (defaults to the whole run in one chunk).  ``early_exit`` stops a
        bucket at the first chunk boundary where every row has reached its
        fixed point (see _make_quiescent_fn); all reported metrics are
        bit-identical to running the full horizon.

        ``trace`` (a ``tracer.TraceSpec``, summary mode only) additionally
        carries the flight-recorder ring per row; decoded events come back
        via ``SweepResult.flight_for``.  Tracing is observation-only: every
        state / telemetry array is bit-identical with it on or off.
        """
        if collect not in ("none", "summary", "full"):
            raise ValueError(
                f"collect must be 'none', 'summary' or 'full', got "
                f"{collect!r}"
            )
        if trace is not None and collect != "summary":
            raise ValueError(
                "trace=TraceSpec(...) requires collect='summary' (the "
                "flight recorder rides the telemetry carry contract)"
            )
        if early_exit and collect == "full":
            raise ValueError(
                "early_exit=True cannot be combined with collect='full': "
                "raw trace streams would be truncated at the quiescence "
                "point.  Use collect='summary' (on-device sketch channels "
                "keep figure fidelity and are early-exit safe) or "
                "collect='none', or run the full horizon with "
                "early_exit=False."
            )
        if telemetry is not None and collect != "summary":
            raise ValueError(
                "a telemetry spec only applies to collect='summary'"
            )
        spec = (
            (telemetry or TelemetrySpec.default())
            if collect == "summary"
            else None
        )
        for bucket in self.buckets:
            self._run_bucket(bucket, collect, chunk, early_exit, spec, trace)
        return SweepResult(self)

    # ------------------------------------------------------------------
    # Chunked carry in/out — the resumable building blocks the batch path
    # below AND the soak runtime (repro.netsim.soak) drive: a bucket's
    # execution is ``carry = bucket_carry(...)`` followed by any sequence
    # of ``run_chunk`` calls whose (t0, n) windows tile ``[0, ticks)``, and
    # the result is bit-identical regardless of how the windows are cut —
    # which is exactly what lets a checkpointed carry resume at any chunk
    # boundary and replay the remaining windows.
    # ------------------------------------------------------------------
    def bucket_carry(
        self, bucket: _Bucket, collect: str = "none",
        spec: TelemetrySpec | None = None, trace: TraceSpec | None = None,
    ):
        """The bucket's t=0 scan carry: vmapped per-row init states, plus
        the stacked telemetry sketch carry in summary mode, plus the
        flight-recorder ring carry when tracing."""
        carry = self._init_states(bucket)
        if collect == "summary":
            tel_prog = self._tel_prog(bucket.program, spec)
            tel0 = jnp.tile(
                tel_prog.init()[None], (bucket.plan.n_padded_rows, 1)
            )
            if trace is not None:
                trc_prog = self._trc_prog(bucket.program, trace)
                trc0 = jnp.tile(
                    trc_prog.init()[None], (bucket.plan.n_padded_rows, 1)
                )
                carry = (carry, tel0, trc0)
            else:
                carry = (carry, tel0)
        return carry

    def chunk_runner(
        self, bucket: _Bucket, n: int, collect: str = "none",
        spec: TelemetrySpec | None = None, example_carry=None,
        trace: TraceSpec | None = None,
    ):
        """The compiled ``(carry, keys, scn, horizons, t0) -> (carry,
        traces)`` executable for an ``n``-tick chunk.  AOT-compiled once
        per (n, collect, spec, trace) and shared by every sub-bucket of the
        program's split group (same shapes, same padded rows); the carry is
        donated on call.  ``example_carry`` supplies lowering shapes (a
        fresh ``bucket_carry`` is built when omitted)."""
        prog = bucket.program
        ck = (n, collect, spec, trace)
        if ck not in prog.chunk_fns:
            if example_carry is None:
                example_carry = self.bucket_carry(bucket, collect, spec, trace)
            fn = self._make_chunk_fn(prog, n, collect, spec, trace)
            prog.chunk_fns[ck] = fn.lower(
                example_carry, bucket.keys, bucket.scn,
                jnp.asarray(bucket.horizons), jnp.zeros((), jnp.int32),
            ).compile()
        return prog.chunk_fns[ck]

    def run_chunk(
        self, bucket: _Bucket, carry, t0: int, n: int,
        collect: str = "none", spec: TelemetrySpec | None = None,
        trace: TraceSpec | None = None,
    ):
        """Advance one bucket's carry over ticks ``[t0, t0 + n)``.  Returns
        ``(carry, traces)``; ``carry`` is donated (the passed-in buffers
        are invalid afterwards — checkpoint via ``jax.device_get`` *before*
        calling).  Rows whose own horizon lies inside the window freeze
        bit-exactly there (heterogeneous buckets), so driving a bucket to
        its horizon in any chunking yields identical results."""
        fn = self.chunk_runner(
            bucket, n, collect, spec, example_carry=carry, trace=trace
        )
        return fn(
            carry, bucket.keys, bucket.scn, jnp.asarray(bucket.horizons),
            jnp.asarray(t0, jnp.int32),
        )

    def finalize_bucket(
        self, bucket: _Bucket, carry, collect: str, ticks_run: int,
        trace_chunks=None, spec: TelemetrySpec | None = None,
        trace: TraceSpec | None = None,
    ):
        """Publish a finished carry onto the bucket (one host transfer):
        ``final_state`` / ``telemetry`` / ``traces`` / ``trace_rows`` as
        ``SweepResult`` expects, pad rows dropped."""
        summary = collect == "summary"
        host = jax.device_get(carry)  # one transfer for the bucket
        keep = bucket.n_rows
        host_state = host[0] if summary else host
        bucket.final_state = jax.tree_util.tree_map(
            lambda x: x[:keep], host_state
        )
        bucket.ticks_run = ticks_run
        if summary:
            bucket.telemetry = host[1][:keep]
            bucket.tel_prog = self._tel_prog(bucket.program, spec)
            if trace is not None:
                bucket.trace_rows = host[2][:keep]
                bucket.trc_prog = self._trc_prog(bucket.program, trace)
        if collect == "full" and trace_chunks:
            bucket.traces = jax.tree_util.tree_map(
                lambda *xs: np.concatenate(xs, axis=0)[:, :keep],
                *trace_chunks,
            )

    def _run_bucket(
        self, bucket: _Bucket, collect: str, chunk: int | None,
        early_exit: bool = False, spec: TelemetrySpec | None = None,
        trace: TraceSpec | None = None,
    ):
        prog = bucket.program
        ticks = bucket.ticks
        summary = collect == "summary"
        if chunk is None:
            # early exit needs chunk boundaries to act on
            chunk = max(64, ticks // 8) if early_exit else ticks
        chunk = max(1, min(chunk, ticks))
        sizes = [chunk] * (ticks // chunk)
        if ticks % chunk:
            sizes.append(ticks % chunk)

        t_c0 = time.time()
        carry = self.bucket_carry(bucket, collect, spec, trace)
        # AOT-compile each distinct chunk length (usually 1-2) untimed;
        # sub-buckets of a split group share the compiled executables.
        for n in sorted(set(sizes)):
            self.chunk_runner(
                bucket, n, collect, spec, example_carry=carry, trace=trace
            )
        if early_exit and prog.quiescent_fn is None:
            prog.quiescent_fn = self._make_quiescent_fn(prog)
        quiescent = prog.quiescent_fn if early_exit else None
        jax.block_until_ready(jax.tree_util.tree_leaves(carry)[0])
        bucket.compile_wall_s = time.time() - t_c0

        trace_chunks = []
        offset = 0
        t_e0 = time.time()
        for n in sizes:
            carry, traces = self.run_chunk(
                bucket, carry, offset, n, collect, spec, trace
            )
            offset += n
            if collect == "full":
                # stream this chunk to host so the device never holds more
                # than `chunk` ticks of trace
                trace_chunks.append(jax.device_get(traces))
            states = carry[0] if summary else carry
            if quiescent is not None and offset < ticks and bool(
                quiescent(
                    states, bucket.scn, jnp.asarray(bucket.horizons),
                    jnp.asarray(offset, jnp.int32),
                )
            ):
                break
        states = carry[0] if summary else carry
        jax.block_until_ready(states.c_done)
        bucket.exec_wall_s = time.time() - t_e0
        self.finalize_bucket(
            bucket, carry, collect, offset, trace_chunks, spec, trace
        )
