"""Sweep engine: shape-bucketed multi-scenario fleets in a few compiled calls.

The paper's headline figures sweep workloads × load balancers × seeds ×
failure schedules; serially that costs one trace + compile + scan per cell.
This module batches *heterogeneous* cells instead:

  1. **Bucketing** — cells are grouped by their padded static shapes
     ``(ticks, adaptive, NC, MSG, F, W)``: conn counts and message-bitmap
     widths round up to powers of two, failure schedules and watch lists pad
     to the bucket max.  Within a bucket every cell compiles to the *same*
     jaxpr, so the whole bucket is one ``lax.scan``.
  2. **Neutral padding** — padded conns never start (start tick 2^29) and
     padded failure rows are never active (start == end == 0); the derived
     static sizes a padded table would perturb (per-conn bitmap width,
     host round-robin width) are pinned via ``SimConfig.msg_slots`` /
     ``conns_per_host`` so the *serial reference* (``serial_sim``) builds
     bit-identical shapes.  Every sweep row is bit-identical to
     ``Simulator.run`` on that reference (tests/test_sweep.py).
  3. **LB dispatch** — cells that differ only in load balancer share the
     bucket through ``SwitchLB``: one ``lax.switch`` branch index per row
     selects the variant, so ECMP/OPS/REPS columns cost one compilation.
     In-network adaptive LBs change the routing function (a static
     property) and bucket separately.
  4. **(scenario, seed) vmap + device sharding** — rows are the product of
     cells and seeds; ``Simulator.step_scenario`` vmaps over the row axis
     and, when more than one device is visible, rows shard across a 1-D
     ``shard_map`` mesh (CPU CI materializes devices with
     ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
  5. **Donated chunked execution** — the scan carry is donated per chunk
     and trace chunks stream to the host, so long sweeps never hold the
     full (ticks, rows, ...) trace on device.  ``collect="none"`` drops
     trace emission entirely (the scan carries no ys), which is the fast
     path benchmarks use.

Example (one compiled call per shape bucket, not per cell):

    cases = [SweepCase(f"fig02/{w}/{lb}", wl, lb, ticks=4000)
             for w, wl in wls.items() for lb in ("ecmp", "ops", "reps")]
    result = SweepEngine(cfg, cases).run()
    for name, summaries in result.summaries().items(): ...
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.load_balancers import SwitchLB, make_lb
from repro.distrib.sharding import SWEEP_AXIS, pad_rows, sweep_mesh
from repro.netsim.config import SimConfig
from repro.netsim.engine import (
    FailureSchedule, ScenarioArrays, Simulator, SimState, Workload,
)
from repro.netsim.metrics import RunSummary, summarize
from repro.utils import compat

# padded conns start here: far beyond any sweep horizon, still well inside
# int32 so `now >= start` arithmetic cannot wrap.
NEVER_TICK = 2**29


def _pow2(n: int) -> int:
    return int(2 ** np.ceil(np.log2(max(int(n), 1))))


@dataclasses.dataclass(frozen=True)
class SweepCase:
    """One cell of a sweep grid: a scenario structure plus its seeds."""

    name: str
    workload: Workload
    lb: str  # load-balancer registry name
    ticks: int
    lb_kwargs: dict = dataclasses.field(default_factory=dict)
    failures: FailureSchedule | None = None
    watch_queues: Any = None  # None = topology default
    seeds: tuple[int, ...] = (0,)


def _canon_lb_kwargs(case: SweepCase, cfg: SimConfig) -> dict:
    """LB kwargs with harness defaults resolved — keying on the raw kwargs
    would give `{}` and `{"evs_size": cfg.evs_size}` distinct SwitchLB
    branches, and every redundant branch costs a full extra LB evaluation
    per tick under the vmapped switch."""
    kw = dict(case.lb_kwargs)
    kw.setdefault("evs_size", cfg.evs_size)
    return kw


def _variant_key(case: SweepCase, cfg: SimConfig) -> tuple:
    return (case.lb, tuple(sorted(_canon_lb_kwargs(case, cfg).items())))


def _pad_workload(wl: Workload, nc: int, n_hosts: int) -> Workload:
    """Pad the conn table to ``nc`` rows with inert connections: they never
    start, depend on nothing, and are spread round-robin over hosts to keep
    the padded host conn-table width small."""
    extra = nc - wl.n_conns
    if extra == 0:
        return wl
    assert extra > 0
    pad_src = (np.arange(extra, dtype=np.int32) % n_hosts).astype(np.int32)
    return Workload(
        src=np.concatenate([wl.src.astype(np.int32), pad_src]),
        dst=np.concatenate(
            [wl.dst.astype(np.int32), (pad_src + 1) % n_hosts]
        ).astype(np.int32),
        msg_pkts=np.concatenate(
            [wl.msg_pkts.astype(np.int32), np.ones((extra,), np.int32)]
        ),
        start=np.concatenate(
            [wl.start.astype(np.int32), np.full((extra,), NEVER_TICK, np.int32)]
        ),
        dep=np.concatenate(
            [wl.dep.astype(np.int32), np.full((extra,), -1, np.int32)]
        ),
        name=wl.name,
    )


def _pad_failures(fs: FailureSchedule | None, f: int) -> FailureSchedule:
    """Pad to ``f`` rows with never-active events (start == end == 0)."""
    fs = fs or FailureSchedule.none()
    extra = f - len(fs.queue)
    assert extra >= 0
    z = np.zeros((extra,), np.int32)
    return FailureSchedule(
        queue=np.concatenate([fs.queue.astype(np.int32), z]),
        start=np.concatenate([fs.start.astype(np.int32), z]),
        end=np.concatenate([fs.end.astype(np.int32), z]),
        kind=np.concatenate([fs.kind.astype(np.int32), z]),
    )


def _host_conns(wl: Workload, n_hosts: int, cph: int) -> np.ndarray:
    """host -> local conn table, same layout the engine builds (-1 padded)."""
    hc = np.full((n_hosts, cph), -1, np.int32)
    fill = np.zeros((n_hosts,), np.int32)
    for c in range(wl.n_conns):
        h = int(wl.src[c])
        hc[h, fill[h]] = c
        fill[h] += 1
    return hc


def _pad_watch(watch: np.ndarray, w: int) -> np.ndarray:
    watch = np.asarray(watch, np.int32)
    extra = w - len(watch)
    assert extra >= 0
    if extra == 0:
        return watch
    fill = watch[-1] if len(watch) else 0
    return np.concatenate([watch, np.full((extra,), fill, np.int32)])


@dataclasses.dataclass
class _Cell:
    case: SweepCase
    padded_wl: Workload
    padded_fs: FailureSchedule
    padded_watch: np.ndarray
    branch: int
    rows: list[int] = dataclasses.field(default_factory=list)  # per seed


@dataclasses.dataclass
class _Bucket:
    key: tuple
    ticks: int
    cfg: SimConfig  # shape-pinned bucket config
    lb: SwitchLB
    cells: list[_Cell]
    sim: Simulator
    n_rows: int
    # stacked per-row inputs
    keys: jax.Array  # (R, key)
    scn: ScenarioArrays  # leaves (R, ...)
    branch_idx: np.ndarray  # (R,)
    # filled by run()
    final_state: Any = None  # host-side SimState, leaves (R, ...)
    traces: Any = None  # host-side TickTrace, leaves (ticks, R, ...) or None
    exec_wall_s: float = 0.0
    compile_wall_s: float = 0.0
    ticks_run: int = 0  # == ticks unless early exit fired sooner


class SweepResult:
    """Per-cell access to a finished sweep (all arrays already on host)."""

    def __init__(self, engine: "SweepEngine"):
        self._engine = engine
        self.buckets = engine.buckets
        self.exec_wall_s = sum(b.exec_wall_s for b in self.buckets)
        self.compile_wall_s = sum(b.compile_wall_s for b in self.buckets)

    def _find(self, name: str) -> tuple[_Bucket, _Cell]:
        for b in self.buckets:
            for c in b.cells:
                if c.case.name == name:
                    return b, c
        raise KeyError(name)

    def state_for(self, name: str, seed_idx: int = 0) -> SimState:
        b, c = self._find(name)
        row = c.rows[seed_idx]
        return jax.tree_util.tree_map(lambda x: x[row], b.final_state)

    def trace_for(self, name: str, seed_idx: int = 0):
        b, c = self._find(name)
        assert b.traces is not None, "run with collect='full' to keep traces"
        row = c.rows[seed_idx]
        return jax.tree_util.tree_map(lambda x: x[:, row], b.traces)

    def summaries(self) -> dict[str, list[RunSummary]]:
        """Per-cell summaries (one per seed), sliced from the single
        host-side copy of each bucket's stacked final state."""
        out: dict[str, list[RunSummary]] = {}
        for b in self.buckets:
            for c in b.cells:
                variant = b.lb.variants[c.branch]
                out[c.case.name] = [
                    summarize(
                        b.sim,
                        jax.tree_util.tree_map(lambda x, r=row: x[r], b.final_state),
                        name=c.case.name,
                        lb_name=variant.name,
                        n_conns=c.case.workload.n_conns,
                        conn_start=c.padded_wl.start,
                    )
                    for row in c.rows
                ]
        return out


class SweepEngine:
    """Buckets a list of SweepCases and runs each bucket as one compiled,
    row-sharded, donated-carry scan."""

    def __init__(
        self,
        cfg: SimConfig,
        cases: Sequence[SweepCase],
        devices: int | str | None = "auto",
        min_conn_bucket: int = 8,
    ):
        self.cfg = cfg
        self.cases = list(cases)
        assert self.cases, "need at least one case"
        if devices == "auto":
            self.mesh = sweep_mesh()
        elif devices in (None, 1):
            self.mesh = None
        else:
            self.mesh = sweep_mesh(int(devices))
        self.min_conn_bucket = min_conn_bucket
        self.buckets = self._build_buckets()

    # ------------------------------------------------------------------
    def _default_watch(self) -> np.ndarray:
        from repro.netsim.topology import Topology

        topo = Topology.build(self.cfg)
        return np.asarray(
            topo.t0_up_queues(0)[: self.cfg.n_watch_queues], np.int32
        )

    def _build_buckets(self) -> list[_Bucket]:
        cfg = self.cfg
        default_watch = self._default_watch()
        groups: dict[tuple, list[tuple[SweepCase, Any]]] = {}
        for case in self.cases:
            variant = make_lb(case.lb, **_canon_lb_kwargs(case, cfg))
            wl = case.workload
            msg_max = int(wl.msg_pkts.max()) if wl.n_conns else 1
            nc_b = _pow2(max(wl.n_conns, self.min_conn_bucket))
            msg_b = int(
                min(cfg.max_msg_pkts, max(_pow2(max(msg_max, 2)), 2))
            )
            n_fail = len(case.failures.queue) if case.failures else 0
            f_b = _pow2(max(n_fail, 1))
            watch = (
                default_watch
                if case.watch_queues is None
                else np.asarray(case.watch_queues, np.int32)
            )
            w_b = _pow2(max(len(watch), 1))
            key = (case.ticks, variant.switch_adaptive, nc_b, msg_b, f_b, w_b)
            groups.setdefault(key, []).append((case, variant, watch))
        buckets = []
        for key, members in groups.items():
            buckets.append(self._build_bucket(key, members))
        return buckets

    def _build_bucket(self, key: tuple, members) -> _Bucket:
        ticks, _adaptive, nc_b, msg_b, f_b, w_b = key
        cfg = self.cfg

        # one SwitchLB branch per distinct (lb name, kwargs) spec
        variant_order: list[tuple] = []
        variants = []
        for case, variant, _watch in members:
            vk = _variant_key(case, cfg)
            if vk not in variant_order:
                variant_order.append(vk)
                variants.append(variant)

        cells: list[_Cell] = []
        for case, _variant, watch in members:
            cells.append(
                _Cell(
                    case=case,
                    padded_wl=_pad_workload(case.workload, nc_b, cfg.n_hosts),
                    padded_fs=_pad_failures(case.failures, f_b),
                    padded_watch=_pad_watch(watch, w_b),
                    branch=variant_order.index(_variant_key(case, cfg)),
                )
            )

        # pin the derived static sizes the padded tables would otherwise
        # perturb, so serial references share bit-identical shapes
        cph_b = 1
        for c in cells:
            counts = np.bincount(c.padded_wl.src, minlength=cfg.n_hosts)
            cph_b = max(cph_b, int(counts.max()))
        cfg_b = cfg.replace(msg_slots=msg_b, conns_per_host=cph_b)

        lb = SwitchLB(variants)
        sim = Simulator(
            cfg_b,
            cells[0].padded_wl,
            lb,
            failures=cells[0].padded_fs,
            watch_queues=cells[0].padded_watch,
            seed=int(cells[0].case.seeds[0]),
        )

        # rows = cells × seeds, padded to a multiple of the mesh size by
        # repeating row 0 (discarded on output)
        row_cells: list[tuple[_Cell, int]] = []
        for c in cells:
            for s in c.case.seeds:
                c.rows.append(len(row_cells))
                row_cells.append((c, int(s)))
        n_rows = len(row_cells)
        n_padded = pad_rows(n_rows, self.mesh)
        row_cells += [row_cells[0]] * (n_padded - n_rows)

        def stack(field_of):
            return jnp.asarray(np.stack([field_of(c, s) for c, s in row_cells]))

        scn = ScenarioArrays(
            conn_src=stack(lambda c, s: c.padded_wl.src.astype(np.int32)),
            conn_dst=stack(lambda c, s: c.padded_wl.dst.astype(np.int32)),
            conn_msg=stack(lambda c, s: c.padded_wl.msg_pkts.astype(np.int32)),
            conn_start=stack(lambda c, s: c.padded_wl.start.astype(np.int32)),
            conn_dep=stack(lambda c, s: c.padded_wl.dep.astype(np.int32)),
            host_conns=stack(
                lambda c, s: _host_conns(c.padded_wl, cfg.n_hosts, cph_b)
            ),
            watch=stack(lambda c, s: c.padded_watch),
            f_queue=stack(lambda c, s: c.padded_fs.queue.astype(np.int32)),
            f_start=stack(lambda c, s: c.padded_fs.start.astype(np.int32)),
            f_end=stack(lambda c, s: c.padded_fs.end.astype(np.int32)),
            f_kind=stack(lambda c, s: c.padded_fs.kind.astype(np.int32)),
        )
        keys = jnp.stack([jax.random.PRNGKey(s) for _, s in row_cells])
        branch_idx = np.asarray([c.branch for c, _ in row_cells], np.int32)
        return _Bucket(
            key=key, ticks=ticks, cfg=cfg_b, lb=lb, cells=cells, sim=sim,
            n_rows=n_rows, keys=keys, scn=scn, branch_idx=branch_idx,
        )

    # ------------------------------------------------------------------
    def serial_sim(self, name: str, seed: int | None = None) -> Simulator:
        """The serial reference for a cell: a plain Simulator built on the
        same padded scenario and shape-pinned config the sweep row ran —
        ``serial_sim(name).run(ticks)`` is bit-identical to the sweep row."""
        for b in self.buckets:
            for c in b.cells:
                if c.case.name == name:
                    lb = make_lb(
                        c.case.lb, **_canon_lb_kwargs(c.case, self.cfg)
                    )
                    return Simulator(
                        b.cfg,
                        c.padded_wl,
                        lb,
                        failures=c.padded_fs,
                        watch_queues=c.padded_watch,
                        seed=int(c.case.seeds[0] if seed is None else seed),
                    )
        raise KeyError(name)

    # ------------------------------------------------------------------
    def _init_states(self, bucket: _Bucket) -> SimState:
        states = jax.vmap(bucket.sim.init_state)(bucket.keys)
        _, variant_states = states.lb_state
        return states._replace(
            lb_state=(jnp.asarray(bucket.branch_idx), variant_states)
        )

    def _make_chunk_fn(self, bucket: _Bucket, n: int, collect: str):
        """Compiled runner for one chunk of ``n`` ticks: carries donated
        states, returns (states, traces-or-None)."""
        sim = bucket.sim
        vstep = jax.vmap(sim.step_scenario, in_axes=(0, None, 0, 0))
        full = collect == "full"

        def body(states, keys, scn, t0):
            def tick(carry, t):
                new_carry, tr = vstep(carry, t, keys, scn)
                return new_carry, (tr if full else None)

            ticks = t0 + jnp.arange(n, dtype=jnp.int32)
            return jax.lax.scan(tick, states, ticks)

        if self.mesh is not None:
            body = compat.shard_map(
                body,
                self.mesh,
                in_specs=(P(SWEEP_AXIS), P(SWEEP_AXIS), P(SWEEP_AXIS), P()),
                out_specs=(P(SWEEP_AXIS), P(None, SWEEP_AXIS) if full else P()),
                check_vma=False,
            )
        return jax.jit(body, donate_argnums=(0,))

    def _make_quiescent_fn(self, bucket: _Bucket):
        """Per-row fixed-point detector.  A row is quiescent when no packet
        slot is allocated (covers FLYING/QUEUED/ACK/NACK/LOST_WAIT — every
        live state holds a slot until consumed) and no connection that can
        still start within the horizon has work left.  Once both hold,
        every later tick is a no-op for packet/conn/stat state, so the
        remaining scan chunks can be skipped without changing any reported
        result (only time-keeping LB internals, e.g. PLB epoch clocks,
        would have kept advancing).
        """
        NP = bucket.sim.NP

        def f(states: SimState, scn: ScenarioArrays, end_tick):
            no_pkts = states.fl_count == NP  # (R,)
            dep = jnp.clip(scn.conn_dep, 0, scn.conn_src.shape[-1] - 1)
            dep_ok = (scn.conn_dep < 0) | jnp.take_along_axis(
                states.c_done, dep, axis=-1
            )
            startable = (scn.conn_start < end_tick) & dep_ok
            has_work = (states.c_rtx_count > 0) | (
                states.c_next_new < scn.conn_msg
            )
            active = startable & ~states.c_done & has_work
            return jnp.all(no_pkts & ~jnp.any(active, axis=-1))

        return jax.jit(f)

    def run(
        self,
        collect: str = "none",
        chunk: int | None = None,
        early_exit: bool = False,
    ) -> SweepResult:
        """Execute every bucket.  ``collect``:

        * ``"none"``  — no per-tick traces (fastest; summaries only);
        * ``"full"``  — full TickTrace streams, fetched chunk-by-chunk.

        ``chunk`` bounds how many ticks of trace live on device at once
        (defaults to the whole run in one chunk).  ``early_exit`` stops a
        bucket at the first chunk boundary where every row has reached its
        fixed point (see _make_quiescent_fn); all reported metrics are
        bit-identical to running the full horizon.  Requires
        ``collect="none"`` (skipped ticks would otherwise be missing from
        the trace streams, even though their values are constant).
        """
        assert collect in ("none", "full"), collect
        assert not (early_exit and collect == "full"), (
            "early_exit would truncate trace streams; use collect='none'"
        )
        for bucket in self.buckets:
            self._run_bucket(bucket, collect, chunk, early_exit)
        return SweepResult(self)

    def _run_bucket(
        self, bucket: _Bucket, collect: str, chunk: int | None,
        early_exit: bool = False,
    ):
        ticks = bucket.ticks
        if chunk is None:
            # early exit needs chunk boundaries to act on
            chunk = max(64, ticks // 8) if early_exit else ticks
        chunk = max(1, min(chunk, ticks))
        sizes = [chunk] * (ticks // chunk)
        if ticks % chunk:
            sizes.append(ticks % chunk)

        t_c0 = time.time()
        states = self._init_states(bucket)
        # AOT-compile each distinct chunk length (usually 1-2) untimed
        compiled: dict[int, Any] = {}
        t0 = jnp.zeros((), jnp.int32)
        for n in sorted(set(sizes)):
            fn = self._make_chunk_fn(bucket, n, collect)
            compiled[n] = fn.lower(states, bucket.keys, bucket.scn, t0).compile()
        quiescent = self._make_quiescent_fn(bucket) if early_exit else None
        jax.block_until_ready(states.c_done)
        bucket.compile_wall_s = time.time() - t_c0

        trace_chunks = []
        offset = 0
        t_e0 = time.time()
        for n in sizes:
            states, traces = compiled[n](
                states, bucket.keys, bucket.scn, jnp.asarray(offset, jnp.int32)
            )
            offset += n
            if collect == "full":
                # stream this chunk to host so the device never holds more
                # than `chunk` ticks of trace
                trace_chunks.append(jax.device_get(traces))
            if quiescent is not None and offset < ticks and bool(
                quiescent(states, bucket.scn, jnp.asarray(ticks, jnp.int32))
            ):
                break
        jax.block_until_ready(states.c_done)
        bucket.exec_wall_s = time.time() - t_e0
        bucket.ticks_run = offset

        host_state = jax.device_get(states)  # one transfer for the bucket
        keep = bucket.n_rows
        bucket.final_state = jax.tree_util.tree_map(
            lambda x: x[:keep], host_state
        )
        if collect == "full":
            bucket.traces = jax.tree_util.tree_map(
                lambda *xs: np.concatenate(xs, axis=0)[:, :keep], *trace_chunks
            )
