"""On-device telemetry: streaming sketch channels for summary collection.

The paper's headline claims are distributional — FCT tails, queue-occupancy
evolution, sub-100µs failure re-routing — but streaming every raw per-tick
trace row to the host (``collect="full"``) costs O(rows × ticks) transfer
bandwidth and is incompatible with quiescence early exit.  This module
replaces the raw stream with **sketches**: each channel is a pure
``(carry, probe) -> carry`` reducer folded inside the scanned tick loop, so
a sweep row's telemetry leaves the device once, as O(bins) integers.

Channels (all ``int32``, all composable via ``TelemetrySpec``):

* ``CounterTotals``   — running sums of the per-tick stat deltas.  Deltas
  telescope, so the totals equal the final ``SimState.s_stats``
  **bit-exactly** (tested) — summary-mode ``RunSummary`` counters are not
  approximations.
* ``RunningScalars``  — exact count/sum/min/max of FCTs, max completion
  tick, max/sum queue occupancy.  Mean FCT from sum/count is bit-identical
  to the host-side mean over raw completion ticks.
* ``Histogram``       — fixed-width log- (or linear-) spaced histogram of
  FCT or queue-length observations: percentiles to bin resolution
  (``sketch_percentile``).  Zero-valued qlen observations are *not*
  accumulated; ``finalize`` reconstructs the zero count from the horizon,
  which keeps post-quiescent ticks no-ops (see below).
* ``WindowedSeries``  — per-window sums at a configurable stride: watched
  per-link service counts (utilization), watched queue occupancy, and the
  full stat-delta vector (ECN marks / drops / deliveries per window).
* ``RecoveryTracker`` — failure-recovery latency: first failure-drop tick,
  first timeout after it (REPS freezing entry), and first successful
  delivery after it (the re-route proxy for the paper's <100µs claim).

**Early-exit compatibility.**  Every reducer is a no-op on a quiescent
tick: histograms only count events / nonzero occupancies, windowed sums add
zeros, trackers take mins over no events, scalars max/sum zeros.  Skipping
post-fixed-point ticks therefore leaves every channel carry bit-identical
to scanning the full horizon (tests/test_telemetry.py) — summary collection
composes with ``early_exit=True``, which ``collect="full"`` cannot.

**Single stacked carry.**  ``TelemetrySpec.build`` compiles the channel set
into a ``TelemetryProgram`` whose per-row carry is ONE flat ``(size,)``
int32 vector with a static slot layout: one pytree leaf per row batch, one
host transfer per bucket, and the sweep engine's per-row horizon freeze is
a single ``where``.

Example::

    spec = TelemetrySpec.default(n_windows=32)
    states, tel = FleetRunner(cfg, wl, lb, seeds=range(8)).run_summary(
        4000, spec)
    tel.result(0)["fct_hist"]           # counts + edges, seed 0
    tel.summaries()[0].p99_fct_ticks    # sketch p99 (bin resolution)

    res = SweepEngine(cfg, cases).run(collect="summary", early_exit=True)
    res.telemetry_for("fig02/tornado/reps")["recovery"]["recovery_us"]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.netsim.config import TICK_NS
from repro.netsim.engine import (
    BIG, N_STATS, ST_DELIVERED, ST_DROPS_CONG, ST_DROPS_FAIL, ST_ECN,
    ST_INJECTED, ST_TIMEOUTS, Probe,
)

STAT_NAMES = (
    "drops_cong", "drops_fail", "timeouts", "delivered",
    "ecn_marks", "injected", "unprocessed", "alloc_fails",
)

# the channels metrics.summarize_sketch needs to build a RunSummary; specs
# missing any of them still run, but summary builders fall back to (or
# assert for) the state path.
SUMMARY_CHANNEL_KEYS = frozenset({"counters", "scalars", "fct_hist"})


# ---------------------------------------------------------------------------
# Channels.  Each is a frozen (hashable) dataclass of declarative knobs; the
# static per-program context — shapes, bin edges, strides — is materialized
# by ``build(sim, ticks)`` and threaded back into the pure methods.
#   slots(built)            -> {field: shape}          (all int32)
#   init(built)             -> {field: np.ndarray}
#   update(built, v, probe) -> {field: jnp.Array}      (pure reducer step)
#   finalize(built, v, horizon) -> {metric: value}     (host-side numpy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CounterTotals:
    """Running sums of ``probe.stats_delta`` — equals final ``s_stats``."""

    @property
    def key(self) -> str:
        return "counters"

    def build(self, sim, ticks: int) -> dict:
        return {}

    def slots(self, built) -> dict:
        return {"totals": (N_STATS,)}

    def init(self, built) -> dict:
        return {"totals": np.zeros((N_STATS,), np.int32)}

    def update(self, built, v: dict, probe: Probe) -> dict:
        return {"totals": v["totals"] + probe.stats_delta}

    def finalize(self, built, v: dict, horizon: int) -> dict:
        totals = np.asarray(v["totals"])
        out = {name: int(totals[i]) for i, name in enumerate(STAT_NAMES)}
        out["totals"] = totals
        return out


# The stacked carry is int32, but run-long value sums (FCT, queue
# occupancy) can exceed 2^31 at paper scale (NQ × occupancy × ticks).  Wide
# sums therefore split into (hi, lo) words: lo holds the low SUM_SHIFT bits
# and hi counts 2^SUM_SHIFT units, giving exact totals up to ~2^51.  The
# per-tick increment must stay below 2^31 - 2^SUM_SHIFT — true by
# construction (one tick observes ≤ NQ × capacity occupancy, and ≤ NQ
# completions of FCT ≤ horizon each).
SUM_SHIFT = 20


def _acc_wide(hi, lo, delta):
    lo = lo + delta
    return hi + (lo >> SUM_SHIFT), lo & ((1 << SUM_SHIFT) - 1)


def _wide_total(hi, lo) -> int:
    return (int(hi) << SUM_SHIFT) + int(lo)


def _conn_mask(conn_filter, n_conns: int) -> np.ndarray:
    """Materialize a cohort's static conn-id tuple as a (NC,) bool mask.
    Built once per program (the tuple is a frozen channel knob, so it is
    hashable and shared by every cell in a bucket); out-of-range ids are
    rejected here rather than silently dropped by a clipped scatter."""
    mask = np.zeros((n_conns,), bool)
    ids = np.asarray(conn_filter, np.int64)
    if ids.size and (ids.min() < 0 or ids.max() >= n_conns):
        raise ValueError(
            f"conn_filter ids must be in [0, {n_conns}), got "
            f"[{ids.min()}, {ids.max()}]"
        )
    mask[ids] = True
    return mask


@dataclasses.dataclass(frozen=True)
class RunningScalars:
    """Exact running scalars: FCT count/sum/min/max, completion-tick max,
    queue-occupancy max/sum.  Mean FCT = sum/count reproduces the host-side
    mean bit-for-bit; mean qlen divides by horizon × NQ at finalize so an
    early-exited run reports the same value as the full horizon.  The two
    run-long sums use (hi, lo) split accumulators so they stay exact far
    past int32 range.

    ``conn_filter`` restricts the FCT-side scalars to a cohort of conn ids
    (fig05-style fg/bg mixed workloads); the queue-side scalars stay
    fabric-global.  A cohort instance needs a distinct ``name`` so its
    carry slots don't collide with the default "scalars" channel."""

    conn_filter: tuple[int, ...] | None = None
    name: str | None = None

    @property
    def key(self) -> str:
        return self.name or "scalars"

    def build(self, sim, ticks: int) -> dict:
        built = {"nq": sim.NQ}
        if self.conn_filter is not None:
            built["mask"] = _conn_mask(self.conn_filter, sim.wl.n_conns)
        return built

    def slots(self, built) -> dict:
        return {
            "fct_count": (), "fct_sum_hi": (), "fct_sum_lo": (),
            "fct_min": (), "fct_max": (), "done_tick_max": (),
            "qlen_max": (), "qlen_sum_hi": (), "qlen_sum_lo": (),
        }

    def init(self, built) -> dict:
        z = np.zeros((), np.int32)
        return {
            "fct_count": z, "fct_sum_hi": z, "fct_sum_lo": z,
            "fct_min": np.asarray(BIG, np.int32),
            "fct_max": np.asarray(-1, np.int32),
            "done_tick_max": np.asarray(-1, np.int32),
            "qlen_max": z, "qlen_sum_hi": z, "qlen_sum_lo": z,
        }

    def update(self, built, v: dict, probe: Probe) -> dict:
        d = probe.done_now
        fct = probe.fct
        if "mask" in built:
            cohort = jnp.asarray(built["mask"])
            d = d & cohort
            fct = jnp.where(cohort, fct, 0)
        fct_hi, fct_lo = _acc_wide(
            v["fct_sum_hi"], v["fct_sum_lo"], jnp.sum(fct)
        )  # fct is 0 where ~done
        q_hi, q_lo = _acc_wide(
            v["qlen_sum_hi"], v["qlen_sum_lo"], jnp.sum(probe.q_len)
        )
        return {
            "fct_count": v["fct_count"] + jnp.sum(d, dtype=jnp.int32),
            "fct_sum_hi": fct_hi, "fct_sum_lo": fct_lo,
            "fct_min": jnp.minimum(
                v["fct_min"], jnp.min(jnp.where(d, fct, BIG))
            ),
            "fct_max": jnp.maximum(
                v["fct_max"], jnp.max(jnp.where(d, fct, -1))
            ),
            "done_tick_max": jnp.maximum(
                v["done_tick_max"], jnp.max(jnp.where(d, probe.now, -1))
            ),
            "qlen_max": jnp.maximum(v["qlen_max"], jnp.max(probe.q_len)),
            "qlen_sum_hi": q_hi, "qlen_sum_lo": q_lo,
        }

    def finalize(self, built, v: dict, horizon: int) -> dict:
        count = int(v["fct_count"])
        fct_sum = _wide_total(v["fct_sum_hi"], v["fct_sum_lo"])
        qlen_sum = _wide_total(v["qlen_sum_hi"], v["qlen_sum_lo"])
        return {
            "fct_count": count,
            "fct_sum": fct_sum,
            "fct_min": int(v["fct_min"]) if count else -1,
            "fct_max": int(v["fct_max"]),
            "mean_fct_ticks": (
                float(fct_sum) / count if count else float("nan")
            ),
            "done_tick_max": int(v["done_tick_max"]),
            "qlen_max": int(v["qlen_max"]),
            "mean_qlen": float(qlen_sum) / (horizon * built["nq"]),
        }


@dataclasses.dataclass(frozen=True)
class Histogram:
    """Fixed-width histogram of an on-device value stream.

    ``source="fct"`` bins completion times as they happen (event-driven);
    ``source="qlen"`` bins every queue's occupancy every tick.  Zero values
    are never accumulated — for qlen the zero count is reconstructed at
    ``finalize`` as ``horizon × NQ - sum(counts)``, which (a) makes the
    carry invariant to skipped post-quiescent ticks and (b) costs nothing.
    ``hi=None`` derives the top edge from the program (the scan horizon for
    FCT, the queue capacity for qlen).

    ``conn_filter`` (source="fct" only) restricts the sketch to a cohort
    of conn ids — fig05-style fg/bg mixed workloads get one histogram per
    cohort, each with a distinct ``name`` so carry slots don't collide.
    """

    source: str = "fct"  # "fct" | "qlen"
    n_bins: int = 64
    lo: int = 1
    hi: int | None = None
    spacing: str = "log"  # "log" | "linear"
    name: str | None = None
    conn_filter: tuple[int, ...] | None = None

    @property
    def key(self) -> str:
        return self.name or f"{self.source}_hist"

    def build(self, sim, ticks: int) -> dict:
        assert self.source in ("fct", "qlen"), self.source
        assert self.spacing in ("log", "linear"), self.spacing
        if self.conn_filter is not None and self.source != "fct":
            raise ValueError(
                "conn_filter only applies to source='fct' histograms"
            )
        hi = self.hi
        if hi is None:
            hi = ticks if self.source == "fct" else sim.cfg.queue_capacity
        hi = max(int(hi), self.lo + 1)
        if self.spacing == "log":
            edges = np.geomspace(float(self.lo), float(hi), self.n_bins + 1)
        else:
            edges = np.linspace(float(self.lo), float(hi), self.n_bins + 1)
        built = {
            "edges": edges.astype(np.float32),
            # streams observed per tick (zero-count reconstruction); 0 for
            # event-driven sources (no implicit zero observations)
            "n_streams": sim.NQ if self.source == "qlen" else 0,
        }
        if self.conn_filter is not None:
            built["mask"] = _conn_mask(self.conn_filter, sim.wl.n_conns)
        return built

    def slots(self, built) -> dict:
        # (hi, lo) split like RunningScalars: a qlen bin can receive up to
        # horizon × NQ increments, past int32 at million-tick horizons.
        # The carry is normalized every tick (lo always < 2^SUM_SHIFT on
        # entry), so a skipped post-quiescent tick is a bitwise no-op.
        return {"counts_hi": (self.n_bins,), "counts_lo": (self.n_bins,)}

    def init(self, built) -> dict:
        return {
            "counts_hi": np.zeros((self.n_bins,), np.int32),
            "counts_lo": np.zeros((self.n_bins,), np.int32),
        }

    def update(self, built, v: dict, probe: Probe) -> dict:
        if self.source == "fct":
            vals, mask = probe.fct, probe.done_now
            if "mask" in built:
                mask = mask & jnp.asarray(built["mask"])
        else:
            vals, mask = probe.q_len, probe.q_len > 0
        idx = jnp.clip(
            jnp.searchsorted(
                jnp.asarray(built["edges"]), vals.astype(jnp.float32),
                side="right",
            )
            - 1,
            0,
            self.n_bins - 1,
        )
        # dense one-hot bincount: a scatter-add here would serialize over
        # rows × K on the CPU/TPU backends (engine.py hot-path notes); the
        # (K, n_bins) masked reduce is vectorized and bit-identical
        binned = jnp.sum(
            (
                (idx[:, None] == jnp.arange(self.n_bins, dtype=idx.dtype))
                & mask[:, None]
            ).astype(jnp.int32),
            axis=0,
        )
        lo = v["counts_lo"] + binned
        hi, lo = v["counts_hi"] + (lo >> SUM_SHIFT), lo & ((1 << SUM_SHIFT) - 1)
        return {"counts_hi": hi, "counts_lo": lo}

    def finalize(self, built, v: dict, horizon: int) -> dict:
        counts = (
            np.asarray(v["counts_hi"], np.int64) << SUM_SHIFT
        ) + np.asarray(v["counts_lo"], np.int64)
        zeros = 0
        if built["n_streams"]:
            zeros = int(horizon) * built["n_streams"] - int(counts.sum())
        return {
            "counts": counts,
            "edges": np.asarray(built["edges"], np.float64),
            "zeros": zeros,
        }


@dataclasses.dataclass(frozen=True)
class WindowedSeries:
    """Windowed time-series at a configurable stride: per-watched-link
    service counts (utilization), watched queue occupancy sums, and the
    stat-delta vector per window.  ``stride=None`` derives
    ``ceil(ticks / n_windows)`` from the program horizon; rows frozen (or
    early-exited) before a window simply leave it zero, exactly like the
    full run would."""

    stride: int | None = None
    n_windows: int = 24

    @property
    def key(self) -> str:
        return "windows"

    def build(self, sim, ticks: int) -> dict:
        stride = self.stride or max(1, -(-ticks // self.n_windows))
        return {
            "stride": int(stride),
            "nw": -(-ticks // int(stride)),
            "w": int(sim.watch.shape[0]),
        }

    def slots(self, built) -> dict:
        nw, w = built["nw"], built["w"]
        return {
            "util": (nw, w), "qlen_sum": (nw, w), "stats": (nw, N_STATS),
        }

    def init(self, built) -> dict:
        return {k: np.zeros(s, np.int32) for k, s in self.slots(built).items()}

    def update(self, built, v: dict, probe: Probe) -> dict:
        w = jnp.minimum(probe.now // built["stride"], built["nw"] - 1)
        # dense one-hot row add — a scalar-index scatter here would cost a
        # serialized scatter thunk per row per tick on CPU/TPU (engine.py
        # hot-path notes); adds are 0 off-window and on quiescent ticks,
        # so the update stays a bitwise no-op where it must be
        row = (
            jnp.arange(built["nw"], dtype=jnp.int32) == w
        )[:, None]  # (nw, 1)
        return {
            "util": v["util"] + jnp.where(row, probe.watch_served[None, :], 0),
            "qlen_sum": v["qlen_sum"]
            + jnp.where(row, probe.watch_qlen[None, :], 0),
            "stats": v["stats"] + jnp.where(row, probe.stats_delta[None, :], 0),
        }

    def finalize(self, built, v: dict, horizon: int) -> dict:
        stride = built["stride"]
        nw = min(built["nw"], -(-int(horizon) // stride))
        ticks_per = np.minimum(
            stride, int(horizon) - stride * np.arange(nw)
        ).astype(np.float64)
        util = np.asarray(v["util"])[:nw]
        return {
            "stride": stride,
            "ticks_per_window": ticks_per,
            "util": util,
            "util_frac": util / ticks_per[:, None],
            "mean_qlen": np.asarray(v["qlen_sum"])[:nw] / ticks_per[:, None],
            "stats": np.asarray(v["stats"])[:nw],
            "ecn": np.asarray(v["stats"])[:nw, ST_ECN],
            "drops": (
                np.asarray(v["stats"])[:nw, ST_DROPS_CONG]
                + np.asarray(v["stats"])[:nw, ST_DROPS_FAIL]
            ),
            "delivered": np.asarray(v["stats"])[:nw, ST_DELIVERED],
            "injected": np.asarray(v["stats"])[:nw, ST_INJECTED],
        }


@dataclasses.dataclass(frozen=True)
class RecoveryTracker:
    """Failure-recovery latency: the first failure-drop tick, the first
    sender timeout after it (REPS freezing entry), and the first successful
    delivery after it — ``recovery_ticks`` is the paper's first-drop →
    first-successful-reroute latency (<100µs claim).  Deliveries in the
    same tick as the first drop don't count: within-tick stage order puts
    service before arrivals, so they cannot have been re-routed."""

    @property
    def key(self) -> str:
        return "recovery"

    def build(self, sim, ticks: int) -> dict:
        return {}

    def slots(self, built) -> dict:
        return {"first_drop": (), "first_timeout": (), "first_redeliver": ()}

    def init(self, built) -> dict:
        b = np.asarray(BIG, np.int32)
        return {"first_drop": b, "first_timeout": b, "first_redeliver": b}

    def update(self, built, v: dict, probe: Probe) -> dict:
        now, sd = probe.now, probe.stats_delta
        first_drop = jnp.minimum(
            v["first_drop"], jnp.where(sd[ST_DROPS_FAIL] > 0, now, BIG)
        )
        after = now > first_drop
        return {
            "first_drop": first_drop,
            "first_timeout": jnp.minimum(
                v["first_timeout"],
                jnp.where((sd[ST_TIMEOUTS] > 0) & after, now, BIG),
            ),
            "first_redeliver": jnp.minimum(
                v["first_redeliver"],
                jnp.where((sd[ST_DELIVERED] > 0) & after, now, BIG),
            ),
        }

    def finalize(self, built, v: dict, horizon: int) -> dict:
        def t(x):
            x = int(x)
            return -1 if x >= BIG else x

        drop, timeout, rer = (
            t(v["first_drop"]), t(v["first_timeout"]), t(v["first_redeliver"])
        )
        rec = rer - drop if (drop >= 0 and rer >= 0) else -1
        return {
            "first_drop_tick": drop,
            "first_timeout_tick": timeout,
            "first_redeliver_tick": rer,
            "recovery_ticks": rec,
            "recovery_us": rec * TICK_NS / 1000.0 if rec >= 0 else float("nan"),
        }


# ---------------------------------------------------------------------------
# Spec + compiled program.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """A declarative, hashable channel set.

    ``channels`` is a tuple of frozen channel dataclasses (``CounterTotals``,
    ``RunningScalars``, ``Histogram``, ``WindowedSeries``,
    ``RecoveryTracker``, or user-defined objects with the same
    build/slots/init/update/finalize protocol); channel ``key``s must be
    unique within a spec.  ``build(sim, ticks)`` compiles the set against
    one simulator program (shapes, horizon) into a ``TelemetryProgram``;
    the same spec can be built against many programs (one per sweep bucket
    group), and specs are hashable so engines can cache programs per spec.

    Invariants: every channel update is a pure ``(carry, probe) -> carry``
    reducer that is a bitwise no-op on an all-zero (quiescent-tick) probe —
    that property is what makes ``collect="summary"`` compatible with
    quiescence early exit and per-row horizon freezing.  ``default()`` is
    the spec whose sketches rebuild a ``RunSummary`` bit-identically
    (counters, completions, runtime, mean FCT; percentiles to bin
    resolution) — see ``SUMMARY_CHANNEL_KEYS``.
    """

    channels: tuple = ()

    @staticmethod
    def default(
        fct_bins: int = 64,
        qlen_bins: int = 32,
        n_windows: int = 24,
        stride: int | None = None,
    ) -> "TelemetrySpec":
        return TelemetrySpec(
            channels=(
                CounterTotals(),
                RunningScalars(),
                Histogram(source="fct", n_bins=fct_bins),
                Histogram(source="qlen", n_bins=qlen_bins),
                WindowedSeries(stride=stride, n_windows=n_windows),
                RecoveryTracker(),
            )
        )

    def build(self, sim, ticks: int) -> "TelemetryProgram":
        return TelemetryProgram(self, sim, ticks)

    def with_cohorts(self, cohorts: dict, fct_bins: int = 64) -> "TelemetrySpec":
        """Extend this spec with one FCT histogram + scalar pair per cohort.

        ``cohorts`` maps a label to a tuple of conn ids — e.g. fig05's
        fg/bg split: ``spec.with_cohorts({"fg": fg_ids, "bg": bg_ids})``
        adds ``fct_hist_fg`` / ``scalars_fg`` (etc.) channels whose
        sketches only observe that cohort's completions, so mixed-workload
        figures (and chaos invariants) read per-cohort FCT distributions
        straight from summary mode."""
        extra = []
        for label, ids in cohorts.items():
            ids = tuple(int(i) for i in ids)
            extra.append(
                Histogram(
                    source="fct", n_bins=fct_bins,
                    name=f"fct_hist_{label}", conn_filter=ids,
                )
            )
            extra.append(
                RunningScalars(name=f"scalars_{label}", conn_filter=ids)
            )
        return TelemetrySpec(channels=self.channels + tuple(extra))


class TelemetryProgram:
    """A spec compiled against one simulator program: a static slot layout
    packing every channel carry into ONE flat ``(size,)`` int32 vector per
    row.  ``update`` is the pure reducer the scan body folds; ``finalize_row``
    unpacks a host-side row into per-channel results."""

    def __init__(self, spec: TelemetrySpec, sim, ticks: int):
        self.spec = spec
        self.ticks = int(ticks)
        if not spec.channels:
            raise ValueError(
                "empty TelemetrySpec: add channels, or start from "
                "TelemetrySpec.default()"
            )
        keys = [ch.key for ch in spec.channels]
        if len(set(keys)) != len(keys):
            raise ValueError(f"duplicate telemetry channel keys: {keys}")
        self._built = [(ch, ch.build(sim, ticks)) for ch in spec.channels]
        self._layout: list[tuple[Any, Any, str, int, tuple, int]] = []
        off = 0
        for ch, built in self._built:
            for field, shape in ch.slots(built).items():
                size = int(np.prod(shape, dtype=np.int64)) if shape else 1
                self._layout.append((ch, built, field, off, tuple(shape), size))
                off += size
        self.size = off

    @property
    def nbytes(self) -> int:
        """Host-transfer bytes per row — the O(bins) in the bandwidth model
        (vs O(ticks) per row for ``collect="full"`` trace streams)."""
        return self.size * 4

    @property
    def channel_keys(self) -> frozenset:
        return frozenset(ch.key for ch, _ in self._built)

    def init(self) -> jnp.ndarray:
        flat = np.zeros((self.size,), np.int32)
        for ch, built, field, off, shape, size in self._layout:
            flat[off : off + size] = np.asarray(
                ch.init(built)[field], np.int32
            ).reshape(-1)
        return jnp.asarray(flat)

    def _views(self, flat) -> dict:
        views: dict[int, dict] = {}
        for ch, built, field, off, shape, size in self._layout:
            views.setdefault(id(ch), {})[field] = (
                flat[off : off + size].reshape(shape)
            )
        return views

    def update(self, flat: jnp.ndarray, probe: Probe) -> jnp.ndarray:
        """One reducer step over the stacked carry (pure; vmap over rows)."""
        views = self._views(flat)
        new: dict[int, dict] = {}
        for ch, built in self._built:
            new[id(ch)] = ch.update(built, views[id(ch)], probe)
        parts = []
        for ch, built, field, off, shape, size in self._layout:
            parts.append(
                jnp.asarray(new[id(ch)][field], jnp.int32).reshape(-1)
            )
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    def finalize_row(self, flat: np.ndarray, horizon: int) -> dict:
        """Unpack one host-side row into ``{channel.key: {metric: value}}``.
        ``horizon`` is the row's own tick horizon (not the bucket's) — it
        drives zero-count reconstruction and window trimming."""
        flat = np.asarray(flat)
        assert flat.shape == (self.size,), (flat.shape, self.size)
        views = self._views(flat)
        return {
            ch.key: ch.finalize(built, views[id(ch)], int(horizon))
            for ch, built in self._built
        }

    def live_row(self, flat: np.ndarray, cursor: int) -> dict:
        """Mid-run view of one row's channels, finalized at the current
        tick ``cursor``: zero-count reconstruction and window trimming use
        ``min(cursor, ticks)``, so a partially-run row reads exactly like a
        completed run whose horizon *was* the cursor.  This is what makes
        the soak runtime's ``inspect()`` meaningful between chunks — e.g.
        RecoveryTracker's recovery latency is observable as soon as the
        redelivery happened, without waiting for the horizon."""
        return self.finalize_row(flat, min(int(cursor), self.ticks))

    def stream_rows(self, flat: np.ndarray, t0: int, t1: int) -> dict:
        """Windowed-series rows *completed* by advancing the cursor from
        ``t0`` to ``t1`` — the streaming counterpart of ``finalize_row``'s
        window block.  A window is complete once the cursor passes its end
        (or the horizon, which completes the partial last window), so
        concatenating the emissions of any chunk tiling of ``[0, ticks)``
        reproduces the finalize-time raw arrays exactly: consecutive calls
        emit ``[t0 // stride, t1 // stride)`` — adjacent, no overlap.

        Returns ``{channel.key: {lo, hi, stride, util, qlen_sum, stats}}``
        (raw int32 counts, rows ``[lo, hi)``) for every ``WindowedSeries``
        channel; empty dict when the spec has none or no window completed."""
        flat = np.asarray(flat)
        assert flat.shape == (self.size,), (flat.shape, self.size)
        views = self._views(flat)
        out: dict = {}
        for ch, built in self._built:
            if not isinstance(ch, WindowedSeries):
                continue
            stride, nw = built["stride"], built["nw"]
            lo = min(nw, int(t0) // stride)
            hi = nw if int(t1) >= self.ticks else min(nw, int(t1) // stride)
            if hi <= lo:
                continue
            v = views[id(ch)]
            out[ch.key] = {
                "lo": lo, "hi": hi, "stride": stride,
                "util": np.asarray(v["util"][lo:hi]),
                "qlen_sum": np.asarray(v["qlen_sum"][lo:hi]),
                "stats": np.asarray(v["stats"][lo:hi]),
            }
        return out


# ---------------------------------------------------------------------------
# Sketch statistics.
# ---------------------------------------------------------------------------


def sketch_percentile(
    counts: np.ndarray, edges: np.ndarray, q: float, zeros: int = 0
) -> float:
    """Percentile from a histogram sketch, exact to bin resolution.

    Uses the nearest-rank-above order statistic (numpy's
    ``method="higher"``): the returned value is the *lower edge* of the bin
    holding that order stat, so it sits within one bin width of the exact
    host-side percentile — and is exact for unit-width linear bins.
    ``zeros`` counts observations below ``edges[0]`` that were never
    accumulated (the qlen channel's reconstructed zero count).

    Empty sketches (no counts, no zeros) have no order statistics: the
    result is NaN, never a fabricated 0.0 — dashboards and gates must be
    able to tell "no data yet" from "all-zero observations".  Malformed
    queries (``q`` outside [0, 100], negative ``zeros`` or counts) raise
    instead of silently clipping.
    """
    if not 0.0 <= float(q) <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q!r}")
    if int(zeros) < 0:
        raise ValueError(f"zeros must be >= 0, got {zeros!r}")
    counts = np.asarray(counts, np.int64)
    if counts.size and int(counts.min()) < 0:
        raise ValueError("histogram counts must be non-negative")
    total = int(counts.sum()) + int(zeros)
    if total == 0:
        return float("nan")  # empty sketch: percentile undefined
    rank = math.ceil(q / 100.0 * (total - 1))  # 0-indexed order stat
    if rank < zeros:
        return 0.0
    cum = np.cumsum(counts)
    b = int(np.searchsorted(cum, rank - zeros + 1, side="left"))
    if b >= len(counts):
        # rank beyond the accumulated mass: inconsistent zeros/counts
        # bookkeeping upstream — unreachable for well-formed sketches
        # (rank <= total - 1 pins b inside the array); surface it as NaN
        # rather than silently returning the last edge.
        return float("nan")
    return float(edges[b])


def sketch_bin_index(edges: np.ndarray, value: float) -> int:
    """The bin a value falls into under the channel's binning rule (clipped
    at both ends) — for "within one bin" assertions across modes."""
    idx = int(np.searchsorted(np.asarray(edges), value, side="right")) - 1
    return max(0, min(idx, len(edges) - 2))
