"""Failure-schedule builders (paper §4.3.3, Appendix D.3)."""
from __future__ import annotations

import numpy as np

from repro.netsim.config import SimConfig
from repro.netsim.engine import FailureSchedule
from repro.netsim.topology import Topology


def link_down(queues, start: int, end: int) -> FailureSchedule:
    q = np.atleast_1d(np.asarray(queues, np.int32))
    n = len(q)
    return FailureSchedule(
        queue=q,
        start=np.full((n,), start, np.int32),
        end=np.full((n,), end, np.int32),
        kind=np.zeros((n,), np.int32),
    )


def link_degraded(queues, start: int, end: int) -> FailureSchedule:
    q = np.atleast_1d(np.asarray(queues, np.int32))
    n = len(q)
    return FailureSchedule(
        queue=q,
        start=np.full((n,), start, np.int32),
        end=np.full((n,), end, np.int32),
        kind=np.ones((n,), np.int32),
    )


def random_degraded_uplinks(
    cfg: SimConfig, fraction: float, start: int = 0, end: int = 2**30, seed: int = 0
) -> FailureSchedule:
    """Degrade a random `fraction` of TOR uplinks to half rate (fig 4)."""
    topo = Topology.build(cfg)
    rng = np.random.RandomState(seed)
    ups = np.concatenate([topo.t0_up_queues(t) for t in range(cfg.n_tors)])
    k = max(1, int(round(fraction * len(ups))))
    chosen = rng.choice(ups, k, replace=False)
    return link_degraded(chosen, start, end)


def random_down_uplinks(
    cfg: SimConfig, fraction: float, start: int, end: int, seed: int = 0
) -> FailureSchedule:
    """Take a random `fraction` of TOR uplinks fully down (fig 7/8)."""
    topo = Topology.build(cfg)
    rng = np.random.RandomState(seed)
    ups = np.concatenate([topo.t0_up_queues(t) for t in range(cfg.n_tors)])
    k = max(1, int(round(fraction * len(ups))))
    chosen = rng.choice(ups, k, replace=False)
    return link_down(chosen, start, end)


def incremental_uplink_failures(
    cfg: SimConfig, tor: int, n_fail: int, first_start: int, interval: int
) -> FailureSchedule:
    """Fail n_fail uplinks of one TOR, staggered (Appendix D.3 / fig 19)."""
    topo = Topology.build(cfg)
    ups = topo.t0_up_queues(tor)[:n_fail]
    scheds = [
        link_down([q], first_start + i * interval, 2**30)
        for i, q in enumerate(ups)
    ]
    return FailureSchedule.concat(*scheds)
