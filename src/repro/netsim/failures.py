"""Failure-schedule builders (paper §4.3.3, Appendix D.3).

Padding/truncation semantics (shared with the sweep packer): a schedule may
be *padded* with inert rows (``FailureSchedule.pad_to``) or *truncated* by
dropping rows that provably never activate before a horizon
(``truncate_dead``) — never by clipping a window's ``end``, which would
resurrect the link at the clip boundary.  Permanent events use ``FOREVER``
as their end tick.
"""
from __future__ import annotations

import numpy as np

from repro.netsim import engine
from repro.netsim.config import SimConfig
from repro.netsim.engine import FailureSchedule
from repro.netsim.topology import Topology

# "permanent" end tick: far beyond any horizon, still int32-safe for the
# engine's `now < end` arithmetic.
FOREVER = 2**30


def truncate_dead(fs: FailureSchedule, horizon: int) -> FailureSchedule:
    """Drop rows that can never be active in ``[0, horizon)`` — inert pads
    (empty windows) and events starting at/after the horizon.  Live rows
    are kept bit-unchanged, so the active-set of every tick < horizon is
    preserved exactly; a row that is live before the horizon is *never*
    dropped or clipped, even if its window extends past it."""
    s = np.asarray(fs.start)
    e = np.asarray(fs.end)
    live = (e > s) & (s < horizon)
    return FailureSchedule(
        queue=np.asarray(fs.queue, np.int32)[live],
        start=s.astype(np.int32)[live],
        end=e.astype(np.int32)[live],
        kind=np.asarray(fs.kind, np.int32)[live],
        param=np.asarray(fs.param, np.int32)[live],
    )


def link_down(queues, start: int, end: int) -> FailureSchedule:
    q = np.atleast_1d(np.asarray(queues, np.int32))
    n = len(q)
    return FailureSchedule(
        queue=q,
        start=np.full((n,), start, np.int32),
        end=np.full((n,), end, np.int32),
        kind=np.zeros((n,), np.int32),
    )


def link_degraded(queues, start: int, end: int) -> FailureSchedule:
    q = np.atleast_1d(np.asarray(queues, np.int32))
    n = len(q)
    return FailureSchedule(
        queue=q,
        start=np.full((n,), start, np.int32),
        end=np.full((n,), end, np.int32),
        kind=np.ones((n,), np.int32),
    )


def gray_loss(queues, start: int, end: int, rate: float) -> FailureSchedule:
    """Gray failure: the link stays up (and invisible to adaptive switch
    routing) but silently drops each served packet with probability
    ``rate``.  The rate is stored fixed-point (``param = round(rate *
    GRAY_SCALE)``) and the per-packet draw goes through the engine's
    threefry tick key, so runs are bit-reproducible across kill/resume."""
    if not 0.0 < rate <= 1.0:
        raise ValueError(f"gray_loss rate must be in (0, 1], got {rate}")
    q = np.atleast_1d(np.asarray(queues, np.int32))
    n = len(q)
    param = int(round(rate * engine.GRAY_SCALE))
    return FailureSchedule(
        queue=q,
        start=np.full((n,), start, np.int32),
        end=np.full((n,), end, np.int32),
        kind=np.full((n,), engine.K_GRAY, np.int32),
        param=np.full((n,), param, np.int32),
    )


def link_flapping(
    queues, start: int, end: int, period: int, down_ticks: int
) -> FailureSchedule:
    """Flapping link(s): periodic *down* windows of ``down_ticks`` every
    ``period`` ticks, first window at ``start``, windows starting at or
    after ``end`` omitted.  Materialized as explicit kind-0 rows (one per
    down window per queue) — no new runtime kind, so the engine's
    active-set arithmetic and the pad/truncate no-resurrect semantics are
    untouched, and a flapping schedule is bit-identical to the equivalent
    hand-composed ``link_down`` stack."""
    if period <= 0 or down_ticks <= 0 or down_ticks >= period:
        raise ValueError(
            "link_flapping needs 0 < down_ticks < period, got "
            f"period={period} down_ticks={down_ticks}"
        )
    starts = np.arange(start, end, period, dtype=np.int64)
    if len(starts) == 0:
        return FailureSchedule.none()
    return FailureSchedule.concat(
        *[link_down(queues, int(s), int(s) + down_ticks) for s in starts]
    )


def switch_down(
    cfg: SimConfig, tor: int, start: int, end: int = FOREVER
) -> FailureSchedule:
    """Correlated switch-level outage: every uplink of ToR ``tor`` goes
    down at once (spine-level outages are ``spine_down``)."""
    assert 0 <= tor < cfg.n_tors, (tor, cfg.n_tors)
    topo = Topology.build(cfg)
    return link_down(topo.t0_up_queues(tor), start, end)


def switch_degraded(
    cfg: SimConfig, tor: int, start: int, end: int = FOREVER
) -> FailureSchedule:
    """Fail-slow switch: every uplink of ToR ``tor`` degrades to half
    rate at once."""
    assert 0 <= tor < cfg.n_tors, (tor, cfg.n_tors)
    topo = Topology.build(cfg)
    return link_degraded(topo.t0_up_queues(tor), start, end)


def spine_degraded(
    cfg: SimConfig, spine: int, start: int, end: int = FOREVER
) -> FailureSchedule:
    """Fail-slow spine: the uplink of every ToR that targets ``spine``
    degrades to half rate for ``[start, end)`` (the degraded sibling of
    ``spine_down``)."""
    assert cfg.tiers == 2, "spine_degraded targets the 2-tier fabric"
    assert 0 <= spine < cfg.uplinks_per_tor, (spine, cfg.uplinks_per_tor)
    topo = Topology.build(cfg)
    qs = [int(topo.t0_up_queues(t)[spine]) for t in range(cfg.n_tors)]
    return link_degraded(qs, start, end)


def random_degraded_uplinks(
    cfg: SimConfig, fraction: float, start: int = 0, end: int = FOREVER, seed: int = 0
) -> FailureSchedule:
    """Degrade a random `fraction` of TOR uplinks to half rate (fig 4)."""
    topo = Topology.build(cfg)
    rng = np.random.RandomState(seed)
    ups = np.concatenate([topo.t0_up_queues(t) for t in range(cfg.n_tors)])
    k = max(1, int(round(fraction * len(ups))))
    chosen = rng.choice(ups, k, replace=False)
    return link_degraded(chosen, start, end)


def random_down_uplinks(
    cfg: SimConfig, fraction: float, start: int, end: int, seed: int = 0
) -> FailureSchedule:
    """Take a random `fraction` of TOR uplinks fully down (fig 7/8)."""
    topo = Topology.build(cfg)
    rng = np.random.RandomState(seed)
    ups = np.concatenate([topo.t0_up_queues(t) for t in range(cfg.n_tors)])
    k = max(1, int(round(fraction * len(ups))))
    chosen = rng.choice(ups, k, replace=False)
    return link_down(chosen, start, end)


def spine_down(
    cfg: SimConfig, spine: int, start: int, end: int = FOREVER
) -> FailureSchedule:
    """Take one whole spine out of a 2-tier fabric: the uplink of *every*
    TOR that targets ``spine`` goes down for ``[start, end)``.  This is the
    canonical live-injection delta for the soak runtime's scenario API
    ("advance 10k ticks, kill a spine, watch recovery") — merge it into a
    running schedule with ``FailureSchedule.merge`` / ``SoakRunner.inject``.
    """
    assert cfg.tiers == 2, "spine_down targets the 2-tier fabric"
    assert 0 <= spine < cfg.uplinks_per_tor, (spine, cfg.uplinks_per_tor)
    topo = Topology.build(cfg)
    qs = [int(topo.t0_up_queues(t)[spine]) for t in range(cfg.n_tors)]
    return link_down(qs, start, end)


def incremental_uplink_failures(
    cfg: SimConfig, tor: int, n_fail: int, first_start: int, interval: int
) -> FailureSchedule:
    """Fail n_fail uplinks of one TOR, staggered (Appendix D.3 / fig 19)."""
    topo = Topology.build(cfg)
    ups = topo.t0_up_queues(tor)[:n_fail]
    scheds = [
        link_down([q], first_start + i * interval, FOREVER)
        for i, q in enumerate(ups)
    ]
    return FailureSchedule.concat(*scheds)
