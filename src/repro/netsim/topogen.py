"""Parameterized, deterministic fabric generator (ARCHITECTURE.md §10).

The built-in arithmetic fat-trees in ``netsim/topology.py`` hard-code two
shapes (2-tier, 3-tier).  This module generates *validated* ``TopologySpec``
tables for a wider family — 3-tier Clos, rail-optimized 2-tier, and
low-diameter direct ToR meshes (the Spritz target) — that the engine
consumes through ONE uniform table-driven router
(``topology.TableTopology``) with no per-fabric special-casing.

A spec is a set of numpy tables over (switch, host) pairs:

  * a queue-id **region layout** partitioning ``[0, n_queues)`` exactly
    once, with the ``n_hosts`` host downlinks always last (queue
    ``t0_down_base + h`` delivers to host ``h`` — the engine's final-hop
    contract);
  * **up-port tables**: per (switch, dst) the contiguous block of
    candidate up/cross queues the EV hash (or adaptive least-queue choice)
    selects from, plus the per-switch degree;
  * **down-port tables**: per (switch, dst) the single deterministic
    down-queue toward ``dst``, or -1 when the switch must keep going up;
  * **ECMP salt planes**: the per-switch hash salts.  Clos fabrics salt
    per switch (independent EV→port mappings at every hop); the
    rail-optimized fabric shares one salt across all ToRs so a given
    (flow, EV) lands on the same rail everywhere — the property that makes
    rails congestion-disjoint for spraying senders.

Generators are pure functions of their integer parameters, addressed by a
spec string (``"clos3:pods=2,tors=2,hosts=4,aggs=2,up=2"``) so a fabric
can live on the frozen ``SimConfig`` (``cfg.fabric``) without making the
config unhashable.  ``build_spec`` is cached; equal strings always yield
identical tables.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

#: kinds build_spec() understands, with their required integer parameters.
GENERATORS: dict[str, tuple[str, ...]] = {
    "clos3": ("pods", "tors", "hosts", "aggs", "up"),
    "rail": ("tors", "hosts", "rails"),
    "mesh": ("tors", "hosts", "planes"),
}


@dataclasses.dataclass(frozen=True)
class Region:
    """One contiguous queue-id region: ``[base, base + size)``."""

    name: str
    base: int
    size: int


@dataclasses.dataclass(frozen=True, eq=False)
class TopologySpec:
    """Validated routing tables for one generated fabric.

    Switch ids are dense ``[0, n_switches)`` with the ``n_tors`` host-facing
    switches (ToRs) first; host ``h`` attaches to switch ``host_sw[h]``.
    Queue ``q`` (a directed link) feeds into switch ``q_sw[q]``; the
    ``n_hosts`` host downlinks are the final region (``q_sw == -1``) and
    queue ``t0_down_base + h`` delivers to host ``h``.

    Routing is uniform up/down: a packet at switch ``sw`` bound for ``dst``
    goes down via ``down_next[sw, dst]`` when that is >= 0, else sprays
    over the ``up_deg[sw]`` queues ``up_base[sw, dst] + [0, up_deg[sw])``
    selected by ``ecmp_hash(flow, ev, salt[sw], up_deg[sw])`` (or the
    adaptive least-queue choice).  Clos fabrics have dst-independent
    ``up_base`` columns; the mesh's cross links are dst-directed.
    """

    name: str
    params: dict
    n_hosts: int
    n_tors: int
    n_switches: int
    n_queues: int
    t0_down_base: int
    regions: tuple[Region, ...]
    diameter: int  # max switch hops on any src->dst path
    host_sw: np.ndarray  # (NH,) int32
    q_sw: np.ndarray  # (NQ,) int32, -1 on host downlinks
    up_base: np.ndarray  # (n_switches, NH) int32
    up_deg: np.ndarray  # (n_switches,) int32, 0 = top switch
    down_next: np.ndarray  # (n_switches, NH) int32, -1 = keep going up
    salt: np.ndarray  # (n_switches,) int32 ECMP salt planes
    sw_up_span: np.ndarray  # (n_switches, 2) int32 [base, size] of up block

    @property
    def max_up_deg(self) -> int:
        return int(self.up_deg.max()) if len(self.up_deg) else 1

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Structural invariants; each violation raises ``ValueError``.

        * the regions partition ``[0, n_queues)`` exactly once, with the
          host-downlink region exactly ``[t0_down_base, n_queues)``;
        * every queue feeds a real switch (or is a host downlink);
        * up blocks lie inside their switch's declared up span and match
          the declared degree;
        * every (switch, dst) either routes down to a valid queue or has a
          positive up degree — no routing dead ends.
        """
        NQ, NH, NS = self.n_queues, self.n_hosts, self.n_switches
        covered = np.zeros(NQ, np.int64)
        for r in self.regions:
            if r.size < 0 or r.base < 0 or r.base + r.size > NQ:
                raise ValueError(
                    f"{self.name}: region {r.name} [{r.base}, "
                    f"{r.base + r.size}) outside [0, {NQ})"
                )
            covered[r.base : r.base + r.size] += 1
        if (covered != 1).any():
            bad = int(np.nonzero(covered != 1)[0][0])
            raise ValueError(
                f"{self.name}: queue id {bad} covered {int(covered[bad])} "
                "times — regions must partition the queue-id space exactly "
                "once"
            )
        tail = next(r for r in self.regions if r.base == self.t0_down_base)
        if tail.size != NH or tail.base + tail.size != NQ:
            raise ValueError(
                f"{self.name}: host downlinks must be the final region "
                f"[{self.t0_down_base}, {NQ}) with one queue per host"
            )
        if self.host_sw.shape != (NH,) or (
            (self.host_sw < 0) | (self.host_sw >= self.n_tors)
        ).any():
            raise ValueError(f"{self.name}: host_sw must map hosts to ToRs")
        qs = self.q_sw
        if qs.shape != (NQ,):
            raise ValueError(f"{self.name}: q_sw must have shape ({NQ},)")
        if (qs[self.t0_down_base :] != -1).any():
            raise ValueError(
                f"{self.name}: host downlinks must have q_sw == -1"
            )
        mid = qs[: self.t0_down_base]
        if len(mid) and ((mid < 0) | (mid >= NS)).any():
            raise ValueError(
                f"{self.name}: q_sw of transit queues must be a switch id"
            )
        dn, ub, deg = self.down_next, self.up_base, self.up_deg
        if dn.shape != (NS, NH) or ub.shape != (NS, NH):
            raise ValueError(
                f"{self.name}: down_next/up_base must be (n_switches, "
                "n_hosts) tables"
            )
        if ((dn < -1) | (dn >= NQ)).any():
            raise ValueError(f"{self.name}: down_next entries outside [-1, {NQ})")
        needs_up = dn < 0  # (NS, NH)
        deg2 = np.broadcast_to(deg[:, None], (NS, NH))
        if (needs_up & (deg2 <= 0)).any():
            s = int(np.nonzero(needs_up.any(axis=1) & (deg <= 0))[0][0])
            raise ValueError(
                f"{self.name}: switch {s} has destinations it can neither "
                "route down nor spray up toward — routing dead end"
            )
        span_b = self.sw_up_span[:, 0][:, None]
        span_e = span_b + self.sw_up_span[:, 1][:, None]
        in_span = (ub >= span_b) & (ub + deg2 <= span_e)
        if (needs_up & ~in_span).any():
            s, d = [
                int(v[0]) for v in np.nonzero(needs_up & ~in_span)
            ][:2]
            raise ValueError(
                f"{self.name}: up block of switch {s} toward host {d} "
                "falls outside the switch's declared up span"
            )

    # ------------------------------------------------------------------
    def walk(self, src: int, dst: int, flow: int, ev: int) -> list[int]:
        """Numpy reference walk of one (src, dst, flow, EV) path — the
        queue ids visited, ending at ``dst``'s downlink.  Used by the
        property tests and as executable documentation of the router; the
        jit router in ``topology.TableTopology`` applies the same tables.
        """
        from repro.netsim.topology import ecmp_hash_np

        path: list[int] = []
        sw = int(self.host_sw[src])
        for _ in range(self.diameter + 1):
            down = int(self.down_next[sw, dst])
            if down >= 0:
                path.append(down)
                if down >= self.t0_down_base:
                    return path
                sw = int(self.q_sw[down])
                continue
            deg = int(self.up_deg[sw])
            choice = ecmp_hash_np(flow, ev, int(self.salt[sw]), deg)
            q = int(self.up_base[sw, dst]) + choice
            path.append(q)
            sw = int(self.q_sw[q])
        raise ValueError(
            f"{self.name}: walk {src}->{dst} exceeded diameter "
            f"{self.diameter}: {path}"
        )


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------
def _hosts_per_tor(n_hosts: int, n_tors: int) -> int:
    if n_tors <= 0 or n_hosts % n_tors:
        raise ValueError(
            f"hosts ({n_hosts}) must divide evenly over tors ({n_tors})"
        )
    return n_hosts // n_tors


def gen_clos3(pods: int, tors: int, hosts: int, aggs: int, up: int) -> TopologySpec:
    """3-tier Clos: ``pods`` pods of ``tors`` ToRs x ``hosts`` hosts each,
    ``aggs`` aggregation switches per pod, ``up`` core uplinks per agg
    (so ``aggs * up`` cores; core ``c`` attaches to agg ``c // up`` of
    every pod).  Queue layout and salts match the built-in arithmetic
    3-tier fat-tree, so for matching parameters the generated tables route
    bit-identically to ``Topology.build(tiers=3)``."""
    P, Tp, H, A, U = pods, tors, hosts, aggs, up
    if min(P, Tp, H, A, U) < 1:
        raise ValueError(f"clos3 parameters must be >= 1, got {(P, Tp, H, A, U)}")
    T = P * Tp  # total tors
    NH = T * H
    C = A * U  # cores
    NS = T + P * A + C  # tors, aggs, cores
    t0_up = 0
    agg_up = T * A
    core_down = agg_up + P * A * U
    agg_down = core_down + C * P
    t0_down = agg_down + P * A * Tp
    NQ = t0_down + NH

    regions = (
        Region("t0_up", t0_up, T * A),
        Region("agg_up", agg_up, P * A * U),
        Region("core_down", core_down, C * P),
        Region("agg_down", agg_down, P * A * Tp),
        Region("t0_down", t0_down, NH),
    )
    hostv = np.arange(NH, dtype=np.int64)
    host_sw = (hostv // H).astype(np.int32)
    dst_tor = hostv // H
    dst_pod = dst_tor // Tp
    dst_tor_local = dst_tor % Tp

    q_sw = np.full(NQ, -1, np.int32)
    q = np.arange(T * A, dtype=np.int64)  # t0_up[t, a]
    t, a = q // A, q % A
    q_sw[t0_up + q] = (T + (t // Tp) * A + a).astype(np.int32)
    q = np.arange(P * A * U, dtype=np.int64)  # agg_up[(p, a), u]
    pa, u = q // U, q % U
    q_sw[agg_up + q] = (T + P * A + (pa % A) * U + u).astype(np.int32)
    q = np.arange(C * P, dtype=np.int64)  # core_down[c, p]
    c, p = q // P, q % P
    q_sw[core_down + q] = (T + p * A + c // U).astype(np.int32)
    q = np.arange(P * A * Tp, dtype=np.int64)  # agg_down[(p, a), tl]
    pa, tl = q // Tp, q % Tp
    q_sw[agg_down + q] = ((pa // A) * Tp + tl).astype(np.int32)

    down_next = np.full((NS, NH), -1, np.int32)
    up_base = np.zeros((NS, NH), np.int32)
    up_deg = np.zeros(NS, np.int32)
    salt = np.zeros(NS, np.int32)
    sw_up_span = np.zeros((NS, 2), np.int32)
    # tors: down to local hosts, up over the pod's aggs (salt = tor id,
    # matching the arithmetic fat-tree's ecmp_hash(..., src_tor, A))
    for_t = np.arange(T, dtype=np.int64)
    up_deg[:T] = A
    salt[:T] = for_t.astype(np.int32)
    up_base[:T, :] = (t0_up + for_t * A)[:, None].astype(np.int32)
    sw_up_span[:T] = np.stack(
        [(t0_up + for_t * A).astype(np.int32), np.full(T, A, np.int32)], 1
    )
    local = dst_tor[None, :] == for_t[:, None]
    down_next[:T][local] = np.broadcast_to(
        (t0_down + hostv)[None, :], (T, NH)
    )[local].astype(np.int32)
    # aggs: down into their own pod, up over their cores (salt =
    # agg_global + 7919, matching the arithmetic fat-tree)
    pa = np.arange(P * A, dtype=np.int64)
    up_deg[T : T + P * A] = U
    salt[T : T + P * A] = (pa + 7919).astype(np.int32)
    up_base[T : T + P * A, :] = (agg_up + pa * U)[:, None].astype(np.int32)
    sw_up_span[T : T + P * A] = np.stack(
        [(agg_up + pa * U).astype(np.int32), np.full(P * A, U, np.int32)], 1
    )
    same_pod = dst_pod[None, :] == (pa // A)[:, None]
    agg_dn = agg_down + pa[:, None] * Tp + dst_tor_local[None, :]
    down_next[T : T + P * A][same_pod] = agg_dn[same_pod].astype(np.int32)
    # cores: pure down switches — every pod reachable
    cv = np.arange(C, dtype=np.int64)
    down_next[T + P * A :, :] = (
        core_down + cv[:, None] * P + dst_pod[None, :]
    ).astype(np.int32)

    return TopologySpec(
        name="clos3",
        params=dict(pods=P, tors=Tp, hosts=H, aggs=A, up=U),
        n_hosts=NH, n_tors=T, n_switches=NS, n_queues=NQ,
        t0_down_base=t0_down, regions=regions, diameter=5,
        host_sw=host_sw, q_sw=q_sw, up_base=up_base, up_deg=up_deg,
        down_next=down_next, salt=salt, sw_up_span=sw_up_span,
    )


RAIL_SALT = 0x5EED  # one shared salt plane: same (flow, EV) -> same rail


def gen_rail(tors: int, hosts: int, rails: int) -> TopologySpec:
    """Rail-optimized 2-tier fabric: ``rails`` spine planes, ToR ``t``'s
    uplink ``r`` attaches to rail ``r``.  All ToRs share ONE ECMP salt
    plane, so a (flow, EV) pair selects the same rail at every ToR — the
    rail-affinity property AI fabrics exploit (McClure et al.): a sprayed
    message's EVs stripe deterministically across rails with no cross-rail
    reconvergence."""
    T, H, R = tors, hosts, rails
    if min(T, H, R) < 1:
        raise ValueError(f"rail parameters must be >= 1, got {(T, H, R)}")
    NH = T * H
    NS = T + R
    t0_up = 0
    sp_down = T * R
    t0_down = sp_down + R * T
    NQ = t0_down + NH
    regions = (
        Region("t0_up", t0_up, T * R),
        Region("rail_down", sp_down, R * T),
        Region("t0_down", t0_down, NH),
    )
    hostv = np.arange(NH, dtype=np.int64)
    dst_tor = hostv // H
    host_sw = dst_tor.astype(np.int32)

    q_sw = np.full(NQ, -1, np.int32)
    q = np.arange(T * R, dtype=np.int64)  # t0_up[t, r] -> rail r
    q_sw[t0_up + q] = (T + q % R).astype(np.int32)
    q = np.arange(R * T, dtype=np.int64)  # rail_down[r, t] -> tor t
    q_sw[sp_down + q] = (q % T).astype(np.int32)

    down_next = np.full((NS, NH), -1, np.int32)
    up_base = np.zeros((NS, NH), np.int32)
    up_deg = np.zeros(NS, np.int32)
    salt = np.zeros(NS, np.int32)
    sw_up_span = np.zeros((NS, 2), np.int32)
    tv = np.arange(T, dtype=np.int64)
    up_deg[:T] = R
    salt[:T] = RAIL_SALT
    up_base[:T, :] = (t0_up + tv * R)[:, None].astype(np.int32)
    sw_up_span[:T] = np.stack(
        [(t0_up + tv * R).astype(np.int32), np.full(T, R, np.int32)], 1
    )
    local = dst_tor[None, :] == tv[:, None]
    down_next[:T][local] = np.broadcast_to(
        (t0_down + hostv)[None, :], (T, NH)
    )[local].astype(np.int32)
    rv = np.arange(R, dtype=np.int64)
    down_next[T:, :] = (sp_down + rv[:, None] * T + dst_tor[None, :]).astype(
        np.int32
    )
    return TopologySpec(
        name="rail",
        params=dict(tors=T, hosts=H, rails=R),
        n_hosts=NH, n_tors=T, n_switches=NS, n_queues=NQ,
        t0_down_base=t0_down, regions=regions, diameter=3,
        host_sw=host_sw, q_sw=q_sw, up_base=up_base, up_deg=up_deg,
        down_next=down_next, salt=salt, sw_up_span=sw_up_span,
    )


def gen_mesh(tors: int, hosts: int, planes: int) -> TopologySpec:
    """Low-diameter direct ToR mesh (the Spritz target): every ToR pair is
    joined by ``planes`` parallel links, giving a 2-switch-hop diameter.
    The EV sprays over the plane axis of the dst-directed link bundle —
    exactly the regime Spritz studies, where path diversity comes from
    parallel planes rather than multi-stage reconvergence.  Queue layout:
    ``mesh[t, j, l]`` (peer index ``j`` skips ``t`` itself) then host
    downlinks."""
    T, H, L = tors, hosts, planes
    if min(T, H, L) < 1:
        raise ValueError(f"mesh parameters must be >= 1, got {(T, H, L)}")
    NH = T * H
    NS = T
    n_mesh = T * (T - 1) * L
    t0_down = n_mesh
    NQ = t0_down + NH
    regions = tuple(
        r for r in (
            Region("mesh", 0, n_mesh),
            Region("t0_down", t0_down, NH),
        ) if r.size > 0 or r.name == "t0_down"
    )
    hostv = np.arange(NH, dtype=np.int64)
    dst_tor = hostv // H
    host_sw = dst_tor.astype(np.int32)

    q_sw = np.full(NQ, -1, np.int32)
    if n_mesh:
        q = np.arange(n_mesh, dtype=np.int64)
        t = q // ((T - 1) * L)
        j = (q // L) % (T - 1)
        peer = j + (j >= t)
        q_sw[q] = peer.astype(np.int32)

    down_next = np.full((NS, NH), -1, np.int32)
    up_base = np.zeros((NS, NH), np.int32)
    up_deg = np.zeros(NS, np.int32)
    salt = np.zeros(NS, np.int32)
    sw_up_span = np.zeros((NS, 2), np.int32)
    tv = np.arange(T, dtype=np.int64)
    up_deg[:] = L
    salt[:] = tv.astype(np.int32)
    if T > 1:
        sw_up_span[:] = np.stack(
            [(tv * (T - 1) * L).astype(np.int32),
             np.full(T, (T - 1) * L, np.int32)], 1
        )
        j = dst_tor[None, :] - (dst_tor[None, :] > tv[:, None])
        up_base[:] = (
            tv[:, None] * (T - 1) * L + np.clip(j, 0, T - 2) * L
        ).astype(np.int32)
    local = dst_tor[None, :] == tv[:, None]
    down_next[local] = np.broadcast_to(
        (t0_down + hostv)[None, :], (T, NH)
    )[local].astype(np.int32)
    return TopologySpec(
        name="mesh",
        params=dict(tors=T, hosts=H, planes=L),
        n_hosts=NH, n_tors=T, n_switches=NS, n_queues=NQ,
        t0_down_base=t0_down, regions=regions, diameter=2,
        host_sw=host_sw, q_sw=q_sw, up_base=up_base, up_deg=up_deg,
        down_next=down_next, salt=salt, sw_up_span=sw_up_span,
    )


# ---------------------------------------------------------------------------
# the spec-string front door (what SimConfig.fabric holds)
# ---------------------------------------------------------------------------
def parse_fabric(spec: str) -> tuple[str, dict]:
    """``"kind:k=v,k=v"`` -> (kind, params).  Raises ``ValueError`` on an
    unknown kind, a malformed pair, or missing/extra parameters, naming
    what a valid string looks like."""
    kind, _, rest = spec.partition(":")
    kind = kind.strip()
    if kind not in GENERATORS:
        raise ValueError(
            f"unknown fabric kind {kind!r}; known: {sorted(GENERATORS)}"
        )
    want = GENERATORS[kind]
    params: dict[str, int] = {}
    for pair in filter(None, (p.strip() for p in rest.split(","))):
        k, sep, v = pair.partition("=")
        if not sep or not v.strip().lstrip("-").isdigit():
            raise ValueError(
                f"malformed fabric parameter {pair!r} in {spec!r}; expected "
                f"'{kind}:' + comma-separated k=<int> pairs {want}"
            )
        params[k.strip()] = int(v)
    missing = [k for k in want if k not in params]
    extra = [k for k in params if k not in want]
    if missing or extra:
        raise ValueError(
            f"fabric {spec!r}: missing {missing or 'none'}, unexpected "
            f"{extra or 'none'}; {kind} takes exactly {want}"
        )
    return kind, params


_BUILDERS = {"clos3": gen_clos3, "rail": gen_rail, "mesh": gen_mesh}


@functools.lru_cache(maxsize=64)
def build_spec(spec: str) -> TopologySpec:
    """Parse + generate + validate the fabric named by ``spec``.  Cached:
    the generator is pure, so equal strings share one table set."""
    kind, params = parse_fabric(spec)
    out = _BUILDERS[kind](**params)
    out.validate()
    return out


def fabric_str(kind: str, **params: int) -> str:
    """The canonical spec string for (kind, params) — the inverse of
    ``parse_fabric``, handy for building ``SimConfig.fabric`` values."""
    want = GENERATORS[kind]  # KeyError on unknown kind is fine here
    return kind + ":" + ",".join(f"{k}={int(params[k])}" for k in want)
