"""Preemption-proof soak runtime: checkpointed sweeps with bit-exact resume
and mid-run fault injection.

``SweepEngine.run`` is the batch path: declare the whole grid, run to the
horizon, read the figures.  Long soak runs need three things the batch path
cannot give:

* **Preemption-proofness.**  A multi-hour sweep on preemptible capacity
  must survive a kill at any instant and resume *bit-identically* — not
  "statistically close": the figure-parity contract of this repo is exact,
  so a resumed run's summaries, sketches and traces must equal the
  uninterrupted run's byte for byte.
* **A scenario API.**  The paper's failover story ("run 10k ticks, kill a
  spine, watch REPS recycle around it") wants ``advance`` / ``inject`` /
  ``inspect`` — driving simulated time interactively, injecting failure
  events mid-run, and observing live telemetry between chunks.
* **One semantics for injected and declared failures.**  An event injected
  at tick *t* must behave exactly like the same event pre-declared in the
  case's ``FailureSchedule`` — enforced here by re-materializing the padded
  schedule through ``FailureSchedule.merge`` (the same validation path
  static composites use) and asserted by tests/test_soak.py on full grids.

``SoakRunner`` layers all three over the engine's chunked carry primitives
(``bucket_carry`` / ``run_chunk`` / ``finalize_bucket``): simulated time
advances in chunks; each chunk boundary snapshots every bucket's donated
state carry, telemetry sketch carry and RNG keys through ``repro.checkpoint``
(atomic tmp-then-rename commits, keep-last-K pruning, bounded-retry saves).
``resume()`` restores the newest committed snapshot — keys are restored
from the snapshot, never re-derived, because conn padding is RNG-visible
and jax's threefry is not prefix-stable — replays the injection log through
the one merge code path, and continues.

Bit-exactness rests on two engine facts: (1) a chunked scan is bit-equal to
an unchunked one for any window tiling (the absolute tick is threaded via
``t0``), and (2) device → npz → device roundtrips are exact for the int32 /
uint32 / bool carries the simulator holds.

Injection headroom: build the engine with ``min_failure_slots`` big enough
for the deltas you plan to inject — the reserved inert rows let the merged
schedule re-materialize without a shape change, and make an injected run
and its statically-declared equivalent plan identical buckets (identical
padding, hence identical RNG streams).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.netsim.config import TICK_NS
from repro.netsim.engine import FailureSchedule, TickTrace
from repro.netsim.failures import truncate_dead
from repro.netsim.sweep import SweepEngine, SweepResult
from repro.netsim.telemetry import TelemetrySpec
from repro.netsim.topology import Topology
from repro.netsim.tracer import CODE_NAMES, TraceSpec

_TRACE_RE = re.compile(r"^trace_b(\d+)_t(\d{9})_n(\d+)\.npz$")
_FLIGHT_RE = re.compile(r"^flight_b(\d+)_t(\d{9})_n(\d+)\.npz$")


@dataclasses.dataclass(frozen=True)
class SoakConfig:
    """Knobs for one soak run.

    chunk:          ticks per scan window; every window boundary is a
                    checkpoint opportunity (and the granularity at which
                    ``advance`` yields control back to the host).
    ckpt_dir:       snapshot root (``step_<cursor>`` dirs inside); None
                    disables checkpointing (pure scenario-API use).
    keep:           keep-last-K committed snapshots (older ones pruned).
    collect:        "none" | "summary" | "full" — same contract as
                    ``SweepEngine.run``; "full" streams per-chunk trace
                    parts to ``ckpt_dir/traces`` so resume can rebuild the
                    complete stream.
    telemetry:      TelemetrySpec for collect="summary" (default spec when
                    None).
    trace:          optional ``tracer.TraceSpec`` (summary mode only): carry
                    the on-device flight-recorder ring per row, draining it
                    incrementally — every chunk boundary decodes each row's
                    new ring segment and appends it to an atomic
                    ``flight/flight_b*_t*_n*.npz`` part file before the
                    checkpoint commits, so kill/resume replays are
                    seamless and the streamed event log is complete even
                    though the on-device ring is bounded.  Observation-only:
                    all carries and derived metrics are bit-identical with
                    tracing on or off.
    stream_series:  also write the chunk's *completed* telemetry windows
                    (``TelemetryProgram.stream_rows``) into each flight
                    part, so dashboards tail windowed series without
                    polling the device.
    async_save:     snapshot to host synchronously but write in a
                    background thread (``checkpoint.save_async``); the
                    runner joins — and re-raises worker IO errors — before
                    starting the next save or finalizing.
    save_retries:   bounded retry count for transient OSErrors per save.
    save_backoff_s: base backoff between retries (doubles each attempt).
    """

    chunk: int = 256
    ckpt_dir: Optional[str] = None
    keep: int = 3
    collect: str = "summary"
    telemetry: Optional[TelemetrySpec] = None
    trace: Optional[TraceSpec] = None
    stream_series: bool = True
    async_save: bool = False
    save_retries: int = 2
    save_backoff_s: float = 0.05


class SoakRunner:
    """Drives a ``SweepEngine`` through simulated time in checkpointed
    chunks.  See the module docstring for the contract; tests/test_soak.py
    for the kill-at-every-boundary matrix that enforces it."""

    def __init__(self, engine: SweepEngine, config: SoakConfig | None = None):
        self.engine = engine
        self.config = config or SoakConfig()
        if self.config.collect not in ("none", "summary", "full"):
            raise ValueError(f"bad collect {self.config.collect!r}")
        self.spec = (
            (self.config.telemetry or TelemetrySpec.default())
            if self.config.collect == "summary"
            else None
        )
        self.trace = self.config.trace
        if self.trace is not None and self.config.collect != "summary":
            raise ValueError(
                "SoakConfig.trace requires collect='summary' (the flight "
                "recorder rides the telemetry carry contract)"
            )
        self.cursor = 0
        self.injections: list[dict] = []
        self.fingerprint = self._fingerprint()
        # device-side carries, one per bucket, advanced in lock-step with
        # `cursor` (a bucket past its own horizon simply stops advancing)
        self.carries = [
            engine.bucket_carry(b, self.config.collect, self.spec, self.trace)
            for b in engine.buckets
        ]
        # collect="full": per-bucket [(t0, n, host TickTrace)] in window
        # order; mirrored as part files under ckpt_dir/traces when
        # checkpointing so a resumed process can rebuild the full stream
        self.trace_parts: list[list[tuple[int, int, Any]]] = [
            [] for _ in engine.buckets
        ]
        # tracing: per-bucket per-kept-row flight-ring push cursor through
        # which events have been flushed to part files (restored from the
        # ring carry itself on resume — flushes always precede the commit)
        self._flight_cursors: list[np.ndarray] = [
            np.zeros((b.n_rows,), np.int64) for b in engine.buckets
        ]
        # jitted row-gather readers for inspect()/flight flushes, cached per
        # (bucket, rows, carry shape) so dashboard polls never recompile
        self._row_readers: dict = {}
        self._flight_meta_written = False
        self._pending: Optional[ckpt.SaveHandle] = None
        self._finalized = False

    # ------------------------------------------------------------------
    @property
    def horizon(self) -> int:
        """The grid's max cell horizon — ``advance`` clamps here."""
        return max(b.ticks for b in self.engine.buckets)

    @property
    def done(self) -> bool:
        return self.cursor >= self.horizon

    def _fingerprint(self) -> str:
        """Digest of everything that shapes execution: the pack plan, the
        pinned config, every case's scenario arrays, and the collect mode.
        A snapshot only resumes onto an engine with the same digest —
        anything else would silently change padding, and padding is
        RNG-visible."""
        h = hashlib.sha256()
        eng = self.engine
        h.update(eng.plan.describe().encode())
        h.update(repr(eng.cfg).encode())
        h.update(str(eng.min_failure_slots).encode())
        for case in eng.cases:
            h.update(
                repr(
                    (
                        case.name,
                        case.ticks,
                        case.lb,
                        sorted(case.lb_kwargs.items()),
                        tuple(int(s) for s in case.seeds),
                    )
                ).encode()
            )
            wl = case.workload
            for a in (wl.src, wl.dst, wl.msg_pkts, wl.start, wl.dep):
                h.update(np.ascontiguousarray(a, np.int64).tobytes())
            fs = case.failures or FailureSchedule.none()
            for a in (fs.queue, fs.start, fs.end, fs.kind, fs.param):
                h.update(np.ascontiguousarray(a, np.int64).tobytes())
            h.update(np.ascontiguousarray(
                eng._watch_for(case), np.int64).tobytes())
        h.update(repr((self.config.collect, self.spec)).encode())
        # appended only when tracing so trace-off digests (and their old
        # snapshots) stay valid; the ring carry changes snapshot shapes, so
        # a trace-on snapshot must never restore onto a trace-off runner
        if self.trace is not None:
            h.update(repr(self.trace).encode())
        return h.hexdigest()

    # ------------------------------------------------------------------
    # Scenario API.
    # ------------------------------------------------------------------
    def advance(self, n_ticks: int) -> int:
        """Advance simulated time by up to ``n_ticks`` (clamped to the
        grid horizon), checkpointing at every chunk boundary crossed.
        Returns the new cursor."""
        assert not self._finalized, "runner already finalized"
        target = min(self.cursor + int(n_ticks), self.horizon)
        while self.cursor < target:
            step = min(self.config.chunk, target - self.cursor)
            t0 = self.cursor
            for bi, bucket in enumerate(self.engine.buckets):
                n = min(t0 + step, bucket.ticks) - t0
                if n <= 0:
                    continue  # bucket already at its own horizon
                carry, traces = self.engine.run_chunk(
                    bucket, self.carries[bi], t0, n,
                    self.config.collect, self.spec, self.trace,
                )
                self.carries[bi] = carry
                if self.config.collect == "full":
                    part = jax.device_get(traces)
                    self.trace_parts[bi].append((t0, n, part))
                    self._write_trace_part(bi, t0, n, part)
                if self.trace is not None:
                    self._flush_flight_part(bi, t0, n)
            self.cursor = t0 + step
            self._checkpoint()
        return self.cursor

    def inject(self, delta: FailureSchedule) -> None:
        """Inject failure events into the *running* grid at the current
        cursor.  The delta is validated and merged into every still-active
        cell's schedule through ``FailureSchedule.merge`` — the same code
        path a statically-declared composite takes — then the padded
        per-row scenario arrays are re-materialized in place (no shape
        change: the rows land in the engine's reserved
        ``min_failure_slots`` headroom).  The injection is recorded in the
        log that snapshots carry, so resume replays it identically; a
        checkpoint is committed immediately after a successful injection."""
        assert not self._finalized, "runner already finalized"
        self._apply_delta(delta, self.cursor)
        self.injections.append(
            {
                "at_tick": int(self.cursor),
                "queue": np.asarray(delta.queue, np.int32).tolist(),
                "start": np.asarray(delta.start, np.int32).tolist(),
                "end": np.asarray(delta.end, np.int32).tolist(),
                "kind": np.asarray(delta.kind, np.int32).tolist(),
                "param": np.asarray(delta.param, np.int32).tolist(),
            }
        )
        self._checkpoint()

    def _gather_rows(self, rows: tuple, arr) -> np.ndarray:
        """Device-side row gather + transfer of only the requested rows.
        The jitted gather is cached per row set, so repeated ``inspect``
        polls (the dashboard's steady state) never recompile and never
        transfer a bucket's padded rows."""
        fn = self._row_readers.get(rows)
        if fn is None:
            idx = jnp.asarray(rows, jnp.int32)
            fn = jax.jit(lambda a: jnp.take(a, idx, axis=0))
            self._row_readers[rows] = fn
        return np.asarray(jax.device_get(fn(arr)))

    def inspect(self) -> dict[str, dict]:
        """Live per-cell view at the current cursor, without disturbing the
        run: ``{cell name: {cursor, ticks, done, telemetry[, flight]}}``
        where ``telemetry`` (summary mode, seed 0) is the sketch channels
        finalized at ``min(cursor, cell ticks)`` — e.g. the RecoveryTracker
        latency is readable as soon as redelivery happened — and
        ``flight`` (when tracing) is the row's decoded ring tail plus the
        failure-edge ticks."""
        out: dict[str, dict] = {}
        summary = self.config.collect == "summary"
        for bi, bucket in enumerate(self.engine.buckets):
            tel = trc = None
            rows = tuple(int(c.rows[0]) for c in bucket.cells)
            if summary:
                tel_prog = self.engine._tel_prog(bucket.program, self.spec)
                tel = self._gather_rows(rows, self.carries[bi][1])
            if self.trace is not None:
                trc_prog = self.engine._trc_prog(bucket.program, self.trace)
                trc = self._gather_rows(rows, self.carries[bi][2])
            for ci, c in enumerate(bucket.cells):
                cell_cursor = min(self.cursor, c.case.ticks)
                info: dict[str, Any] = {
                    "cursor": cell_cursor,
                    "ticks": c.case.ticks,
                    "done": cell_cursor >= c.case.ticks,
                }
                if summary:
                    info["telemetry"] = tel_prog.live_row(
                        tel[ci], cell_cursor
                    )
                if trc is not None:
                    info["flight"] = trc_prog.decode_row(trc[ci])
                out[c.case.name] = info
        return out

    def result(self) -> SweepResult:
        """Finalize every bucket at the current cursor and return the
        standard ``SweepResult`` view.  Requires the grid to have reached
        its horizon (partial figures are what ``inspect`` is for)."""
        assert self.done, (
            f"grid not finished: cursor {self.cursor} < horizon "
            f"{self.horizon}; advance() further or use inspect()"
        )
        self._join_pending()
        full = self.config.collect == "full"
        for bi, bucket in enumerate(self.engine.buckets):
            chunks = None
            if full:
                chunks = [p for _, _, p in self._contiguous_parts(bi)]
            self.engine.finalize_bucket(
                bucket, self.carries[bi], self.config.collect,
                bucket.ticks, chunks, self.spec, self.trace,
            )
            self.carries[bi] = None  # host copies now own the data
        self._finalized = True
        return SweepResult(self.engine)

    # ------------------------------------------------------------------
    # Injection internals.
    # ------------------------------------------------------------------
    def _apply_delta(self, delta: FailureSchedule, at_tick: int) -> None:
        topo = Topology.build(self.engine.cfg)
        # validate against every still-active cell BEFORE mutating any —
        # a partially-applied injection could never match a static run
        staged: list[tuple[int, Any, FailureSchedule]] = []
        for bi, bucket in enumerate(self.engine.buckets):
            f_slots = bucket.plan.key[4]
            for c in bucket.cells:
                if c.case.ticks <= at_tick:
                    continue  # cell finished; delta can never activate
                live = truncate_dead(c.padded_fs, c.case.ticks)
                merged = live.merge(
                    delta, at_tick=at_tick, n_queues=topo.n_queues
                )
                live_merged = truncate_dead(merged, c.case.ticks)
                if len(live_merged) > f_slots:
                    raise ValueError(
                        f"cell {c.case.name!r}: merged schedule needs "
                        f"{len(live_merged)} failure rows but the bucket "
                        f"reserved {f_slots}; build the engine with "
                        f"min_failure_slots >= {len(live_merged)} to leave "
                        "injection headroom"
                    )
                staged.append((bi, c, live_merged.pad_to(f_slots)))
        # commit: re-materialize the padded schedules into the scenario
        # arrays, one host round-trip per touched bucket
        touched = sorted({bi for bi, _, _ in staged})
        for bi in touched:
            bucket = self.engine.buckets[bi]
            host = {
                name: np.array(jax.device_get(getattr(bucket.scn, name)))
                for name in ("f_queue", "f_start", "f_end", "f_kind", "f_param")
            }
            for sbi, c, padded in staged:
                if sbi != bi:
                    continue
                c.padded_fs = padded
                for row in c.rows:
                    host["f_queue"][row] = padded.queue
                    host["f_start"][row] = padded.start
                    host["f_end"][row] = padded.end
                    host["f_kind"][row] = padded.kind
                    host["f_param"][row] = padded.param
            # pad rows repeat row 0 at build time; keep that exact shape so
            # an injected bucket is indistinguishable from a fresh build
            for name in host:
                host[name][bucket.n_rows:] = host[name][0]
            bucket.scn = bucket.scn._replace(
                **{k: jnp.asarray(v) for k, v in host.items()}
            )

    # ------------------------------------------------------------------
    # Checkpoint / resume.
    # ------------------------------------------------------------------
    def _trees(self) -> dict[str, Any]:
        trees: dict[str, Any] = {}
        for bi, bucket in enumerate(self.engine.buckets):
            trees[f"b{bi}_carry"] = self.carries[bi]
            trees[f"b{bi}_keys"] = bucket.keys
        return trees

    def _extra(self) -> dict:
        return {
            "soak": {
                "fingerprint": self.fingerprint,
                "cursor": int(self.cursor),
                "collect": self.config.collect,
                "chunk": int(self.config.chunk),
                "injections": self.injections,
            }
        }

    def _join_pending(self) -> None:
        if self._pending is not None:
            pending, self._pending = self._pending, None
            pending.join()  # re-raises background IO failures

    def _checkpoint(self) -> None:
        cfg = self.config
        if cfg.ckpt_dir is None:
            return
        path = os.path.join(cfg.ckpt_dir, f"step_{self.cursor}")
        self._join_pending()
        if cfg.async_save:
            # prune *now*, while no save is in flight — pruning sweeps
            # stale .tmp staging dirs and must never race a live one
            ckpt.prune(cfg.ckpt_dir, cfg.keep)
            self._pending = ckpt.save_async(
                path, self.cursor, self._trees(), extra=self._extra(),
                retries=cfg.save_retries, backoff_s=cfg.save_backoff_s,
            )
        else:
            ckpt.save(
                path, self.cursor, self._trees(), extra=self._extra(),
                retries=cfg.save_retries, backoff_s=cfg.save_backoff_s,
            )
            ckpt.prune(cfg.ckpt_dir, cfg.keep)

    def resume(self) -> "SoakRunner":
        """Restore the newest committed snapshot under ``ckpt_dir`` into
        this (freshly constructed) runner: replay the injection log through
        the live-injection code path, then load every bucket's carry *and*
        RNG keys from the snapshot (never re-derived).  Returns self."""
        cfg = self.config
        assert cfg.ckpt_dir is not None, "SoakConfig.ckpt_dir not set"
        assert self.cursor == 0 and not self.injections, (
            "resume() must be called on a fresh runner"
        )
        path = ckpt.latest(cfg.ckpt_dir)
        if path is None:
            raise FileNotFoundError(
                f"no committed snapshot under {cfg.ckpt_dir}"
            )
        meta = ckpt.read_manifest(path)["soak"]
        if meta["fingerprint"] != self.fingerprint:
            raise ValueError(
                "snapshot belongs to a different sweep: plan/scenario "
                "fingerprint mismatch (engine cases, config, packing or "
                "collect mode changed since the snapshot was written)"
            )
        # injections first: they rebuild padded schedules + scenario
        # arrays, and must be in place before the carries continue
        for inj in meta["injections"]:
            delta = FailureSchedule(
                queue=np.asarray(inj["queue"], np.int32),
                start=np.asarray(inj["start"], np.int32),
                end=np.asarray(inj["end"], np.int32),
                kind=np.asarray(inj["kind"], np.int32),
                # absent in snapshots written before the gray fault model
                param=np.asarray(
                    inj.get("param", np.zeros(len(inj["queue"]), np.int32)),
                    np.int32,
                ),
            )
            self._apply_delta(delta, int(inj["at_tick"]))
            self.injections.append(inj)
        like = self._trees()
        trees, step = ckpt.restore(path, like)
        for bi, bucket in enumerate(self.engine.buckets):
            self.carries[bi] = trees[f"b{bi}_carry"]
            bucket.keys = trees[f"b{bi}_keys"]
        self.cursor = int(step)
        if self.config.collect == "full":
            self._load_trace_parts()
        if self.trace is not None:
            self._load_flight_state()
        return self

    # ------------------------------------------------------------------
    # Full-trace streaming (collect="full").
    # ------------------------------------------------------------------
    def _traces_dir(self) -> Optional[str]:
        if self.config.ckpt_dir is None:
            return None
        d = os.path.join(self.config.ckpt_dir, "traces")
        os.makedirs(d, exist_ok=True)
        return d

    def _write_trace_part(self, bi: int, t0: int, n: int, part) -> None:
        """Persist one chunk's host trace as an atomic npz part file.
        Re-running a window after resume rewrites the same deterministic
        bytes, so a stale part from a killed timeline is harmless — it is
        deleted on resume anyway (only parts below the restored cursor
        survive)."""
        d = self._traces_dir()
        if d is None:
            return
        fname = f"trace_b{bi}_t{t0:09d}_n{n}.npz"
        tmp = os.path.join(d, fname + ".tmp")
        with open(tmp, "wb") as f:  # handle, or np.savez appends ".npz"
            np.savez(f, **{k: np.asarray(v)
                           for k, v in zip(TickTrace._fields, part)})
        os.replace(tmp, os.path.join(d, fname))

    def _load_trace_parts(self) -> None:
        """Rebuild the in-memory per-bucket part lists from disk: keep
        parts strictly below the restored cursor, delete the rest (they
        cover windows the resumed run will re-execute — bit-identically,
        but possibly with a different chunking)."""
        d = self._traces_dir()
        assert d is not None
        parts: dict[int, list[tuple[int, int, Any]]] = {}
        for fname in sorted(os.listdir(d)):
            m = _TRACE_RE.match(fname)
            if m is None:
                if fname.endswith(".tmp"):
                    os.unlink(os.path.join(d, fname))
                continue
            bi, t0, n = int(m.group(1)), int(m.group(2)), int(m.group(3))
            p = os.path.join(d, fname)
            if t0 >= self.cursor:
                os.unlink(p)
                continue
            with np.load(p) as data:
                part = TickTrace(*[data[k] for k in TickTrace._fields])
            parts.setdefault(bi, []).append((t0, n, part))
        self.trace_parts = [
            sorted(parts.get(bi, []))
            for bi in range(len(self.engine.buckets))
        ]

    # ------------------------------------------------------------------
    # Flight-recorder streaming (trace=TraceSpec(...)).
    # ------------------------------------------------------------------
    def _flight_dir(self) -> Optional[str]:
        if self.config.ckpt_dir is None:
            return None
        d = os.path.join(self.config.ckpt_dir, "flight")
        os.makedirs(d, exist_ok=True)
        return d

    def _write_flight_meta(self) -> None:
        """One-time sidecar mapping the streamed part files back to cells:
        event code table, tick duration, and each bucket's kept-row → cell
        assignment (so consumers never need the engine to decode parts)."""
        d = self._flight_dir()
        if d is None or self._flight_meta_written:
            return
        import json

        meta = {
            "tick_ns": TICK_NS,
            "ring": int(self.trace.ring),
            "marker_every": int(self.trace.marker_every),
            "codes": {str(k): v for k, v in CODE_NAMES.items()},
            "buckets": [
                {
                    "cells": [
                        {
                            "name": c.case.name,
                            "ticks": int(c.case.ticks),
                            "seeds": [int(s) for s in c.case.seeds],
                            "rows": [int(r) for r in c.rows],
                        }
                        for c in b.cells
                    ],
                    "n_rows": int(b.n_rows),
                }
                for b in self.engine.buckets
            ],
        }
        tmp = os.path.join(d, "flight_meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=1)
        os.replace(tmp, os.path.join(d, "flight_meta.json"))
        self._flight_meta_written = True

    def _flush_flight_part(self, bi: int, t0: int, n: int) -> None:
        """Drain the window's new ring events for every kept row of one
        bucket into an atomic ``flight_b*_t*_n*.npz`` part.  Runs *before*
        the window's checkpoint commits (same ordering as the full-trace
        parts), so after a kill the restored ring cursors always equal the
        flushed-through cursors and re-executed windows rewrite the same
        deterministic bytes.  Stale parts from a killed timeline are
        deleted on resume.  ``lost`` counts ring overwrites within the
        window (> ring pushes between flushes) — reported, never silent."""
        d = self._flight_dir()
        if d is None:
            return
        self._write_flight_meta()
        bucket = self.engine.buckets[bi]
        trc_prog = self.engine._trc_prog(bucket.program, self.trace)
        rows = tuple(range(bucket.n_rows))
        flat = self._gather_rows(rows, self.carries[bi][2])
        since = self._flight_cursors[bi]
        ev_row, ev_seq, ev_tick, ev_code, ev_val = [], [], [], [], []
        cursor = np.zeros((bucket.n_rows,), np.int64)
        lost = np.zeros((bucket.n_rows,), np.int64)
        first_drop = np.zeros((bucket.n_rows,), np.int64)
        first_red = np.zeros((bucket.n_rows,), np.int64)
        for r in range(bucket.n_rows):
            ev = trc_prog.decode_row(flat[r], since=int(since[r]))
            cursor[r], lost[r] = ev["cursor"], ev["lost"]
            first_drop[r] = ev["first_drop_tick"]
            first_red[r] = ev["first_redeliver_tick"]
            ev_row.append(np.full(ev["seq"].shape, r, np.int32))
            ev_seq.append(ev["seq"])
            ev_tick.append(ev["tick"])
            ev_code.append(ev["code"])
            ev_val.append(ev["value"])
        part = {
            "row": np.concatenate(ev_row) if ev_row else np.zeros(0, np.int32),
            "seq": np.concatenate(ev_seq),
            "tick": np.concatenate(ev_tick),
            "code": np.concatenate(ev_code),
            "value": np.concatenate(ev_val),
            "since": since.copy(),
            "cursor": cursor,
            "lost": lost,
            "first_drop_tick": first_drop,
            "first_redeliver_tick": first_red,
        }
        if self.config.stream_series and self.spec is not None:
            tel_prog = self.engine._tel_prog(bucket.program, self.spec)
            tel = self._gather_rows(rows, self.carries[bi][1])
            per_row = [
                tel_prog.stream_rows(tel[r], t0, t0 + n)
                for r in range(bucket.n_rows)
            ]
            for key, s in per_row[0].items():
                part[f"series_{key}_lo"] = np.asarray(s["lo"], np.int64)
                part[f"series_{key}_stride"] = np.asarray(
                    s["stride"], np.int64
                )
                for f in ("util", "qlen_sum", "stats"):
                    part[f"series_{key}_{f}"] = np.stack(
                        [pr[key][f] for pr in per_row]
                    )
        fname = f"flight_b{bi}_t{t0:09d}_n{n}.npz"
        tmp = os.path.join(d, fname + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **part)
        os.replace(tmp, os.path.join(d, fname))
        self._flight_cursors[bi] = cursor

    def _load_flight_state(self) -> None:
        """Resume-side cleanup: restore per-row flushed-through cursors
        from the restored ring carries (flushes always precede the commit,
        so they agree), and delete parts at/after the restored cursor —
        those windows will be re-executed and rewritten bit-identically."""
        for bi, bucket in enumerate(self.engine.buckets):
            flat = self._gather_rows(
                tuple(range(bucket.n_rows)), self.carries[bi][2]
            )
            self._flight_cursors[bi] = np.asarray(flat[:, 0], np.int64)
        d = self._flight_dir()
        if d is None:
            return
        for fname in sorted(os.listdir(d)):
            m = _FLIGHT_RE.match(fname)
            if m is None:
                if fname.endswith(".tmp"):
                    os.unlink(os.path.join(d, fname))
                continue
            if int(m.group(2)) >= self.cursor:
                os.unlink(os.path.join(d, fname))
        self._flight_meta_written = False  # rewrite (same bytes) next flush

    def _contiguous_parts(self, bi: int) -> list[tuple[int, int, Any]]:
        """The bucket's parts in window order, asserted to tile
        ``[0, bucket.ticks)`` exactly — a gap means part files were lost
        out-of-band (the checkpoint only commits after its windows' parts
        are on disk)."""
        bucket = self.engine.buckets[bi]
        parts = sorted(self.trace_parts[bi])
        want = 0
        for t0, n, _ in parts:
            assert t0 == want, (
                f"trace stream for bucket {bi} has a gap: expected a part "
                f"at t0={want}, found t0={t0}"
            )
            want = t0 + n
        assert want == bucket.ticks, (
            f"trace stream for bucket {bi} ends at {want}, horizon is "
            f"{bucket.ticks}"
        )
        return parts
