"""Fat-tree topology: queue-id layout, ECMP hashing, and the hop-transition
function (DESIGN.md §3 "Simulator time model").

Every directed link has one FIFO queue at its source.  Queue-id regions:

2-tier (T tors × H hosts each, U uplinks == U spines):
    t0_up[t, u]   = t*U + u                         [0,            T*U)
    sp_down[s, t] = T*U + s*T + t                   [T*U,          T*U+U*T)
    t0_down[t, h] = T*U + U*T + t*H + h             [...,          +T*H)

3-tier (P pods × Tp tors × H hosts; A aggs/pod; U2 core-uplinks/agg;
        C = A*U2 cores; core c attaches to agg c//U2 of every pod):
    t0_up[t, a]        = t*A + a
    agg_up[p, a, u]    = T*A + (p*A + a)*U2 + u
    core_down[c, p]    = T*A + P*A*U2 + c*P + p
    agg_down[p, a, tl] = ... + C*P + (p*A + a)*Tp + tl
    t0_down[t, h]      = ... + P*A*Tp + t*H + h

The packet's EV selects the up-direction "choice" ports via a mixing hash
of (flow_id, EV, switch salt); down-direction ports are determined by the
destination (standard Clos routing).  This mirrors §2.2: the sender does
not know the EV→path mapping, only that distinct EVs hash independently.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim.config import SimConfig


def mix32(x: jax.Array) -> jax.Array:
    """Murmur3-style 32-bit finalizer (good avalanche; used as ECMP hash)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def ecmp_hash(flow_id: jax.Array, ev: jax.Array, salt: jax.Array, nports) -> jax.Array:
    h = mix32(
        flow_id.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
        ^ ev.astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
        ^ salt.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D)
    )
    return (h % jnp.asarray(nports, jnp.uint32)).astype(jnp.int32)


def _mix32_np(x: int) -> int:
    x &= 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x7FEB352D) & 0xFFFFFFFF
    x ^= x >> 15
    x = (x * 0x846CA68B) & 0xFFFFFFFF
    x ^= x >> 16
    return x


def ecmp_hash_np(flow_id: int, ev: int, salt: int, nports: int) -> int:
    """Bit-exact numpy/python mirror of ``ecmp_hash`` — the reference the
    topogen property tests and ``TopologySpec.walk`` use off-device."""
    h = _mix32_np(
        ((flow_id * 0x9E3779B1) ^ (ev * 0x85EBCA77) ^ (salt * 0xC2B2AE3D))
        & 0xFFFFFFFF
    )
    return int(h % max(int(nports), 1))


@dataclasses.dataclass(frozen=True)
class Topology:
    cfg: SimConfig
    n_queues: int
    # region bases (python ints — static under jit)
    t0_up_base: int
    agg_up_base: int  # 3-tier only (== -1 for 2-tier)
    core_down_base: int
    agg_down_base: int
    t0_down_base: int

    @staticmethod
    def build(cfg: SimConfig) -> "Topology":
        if cfg.fabric:
            # generated fabric (netsim/topogen.py): same interface, ONE
            # table-driven router for every fabric kind — the engine never
            # branches on what kind of fabric it is running.
            return TableTopology.build(cfg)
        T, H = cfg.n_tors, cfg.hosts_per_tor
        if cfg.tiers == 2:
            U = cfg.uplinks_per_tor
            t0_up = 0
            sp_down = T * U
            t0_down = sp_down + U * T
            n_queues = t0_down + T * H
            return Topology(
                cfg=cfg,
                n_queues=n_queues,
                t0_up_base=t0_up,
                agg_up_base=-1,
                core_down_base=sp_down,  # reuse for spine-down region
                agg_down_base=-1,
                t0_down_base=t0_down,
            )
        A, U2, P, Tp = cfg.aggs_per_pod, cfg.agg_uplinks, cfg.n_pods, cfg.tors_per_pod
        C = cfg.n_cores
        t0_up = 0
        agg_up = T * A
        core_down = agg_up + P * A * U2
        agg_down = core_down + C * P
        t0_down = agg_down + P * A * Tp
        n_queues = t0_down + T * H
        return Topology(
            cfg=cfg,
            n_queues=n_queues,
            t0_up_base=t0_up,
            agg_up_base=agg_up,
            core_down_base=core_down,
            agg_down_base=agg_down,
            t0_down_base=t0_down,
        )

    @property
    def diameter(self) -> int:
        """Max queue hops on any src->dst path (host downlink included)."""
        return 3 if self.cfg.tiers == 2 else 5

    # -- helpers for benchmarks / tests (numpy, not jitted) ----------------
    def t0_up_queues(self, tor: int) -> np.ndarray:
        cfg = self.cfg
        n_up = cfg.uplinks_per_tor if cfg.tiers == 2 else cfg.aggs_per_pod
        return np.arange(n_up) + self.t0_up_base + tor * n_up

    def t0_down_queue(self, host: int) -> int:
        cfg = self.cfg
        t, hl = host // cfg.hosts_per_tor, host % cfg.hosts_per_tor
        return self.t0_down_base + t * cfg.hosts_per_tor + hl

    def is_final_hop(self, q: jax.Array) -> jax.Array:
        return q >= self.t0_down_base

    # -- the hop-transition function (jit-traceable) ------------------------
    def next_queue(
        self,
        at_injection: jax.Array,  # bool (K,): packet leaving the source host
        cur_queue: jax.Array,  # int32 (K,): queue just dequeued from
        flow_id: jax.Array,  # int32 (K,)
        ev: jax.Array,  # int32 (K,)
        src: jax.Array,  # int32 (K,) source host id
        dst: jax.Array,  # int32 (K,) destination host id
        q_len: jax.Array,  # int32 (n_queues,): current lengths (adaptive)
        adaptive: bool,  # static: in-network least-queue choice
    ) -> jax.Array:
        cfg = self.cfg
        T, H = cfg.n_tors, cfg.hosts_per_tor
        src_tor, dst_tor = src // H, dst // H
        dst_local = dst % H
        same_tor = src_tor == dst_tor
        t0_down = self.t0_down_base + dst_tor * H + dst_local

        if cfg.tiers == 2:
            U = cfg.uplinks_per_tor
            up_choice = ecmp_hash(flow_id, ev, src_tor, U)
            if adaptive:
                # switch-local least-queue pick among this TOR's uplinks
                cand = self.t0_up_base + src_tor[:, None] * U + jnp.arange(U)
                lens = q_len[cand]
                up_choice = jnp.argmin(lens, axis=1).astype(jnp.int32)
            t0_up = self.t0_up_base + src_tor * U + up_choice
            # cur_queue regions
            at_t0_up = cur_queue < self.core_down_base  # t0_up region
            spine = jnp.where(at_t0_up, cur_queue - self.t0_up_base, 0) % U
            sp_down = self.core_down_base + spine * T + dst_tor

            nxt = jnp.where(
                at_injection,
                jnp.where(same_tor, t0_down, t0_up),
                jnp.where(at_t0_up, sp_down, t0_down),
            )
            return nxt.astype(jnp.int32)

        # ---- 3-tier ----
        A, U2, Tp = cfg.aggs_per_pod, cfg.agg_uplinks, cfg.tors_per_pod
        src_pod, dst_pod = src_tor // Tp, dst_tor // Tp
        dst_tor_local = dst_tor % Tp
        same_pod = src_pod == dst_pod

        up1 = ecmp_hash(flow_id, ev, src_tor, A)
        if adaptive:
            cand = self.t0_up_base + src_tor[:, None] * A + jnp.arange(A)
            up1 = jnp.argmin(q_len[cand], axis=1).astype(jnp.int32)
        t0_up = self.t0_up_base + src_tor * A + up1

        in_t0_up = cur_queue < self.agg_up_base
        agg_id = jnp.where(in_t0_up, cur_queue - self.t0_up_base, 0)
        agg_a = agg_id % A  # agg index within the pod
        agg_global = src_pod * A + agg_a
        up2 = ecmp_hash(flow_id, ev, agg_global + 7919, U2)
        if adaptive:
            cand = self.agg_up_base + agg_global[:, None] * U2 + jnp.arange(U2)
            up2 = jnp.argmin(q_len[cand], axis=1).astype(jnp.int32)
        agg_up = self.agg_up_base + agg_global * U2 + up2
        agg_down_same = self.agg_down_base + agg_global * Tp + dst_tor_local

        in_agg_up = (cur_queue >= self.agg_up_base) & (
            cur_queue < self.core_down_base
        )
        core = jnp.where(in_agg_up, cur_queue - self.agg_up_base, 0) % (
            A * U2
        )  # (p*A+a)*U2+u -> c = a*U2+u
        core = (jnp.where(in_agg_up, cur_queue - self.agg_up_base, 0) // U2 % A) * U2 + (
            jnp.where(in_agg_up, cur_queue - self.agg_up_base, 0) % U2
        )
        core_down = self.core_down_base + core * cfg.n_pods + dst_pod

        in_core_down = (cur_queue >= self.core_down_base) & (
            cur_queue < self.agg_down_base
        )
        core_at = jnp.where(in_core_down, cur_queue - self.core_down_base, 0) // cfg.n_pods
        dst_agg = core_at // U2
        agg_down_x = (
            self.agg_down_base + (dst_pod * A + dst_agg) * Tp + dst_tor_local
        )

        nxt = jnp.where(
            at_injection,
            jnp.where(same_tor, t0_down, t0_up),
            jnp.where(
                in_t0_up,
                jnp.where(same_pod, agg_down_same, agg_up),
                jnp.where(
                    in_agg_up,
                    core_down,
                    jnp.where(in_core_down, agg_down_x, t0_down),
                ),
            ),
        )
        return nxt.astype(jnp.int32)


class TableTopology:
    """Table-driven topology built from a generated ``TopologySpec``
    (netsim/topogen.py) — the SAME consumer interface as the arithmetic
    ``Topology`` (``n_queues`` / ``t0_down_base`` / ``next_queue`` /
    ``t0_up_queues`` / ``t0_down_queue`` / ``is_final_hop``), so the
    engine and sweep run generated fabrics with zero special-casing.

    Routing is one uniform up/down rule over the spec's tables: route down
    via ``down_next[sw, dst]`` when defined, else spray over the
    ``up_deg[sw]``-wide candidate block ``up_base[sw, dst] + choice`` with
    the choice hashed from (flow, EV, per-switch salt plane) — or picked
    adaptively by least queue length when the LB is switch-adaptive.
    """

    def __init__(self, cfg: SimConfig, spec):
        if spec.n_hosts != cfg.n_hosts:
            raise ValueError(
                f"fabric {cfg.fabric!r} has {spec.n_hosts} hosts but "
                f"SimConfig.n_hosts={cfg.n_hosts}; they must agree"
            )
        self.cfg = cfg
        self.spec = spec
        self.n_queues = spec.n_queues
        self.t0_down_base = spec.t0_down_base
        # region bases kept for interface parity (unused by the router)
        self.t0_up_base = 0
        self.agg_up_base = -1
        self.core_down_base = -1
        self.agg_down_base = -1
        self._host_sw = jnp.asarray(spec.host_sw)
        self._q_sw = jnp.asarray(spec.q_sw)
        self._up_base = jnp.asarray(spec.up_base)
        self._up_deg = jnp.asarray(spec.up_deg)
        self._down_next = jnp.asarray(spec.down_next)
        self._salt = jnp.asarray(spec.salt)

    @staticmethod
    def build(cfg: SimConfig) -> "TableTopology":
        from repro.netsim.topogen import build_spec

        return TableTopology(cfg, build_spec(cfg.fabric))

    @property
    def diameter(self) -> int:
        """Max queue hops on any src->dst path (host downlink included)."""
        return self.spec.diameter

    # -- helpers for benchmarks / tests (numpy, not jitted) ----------------
    def t0_up_queues(self, tor: int) -> np.ndarray:
        base, size = (int(v) for v in self.spec.sw_up_span[tor])
        return np.arange(size) + base

    def t0_down_queue(self, host: int) -> int:
        return self.t0_down_base + host

    def is_final_hop(self, q: jax.Array) -> jax.Array:
        return q >= self.t0_down_base

    # -- the hop-transition function (jit-traceable) ------------------------
    def next_queue(
        self,
        at_injection: jax.Array,
        cur_queue: jax.Array,
        flow_id: jax.Array,
        ev: jax.Array,
        src: jax.Array,
        dst: jax.Array,
        q_len: jax.Array,
        adaptive: bool,
    ) -> jax.Array:
        NH, NQ, NS = self.cfg.n_hosts, self.n_queues, self.spec.n_switches
        sw = jnp.where(
            at_injection,
            self._host_sw[jnp.clip(src, 0, NH - 1)],
            self._q_sw[jnp.clip(cur_queue, 0, NQ - 1)],
        )
        # garbage lanes (padded arrivals, final-hop queues) clip to a real
        # switch; their outputs are masked off by the caller's a_valid
        sw = jnp.clip(sw, 0, NS - 1)
        dstc = jnp.clip(dst, 0, NH - 1)
        down_q = self._down_next[sw, dstc]
        base = self._up_base[sw, dstc]
        deg = self._up_deg[sw]
        choice = ecmp_hash(
            flow_id, ev, self._salt[sw], jnp.maximum(deg, 1)
        )
        if adaptive:
            maxd = max(self.spec.max_up_deg, 1)
            cand = base[:, None] + jnp.arange(maxd, dtype=jnp.int32)
            lens = q_len[jnp.clip(cand, 0, NQ - 1)]
            lens = jnp.where(
                jnp.arange(maxd, dtype=jnp.int32)[None, :] < deg[:, None],
                lens,
                jnp.int32(2**30),
            )
            choice = jnp.argmin(lens, axis=1).astype(jnp.int32)
        nxt = jnp.where(down_q >= 0, down_q, base + choice)
        return nxt.astype(jnp.int32)
