"""Batched fleet execution: one compiled scan over a vmapped tick.

A sweep in the paper's evaluation style runs the *same scenario structure*
(topology, workload, load balancer, failure schedule) under many seeds or
dynamic-state variants.  Executing those serially recompiles nothing but
still pays the full per-tick dispatch cost per run; ``FleetRunner`` instead
vmaps the engine's pure ``Simulator._step`` over the per-run axis, so an
entire sweep advances in a single ``lax.scan`` — per-tick fixed costs are
amortized across the whole fleet.

Because ``vmap`` preserves per-row semantics exactly, each row of a fleet
run is bit-identical to the corresponding serial ``Simulator(seed=s)`` run
(asserted by tests/test_fleet.py).

Example:

    fleet = FleetRunner(cfg, wl, make_lb("reps"), seeds=range(8))
    states, traces = fleet.run(4000)        # leading axis = seed
    for s in fleet.summaries(states): ...   # per-seed RunSummary
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.load_balancers import LoadBalancer
from repro.netsim.config import SimConfig
from repro.netsim.engine import FailureSchedule, Simulator, SimState, Workload
from repro.netsim.metrics import RunSummary, summarize


class FleetRunner:
    """Runs one scenario structure under a batch of seeds in lock-step."""

    def __init__(
        self,
        cfg: SimConfig,
        workload: Workload,
        lb: LoadBalancer,
        failures: FailureSchedule | None = None,
        watch_queues=None,
        seeds: Sequence[int] = (0,),
    ):
        self.seeds = tuple(int(s) for s in seeds)
        assert self.seeds, "need at least one seed"
        self.sim = Simulator(
            cfg, workload, lb, failures=failures, watch_queues=watch_queues,
            seed=self.seeds[0],
        )

    @property
    def n_runs(self) -> int:
        return len(self.seeds)

    # ------------------------------------------------------------------
    def base_keys(self) -> jax.Array:
        return jnp.stack([jax.random.PRNGKey(s) for s in self.seeds])

    def init_states(self) -> SimState:
        """Per-seed initial states, stacked on a leading fleet axis."""
        return jax.vmap(self.sim.init_state)(self.base_keys())

    # ------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=(0, 1))
    def _run(self, n_ticks: int, keys: jax.Array, states: SimState):
        step = jax.vmap(self.sim._step, in_axes=(0, None, 0))

        def tick(carry, t):
            return step(carry, t, keys)

        ticks = jnp.arange(n_ticks, dtype=jnp.int32)
        return jax.lax.scan(tick, states, ticks)

    def run(self, n_ticks: int, states: SimState | None = None):
        """Advance the whole fleet n_ticks; returns (states, traces) with a
        leading fleet axis (traces: (n_ticks, n_runs, ...))."""
        if states is None:
            states = self.init_states()
        return self._run(n_ticks, self.base_keys(), states)

    # ------------------------------------------------------------------
    def state_at(self, states: SimState, i: int) -> SimState:
        """Slice run i's SimState out of the stacked fleet state."""
        return jax.tree_util.tree_map(lambda x: x[i], states)

    def summaries(self, states: SimState, name: str | None = None) -> list[RunSummary]:
        # one device_get for the whole stacked state — summarize() touches
        # many leaves per run, and slicing device arrays per run costs
        # O(n_runs * n_leaves) host round-trips
        host_states = jax.device_get(states)
        return [
            summarize(
                self.sim,
                jax.tree_util.tree_map(lambda x, i=i: x[i], host_states),
                name=name,
            )
            for i in range(self.n_runs)
        ]
