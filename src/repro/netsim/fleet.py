"""Batched fleet execution: one compiled scan over a vmapped tick.

A sweep in the paper's evaluation style runs the *same scenario structure*
(topology, workload, load balancer, failure schedule) under many seeds or
dynamic-state variants.  Executing those serially recompiles nothing but
still pays the full per-tick dispatch cost per run; ``FleetRunner`` instead
vmaps the engine's pure ``Simulator._step`` over the per-run axis, so an
entire sweep advances in a single ``lax.scan`` — per-tick fixed costs are
amortized across the whole fleet.

Because ``vmap`` preserves per-row semantics exactly, each row of a fleet
run is bit-identical to the corresponding serial ``Simulator(seed=s)`` run
(asserted by tests/test_fleet.py).

Example:

    fleet = FleetRunner(cfg, wl, make_lb("reps"), seeds=range(8))
    states, traces = fleet.run(4000)        # leading axis = seed
    for s in fleet.summaries(states): ...   # per-seed RunSummary
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.load_balancers import LoadBalancer
from repro.netsim.config import SimConfig
from repro.netsim.engine import FailureSchedule, Simulator, SimState, Workload
from repro.netsim.metrics import RunSummary, summarize, summarize_sketch
from repro.netsim.telemetry import TelemetrySpec


class FleetRunner:
    """Runs one scenario structure under a batch of seeds in lock-step.

    ``kernels_backend`` (optional) pins the engine's segment-rank /
    segment-sum hot-spot backend for this fleet — same contract as
    ``SweepEngine(kernels_backend=...)`` / ``SimConfig.kernels_backend``:
    the Pallas kernels sit inside the vmapped tick, so the per-seed row
    axis batches them into one launch per tick; ``None`` keeps the
    config's own setting.  Backends are bit-identical, so flipping it
    never changes any row's results.
    """

    def __init__(
        self,
        cfg: SimConfig,
        workload: Workload,
        lb: LoadBalancer,
        failures: FailureSchedule | None = None,
        watch_queues=None,
        seeds: Sequence[int] = (0,),
        kernels_backend: str | None = None,
    ):
        self.seeds = tuple(int(s) for s in seeds)
        assert self.seeds, "need at least one seed"
        if kernels_backend is not None:
            from repro.distrib.sharding import resolve_kernels_backend

            cfg = cfg.replace(
                kernels_backend=resolve_kernels_backend(kernels_backend)
            )
        self.sim = Simulator(
            cfg, workload, lb, failures=failures, watch_queues=watch_queues,
            seed=self.seeds[0],
        )
        # (spec, n_ticks) -> TelemetryProgram: _run_summary treats the
        # program as a static (identity-hashed) jit arg, so reusing one
        # instance per spec keeps repeated run_summary calls on one compile
        self._tel_progs: dict = {}

    @property
    def n_runs(self) -> int:
        return len(self.seeds)

    # ------------------------------------------------------------------
    def base_keys(self) -> jax.Array:
        return jnp.stack([jax.random.PRNGKey(s) for s in self.seeds])

    def init_states(self) -> SimState:
        """Per-seed initial states, stacked on a leading fleet axis."""
        return jax.vmap(self.sim.init_state)(self.base_keys())

    # ------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=(0, 1))
    def _run(self, n_ticks: int, keys: jax.Array, states: SimState):
        step = jax.vmap(self.sim._step, in_axes=(0, None, 0))

        def tick(carry, t):
            return step(carry, t, keys)

        ticks = jnp.arange(n_ticks, dtype=jnp.int32)
        return jax.lax.scan(tick, states, ticks)

    def run(self, n_ticks: int, states: SimState | None = None):
        """Advance the whole fleet n_ticks; returns (states, traces) with a
        leading fleet axis (traces: (n_ticks, n_runs, ...))."""
        if states is None:
            states = self.init_states()
        return self._run(n_ticks, self.base_keys(), states)

    # ------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=(0, 1, 2))
    def _run_summary(
        self, n_ticks: int, prog, keys: jax.Array, states: SimState,
        tel: jax.Array, t0: jax.Array,
    ):
        step = jax.vmap(self.sim.step_probe, in_axes=(0, None, 0, None))
        update = jax.vmap(prog.update)

        def tick(carry, t):
            st, tl = carry
            new_st, probe = step(st, t, keys, self.sim.scn)
            return (new_st, update(tl, probe)), None

        ticks = t0 + jnp.arange(n_ticks, dtype=jnp.int32)
        (states, tel), _ = jax.lax.scan(tick, (states, tel), ticks)
        return states, tel

    def run_summary(
        self,
        n_ticks: int,
        spec: TelemetrySpec | None = None,
        states: SimState | None = None,
        tel: jax.Array | None = None,
        t0: int = 0,
        horizon: int | None = None,
    ) -> tuple[SimState, "FleetTelemetry"]:
        """The single-scenario summary path: advance the fleet with the
        spec's sketch channels reduced on device (``collect="summary"`` of
        the sweep engine, same ``TelemetrySpec`` grammar).  Returns the
        stacked final states plus a ``FleetTelemetry`` view — no per-tick
        trace ever exists, so host traffic is O(seeds × bins).

        Chunked resume: pass the previous call's ``states`` and
        ``telemetry.tel`` back in together with ``t0`` (ticks already run)
        and the pinned total ``horizon`` — the concatenation of chunked
        calls is bit-identical to one uninterrupted call, because the scan
        sees the same absolute tick values and the same sketch layout.
        ``horizon`` defaults to ``t0 + n_ticks`` (the one-shot case)."""
        spec = spec or TelemetrySpec.default()
        horizon = int(horizon if horizon is not None else t0 + n_ticks)
        key = (spec, horizon)
        if key not in self._tel_progs:
            self._tel_progs[key] = spec.build(self.sim, horizon)
        prog = self._tel_progs[key]
        if states is None:
            states = self.init_states()
        if tel is None:
            tel = jnp.tile(prog.init()[None], (self.n_runs, 1))
        states, tel = self._run_summary(
            n_ticks, prog, self.base_keys(), states, jnp.asarray(tel),
            jnp.asarray(t0, jnp.int32),
        )
        return states, FleetTelemetry(
            self, prog, jax.device_get(tel), min(horizon, t0 + int(n_ticks))
        )

    # ------------------------------------------------------------------
    def state_at(self, states: SimState, i: int) -> SimState:
        """Slice run i's SimState out of the stacked fleet state."""
        return jax.tree_util.tree_map(lambda x: x[i], states)

    def summaries(self, states: SimState, name: str | None = None) -> list[RunSummary]:
        # one device_get for the whole stacked state — summarize() touches
        # many leaves per run, and slicing device arrays per run costs
        # O(n_runs * n_leaves) host round-trips
        host_states = jax.device_get(states)
        return [
            summarize(
                self.sim,
                jax.tree_util.tree_map(lambda x, i=i: x[i], host_states),
                name=name,
            )
            for i in range(self.n_runs)
        ]


class FleetTelemetry:
    """Host-side view of a fleet's stacked telemetry sketches: one finalized
    channel dict per seed, plus sketch-built ``RunSummary`` rows (counters,
    completions, runtime and mean FCT bit-identical to the state path)."""

    def __init__(self, fleet: FleetRunner, prog, tel, n_ticks: int):
        self.fleet = fleet
        self.prog = prog
        self.tel = tel  # (n_runs, size) int32
        self.n_ticks = n_ticks

    @property
    def nbytes_per_run(self) -> int:
        return self.prog.nbytes

    def result(self, i: int = 0) -> dict:
        return self.prog.finalize_row(self.tel[i], self.n_ticks)

    def summaries(self, name: str | None = None) -> list[RunSummary]:
        sim = self.fleet.sim
        return [
            summarize_sketch(
                self.result(i),
                name=name or sim.wl.name,
                lb_name=sim.lb.name,
                n_conns=sim.wl.n_conns,
            )
            for i in range(self.fleet.n_runs)
        ]
