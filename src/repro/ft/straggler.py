"""Straggler mitigation: the REPS cache-good-paths insight applied to slow
workers/channels.

A straggling DCN channel (or a slow host NIC behind it) manifests as
persistently ECN-marked (latency-above-threshold) chunk completions; the
REPS scheduler simply stops recycling it — no explicit blacklist, no per-
channel statistics (paper §3.3: track only good paths).  This module adds
the monitoring half: an EWMA latency tracker that converts completion
latencies into the ECN analogue fed to RepsChannelScheduler, plus step-time
watchdogs for the training loop.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class LatencyECN:
    """Maps per-chunk latencies to ECN marks via an adaptive threshold."""
    factor: float = 1.5  # mark if latency > factor * EWMA
    alpha: float = 0.1
    ewma_us: float = 0.0

    def mark(self, latencies_us: np.ndarray) -> np.ndarray:
        out = np.zeros(len(latencies_us), bool)
        for i, l in enumerate(latencies_us):
            if self.ewma_us == 0.0:
                self.ewma_us = float(l)
            out[i] = l > self.factor * self.ewma_us
            self.ewma_us = (1 - self.alpha) * self.ewma_us + self.alpha * float(l)
        return out


@dataclasses.dataclass
class StepWatchdog:
    """Detects straggling steps (e.g. a failing host slowing the collective)
    and reports when recovery action (freeze + re-route, checkpoint restart)
    should fire."""
    factor: float = 3.0
    alpha: float = 0.2
    ewma_s: float = 0.0
    slow_steps: int = 0
    trigger_after: int = 3

    def observe(self, step_seconds: float) -> bool:
        if self.ewma_s == 0.0:
            self.ewma_s = step_seconds
        slow = step_seconds > self.factor * self.ewma_s
        self.slow_steps = self.slow_steps + 1 if slow else 0
        self.ewma_s = (1 - self.alpha) * self.ewma_s + self.alpha * step_seconds
        return self.slow_steps >= self.trigger_after


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
