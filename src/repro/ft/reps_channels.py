"""REPS as a first-class feature of the distributed runtime (DESIGN.md §3).

Multi-pod training reduces gradients across pods over the datacenter
Ethernet fabric (DCN) — exactly the multipath domain the paper targets.
This module applies REPS at that layer: gradient buckets are chunked across
parallel DCN *channels* (the EV space); per-chunk completion feedback plays
the role of ACKs (a congested channel's latency-above-threshold is the ECN
analogue, which doubles as straggler mitigation), chunk timeouts play the
role of failure detection and trigger freezing mode.

The scheduler is the *unmodified* `repro.core.reps` state machine — the
same code validated against the paper's pseudocode — driving channel choice
for every chunk.  `ChannelSim` models the DCN channel pool (capacities,
congestion, failure windows) so the behaviour is testable and demoable on
CPU (examples/failover_demo.py); on a real deployment the same scheduler
would consume completion timestamps from the collective runtime.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reps as reps_core


@dataclasses.dataclass
class ChannelSimConfig:
    n_channels: int = 16
    base_latency_us: float = 50.0
    congestion_latency_us: float = 400.0  # when oversubscribed
    ecn_threshold_us: float = 120.0
    timeout_us: float = 1000.0
    capacity_chunks: int = 4  # chunks per channel per round at base latency


class ChannelSim:
    """Round-based DCN channel model with failure/degradation windows."""

    def __init__(self, cfg: ChannelSimConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.RandomState(seed)
        self.failed = np.zeros(cfg.n_channels, bool)
        self.degraded = np.zeros(cfg.n_channels, bool)

    def set_failed(self, channels, failed=True):
        self.failed[np.asarray(channels)] = failed

    def set_degraded(self, channels, degraded=True):
        self.degraded[np.asarray(channels)] = degraded

    def round(self, chunk_channels: np.ndarray):
        """Send one chunk per entry over its channel; returns per-chunk
        (latency_us, ecn, timed_out)."""
        cfg = self.cfg
        counts = np.bincount(chunk_channels, minlength=cfg.n_channels)
        lat = np.empty(len(chunk_channels), np.float64)
        ecn = np.zeros(len(chunk_channels), bool)
        timeout = np.zeros(len(chunk_channels), bool)
        for i, ch in enumerate(chunk_channels):
            if self.failed[ch]:
                timeout[i] = True
                lat[i] = cfg.timeout_us
                continue
            cap = cfg.capacity_chunks // (2 if self.degraded[ch] else 1)
            load = counts[ch] / max(cap, 1)
            base = cfg.base_latency_us * (2 if self.degraded[ch] else 1)
            lat[i] = base + max(0.0, load - 1.0) * cfg.congestion_latency_us
            lat[i] *= 1.0 + 0.05 * self.rng.rand()
            ecn[i] = lat[i] > cfg.ecn_threshold_us
        return lat, ecn, timeout


class RepsChannelScheduler:
    """Drives chunk→channel assignment with the paper's algorithm."""

    def __init__(
        self,
        n_channels: int,
        buffer_size: int = 8,
        num_pkts_bdp: int = 8,
        freezing_timeout_rounds: int = 4,
        seed: int = 0,
    ):
        self.cfg = reps_core.REPSConfig(
            buffer_size=buffer_size,
            evs_size=n_channels,  # the EV space IS the channel pool
            num_pkts_bdp=num_pkts_bdp,
            freezing_timeout=freezing_timeout_rounds,
        )
        self.state = reps_core.init_state(self.cfg, 1)
        self.key = jax.random.PRNGKey(seed)
        self.round_idx = 0

    def assign(self, n_chunks: int) -> np.ndarray:
        """Pick a channel for each chunk of this round (sequential pops from
        the REPS buffer — the send datapath, Algorithm 2)."""
        chosen = np.empty(n_chunks, np.int32)
        mask = jnp.ones((1,), jnp.bool_)
        for i in range(n_chunks):
            self.key, sub = jax.random.split(self.key)
            ev, self.state = reps_core.choose_ev(self.cfg, self.state, mask, sub)
            chosen[i] = int(ev[0])
        return chosen

    def feedback(self, channels: np.ndarray, ecn: np.ndarray, timeout: np.ndarray):
        """ACK/timeout ingestion (Algorithm 1) for each completed chunk."""
        now = jnp.int32(self.round_idx)
        mask = jnp.ones((1,), jnp.bool_)
        for ch, e, to in zip(channels, ecn, timeout):
            if to:
                self.state = reps_core.on_failure_detection(
                    self.cfg, self.state, mask, now
                )
            else:
                self.state = reps_core.on_ack(
                    self.cfg,
                    self.state,
                    mask,
                    jnp.asarray([int(ch)], jnp.int32),
                    jnp.asarray([bool(e)]),
                    now,
                )
        self.round_idx += 1

    @property
    def is_freezing(self) -> bool:
        return bool(self.state.is_freezing[0])


@dataclasses.dataclass
class ReduceReport:
    rounds: int
    total_latency_us: float
    p99_chunk_latency_us: float
    timeouts: int
    ecn_marked: int


def run_cross_pod_reduce(
    scheduler,
    sim: ChannelSim,
    n_chunks_total: int,
    chunks_per_round: int,
) -> ReduceReport:
    """Simulate a bucketed cross-pod gradient reduction: chunks stream in
    rounds; a round's makespan is its slowest chunk (collective semantics);
    timed-out chunks are retransmitted."""
    remaining = n_chunks_total
    total_lat = 0.0
    lats: list[float] = []
    timeouts = ecn_total = rounds = 0
    while remaining > 0:
        n = min(chunks_per_round, remaining)
        chans = scheduler.assign(n)
        lat, ecn, to = sim.round(chans)
        scheduler.feedback(chans, ecn, to)
        done = int(np.sum(~to))
        remaining -= done
        timeouts += int(np.sum(to))
        ecn_total += int(np.sum(ecn & ~to))
        total_lat += float(np.max(lat))
        lats.extend(lat[~to].tolist() if done else [float(np.max(lat))])
        rounds += 1
        if rounds > 100 * (n_chunks_total // chunks_per_round + 1):
            break  # safety
    return ReduceReport(
        rounds=rounds,
        total_latency_us=total_lat,
        p99_chunk_latency_us=float(np.percentile(lats, 99)) if lats else 0.0,
        timeouts=timeouts,
        ecn_marked=ecn_total,
    )


class OpsChannelScheduler:
    """Oblivious baseline: uniform random channel per chunk."""

    def __init__(self, n_channels: int, seed: int = 0):
        self.n = n_channels
        self.rng = np.random.RandomState(seed)
        self.round_idx = 0

    def assign(self, n_chunks: int) -> np.ndarray:
        return self.rng.randint(0, self.n, n_chunks).astype(np.int32)

    def feedback(self, channels, ecn, timeout):
        self.round_idx += 1
