from repro.ft import reps_channels, straggler
from repro.ft.reps_channels import (
    ChannelSim,
    ChannelSimConfig,
    OpsChannelScheduler,
    RepsChannelScheduler,
    run_cross_pod_reduce,
)
from repro.ft.straggler import LatencyECN, StepWatchdog

__all__ = [
    "reps_channels", "straggler", "ChannelSim", "ChannelSimConfig",
    "OpsChannelScheduler", "RepsChannelScheduler", "run_cross_pod_reduce",
    "LatencyECN", "StepWatchdog",
]
