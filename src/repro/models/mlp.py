"""Feed-forward layers: gated dense MLP and the expert-parallel MoE.

MoE design (DESIGN.md §5): tokens are replicated across the model axis
between blocks (standard TP residual stream), experts are sharded over the
model axis.  Each expert shard therefore dispatches *locally* — it selects,
from the tokens it already holds, those routed to its own experts; no
dispatch collective is needed, and the combine is the same single psum that
Megatron-style TP FFN layers already pay.  Capacity-bounded (GShard-style
"dropping"): per shard, each expert accepts up to
ceil(T_local * top_k / n_experts * capacity) tokens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distrib.sharding import active_mesh, resolve_spec, shard
from repro.models.common import act_fn, dense_init, split_keys
from repro.utils import compat


def init_mlp_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "w1": dense_init(ks[0], (d, f), d, dtype),  # gate
        "w3": dense_init(ks[1], (d, f), d, dtype),  # up
        "w2": dense_init(ks[2], (f, d), f, dtype),  # down
    }


def mlp(x, p, cfg: ModelConfig):
    act = act_fn(cfg.act)
    h = act(jnp.einsum("bsd,df->bsf", x, p["w1"])) * jnp.einsum(
        "bsd,df->bsf", x, p["w3"]
    )
    h = shard(h, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    return shard(y, "batch", "seq", None)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def init_moe_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), d, dtype),
        "w1": dense_init(ks[1], (E, d, f), d, dtype),
        "w3": dense_init(ks[2], (E, d, f), d, dtype),
        "w2": dense_init(ks[3], (E, f, d), f, dtype),
    }


def _moe_local(x, p, cfg: ModelConfig, n_shards: int, shard_idx):
    """Per-shard MoE math. x: (b_loc, S, d); p holds this shard's experts
    (E_loc, ...) plus the full (replicated) router."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = p["w1"].shape[0]
    act = act_fn(cfg.act)
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    weights, ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)  # (T,k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # auxiliary load-balance loss (computed identically on every shard)
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # local expert range for this shard
    lo = shard_idx * E_loc
    ids_l = ids - lo  # (T, k), valid iff in [0, E_loc)
    in_range = (ids_l >= 0) & (ids_l < E_loc)
    flat_ids = jnp.where(in_range, ids_l, E_loc).reshape(-1)  # (T*k,)

    # capacity floor matters at decode (T small): never drop when T*k is tiny
    cap = max(int((T * k / E) * cfg.moe_capacity) + 1, min(T * k, 32))
    onehot = jax.nn.one_hot(flat_ids, E_loc, dtype=jnp.int32)  # (T*k, E_loc)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)  # position within expert
    my_pos = jnp.take_along_axis(
        pos, jnp.minimum(flat_ids, E_loc - 1)[:, None], axis=1
    )[:, 0]
    keep = in_range.reshape(-1) & (my_pos < cap)

    # Gather-based dispatch (EXPERIMENTS.md §Perf iter 3): scatter only the
    # *assignment indices* into the (E_loc, cap) slot map, then build the
    # expert buffer with a gather.  The combine is a reshape + weighted sum
    # — no (T*k, d)-sized scatter anywhere, which removes the per-element
    # u32 scatter-index tensors XLA materializes for big scatters and keeps
    # the whole path in the compute dtype.
    A = T * k
    tok_of = jnp.repeat(jnp.arange(T), k)
    e_idx = jnp.where(keep, flat_ids, E_loc)  # E_loc = drop row
    slot_src = jnp.full((E_loc + 1, cap), A, jnp.int32)
    slot_src = slot_src.at[e_idx, jnp.where(keep, my_pos, 0)].set(
        jnp.arange(A, dtype=jnp.int32), mode="drop"
    )
    slot_src = slot_src[:E_loc]  # (E_loc, cap); A = empty slot
    slot_tok = jnp.where(slot_src < A, tok_of[jnp.minimum(slot_src, A - 1)], T)
    buf = jnp.where(
        (slot_src < A)[..., None],
        xt[jnp.minimum(slot_tok, T - 1)],
        jnp.zeros((), xt.dtype),
    )  # (E_loc, cap, d)

    h = act(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w3"]
    )
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"])  # (E_loc, cap, d)

    # combine: gather each assignment's expert output, weighted sum over k
    y_asg = y[jnp.minimum(e_idx, E_loc - 1), jnp.where(keep, my_pos, 0)]
    w_flat = jnp.where(keep, weights.reshape(-1), 0.0).astype(y.dtype)
    out = (y_asg * w_flat[:, None]).reshape(T, k, d).sum(axis=1)
    return out.reshape(B, S, d), aux


def moe(x, p, cfg: ModelConfig):
    """Expert-parallel MoE. Returns (y, aux_loss)."""
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        y, aux = _moe_local(x, p, cfg, 1, 0)
        return y, aux

    tok_spec = resolve_spec(("batch", None, None))
    # FSDP-style secondary sharding of expert FFN dims over the data axis
    # (rules key "moe_fsdp"): weights are stored (model, data)-sharded and
    # all-gathered per layer at use — ZeRO-3 for the expert store.
    w13_spec = resolve_spec(("experts", None, "moe_fsdp"))
    w2_spec = resolve_spec(("experts", "moe_fsdp", None))
    fsdp = "data" in jax.tree.leaves(w13_spec)
    exp_spec = {
        "router": P(),
        "w1": w13_spec,
        "w3": w13_spec,
        "w2": w2_spec,
    }
    n_shards = mesh.shape["model"]
    assert cfg.n_experts % n_shards == 0, (
        f"{cfg.n_experts} experts not divisible by model={n_shards}"
    )

    def local_fn(x_loc, p_loc):
        idx = jax.lax.axis_index("model")
        if fsdp:
            p_loc = dict(
                p_loc,
                w1=jax.lax.all_gather(p_loc["w1"], "data", axis=2, tiled=True),
                w3=jax.lax.all_gather(p_loc["w3"], "data", axis=2, tiled=True),
                w2=jax.lax.all_gather(p_loc["w2"], "data", axis=1, tiled=True),
            )
        y, aux = _moe_local(x_loc, p_loc, cfg, n_shards, idx)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")
        return y, aux

    fn = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(tok_spec, exp_spec),
        out_specs=(tok_spec, P()),
        check_vma=False,
    )
    return fn(x, p)
