"""Recurrent-family models: RWKV6 (attention-free) and Zamba2 (Mamba2
backbone + one shared attention block applied periodically).

Both are state-based at decode: the "KV cache" is a fixed-size recurrent
state, which is why these two architectures run the long_500k cell
(DESIGN.md §4).  Zamba2's shared attention block keeps a bounded sliding
KV window (ring buffer) so its cache is O(window), not O(context).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distrib.sharding import shard
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import ssm
from repro.models.common import apply_rope, dense_init, rms_norm, split_keys

Params = dict[str, Any]


# ===========================================================================
# RWKV6
# ===========================================================================
def rwkv_init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = split_keys(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[i])
        layers.append(
            {
                "norm1": jnp.ones((cfg.d_model,), dtype),
                "norm2": jnp.ones((cfg.d_model,), dtype),
                "tmix": ssm.init_rwkv_tmix_params(k1, cfg, dtype),
                "cmix": ssm.init_rwkv_cmix_params(k2, cfg, dtype),
            }
        )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed": dense_init(ks[-3], (cfg.vocab, cfg.d_model), cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[-2], (cfg.d_model, cfg.vocab), cfg.d_model, dtype),
    }


def rwkv_param_axes(cfg: ModelConfig):
    layer = {
        "norm1": (None,),
        "norm2": (None,),
        "tmix": {
            "mu": (None, "embed"),
            "wr": ("embed", "state"),
            "wk": ("embed", "state"),
            "wv": ("embed", "state"),
            "wg": ("embed", "state"),
            "wo": ("state", "embed"),
            "w0": ("state",),
            "wa": (None, None),
            "wb": (None, "state"),
            "u": ("heads", None),
            "ln_w": ("state",),
        },
        "cmix": {
            "mu": (None, "embed"),
            "wk": ("embed", "mlp"),
            "wv": ("mlp", "embed"),
            "wr": ("embed", None),
        },
    }
    stacked = jax.tree.map(
        lambda ax: (None, *ax), layer, is_leaf=lambda x: isinstance(x, tuple)
    )
    return {
        "embed": ("vocab", "embed"),
        "layers": stacked,
        "final_norm": ("embed",),
        "lm_head": ("embed", "vocab"),
    }


def rwkv_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, K = cfg.n_heads, cfg.head_dim
    return {
        "wkv": jnp.zeros((cfg.n_layers, batch, H, K, K), dtype),
        "tshift1": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
        "tshift2": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
    }


def rwkv_forward(params: Params, cfg: ModelConfig, batch: dict, state=None,
                 remat: bool = False):
    """Returns (logits, aux=0, new_state). state=None -> zeros."""
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = shard(x, "batch", "seq", None)
    B = x.shape[0]
    if state is None:
        state = rwkv_state_init(cfg, B, jnp.float32)

    def layer_fn(x, inp):
        p, wkv0, ts1, ts2 = inp
        h = rms_norm(x, p["norm1"])
        a, (last1, wkv1) = ssm.rwkv_tmix(h, ts1, p["tmix"], cfg, wkv0)
        x = x + a
        h = rms_norm(x, p["norm2"])
        m, last2 = ssm.rwkv_cmix(h, ts2, p["cmix"])
        x = x + m
        return x, (wkv1, last1, last2)

    if remat:
        layer_fn = jax.checkpoint(layer_fn)
    x, (wkv, ts1, ts2) = jax.lax.scan(
        layer_fn, x, (params["layers"], state["wkv"], state["tshift1"], state["tshift2"])
    )
    h = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    logits = shard(logits, "batch", "seq", "vocab")
    new_state = {"wkv": wkv, "tshift1": ts1, "tshift2": ts2}
    return logits, jnp.float32(0.0), new_state


# ===========================================================================
# Zamba2: mamba2 backbone + shared attention block every `period` layers
# ===========================================================================
def zamba_init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = split_keys(key, cfg.n_layers + 5)
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {
                "norm": jnp.ones((cfg.d_model,), dtype),
                "mamba": ssm.init_mamba_params(ks[i], cfg, dtype),
            }
        )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    k1, k2 = jax.random.split(ks[-4])
    shared = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attn_params(k1, cfg, dtype),
        "mlp": mlp_mod.init_mlp_params(k2, cfg, dtype),
    }
    return {
        "embed": dense_init(ks[-3], (cfg.vocab, cfg.d_model), cfg.d_model, dtype),
        "layers": stacked,
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def zamba_param_axes(cfg: ModelConfig):
    layer = {
        "norm": (None,),
        "mamba": {
            "in_x": ("embed", "state"),
            "in_z": ("embed", "state"),
            "in_bc": ("embed", None),
            "in_dt": ("embed", "heads"),
            "dt_bias": ("heads",),
            "a_log": ("heads",),
            "d_skip": ("heads",),
            "conv_w": (None, "state"),
            "out": ("state", "embed"),
        },
    }
    stacked = jax.tree.map(
        lambda ax: (None, *ax), layer, is_leaf=lambda x: isinstance(x, tuple)
    )
    return {
        "embed": ("vocab", "embed"),
        "layers": stacked,
        "shared": {
            "norm1": ("embed",),
            "norm2": ("embed",),
            "attn": {
                "wq": ("embed", "heads", "head_dim"),
                "wk": ("embed", "kv_heads", "head_dim"),
                "wv": ("embed", "kv_heads", "head_dim"),
                "wo": ("heads", "head_dim", "embed"),
            },
            "mlp": {
                "w1": ("embed", "mlp"),
                "w3": ("embed", "mlp"),
                "w2": ("mlp", "embed"),
            },
        },
        "final_norm": ("embed",),
    }


def _n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_period


def zamba_state_init(cfg: ModelConfig, batch: int, window: int,
                     dtype=jnp.float32):
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.ssm_state
    d_in = H * P
    G = _n_groups(cfg)
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, N, P), dtype),
        "conv": jnp.zeros(
            (cfg.n_layers, batch, ssm.CONV_W - 1, d_in + 2 * N), dtype
        ),
        "k": jnp.zeros((G, batch, window, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": jnp.zeros((G, batch, window, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
    }


def _shared_block_train(x, p, cfg: ModelConfig, positions):
    h = rms_norm(x, p["norm1"])
    a = attn.attention_train(h, p["attn"], cfg, positions,
                             window=cfg.shared_attn_window)
    x = x + a
    h = rms_norm(x, p["norm2"])
    return x + mlp_mod.mlp(h, p["mlp"], cfg)


def zamba_forward(params: Params, cfg: ModelConfig, batch: dict,
                  remat: bool = False):
    """Training forward (states start at zero). Returns (logits, aux)."""
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = shard(x, "batch", "seq", None)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    period = cfg.shared_attn_period
    G = _n_groups(cfg)
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.ssm_state

    def mamba_layer(x, p):
        h = rms_norm(x, p["norm"])
        conv0 = jnp.zeros((B, ssm.CONV_W - 1, H * P + 2 * N), x.dtype)
        s0 = jnp.zeros((B, H, N, P), jnp.float32)
        y, _ = ssm.mamba_mixer(h, p["mamba"], cfg, conv0, s0)
        return x + y, None

    shared_block = _shared_block_train
    if remat:
        mamba_layer = jax.checkpoint(mamba_layer)
        shared_block = jax.checkpoint(
            _shared_block_train, static_argnums=(2,)
        )

    take = lambda tree, lo, hi: jax.tree.map(lambda a: a[lo:hi], tree)
    for g in range(G):
        grp = take(params["layers"], g * period, (g + 1) * period)
        x, _ = jax.lax.scan(mamba_layer, x, grp)
        x = shared_block(x, params["shared"], cfg, positions)
    rem = cfg.n_layers - G * period
    if rem:
        grp = take(params["layers"], G * period, cfg.n_layers)
        x, _ = jax.lax.scan(mamba_layer, x, grp)

    h = rms_norm(x, params["final_norm"])
    head = params["embed"].T.astype(h.dtype)
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return shard(logits, "batch", "seq", "vocab"), jnp.float32(0.0)


def zamba_prefill(params: Params, cfg: ModelConfig, batch: dict, window: int):
    """Forward over the prompt collecting final SSM/conv states and the
    shared-attention ring caches (last `window` positions). Returns
    (last_logits, state, cache_len)."""
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = shard(x, "batch", "seq", None)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    period = cfg.shared_attn_period
    G = _n_groups(cfg)
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.ssm_state
    W = min(window, S) if S < window else window

    def mamba_layer(x, p):
        h = rms_norm(x, p["norm"])
        conv0 = jnp.zeros((B, ssm.CONV_W - 1, H * P + 2 * N), x.dtype)
        s0 = jnp.zeros((B, H, N, P), jnp.float32)
        y, (conv1, s1) = ssm.mamba_mixer(h, p["mamba"], cfg, conv0, s0)
        return x + y, (conv1, s1)

    take = lambda tree, lo, hi: jax.tree.map(lambda a: a[lo:hi], tree)
    convs, ssms, ks, vs = [], [], [], []
    n_groups_total = G + (1 if cfg.n_layers > G * period else 0)
    for g in range(n_groups_total):
        lo, hi = g * period, min((g + 1) * period, cfg.n_layers)
        grp = take(params["layers"], lo, hi)
        x, (conv1, s1) = jax.lax.scan(mamba_layer, x, grp)
        convs.append(conv1)
        ssms.append(s1)
        if g < G:
            p = params["shared"]
            h = rms_norm(x, p["norm1"])
            q, k, v = attn._project_qkv(h, p["attn"], cfg, positions)
            q = shard(q, "batch", "seq", "heads", None)
            o = attn.flash_attention(
                q, k, v, positions, positions, window=cfg.shared_attn_window
            )
            a = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
            x = x + shard(a, "batch", "seq", None)
            h2 = rms_norm(x, p["norm2"])
            x = x + mlp_mod.mlp(h2, p["mlp"], cfg)
            # ring cache: keep the last `window` (rotated by position % W)
            tail_k = k[:, -W:].astype(jnp.bfloat16)
            tail_v = v[:, -W:].astype(jnp.bfloat16)
            tail_pos = positions[-W:] % window
            ck = jnp.zeros(
                (B, window, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16
            ).at[:, tail_pos].set(tail_k)
            cv = jnp.zeros_like(ck).at[:, tail_pos].set(tail_v)
            ks.append(shard(ck, "batch", "kv_seq", "kv_heads", None))
            vs.append(shard(cv, "batch", "kv_seq", "kv_heads", None))

    h = rms_norm(x, params["final_norm"])
    head = params["embed"].T.astype(h.dtype)
    logits = jnp.einsum("bsd,dv->bsv", h[:, -1:, :], head)
    state = {
        "ssm": jnp.concatenate(ssms, axis=0),
        "conv": jnp.concatenate(convs, axis=0),
        "k": jnp.stack(ks),
        "v": jnp.stack(vs),
    }
    return shard(logits, "batch", None, "vocab"), state, jnp.int32(S)


def zamba_decode_step(params: Params, cfg: ModelConfig, state, tokens,
                      cache_len, window: int):
    """One token through the hybrid stack with O(1)+O(window) state."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B,1,d)
    x = shard(x, "batch", None, None)
    B = x.shape[0]
    period = cfg.shared_attn_period
    G = _n_groups(cfg)

    def mamba_layer(x, inp):
        p, conv0, s0 = inp
        h = rms_norm(x, p["norm"])
        y, (conv1, s1) = ssm.mamba_mixer(h, p["mamba"], cfg, conv0, s0)
        return x + y, (conv1, s1)

    take = lambda tree, lo, hi: jax.tree.map(lambda a: a[lo:hi], tree)
    convs, ssms, ks, vs = [], [], [], []
    for g in range(G + (1 if cfg.n_layers > G * period else 0)):
        lo, hi = g * period, min((g + 1) * period, cfg.n_layers)
        grp = take(params["layers"], lo, hi)
        x, (conv1, s1) = jax.lax.scan(
            mamba_layer, x, (grp, state["conv"][lo:hi], state["ssm"][lo:hi])
        )
        convs.append(conv1)
        ssms.append(s1)
        if g < G:
            p = params["shared"]
            ck, cv = state["k"][g], state["v"][g]
            ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
            cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
            h = rms_norm(x, p["norm1"])
            # ring-buffer write at cache_len % window; RoPE uses the absolute
            # position so overwriting old slots is consistent.
            slot = cache_len % window
            pos = jnp.full((1,), cache_len, jnp.int32)
            k1 = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
            v1 = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
            if cfg.rope_theta:
                k1 = apply_rope(k1, pos, cfg.rope_theta)
            # masked select (not DUS): partitions cleanly along the sharded
            # sequence dim (see attention.decode_kv_update)
            sel = (jnp.arange(window) == slot)[None, :, None, None]
            ck = jnp.where(sel, k1.astype(ck.dtype), ck)
            cv = jnp.where(sel, v1.astype(cv.dtype), cv)
            # attend over valid ring slots (all, once wrapped)
            q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
            if cfg.rope_theta:
                q = apply_rope(q, pos, cfg.rope_theta)
            Hq, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            Gq = Hq // Hk
            qf = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))).reshape(
                B, Hk, Gq, hd
            )
            s = jnp.einsum("bkgh,bskh->bkgs", qf, ck.astype(jnp.float32))
            valid = (jnp.arange(window) <= cache_len)[None, None, None, :]
            s = jnp.where(valid | (cache_len >= window), s, attn.NEG_INF)
            w = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bkgs,bskh->bkgh", w, cv.astype(jnp.float32))
            o = o.reshape(B, 1, Hq, hd).astype(x.dtype)
            a = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
            x = x + a
            h2 = rms_norm(x, p["norm2"])
            x = x + mlp_mod.mlp(h2, p["mlp"], cfg)
            ks.append(ck)
            vs.append(cv)

    h = rms_norm(x, params["final_norm"])
    head = params["embed"].T.astype(h.dtype)
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    new_state = {
        "ssm": jnp.concatenate(ssms, axis=0),
        "conv": jnp.concatenate(convs, axis=0),
        "k": jnp.stack(ks),
        "v": jnp.stack(vs),
    }
    return shard(logits, "batch", None, "vocab"), new_state
