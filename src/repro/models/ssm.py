"""Attention-free mixers: RWKV6 ("Finch", data-dependent per-channel decay)
and Mamba2-style SSD (scalar-per-head decay) — both in chunked linear-
attention form for training, with O(1) recurrent state for decode.

Chunked form (chunk c, within-chunk cumulative log-decay logP_t):

    S_t = exp(logP_t) ⊙ S_0 + Σ_{s<=t} exp(logP_t - logP_s) ⊙ k_s^T v_s

All exponents are differences with t >= s, hence <= 0: no overflow, and
underflow maps to exactly the vanishing contribution it represents — the
standard stable formulation (cf. flash-linear-attention).

RWKV6 reads the state *before* the update plus a bonus term
(y_t = r_t·(S_{t-1} + diag(u) k_t^T v_t)); SSD reads after (y_t = C_t·h_t).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distrib.sharding import shard
from repro.models.common import dense_init, split_keys

LOG_DECAY_FLOOR = -8.0  # per-step clamp; exp(-8) ~ 3e-4 per step


# ---------------------------------------------------------------------------
# generic chunked scans
# ---------------------------------------------------------------------------
def chunked_rwkv(r, k, v, logw, u, state0, chunk: int = 16):
    """RWKV6 WKV. r,k,logw: (B,S,H,K); v: (B,S,H,V); u: (H,K);
    state0: (B,H,K,V). Returns (y (B,S,H,V), state (B,H,K,V))."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n = S // c
    f32 = jnp.float32
    # NOTE (§Perf iter 6, refuted): staging these views in bf16 and
    # upcasting inside the body was tried and measured WORSE (5.78s ->
    # 6.80s t_mem): the per-chunk f32 conversion materializes 256x/layer
    # instead of once.  f32 staging outside the scan stays.
    rr = r.astype(f32).reshape(B, n, c, H, K).transpose(1, 0, 2, 3, 4)
    kk = k.astype(f32).reshape(B, n, c, H, K).transpose(1, 0, 2, 3, 4)
    vv = v.astype(f32).reshape(B, n, c, H, V).transpose(1, 0, 2, 3, 4)
    lw = jnp.clip(logw.astype(f32), LOG_DECAY_FLOOR, 0.0)
    lw = lw.reshape(B, n, c, H, K).transpose(1, 0, 2, 3, 4)

    def body(S0, blk):
        rb, kb, vb, lwb = blk  # (B,c,H,K/V)
        logP = jnp.cumsum(lwb, axis=1)  # inclusive (B,c,H,K)
        # inter-chunk: y_t += (r_t * P_{t-1}) S0 ; P_{t-1} = P_t / w_t
        rP = rb * jnp.exp(logP - lwb)
        y = jnp.einsum("bthk,bhkv->bthv", rP, S0)
        # intra-chunk, strictly causal (s < t)
        D = jnp.exp(
            (logP - lwb)[:, :, None, :, :] - logP[:, None, :, :, :]
        )  # (B,t,s,H,K): P_{t-1}/P_s
        mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[
            None, :, :, None, None
        ]
        A = jnp.einsum("bthk,bshk,btshk->bths", rb, kb, jnp.where(mask, D, 0.0))
        y = y + jnp.einsum("bths,bshv->bthv", A, vb)
        # bonus (s == t)
        y = y + jnp.einsum("bthk,bthk,bthv->bthv", rb, u[None, None] * kb, vb)
        # state to end of chunk
        decay_to_end = jnp.exp(logP[:, -1:, :, :] - logP)  # (B,c,H,K)
        S1 = jnp.exp(logP[:, -1])[..., None] * S0 + jnp.einsum(
            "bshk,bshv->bhkv", kb * decay_to_end, vb
        )
        return S1, y

    state, ys = jax.lax.scan(body, state0.astype(f32), (rr, kk, vv, lw))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, V)
    return y.astype(r.dtype), state


def rwkv_step(r, k, v, logw, u, state):
    """Single-token recurrence. r,k,logw: (B,H,K); v: (B,H,V);
    state: (B,H,K,V)."""
    f32 = jnp.float32
    r, k, v = r.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(jnp.clip(logw.astype(f32), LOG_DECAY_FLOOR, 0.0))
    kv = k[..., :, None] * v[..., None, :]  # (B,H,K,V)
    y = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, ..., None] * kv)
    new_state = w[..., None] * state + kv
    return y, new_state


def chunked_ssd(r, k, v, loga, state0, chunk: int = 32):
    """Mamba2 SSD. r(C),k(B): (B,S,H,N); v(x): (B,S,H,P); loga: (B,S,H);
    state0: (B,H,N,P). y_t = C_t h_t (read AFTER update)."""
    B, S, H, N = r.shape
    P = v.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    n = S // c
    f32 = jnp.float32
    rr = r.astype(f32).reshape(B, n, c, H, N).transpose(1, 0, 2, 3, 4)
    kk = k.astype(f32).reshape(B, n, c, H, N).transpose(1, 0, 2, 3, 4)
    vv = v.astype(f32).reshape(B, n, c, H, P).transpose(1, 0, 2, 3, 4)
    la = jnp.clip(loga.astype(f32), LOG_DECAY_FLOOR, 0.0)
    la = la.reshape(B, n, c, H).transpose(1, 0, 2, 3)

    def body(S0, blk):
        rb, kb, vb, lab = blk
        logP = jnp.cumsum(lab, axis=1)  # (B,c,H)
        y = jnp.einsum("bthn,bhnp->bthp", rb * jnp.exp(logP)[..., None], S0)
        # D[b,t,h,s] = exp(logP_t - logP_s)
        D = jnp.exp(logP[:, :, :, None] - logP.transpose(0, 2, 1)[:, None, :, :])
        mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])[
            None, :, None, :
        ]
        A = jnp.einsum("bthn,bshn->bths", rb, kb) * jnp.where(mask, D, 0.0)
        y = y + jnp.einsum("bths,bshp->bthp", A, vb)
        decay_to_end = jnp.exp(logP[:, -1:, :] - logP)  # (B,c,H)
        S1 = jnp.exp(logP[:, -1])[..., None, None] * S0 + jnp.einsum(
            "bshn,bshp->bhnp", kb * decay_to_end[..., None], vb
        )
        return S1, y

    state, ys = jax.lax.scan(body, state0.astype(f32), (rr, kk, vv, la))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y.astype(r.dtype), state


def ssd_step(r, k, v, loga, state):
    """r,k: (B,H,N); v: (B,H,P); loga: (B,H); state: (B,H,N,P)."""
    f32 = jnp.float32
    a = jnp.exp(jnp.clip(loga.astype(f32), LOG_DECAY_FLOOR, 0.0))
    new_state = a[..., None, None] * state + k.astype(f32)[..., :, None] * v.astype(
        f32
    )[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", r.astype(f32), new_state)
    return y, new_state


# ---------------------------------------------------------------------------
# RWKV6 blocks
# ---------------------------------------------------------------------------
def init_rwkv_tmix_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H, K = cfg.n_heads, cfg.head_dim
    ks = split_keys(key, 8)
    lora = 64
    return {
        "mu": jnp.full((5, d), 0.5, dtype),  # lerp coeffs for r,k,v,g,w
        "wr": dense_init(ks[0], (d, d), d, dtype),
        "wk": dense_init(ks[1], (d, d), d, dtype),
        "wv": dense_init(ks[2], (d, d), d, dtype),
        "wg": dense_init(ks[3], (d, d), d, dtype),
        "wo": dense_init(ks[4], (d, d), d, dtype),
        "w0": jnp.full((d,), -2.0, dtype),  # base log-log decay
        "wa": dense_init(ks[5], (d, 64), d, dtype),
        "wb": dense_init(ks[6], (lora, d), lora, dtype) * 0.1,
        "u": dense_init(ks[7], (H, K), K, dtype),
        "ln_w": jnp.ones((d,), dtype),
    }


def _token_shift(x, prev):
    """prev: (B,1,d) last token of the previous segment (zeros at start)."""
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def rwkv_tmix(x, prev_tok, p, cfg: ModelConfig, state0):
    """x: (B,S,d). Returns (y, (last_token, state))."""
    B, S, d = x.shape
    H, K = cfg.n_heads, cfg.head_dim
    xs = _token_shift(x, prev_tok)
    lerp = lambda i: x + (xs - x) * p["mu"][i]
    r = jnp.einsum("bsd,de->bse", lerp(0), p["wr"]).reshape(B, S, H, K)
    k = jnp.einsum("bsd,de->bse", lerp(1), p["wk"]).reshape(B, S, H, K)
    v = jnp.einsum("bsd,de->bse", lerp(2), p["wv"]).reshape(B, S, H, K)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", lerp(3), p["wg"]))
    # data-dependent decay (the Finch hallmark): low-rank dynamic log-decay
    ww = p["w0"] + jnp.einsum(
        "bsd,dl,le->bse", jnp.tanh(lerp(4)), p["wa"], p["wb"]
    )
    logw = -jnp.exp(jnp.clip(ww.astype(jnp.float32), -10.0, 2.0))  # < 0
    logw = logw.reshape(B, S, H, K)
    r, k, v = (shard(t, "batch", "seq", "heads", None) for t in (r, k, v))
    y, state = chunked_rwkv(r, k, v, logw, p["u"], state0)
    y = y.reshape(B, S, d)
    # per-head group norm (approximated with RMS over head dims)
    yh = y.reshape(B, S, H, K).astype(jnp.float32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-5)
    y = (yh.reshape(B, S, d) * p["ln_w"]).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", y * g, p["wo"])
    return shard(y, "batch", "seq", None), (x[:, -1:], state)


def init_rwkv_cmix_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "mu": jnp.full((2, d), 0.5, dtype),
        "wk": dense_init(ks[0], (d, f), d, dtype),
        "wv": dense_init(ks[1], (f, d), f, dtype),
        "wr": dense_init(ks[2], (d, d), d, dtype),
    }


def rwkv_cmix(x, prev_tok, p):
    xs = _token_shift(x, prev_tok)
    xk = x + (xs - x) * p["mu"][0]
    xr = x + (xs - x) * p["mu"][1]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    k = shard(k, "batch", "seq", "mlp")
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    return shard(r * kv, "batch", "seq", None), x[:, -1:]


# ---------------------------------------------------------------------------
# Mamba2-style mixer (zamba2 backbone)
# ---------------------------------------------------------------------------
CONV_W = 4


def init_mamba_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H, P = cfg.n_heads, cfg.head_dim
    N = cfg.ssm_state
    d_in = H * P
    ks = split_keys(key, 6)
    return {
        "in_x": dense_init(ks[0], (d, d_in), d, dtype),
        "in_z": dense_init(ks[1], (d, d_in), d, dtype),
        "in_bc": dense_init(ks[2], (d, 2 * N), d, dtype),
        "in_dt": dense_init(ks[3], (d, H), d, dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "a_log": jnp.zeros((H,), dtype),  # A = -exp(a_log)
        "d_skip": jnp.ones((H,), dtype),
        "conv_w": dense_init(ks[4], (CONV_W, d_in + 2 * N), CONV_W, dtype),
        "out": dense_init(ks[5], (d_in, d), d_in, dtype),
    }


def _causal_conv(u, w, prev):
    """Depthwise causal conv, width CONV_W. u: (B,S,C); w: (CONV_W,C);
    prev: (B, CONV_W-1, C) left context."""
    x = jnp.concatenate([prev.astype(u.dtype), u], axis=1)
    out = sum(
        x[:, i : i + u.shape[1]] * w[i][None, None, :] for i in range(CONV_W)
    )
    return jax.nn.silu(out), x[:, -(CONV_W - 1) :]


def mamba_mixer(x, p, cfg: ModelConfig, conv_prev, state0):
    """x: (B,S,d). Returns (y, (conv_state, ssm_state))."""
    B, S, d = x.shape
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.ssm_state
    d_in = H * P
    xz = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xi = jnp.einsum("bsd,de->bse", x, p["in_x"])
    bc = jnp.einsum("bsd,dn->bsn", x, p["in_bc"])
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, p["in_dt"]) + p["dt_bias"])
    conv_in = jnp.concatenate([xi, bc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], conv_prev)
    xi = conv_out[..., :d_in].reshape(B, S, H, P)
    Bm = jnp.broadcast_to(
        conv_out[..., d_in : d_in + N][:, :, None, :], (B, S, H, N)
    )
    Cm = jnp.broadcast_to(
        conv_out[..., d_in + N :][:, :, None, :], (B, S, H, N)
    )
    loga = -jnp.exp(p["a_log"])[None, None, :] * dt  # (B,S,H)
    v = xi * dt[..., None]  # fold dt into the input (standard SSD form)
    Cm = shard(Cm, "batch", "seq", "heads", None)
    v = shard(v, "batch", "seq", "heads", None)
    y, state = chunked_ssd(Cm, Bm, v, loga, state0)
    y = y + xi * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_in) * jax.nn.silu(xz)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    return shard(out, "batch", "seq", None), (conv_state, state)


def mamba_mixer_step(x, p, cfg: ModelConfig, conv_prev, state):
    """Single token. x: (B,1,d)."""
    y, (conv_state, new_state) = mamba_mixer(x, p, cfg, conv_prev, state)
    return y, (conv_state, new_state)
