"""Decoder-only transformer assembly (dense, MoE, audio/vlm-stub variants).

Layers are scanned (stacked parameter pytrees) to keep HLO size and compile
time bounded at 512-device dry-runs; the gemma3 5:1 local:global pattern is
a per-layer window array threaded through the scan (data, not control flow).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distrib.sharding import shard
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import dense_init, rms_norm, split_keys

Params = dict[str, Any]


def _layer_init(key, cfg: ModelConfig, dtype):
    ks = split_keys(key, 2)
    p = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attn_params(ks[0], cfg, dtype),
    }
    if cfg.n_experts:
        p["moe"] = mlp_mod.init_moe_params(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp_params(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = split_keys(key, cfg.n_layers + 3)
    layers = [_layer_init(ks[i], cfg, dtype) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    p: Params = {
        "embed": dense_init(ks[-3], (cfg.vocab, cfg.d_model), cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(
            ks[-2], (cfg.d_model, cfg.vocab), cfg.d_model, dtype
        )
    return p


def _layer_axes(cfg: ModelConfig):
    a = {
        "norm1": ("embed",),
        "norm2": ("embed",),
        "attn": {
            "wq": ("embed", "heads", "head_dim"),
            "wk": ("embed", "kv_heads", "head_dim"),
            "wv": ("embed", "kv_heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed"),
        },
    }
    if cfg.qkv_bias:
        a["attn"]["bq"] = ("heads", "head_dim")
        a["attn"]["bk"] = ("kv_heads", "head_dim")
        a["attn"]["bv"] = ("kv_heads", "head_dim")
    if cfg.n_experts:
        a["moe"] = {
            "router": ("embed", None),
            "w1": ("experts", None, "moe_fsdp"),
            "w3": ("experts", None, "moe_fsdp"),
            "w2": ("experts", "moe_fsdp", None),
        }
    else:
        a["mlp"] = {"w1": ("embed", "mlp"), "w3": ("embed", "mlp"), "w2": ("mlp", "embed")}
    return a


def param_axes(cfg: ModelConfig):
    """Logical-axis tree matching init_params' structure (layers get a
    leading None for the stacked L dim)."""
    layer = _layer_axes(cfg)
    stacked = jax.tree.map(
        lambda ax: (None, *ax), layer, is_leaf=lambda x: isinstance(x, tuple)
    )
    axes = {
        "embed": ("vocab", "embed"),
        "layers": stacked,
        "final_norm": ("embed",),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    return axes


def window_schedule(cfg: ModelConfig, seq_len: int) -> jnp.ndarray:
    """Per-layer attention window (seq_len => effectively global)."""
    if cfg.window_pattern is None:
        return jnp.full((cfg.n_layers,), seq_len + 1, jnp.int32)
    w, period = cfg.window_pattern
    sched = [
        seq_len + 1 if (i + 1) % period == 0 else w for i in range(cfg.n_layers)
    ]
    return jnp.asarray(sched, jnp.int32)


def _embed_in(params, cfg: ModelConfig, batch):
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return shard(x, "batch", "seq", None)


def _logits(params, cfg: ModelConfig, x):
    h = rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    head = (
        params["lm_head"]
        if "lm_head" in params
        else params["embed"].T.astype(h.dtype)
    )
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return shard(logits, "batch", "seq", "vocab")


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    *,
    remat: bool = False,
    remat_policy: Optional[str] = None,
):
    """Training/eval forward. Returns (logits, aux_loss)."""
    x = _embed_in(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = window_schedule(cfg, S)

    def layer_fn(x, inp):
        p, window = inp
        h = rms_norm(x, p["norm1"], plus_one=cfg.norm_plus_one)
        a = attn.attention_train(h, p["attn"], cfg, positions, window=window)
        x = x + a
        h = rms_norm(x, p["norm2"], plus_one=cfg.norm_plus_one)
        if cfg.n_experts:
            m, aux = mlp_mod.moe(h, p["moe"], cfg)
        else:
            m, aux = mlp_mod.mlp(h, p["mlp"], cfg), jnp.float32(0.0)
        return x + m, aux

    if remat:
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        layer_fn = jax.checkpoint(layer_fn, policy=policy)

    x, auxs = jax.lax.scan(layer_fn, x, (params["layers"], windows))
    return _logits(params, cfg, x), jnp.sum(auxs)


# ---------------------------------------------------------------------------
# serving: prefill + decode with a sequence-sharded KV cache
# ---------------------------------------------------------------------------
def prefill(params: Params, cfg: ModelConfig, batch: dict, max_len: int):
    """Forward over the prompt, returning (last_logits, cache, cache_len).

    The cache is (L, B, max_len, Hk, hd) for k and v, sharded along the
    sequence ("kv_seq")."""
    x = _embed_in(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = window_schedule(cfg, S)

    def layer_fn(x, inp):
        p, window = inp
        h = rms_norm(x, p["norm1"], plus_one=cfg.norm_plus_one)
        q, k, v = attn._project_qkv(h, p["attn"], cfg, positions)
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "kv_seq", "kv_heads", None)
        v = shard(v, "batch", "kv_seq", "kv_heads", None)
        o = attn.flash_attention(q, k, v, positions, positions, window=window)
        a = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        x = x + shard(a, "batch", "seq", None)
        h = rms_norm(x, p["norm2"], plus_one=cfg.norm_plus_one)
        if cfg.n_experts:
            m, _ = mlp_mod.moe(h, p["moe"], cfg)
        else:
            m = mlp_mod.mlp(h, p["mlp"], cfg)
        pad = max_len - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
        vc = shard(vc, "batch", "kv_seq", "kv_heads", None)
        return x + m, {"k": kc, "v": vc}

    x, cache = jax.lax.scan(layer_fn, x, (params["layers"], windows))
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, cache, jnp.int32(S)


def decode_step(params: Params, cfg: ModelConfig, cache, tokens, cache_len):
    """One decode step. tokens: (B, 1) int32 (or embeds (B,1,d));
    cache: {"k","v"}: (L, B, S, Hk, hd). Returns (logits, cache)."""
    if tokens.ndim == 3:
        x = tokens
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    x = shard(x, "batch", None, None)
    S = cache["k"].shape[2]
    windows = window_schedule(cfg, S)

    def layer_fn(x, inp):
        p, window, ck, cv = inp
        ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
        cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
        h = rms_norm(x, p["norm1"], plus_one=cfg.norm_plus_one)
        ck, cv = attn.decode_kv_update(p["attn"], cfg, h, ck, cv, cache_len)
        a = attn.attention_decode(h, p["attn"], cfg, ck, cv, cache_len, window=window)
        x = x + shard(a, "batch", None, None)
        h = rms_norm(x, p["norm2"], plus_one=cfg.norm_plus_one)
        if cfg.n_experts:
            m, _ = mlp_mod.moe(h, p["moe"], cfg)
        else:
            m = mlp_mod.mlp(h, p["mlp"], cfg)
        return x + m, {"k": ck, "v": cv}

    x, new_cache = jax.lax.scan(
        layer_fn, x, (params["layers"], windows, cache["k"], cache["v"])
    )
    return _logits(params, cfg, x), new_cache
