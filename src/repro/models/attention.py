"""GQA attention: flash-style (KV-chunked online softmax) for train/prefill,
sequence-sharded-cache attention for decode.

Sharding strategies (DESIGN.md §5):
  * "heads":    q/k/v heads sharded over the model axis (Megatron-style);
                KV heads with fewer heads than shards rely on GSPMD padding.
  * "sequence": for architectures whose head count does not divide the
                model axis (qwen1.5: 20H, gemma3: 8H) — queries are sharded
                along the sequence, K/V stay replicated; attention FLOPs
                still split 16-way and no head padding is wasted.

Decode: the KV cache is sharded along the *sequence* axis ("kv_seq" rule);
softmax reductions over the sharded axis are partitioned by GSPMD into
per-shard partials + all-reduce — the flash-decode pattern without manual
collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distrib.sharding import shard
from repro.models.common import apply_rope, dense_init, split_keys

NEG_INF = -1e30


def init_attn_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), d, dtype),
        "wk": dense_init(ks[1], (d, Hk, hd), d, dtype),
        "wv": dense_init(ks[2], (d, Hk, hd), d, dtype),
        "wo": dense_init(ks[3], (H, hd, d), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Hk, hd), dtype)
        p["bv"] = jnp.zeros((Hk, hd), dtype)
    return p


def _project_qkv(x, p, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k, n_heads: int):
    """GQA: repeat KV heads to the full head count.  Under a head-sharded
    constraint each device materializes only its own repeated heads, so this
    costs no replicated memory — and it keeps every attention einsum purely
    head-parallel (no grouped-dim reshape for GSPMD to trip on)."""
    Hk = k.shape[-2]
    if Hk == n_heads:
        return k
    return jnp.repeat(k, n_heads // Hk, axis=-2)


def flash_attention(
    q,  # (B, Sq, H, hd)
    k,  # (B, Skv, Hk, hd)
    v,  # (B, Skv, Hk, hd)
    q_pos,  # (Sq,) absolute positions of queries
    kv_pos,  # (Skv,)
    window: Optional[int] = None,  # sliding window (None = full causal)
    chunk: int = 1024,
):
    """KV-chunked online-softmax attention (keeps peak memory at
    (B, H, Sq, chunk) instead of (B, H, Sq, Skv))."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = q.astype(jnp.float32) * scale

    chunk = min(chunk, Skv)
    n_chunks = (Skv + chunk - 1) // chunk
    pad = n_chunks * chunk - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kv_posp = jnp.pad(kv_pos, (0, pad), constant_values=-(10**9))
    kc = kp.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_posp.reshape(n_chunks, chunk)

    m0 = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    o0 = jnp.zeros((B, Sq, H, hd), jnp.float32)

    def body(carry, blk):
        m, l, o = carry
        kb, vb, pb = blk  # (B, c, H, hd), (B, c, H, hd), (c,)
        s = jnp.einsum("bqhd,bchd->bqhc", qf, kb.astype(jnp.float32))
        ok = q_pos[None, :, None, None] >= pb[None, None, None, :]
        if window is not None:
            ok = ok & (
                q_pos[None, :, None, None] - pb[None, None, None, :] < window
            )
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, o_new), None

    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kc, vc, pc))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def attention_train(x, p, cfg: ModelConfig, positions, window=None):
    """Full-sequence attention (training / prefill forward)."""
    q, k, v = _project_qkv(x, p, cfg, positions)
    if cfg.attn_strategy == "sequence":
        q = shard(q, "batch", "seq_model", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
    else:
        # expand KV to full heads pre-constraint so the whole attention is
        # head-parallel even when n_kv_heads < the model axis (GQA).
        k = _expand_kv(k, cfg.n_heads)
        v = _expand_kv(v, cfg.n_heads)
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "heads", None)
        v = shard(v, "batch", "seq", "heads", None)
    out = flash_attention(q, k, v, positions, positions, window=window)
    if cfg.attn_strategy == "sequence":
        out = shard(out, "batch", "seq_model", None, None)
    else:
        out = shard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(y, "batch", "seq", None)


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    n_layers: int
    batch: int
    max_len: int
    n_kv_heads: int
    head_dim: int

    def init(self, dtype=jnp.bfloat16):
        shape = (
            self.n_layers,
            self.batch,
            self.max_len,
            self.n_kv_heads,
            self.head_dim,
        )
        z = jnp.zeros(shape, dtype)
        return {"k": z, "v": z}


def shard_cache(cache):
    return {
        "k": shard(cache["k"], None, "batch", "kv_seq", None, None),
        "v": shard(cache["v"], None, "batch", "kv_seq", None, None),
    }


def attention_decode(x, p, cfg: ModelConfig, layer_k, layer_v, cache_len, window=None):
    """One-token decode against a sequence-sharded KV cache.

    x: (B, 1, d); layer_k/v: (B, S, Hk, hd) (already containing this step's
    K/V at position cache_len); cache_len: scalar int32.
    """
    B = x.shape[0]
    pos = jnp.full((1,), cache_len, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if cfg.rope_theta:
        q = apply_rope(q, pos, cfg.rope_theta)
    H, hd = cfg.n_heads, cfg.head_dim
    S = layer_k.shape[1]
    kf = _expand_kv(layer_k, H).astype(jnp.float32)
    vf = _expand_kv(layer_v, H).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = (q.astype(jnp.float32) * scale).reshape(B, H, hd)
    kv_pos = jnp.arange(S)
    s = jnp.einsum("bhd,bshd->bhs", qf, kf)
    # flash-decode: pin the score tensor to the cache's sequence sharding so
    # each shard computes attention over its own KV chunk and only the
    # softmax reductions cross shards — without this constraint GSPMD
    # gathers the whole cache per layer (EXPERIMENTS.md §Perf iter 4).
    s = shard(s, "batch", None, "kv_seq")
    ok = kv_pos[None, None, :] <= cache_len
    if window is not None:
        ok = ok & (cache_len - kv_pos[None, None, :] < window)
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)  # GSPMD partitions the sharded-S reduce
    w = shard(w, "batch", None, "kv_seq")
    out = jnp.einsum("bhs,bshd->bhd", w, vf)
    out = out.reshape(B, 1, H, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y


def decode_kv_update(p, cfg: ModelConfig, x, cache_k, cache_v, cache_len):
    """Project this token's K/V and write them at cache_len."""
    pos = jnp.full((1,), cache_len, jnp.int32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    if cfg.rope_theta:
        k = apply_rope(k, pos, cfg.rope_theta)
    # Masked select rather than dynamic_update_slice: a DUS with a dynamic
    # index into the sequence-sharded cache forces GSPMD into "involuntary
    # full rematerialization" (replicate + repartition the whole cache per
    # layer); the elementwise select partitions cleanly along the sharded
    # sequence (verified in the dry-run HLO — EXPERIMENTS.md §Perf).
    S = cache_k.shape[1]
    sel = (jnp.arange(S) == cache_len)[None, :, None, None]
    ck = jnp.where(sel, k.astype(cache_k.dtype), cache_k)
    cv = jnp.where(sel, v.astype(cache_v.dtype), cache_v)
    return ck, cv
