from repro.models import attention, common, mlp, model_zoo, recurrent, ssm, transformer
from repro.models.model_zoo import Model, build_model, cross_entropy

__all__ = [
    "attention", "common", "mlp", "model_zoo", "recurrent", "ssm",
    "transformer", "Model", "build_model", "cross_entropy",
]
