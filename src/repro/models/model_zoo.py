"""Unified model interface over the four families (--arch <id> dispatch).

A `Model` bundles init / loss / prefill / decode plus the shape-aware
`input_specs` used by the multi-pod dry-run (ShapeDtypeStruct stand-ins, no
allocation) and the logical-axis trees the launcher resolves to shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import recurrent, transformer

Params = Any


def cross_entropy(logits, labels):
    """Mean next-token NLL over a (possibly vocab-sharded) logits tensor.

    The correct-class logit is extracted with a one-hot contraction rather
    than take_along_axis: a gather across the sharded vocab axis makes
    GSPMD all-gather the full logits (tens of GB at 256k vocab), while the
    one-hot einsum stays sharded and reduces with a small psum."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.einsum("bsv,bsv->bs", lf, onehot)
    return jnp.mean(logz - ll)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable  # (key, dtype) -> params
    param_axes: Callable  # () -> logical-axis tree
    loss_fn: Callable  # (params, batch, remat) -> (loss, metrics)
    prefill_fn: Optional[Callable]  # (params, batch, max_len) -> (logits, cache, len)
    decode_fn: Callable  # (params, state, tokens, cache_len) -> (logits, state)
    decode_state_spec: Callable  # (shape) -> pytree of ShapeDtypeStruct
    decode_state_axes: Callable  # () -> logical-axis tree for the state
    input_specs: Callable  # (shape) -> batch of ShapeDtypeStruct
    batch_axes: Callable  # (shape) -> logical-axis tree for the batch

    def init_decode_state(self, shape: ShapeConfig):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.decode_state_spec(shape)
        )


AUX_COEF = 0.01


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _train_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend in ("audio_stub", "vision_stub"):
        batch = {
            "embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
            "labels": _sds((B, S), jnp.int32),
        }
        axes = {"embeds": ("batch", "seq", None), "labels": ("batch", "seq")}
    else:
        batch = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    return batch, axes


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "ssm":
        return _build_rwkv(cfg)
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    return _build_transformer(cfg)


# ---------------------------------------------------------------------------
def _build_transformer(cfg: ModelConfig) -> Model:
    def loss_fn(params, batch, remat=True, remat_policy=None):
        logits, aux = transformer.forward(
            params, cfg, batch, remat=remat, remat_policy=remat_policy
        )
        loss = cross_entropy(logits, batch["labels"])
        return loss + AUX_COEF * aux, {"xent": loss, "aux": aux}

    def prefill_fn(params, batch, max_len):
        return transformer.prefill(params, cfg, batch, max_len)

    def decode_fn(params, cache, tokens, cache_len):
        return transformer.decode_step(params, cfg, cache, tokens, cache_len)

    def decode_state_spec(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        sh = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)
        return {"k": _sds(sh, jnp.bfloat16), "v": _sds(sh, jnp.bfloat16)}

    def decode_state_axes():
        ax = (None, "batch", "kv_seq", "kv_heads", None)
        return {"k": ax, "v": ax}

    return Model(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.float32: transformer.init_params(
            cfg, key, dtype
        ),
        param_axes=lambda: transformer.param_axes(cfg),
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        decode_state_spec=decode_state_spec,
        decode_state_axes=decode_state_axes,
        input_specs=lambda shape: _train_batch_specs(cfg, shape)[0],
        batch_axes=lambda shape: _train_batch_specs(cfg, shape)[1],
    )


# ---------------------------------------------------------------------------
def _build_rwkv(cfg: ModelConfig) -> Model:
    def loss_fn(params, batch, remat=True, remat_policy=None):
        logits, aux, _ = recurrent.rwkv_forward(
            params, cfg, batch, state=None, remat=remat
        )
        loss = cross_entropy(logits, batch["labels"])
        return loss, {"xent": loss, "aux": aux}

    def prefill_fn(params, batch, max_len):
        logits, _, state = recurrent.rwkv_forward(params, cfg, batch)
        return logits[:, -1:, :], state, jnp.int32(batch["tokens"].shape[1])

    def decode_fn(params, state, tokens, cache_len):
        logits, _, new_state = recurrent.rwkv_forward(
            params, cfg, {"tokens": tokens}, state=state
        )
        return logits, new_state

    def decode_state_spec(shape: ShapeConfig):
        B = shape.global_batch
        H, K = cfg.n_heads, cfg.head_dim
        return {
            "wkv": _sds((cfg.n_layers, B, H, K, K), jnp.float32),
            "tshift1": _sds((cfg.n_layers, B, 1, cfg.d_model), jnp.float32),
            "tshift2": _sds((cfg.n_layers, B, 1, cfg.d_model), jnp.float32),
        }

    def decode_state_axes():
        return {
            "wkv": (None, "batch", "heads", None, None),
            "tshift1": (None, "batch", None, None),
            "tshift2": (None, "batch", None, None),
        }

    return Model(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.float32: recurrent.rwkv_init_params(
            cfg, key, dtype
        ),
        param_axes=lambda: recurrent.rwkv_param_axes(cfg),
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        decode_state_spec=decode_state_spec,
        decode_state_axes=decode_state_axes,
        input_specs=lambda shape: _train_batch_specs(cfg, shape)[0],
        batch_axes=lambda shape: _train_batch_specs(cfg, shape)[1],
    )


# ---------------------------------------------------------------------------
def _build_zamba(cfg: ModelConfig) -> Model:
    def loss_fn(params, batch, remat=True, remat_policy=None):
        logits, aux = recurrent.zamba_forward(params, cfg, batch, remat=remat)
        loss = cross_entropy(logits, batch["labels"])
        return loss, {"xent": loss, "aux": aux}

    def decode_fn(params, state, tokens, cache_len):
        window = state["k"].shape[2]
        return recurrent.zamba_decode_step(
            params, cfg, state, tokens, cache_len, window
        )

    def decode_state_spec(shape: ShapeConfig):
        B = shape.global_batch
        window = min(cfg.shared_attn_window, shape.seq_len)
        H, P, N = cfg.n_heads, cfg.head_dim, cfg.ssm_state
        G = cfg.n_layers // cfg.shared_attn_period
        from repro.models import ssm as ssm_mod

        return {
            "ssm": _sds((cfg.n_layers, B, H, N, P), jnp.float32),
            "conv": _sds(
                (cfg.n_layers, B, ssm_mod.CONV_W - 1, H * P + 2 * N), jnp.float32
            ),
            "k": _sds((G, B, window, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
            "v": _sds((G, B, window, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        }

    def decode_state_axes():
        return {
            "ssm": (None, "batch", "heads", None, None),
            "conv": (None, "batch", None, "state"),
            "k": (None, "batch", "kv_seq", "kv_heads", None),
            "v": (None, "batch", "kv_seq", "kv_heads", None),
        }

    def prefill_fn(params, batch, max_len):
        window = min(cfg.shared_attn_window, max_len)
        return recurrent.zamba_prefill(params, cfg, batch, window)

    return Model(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.float32: recurrent.zamba_init_params(
            cfg, key, dtype
        ),
        param_axes=lambda: recurrent.zamba_param_axes(cfg),
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        decode_state_spec=decode_state_spec,
        decode_state_axes=decode_state_axes,
        input_specs=lambda shape: _train_batch_specs(cfg, shape)[0],
        batch_axes=lambda shape: _train_batch_specs(cfg, shape)[1],
    )
