"""Shared model components: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, w, *, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    while ang.ndim < x.ndim - 1:  # align S with x's seq axis (-3), broadcast heads
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def act_fn(name: str):
    if name in ("swiglu", "rwkv_ffn"):
        return jax.nn.silu
    if name == "geglu":
        return jax.nn.gelu
    raise ValueError(name)
