"""Fig 4: asymmetric macro — ~2% of TOR uplinks degraded; synthetic + DC +
collective workloads across load balancers."""
from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.netsim import failures, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    fs = failures.random_degraded_uplinks(cfg, 0.03, seed=4)
    n = cfg.n_hosts
    for wname, wl in {
        "permutation": workloads.permutation(n, msg(256, 2048), seed=1),
        "tornado": workloads.tornado(n, msg(256, 2048)),
    }.items():
        for lbn in ["ecmp", "ops", "reps", "plb", "bitmap", "adaptive_roce"]:
            _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 5000, fs)
            completion_row(rows, f"fig04/{wname}/{lbn}", s, wall)
    wl = workloads.ring_allreduce(16, msg(128, 1024))
    for lbn in ["ops", "reps", "bitmap"]:
        _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 14000, fs)
        completion_row(rows, f"fig04/ring_allreduce/{lbn}", s, wall)
    return rows


if __name__ == "__main__":
    main()
