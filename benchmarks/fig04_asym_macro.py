"""Fig 4: asymmetric macro — ~2% of TOR uplinks degraded; synthetic + DC +
collective workloads across load balancers.

The whole grid runs as one sweep submission (benchmarks.common.figure_grid
→ repro.netsim.sweep): the synthetic-workload × endpoint-LB block shares
one bucket scan, adaptive RoCE buckets separately (in-network routing is a
static property), and the ring-AllReduce block keeps its own shapes/horizon
unless the packer can fuse it under the waste budget.  Every cell's metrics
are bit-identical to the PR 2 per-cell `run_one` path
(tests/test_figure_parity.py).  BENCH_SMOKE=1 restricts to the canonical
LBs on the synthetic workloads.
"""
from benchmarks.common import SMOKE, Rows, ci_cfg, figure_grid, msg, sweep_case
from repro.netsim import failures, workloads

LBS = ["ecmp", "ops", "reps", "plb", "bitmap", "adaptive_roce"]
SMOKE_LBS = ["ecmp", "ops", "reps"]


def cases(cfg, smoke=SMOKE):
    """Declarative cell list for the fig04 grid (smoke = CI subset)."""
    fs = failures.random_degraded_uplinks(cfg, 0.03, seed=4)
    n = cfg.n_hosts
    lbs = SMOKE_LBS if smoke else LBS
    out = [
        sweep_case(f"fig04/{wname}/{lbn}", wl, lbn, 5000, cfg, failures=fs)
        for wname, wl in {
            "permutation": workloads.permutation(n, msg(256, 2048), seed=1),
            "tornado": workloads.tornado(n, msg(256, 2048)),
        }.items()
        for lbn in lbs
    ]
    if not smoke:
        wl = workloads.ring_allreduce(16, msg(128, 1024))
        out += [
            sweep_case(f"fig04/ring_allreduce/{lbn}", wl, lbn, 14000, cfg,
                       failures=fs)
            for lbn in ["ops", "reps", "bitmap"]
        ]
    return out


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    figure_grid(rows, "fig04", cfg, cases(cfg))
    return rows


if __name__ == "__main__":
    main()
