"""Fig 18 (Appendix D.2): 3-tier fat tree — REPS performs comparably to the
2-tier case (one EV steers two choice hops)."""
from benchmarks.common import FULL, Rows, completion_row, lb_for, msg, run_one
from repro.netsim import SimConfig, workloads


def main(rows=None):
    rows = rows or Rows()
    if FULL:
        cfg = SimConfig(
            n_hosts=128, hosts_per_tor=16, tiers=3, tors_per_pod=2,
            aggs_per_pod=4, agg_uplinks=4,
        )
    else:
        cfg = SimConfig(
            n_hosts=64, hosts_per_tor=8, tiers=3, tors_per_pod=2,
            aggs_per_pod=4, agg_uplinks=4, evs_size=256, queue_capacity=64,
            init_cwnd_pkts=50, max_cwnd_pkts=100, rto_ticks=600,
            max_msg_pkts=1024,
        )
    wl = workloads.permutation(cfg.n_hosts, msg(256, 2048), seed=3)
    for lbn in ["ecmp", "ops", "reps"]:
        _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 6000)
        completion_row(rows, f"fig18/3tier/{lbn}", s, wall)
    return rows


if __name__ == "__main__":
    main()
