"""Fig 2: healthy symmetric network — synthetic benchmarks, DC traces and
AI collectives across all load balancers.

Runs each scenario through the batched FleetRunner (BENCH_SEEDS seeds in
one compiled scan; metrics reported for seed 0 == the serial run).
BENCH_SMOKE=1 restricts to the three canonical LBs and the synthetic
workloads for CI perf tracking.
"""
from benchmarks.common import (
    SMOKE, Rows, ci_cfg, completion_row, lb_for, msg, run_fleet,
    throughput_extra,
)
from repro.netsim import workloads

LBS = ["ecmp", "ops", "reps", "plb", "flowlet", "mptcp", "mprdma", "bitmap",
       "adaptive_roce"]
SMOKE_LBS = ["ecmp", "ops", "reps"]


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    n = cfg.n_hosts
    lbs = SMOKE_LBS if SMOKE else LBS
    wls = {
        "incast8": workloads.incast(n, 8, msg(128, 1024)),
        "permutation": workloads.permutation(n, msg(256, 2048), seed=1),
        "tornado": workloads.tornado(n, msg(256, 2048)),
    }
    ticks = 4000
    for wname, wl in wls.items():
        for lbn in lbs:
            fleet, _, _, sums, wall = run_fleet(cfg, wl, lb_for(cfg, lbn), ticks)
            completion_row(
                rows, f"fig02/{wname}/{lbn}", sums[0], wall, ticks=ticks,
                n_runs=fleet.n_runs,
            )
    if SMOKE:
        return rows
    # DC traces (websearch) at moderate load
    wl = workloads.websearch_trace(n, load=0.6, duration_ticks=1500, seed=2, max_pkts=cfg.max_msg_pkts)
    for lbn in ["ecmp", "ops", "reps", "plb", "bitmap"]:
        fleet, _, _, sums, wall = run_fleet(cfg, wl, lb_for(cfg, lbn), 4500)
        s = sums[0]
        rows.add(
            f"fig02/websearch60/{lbn}", wall * 1e6,
            f"completed={s.completed}/{s.n_conns};mean_fct={s.mean_fct_ticks:.0f};"
            f"p99_fct={s.p99_fct_ticks:.0f}",
            **throughput_extra(4500, fleet.n_runs, wall),
        )
    # AI collectives
    for cname, wl in {
        "ring_allreduce": workloads.ring_allreduce(16, msg(128, 1024)),
        "butterfly_allreduce": workloads.butterfly_allreduce(16, msg(128, 1024)),
        "alltoall_w4": workloads.alltoall(16, msg(16, 64), window=4),
    }.items():
        for lbn in ["ecmp", "ops", "reps", "adaptive_roce"]:
            fleet, _, _, sums, wall = run_fleet(cfg, wl, lb_for(cfg, lbn), 12000)
            completion_row(
                rows, f"fig02/{cname}/{lbn}", sums[0], wall, ticks=12000,
                n_runs=fleet.n_runs,
            )
    return rows


if __name__ == "__main__":
    main()
