"""Fig 2: healthy symmetric network — synthetic benchmarks, DC traces and
AI collectives across all load balancers.

The whole figure is submitted as ONE sweep batch (figure_grid →
repro.netsim.sweep): cells sharing padded shapes compile together, the
ECMP/OPS/REPS columns ride one lax.switch, seeds vmap on the row axis, and
rows shard across visible devices.  Per-cell metrics are bit-identical to
the serial Simulator.run on the same padded scenario (tests/test_sweep.py);
seed-0 is the reported run.  BENCH_SMOKE=1 restricts to the three canonical
LBs and the synthetic workloads for CI perf tracking.
"""
from benchmarks.common import (
    SMOKE, Rows, ci_cfg, completion_fmt, figure_grid, msg, sweep_case,
)
from repro.netsim import workloads

LBS = ["ecmp", "ops", "reps", "plb", "flowlet", "mptcp", "mprdma", "bitmap",
       "adaptive_roce"]
SMOKE_LBS = ["ecmp", "ops", "reps"]


def cases(cfg, smoke=SMOKE):
    n = cfg.n_hosts
    lbs = SMOKE_LBS if smoke else LBS
    wls = {
        "incast8": workloads.incast(n, 8, msg(128, 1024)),
        "permutation": workloads.permutation(n, msg(256, 2048), seed=1),
        "tornado": workloads.tornado(n, msg(256, 2048)),
    }
    out = [
        sweep_case(f"fig02/{wname}/{lbn}", wl, lbn, 4000, cfg)
        for wname, wl in wls.items()
        for lbn in lbs
    ]
    if not smoke:
        # DC traces (websearch) at moderate load
        wsw = workloads.websearch_trace(
            n, load=0.6, duration_ticks=1500, seed=2, max_pkts=cfg.max_msg_pkts
        )
        out += [
            sweep_case(f"fig02/websearch60/{lbn}", wsw, lbn, 4500, cfg)
            for lbn in ["ecmp", "ops", "reps", "plb", "bitmap"]
        ]
        # AI collectives
        out += [
            sweep_case(f"fig02/{cname}/{lbn}", wl, lbn, 12000, cfg)
            for cname, wl in {
                "ring_allreduce": workloads.ring_allreduce(16, msg(128, 1024)),
                "butterfly_allreduce": workloads.butterfly_allreduce(16, msg(128, 1024)),
                "alltoall_w4": workloads.alltoall(16, msg(16, 64), window=4),
            }.items()
            for lbn in ["ecmp", "ops", "reps", "adaptive_roce"]
        ]
    return out


def _fmt(name, s):
    if "/websearch" in name:  # trace cells read better with FCT stats
        return (
            f"completed={s.completed}/{s.n_conns};"
            f"mean_fct={s.mean_fct_ticks:.0f};"
            f"p99_fct={s.p99_fct_ticks:.0f}"
        )
    return completion_fmt(s)


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    figure_grid(rows, "fig02", cfg, cases(cfg), fmt=_fmt)
    return rows


if __name__ == "__main__":
    main()
