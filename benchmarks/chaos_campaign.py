"""Chaos campaign entry: seeded random gray-failure scenarios with
invariant checking and automatic shrinking (repro.netsim.chaos).

This is the CLI the CI chaos-smoke job drives:

    # fixed-seed campaign over the fault archetype space (exit 1 on any
    # invariant violation, after shrinking + writing the repro artifact)
    python -m benchmarks.chaos_campaign --seed 42 --budget 120 --artifacts /tmp/chaos

    # re-run a shrunken repro artifact; exits 0 only if the violation
    # reproduces AND the run is bit-identical to the recorded digest
    python -m benchmarks.chaos_campaign --replay /tmp/chaos/chaos_repro_*.json

    # prove the checker has teeth: the known-bad fixture (ecmp under a
    # permanent half-fabric outage) must violate, shrink, and replay
    python -m benchmarks.chaos_campaign --known-bad --artifacts /tmp/chaos

Campaigns are deterministic in ``--seed``: the same seed always generates
the same scenarios, faults, and mid-run injection points, so a CI failure
is replayable locally with nothing but this command line.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.netsim.chaos import ChaosCampaign, known_bad_scenario


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=42,
                    help="campaign seed (scenario generation is a pure "
                         "function of it)")
    ap.add_argument("--budget", type=float, default=180.0,
                    help="wall-clock budget in seconds (at least "
                         "--min-scenarios run regardless)")
    ap.add_argument("--min-scenarios", type=int, default=5,
                    help="scenarios to run even past budget (the default "
                         "covers every fault archetype once)")
    ap.add_argument("--max-scenarios", type=int, default=None,
                    help="hard cap on scenario count")
    ap.add_argument("--lb", default="reps",
                    help="load balancer under test")
    ap.add_argument("--artifacts", default=None,
                    help="directory for shrunken repro artifacts")
    ap.add_argument("--replay", default=None, metavar="ARTIFACT",
                    help="re-run a repro artifact instead of a campaign; "
                         "exit 0 iff the violation reproduces bit-exactly")
    ap.add_argument("--known-bad", action="store_true",
                    help="run the known-bad fixture through the full "
                         "violation -> shrink -> replay cycle (exit 0 iff "
                         "every step behaves)")
    ap.add_argument("--out", default=None, help="write the report JSON here")
    args = ap.parse_args(argv)

    campaign = ChaosCampaign(
        seed=args.seed, budget_s=args.budget,
        min_scenarios=args.min_scenarios, max_scenarios=args.max_scenarios,
        lb=args.lb,
    )

    if args.replay:
        with open(args.replay) as fh:
            artifact = json.load(fh)
        print(f"replaying {args.replay} "
              f"(expected digest {artifact['record_digest'][:12]})")
        violations, bit_exact = campaign.replay(artifact)
        for v in violations:
            print(f"  {v.invariant} @ {v.cell} t={v.tick}: {v.detail}")
        print(f"violations={len(violations)} bit_exact={bit_exact}")
        return 0 if (violations and bit_exact) else 1

    if args.known_bad:
        scenario = known_bad_scenario()
        violations, _ = campaign.run_scenario(scenario)
        if not violations:
            print("FAIL: known-bad fixture produced no violation — the "
                  "invariant checker has lost its teeth")
            return 1
        print(f"known-bad fixture violated as expected: "
              f"{sorted({v.invariant for v in violations})}")
        minimal, mv, mrec = campaign.shrink(scenario)
        artifact = campaign.make_artifact(minimal, mv, mrec)
        print(f"shrunk to {len(minimal.faults)} fault(s), "
              f"{minimal.n_conns or 'all'} conns, {minimal.ticks} ticks, "
              f"{minimal.msg_pkts} pkts")
        if args.artifacts:
            import os

            os.makedirs(args.artifacts, exist_ok=True)
            path = os.path.join(args.artifacts, "chaos_known_bad.json")
            with open(path, "w") as fh:
                json.dump(artifact, fh, indent=2, sort_keys=True)
            print(f"artifact written to {path}")
        rv, bit_exact = campaign.replay(artifact)
        print(f"replay: violations={len(rv)} bit_exact={bit_exact}")
        return 0 if (rv and bit_exact) else 1

    report = campaign.run(artifact_dir=args.artifacts)
    blob = json.dumps(report, indent=1, sort_keys=True)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(blob + "\n")
    print(f"scenarios={len(report['scenarios'])} "
          f"violations={len(report['violations'])} "
          f"elapsed={report['elapsed_s']}s")
    for v in report["violations"]:
        print(f"  {v['invariant']} @ {v['cell']} t={v['tick']}: {v['detail']}")
    if report.get("artifact_path"):
        print(f"minimal repro: {report['artifact_path']}")
        print(f"replay with: PYTHONPATH=src python -m benchmarks.chaos_campaign "
              f"--replay {report['artifact_path']}")
    return 1 if report["violations"] else 0


if __name__ == "__main__":
    sys.exit(main())
