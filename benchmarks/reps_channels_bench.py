"""Beyond-paper: REPS as the cross-pod gradient-channel scheduler
(repro.ft.reps_channels) vs oblivious assignment, under channel failures,
degradation and a straggler."""
import time

import numpy as np

from benchmarks.common import Rows
from repro.ft import (
    ChannelSim,
    ChannelSimConfig,
    OpsChannelScheduler,
    RepsChannelScheduler,
    run_cross_pod_reduce,
)


def scenario(name, setup, rows):
    for sname, mk in [
        ("ops", lambda: OpsChannelScheduler(16, seed=0)),
        ("reps", lambda: RepsChannelScheduler(16, seed=0)),
    ]:
        sim = ChannelSim(ChannelSimConfig(n_channels=16), seed=0)
        setup(sim)
        t0 = time.time()
        rep = run_cross_pod_reduce(mk(), sim, n_chunks_total=256, chunks_per_round=32)
        rows.add(
            f"reps_channels/{name}/{sname}", (time.time() - t0) * 1e6,
            f"makespan_us={rep.total_latency_us:.0f};rounds={rep.rounds};"
            f"timeouts={rep.timeouts};p99_us={rep.p99_chunk_latency_us:.0f}",
        )


def main(rows=None):
    rows = rows or Rows()
    scenario("healthy", lambda sim: None, rows)
    scenario("fail6of16", lambda sim: sim.set_failed(range(6)), rows)
    scenario("degraded4", lambda sim: sim.set_degraded(range(4)), rows)
    return rows


if __name__ == "__main__":
    main()
