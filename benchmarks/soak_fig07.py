"""Fig 7-class soak entry: the failure-modes macro grid driven through the
checkpointed soak runtime (repro.netsim.soak) instead of the batch path.

Not part of the benchmarks/run.py row harness — this is the CLI the CI
soak-smoke job drives to prove the preemption contract end-to-end on a real
figure grid:

    # uninterrupted golden
    python -m benchmarks.soak_fig07 --ckpt /tmp/ck_a --out straight.json
    # killed mid-run (exits 137 after the boundary checkpoint commits) ...
    python -m benchmarks.soak_fig07 --ckpt /tmp/ck_b --kill-at 240 || true
    # ... resumed, must be bit-identical to the golden
    python -m benchmarks.soak_fig07 --ckpt /tmp/ck_b --resume --out resumed.json
    diff straight.json resumed.json

The emitted JSON is a canonical byte-stable record of everything a figure
would read: every cell/seed ``RunSummary`` field verbatim plus a sha256 of
each cell's raw telemetry sketch carry — if the two files are equal, the
resumed figures are bit-equal.  ``--inject-spine N`` additionally kills one
spine mid-run through ``SoakRunner.inject`` (same merge path as a
pre-declared schedule; tests/test_soak.py asserts that equivalence).

Scaled down from fig07's horizons so the whole kill/resume matrix fits a
CI minute; BENCH_SEEDS widens the per-cell seed axis as usual.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os

from benchmarks.common import ci_cfg, msg, sweep_case
from repro.netsim import SoakConfig, SoakRunner, SweepEngine, failures, workloads
from repro.netsim.tracer import TraceSpec

LBS = ["ops", "reps"]
MIN_FAILURE_SLOTS = 16  # headroom for --inject-spine deltas


def cases(cfg, ticks: int):
    """The fig07 structure (static partial failures + permutation and ring
    AllReduce blocks x LB columns) at soak-smoke horizons: the AllReduce
    block runs 2x the permutation horizon, so the grid exercises
    horizon-heterogeneous buckets under the soak cursor."""
    fs = failures.random_down_uplinks(
        cfg, 0.05, max(ticks // 8, 1), failures.FOREVER, seed=7
    )
    n = cfg.n_hosts
    blocks = [
        ("permutation", workloads.permutation(n, msg(48, 2048), seed=1), ticks),
        ("ring_allreduce", workloads.ring_allreduce(16, msg(24, 1024)), 2 * ticks),
    ]
    out = []
    for wname, wl, t in blocks:
        for lbn in LBS:
            kw = {"freezing_timeout": 800} if lbn == "reps" else {}
            out.append(
                sweep_case(f"fig07soak/{wname}/{lbn}", wl, lbn, t, cfg,
                           failures=fs, **kw)
            )
    return out


def record(soak: SoakRunner) -> dict:
    """Canonical JSON-able record of the finished run: exact RunSummary
    fields per cell/seed + sha256 of each cell's sketch rows."""
    res = soak.result()
    summaries = {
        name: [dataclasses.asdict(s) for s in ss]
        for name, ss in sorted(res.summaries().items())
    }
    tel_sha = {}
    for b in res.buckets:
        for c in b.cells:
            h = hashlib.sha256()
            for row in c.rows:
                h.update(b.telemetry[row].tobytes())
            tel_sha[c.case.name] = h.hexdigest()
    return {
        "cursor": int(soak.cursor),
        "injections": soak.injections,
        "summaries": summaries,
        "telemetry_sha256": dict(sorted(tel_sha.items())),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ckpt", required=True, help="checkpoint root dir")
    ap.add_argument("--ticks", type=int, default=480,
                    help="permutation-block horizon (AllReduce runs 2x)")
    ap.add_argument("--chunk", type=int, default=120,
                    help="ticks per chunk == checkpoint cadence")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="os._exit(137) at the first boundary >= this tick "
                         "(after its checkpoint commits) — the simulated "
                         "preemption")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest committed snapshot first")
    ap.add_argument("--inject-spine", type=int, default=None,
                    help="inject a spine_down delta mid-run")
    ap.add_argument("--inject-at", type=int, default=None,
                    help="cursor tick for --inject-spine (defaults to one "
                         "chunk in; must be a boundary the run reaches)")
    ap.add_argument("--trace", type=int, default=0,
                    help="flight-recorder ring size (0 = off): carry the "
                         "on-device tracer and stream flight_*.npz parts "
                         "under <ckpt>/flight.  Observation-only — the "
                         "emitted record is byte-identical traced or not "
                         "(the CI trace-smoke job diffs the two).")
    ap.add_argument("--out", default=None, help="write the record JSON here")
    args = ap.parse_args(argv)

    cfg = ci_cfg()
    engine = SweepEngine(
        cfg, cases(cfg, args.ticks), min_failure_slots=MIN_FAILURE_SLOTS
    )
    trace = TraceSpec(ring=args.trace) if args.trace else None
    soak = SoakRunner(
        engine, SoakConfig(chunk=args.chunk, ckpt_dir=args.ckpt, trace=trace)
    )
    if args.resume:
        soak.resume()
        print(f"resumed at cursor {soak.cursor} "
              f"({len(soak.injections)} injection(s) replayed)")

    inject_at = None
    if args.inject_spine is not None:
        inject_at = args.inject_at if args.inject_at is not None else args.chunk

    while not soak.done:
        if (inject_at is not None and soak.cursor == inject_at
                and not soak.injections):
            soak.inject(failures.spine_down(cfg, args.inject_spine,
                                            start=inject_at))
            print(f"injected spine_down({args.inject_spine}) at {inject_at}")
        soak.advance(args.chunk)
        if args.kill_at is not None and soak.cursor >= args.kill_at:
            print(f"killed at cursor {soak.cursor} (checkpoint committed)")
            os._exit(137)  # hard preemption: no atexit, no cleanup

    rec = record(soak)
    blob = json.dumps(rec, indent=1, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
    for name, sha in rec["telemetry_sha256"].items():
        done = rec["summaries"][name][0]["completed"]
        print(f"{name}: completed={done} sketch={sha[:12]}")
    print(f"cursor={rec['cursor']}")
    return rec


if __name__ == "__main__":
    main()
