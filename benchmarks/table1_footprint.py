"""Table 1: per-connection memory footprint of REPS.

Two modes:

* default — the paper's arithmetic footprint (``state_footprint_bits``)
  for 1- and 8-deep buffers: ``table1/buffer{n}`` rows.
* ``--conns N`` (scale mode) — *measured* end-to-end: instantiate the
  vectorized REPS state at N connections, bit-pack it into the Table 1
  layout (``reps.pack_state``), and report the actual packed bytes per
  connection plus a pack/unpack round-trip check.  ``--conns 1000000``
  completes on one CPU host and must report ≤ 25 B/conn (the paper's
  claim; asserted).  Emits ``scale/footprint_conns{N}`` rows for
  BENCH_netsim.json; tests/test_scale_mode.py runs the same path at 1e5
  conns as a tier-1 regression.
"""
import argparse
import time

import numpy as np

from benchmarks.common import Rows
from repro.core.reps import (
    REPSConfig, init_state, pack_state, state_footprint_bits, unpack_state,
)

PAPER_BYTES_PER_CONN = 25


def measure_scale(n_conns: int, rows: "Rows", buffer_size: int = 8):
    """Instantiate, perturb, bit-pack, and round-trip N conns of REPS
    state; add a ``scale/footprint_conns{N}`` row and return the measured
    bytes/conn."""
    cfg = REPSConfig(buffer_size=buffer_size)
    t0 = time.time()
    state = init_state(cfg, n_conns)
    # perturb every field deterministically so the round trip exercises
    # real bit patterns, not the all-zeros init
    rng = np.random.default_rng(0)
    state = state.replace(
        buf_ev=state.buf_ev + rng.integers(
            0, cfg.evs_size, state.buf_ev.shape, dtype=np.int32
        ),
        buf_valid=rng.integers(0, 2, state.buf_valid.shape).astype(bool),
        head=state.head + rng.integers(0, buffer_size, (n_conns,), dtype=np.int32),
        num_valid=state.num_valid
        + rng.integers(0, buffer_size + 1, (n_conns,), dtype=np.int32),
        is_freezing=rng.integers(0, 2, (n_conns,)).astype(bool),
        exit_freezing=state.exit_freezing
        + rng.integers(0, 1 << 20, (n_conns,), dtype=np.int32),
        n_cached=state.n_cached
        + rng.integers(0, 2, (n_conns,), dtype=np.int32),
    )
    packed = pack_state(cfg, state)
    bytes_per_conn = packed.nbytes / n_conns
    # lossless on every algorithm-visible field (n_cached reconstructs as
    # its isEmpty indicator — the only bit the algorithm reads)
    back = unpack_state(cfg, packed)
    for f in ("buf_ev", "buf_valid", "head", "num_valid",
              "explore_counter", "is_freezing", "exit_freezing"):
        assert np.array_equal(
            np.asarray(getattr(back, f)), np.asarray(getattr(state, f))
        ), f"round-trip mismatch: {f}"
    assert np.array_equal(
        np.asarray(back.n_cached), (np.asarray(state.n_cached) > 0)
    ), "round-trip mismatch: n_cached indicator"
    wall = time.time() - t0
    assert bytes_per_conn <= PAPER_BYTES_PER_CONN, (
        f"measured {bytes_per_conn:.3f} B/conn exceeds the paper's "
        f"{PAPER_BYTES_PER_CONN} B/conn claim"
    )
    rows.add(
        f"scale/footprint_conns{n_conns}", wall * 1e6,
        f"bytes_per_conn={bytes_per_conn:.3f};"
        f"packed_mb={packed.nbytes / 1e6:.1f};roundtrip=ok",
    )
    return bytes_per_conn


def main(rows=None, conns: int | None = None):
    rows = rows or Rows()
    for n in [1, 8]:
        t0 = time.time()
        fp = state_footprint_bits(REPSConfig(buffer_size=n))
        rows.add(
            f"table1/buffer{n}", (time.time() - t0) * 1e6,
            f"total_bits={fp['total_bits']};bytes={fp['total_bytes_ceil']}",
        )
    if conns:
        bpc = measure_scale(conns, rows)
        print(
            f"scale mode: {conns} conns packed at {bpc:.3f} B/conn "
            f"(paper claim <= {PAPER_BYTES_PER_CONN})"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--conns", type=int, default=None,
        help="measured scale mode: pack N connections of live REPS state "
        "and assert <= 25 B/conn (e.g. --conns 1000000)",
    )
    main(conns=ap.parse_args().conns)
