"""Table 1: per-connection memory footprint of REPS."""
import time

from benchmarks.common import Rows
from repro.core.reps import REPSConfig, state_footprint_bits


def main(rows=None):
    rows = rows or Rows()
    for n in [1, 8]:
        t0 = time.time()
        fp = state_footprint_bits(REPSConfig(buffer_size=n))
        rows.add(
            f"table1/buffer{n}", (time.time() - t0) * 1e6,
            f"total_bits={fp['total_bits']};bytes={fp['total_bytes_ceil']}",
        )
    return rows


if __name__ == "__main__":
    main()
