"""Shared benchmark helpers.

Rows are (name, us_per_call, derived) — `us_per_call` is the wall-clock of
the measured run (compile excluded where it matters is not attempted on
CPU; it's a harness-time figure), `derived` the paper-relevant metric.

Default sizes are CI-scale (1 CPU core); set BENCH_FULL=1 for paper-scale
(128/1024 hosts, MiB messages) — same code, bigger constants.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import make_lb
from repro.netsim import SimConfig, Simulator, summarize

FULL = bool(int(os.environ.get("BENCH_FULL", "0")))


def ci_cfg(**kw) -> SimConfig:
    if FULL:
        base = dict(
            n_hosts=128, hosts_per_tor=16, uplinks_per_tor=16, evs_size=65536,
            queue_capacity=85, init_cwnd_pkts=85, max_cwnd_pkts=170,
            rto_ticks=854, max_msg_pkts=4096,
        )
    else:
        base = dict(
            n_hosts=64, hosts_per_tor=8, uplinks_per_tor=8, evs_size=256,
            queue_capacity=64, init_cwnd_pkts=50, max_cwnd_pkts=100,
            rto_ticks=500, max_msg_pkts=1024,
        )
    base.update(kw)
    return SimConfig(**base)


def msg(pkts_ci: int, pkts_full: int) -> int:
    return pkts_full if FULL else pkts_ci


def lb_for(cfg: SimConfig, name: str, **kw):
    return make_lb(name, evs_size=kw.pop("evs_size", cfg.evs_size), **kw)


def run_one(cfg, wl, lb, ticks, failures=None, watch=None, seed=0):
    sim = Simulator(cfg, wl, lb, failures=failures, watch_queues=watch, seed=seed)
    t0 = time.time()
    st, tr = sim.run(ticks)
    jax.block_until_ready(st.c_done)
    wall = time.time() - t0
    return sim, st, tr, summarize(sim, st), wall


class Rows:
    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us: float, derived: str):
        self.rows.append((name, us, derived))
        print(f"{name},{us:.0f},{derived}", flush=True)

    def extend(self, other: "Rows"):
        self.rows.extend(other.rows)


def completion_row(rows: Rows, tag: str, s, wall: float):
    rows.add(
        tag,
        wall * 1e6,
        f"runtime_ticks={s.runtime_ticks};completed={s.completed}/{s.n_conns};"
        f"drops={s.drops_cong}+{s.drops_fail};timeouts={s.timeouts}",
    )
