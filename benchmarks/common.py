"""Shared benchmark helpers.

Rows are (name, us_per_call, derived) plus a structured record per row
(`Rows.records`) that run.py aggregates into BENCH_netsim.json.

Timing protocol: scenarios are compiled ahead-of-time (untimed) via
jit.lower(...).compile(), then the measured run executes the compiled
artifact and blocks on the result — `us_per_call` therefore excludes
compile time.  Rows that execute a simulator also report ticks/sec.

Default sizes are CI-scale (1 CPU core); set BENCH_FULL=1 for paper-scale
(128/1024 hosts, MiB messages) — same code, bigger constants.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import make_lb
from repro.netsim import (
    FleetRunner, SimConfig, Simulator, SweepCase, SweepEngine, summarize,
)

FULL = bool(int(os.environ.get("BENCH_FULL", "0")))
# BENCH_SEEDS>1 runs netsim scenarios as a vmapped fleet over that many
# seeds (reported metrics stay those of the first seed = the serial run).
SEEDS = max(1, int(os.environ.get("BENCH_SEEDS", "1")))
# BENCH_SMOKE=1 shrinks figure mains to a CI-smoke subset (see fig modules).
SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))
# BENCH_COLLECT picks the sweep collection mode for figure grids (also the
# `--collect` flag of benchmarks.run): "summary" (the default: on-device
# telemetry sketch channels — figure metrics come from sketches, O(bins)
# host bytes per row, early-exit compatible), "none" (state summaries
# only), or "full" (raw trace streams, kept as the parity reference;
# disables quiescence early exit).
COLLECT = os.environ.get("BENCH_COLLECT", "summary")
assert COLLECT in ("none", "summary", "full"), COLLECT
# BENCH_KERNELS pins the engine's segment-rank/segment-sum backend for the
# sweep grids (SimConfig.kernels_backend): "auto" (default — jnp off-TPU),
# "jnp", or "pallas".  Forcing "pallas" off-TPU runs the tiled kernels
# under interpret=True; figure_grid then emits ONLY an informational
# `{fig}/sweep_total_pallas_interpret` row (keyed ticks_per_sec_info so no
# CI gate compares interpret-mode throughput against compiled baselines).
KERNELS = os.environ.get("BENCH_KERNELS", "auto")
assert KERNELS in ("auto", "jnp", "pallas"), KERNELS
# BENCH_TRACE>0 folds the on-device flight recorder (repro.netsim.tracer)
# into summary-mode figure grids with that ring size (also the `--trace`
# flag of benchmarks.run).  Tracing is observation-only — every metric is
# bit-identical on or off — but it adds per-tick recorder work by design,
# so every row is stamped with its trace context and the CI throughput
# gates compare trace-off rows only.
TRACE = max(0, int(os.environ.get("BENCH_TRACE", "0")))
# BENCH_MEASURED_COSTS=1 feeds the committed BENCH_netsim.json bucket rows
# (measured_row_tick_us) back into the packer's cost model in place of the
# footprint estimate (sweep.pack measured_costs).  Off by default for the
# gated smoke grids: a replan can re-bucket cells, and bucket membership is
# RNG-visible through shrink-to-fit conn padding (threefry draws are not
# prefix-stable), which would churn committed derived metrics.
MEASURED = bool(int(os.environ.get("BENCH_MEASURED_COSTS", "0")))


def ci_cfg(**kw) -> SimConfig:
    if FULL:
        base = dict(
            n_hosts=128, hosts_per_tor=16, uplinks_per_tor=16, evs_size=65536,
            queue_capacity=85, init_cwnd_pkts=85, max_cwnd_pkts=170,
            rto_ticks=854, max_msg_pkts=4096,
        )
    else:
        base = dict(
            n_hosts=64, hosts_per_tor=8, uplinks_per_tor=8, evs_size=256,
            queue_capacity=64, init_cwnd_pkts=50, max_cwnd_pkts=100,
            rto_ticks=500, max_msg_pkts=1024,
        )
    base.update(kw)
    return SimConfig(**base)


def msg(pkts_ci: int, pkts_full: int) -> int:
    return pkts_full if FULL else pkts_ci


def lb_for(cfg: SimConfig, name: str, **kw):
    return make_lb(name, evs_size=kw.pop("evs_size", cfg.evs_size), **kw)


def run_one(cfg, wl, lb, ticks, failures=None, watch=None, seed=0):
    """Compile (untimed), then run one scenario and time only execution.

    Returns (sim, final_state, trace, summary, wall_seconds).
    """
    sim = Simulator(cfg, wl, lb, failures=failures, watch_queues=watch, seed=seed)
    state = sim.init_state()
    # AOT compile (untimed) so the measured run is execution only
    compiled = jax.jit(lambda st: sim._run(ticks, st)).lower(state).compile()
    t0 = time.time()
    st, tr = compiled(state)
    jax.block_until_ready(st.c_done)
    wall = time.time() - t0
    return sim, st, tr, summarize(sim, st), wall


def run_fleet(cfg, wl, lb, ticks, failures=None, watch=None, seeds=None):
    """Run a whole multi-seed sweep as one compiled vmapped scan.

    Returns (fleet, states, traces, summaries, wall_seconds); wall covers
    the entire fleet (compile excluded), summaries are per-seed.
    """
    if seeds is None:
        seeds = list(range(SEEDS))
    fleet = FleetRunner(
        cfg, wl, lb, failures=failures, watch_queues=watch, seeds=seeds
    )
    keys, states = fleet.base_keys(), fleet.init_states()
    compiled = (
        jax.jit(lambda k, s: fleet._run(ticks, k, s)).lower(keys, states).compile()
    )
    t0 = time.time()
    states, traces = compiled(keys, states)
    jax.block_until_ready(states.c_done)
    wall = time.time() - t0
    return fleet, states, traces, fleet.summaries(states), wall


def sweep_case(name, wl, lbn, ticks, cfg, failures=None, watch=None, **lb_kwargs):
    """A SweepCase with the harness defaults: cfg-derived evs_size and the
    BENCH_SEEDS seed axis."""
    lb_kwargs.setdefault("evs_size", cfg.evs_size)
    return SweepCase(
        name=name, workload=wl, lb=lbn, ticks=ticks, lb_kwargs=lb_kwargs,
        failures=failures, watch_queues=watch, seeds=tuple(range(SEEDS)),
    )


def measured_costs() -> dict:
    """The packer's measured-cost feedback, harvested from the committed
    BENCH_netsim.json bucket rows when BENCH_MEASURED_COSTS=1 (else {} —
    the packer falls back to the footprint estimate)."""
    if not MEASURED:
        return {}
    from repro.netsim.sweep import measured_costs_from_bench

    return measured_costs_from_bench(
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH_netsim.json")
    )


def run_sweep(cfg, cases, packer=None, collect=None, kernels=None):
    """Submit a whole figure as one sweep: a few compiled bucket scans
    instead of one trace+compile+run per (workload, lb) cell.  Compile is
    excluded from exec walls (AOT per bucket, same protocol as run_one).
    ``collect`` defaults to BENCH_COLLECT; "none" and "summary" stop at
    quiescence (early_exit) — reported metrics are bit-identical to the
    full horizon, see netsim/sweep.py — while "full" keeps raw trace
    streams and must scan every tick.  ``kernels`` defaults to
    BENCH_KERNELS (engine hot-spot backend; bit-identical either way)."""
    collect = collect or COLLECT
    eng = SweepEngine(
        cfg, cases, packer=packer, kernels_backend=kernels or KERNELS,
        measured_costs=measured_costs(),
    )
    res = eng.run(
        collect=collect, early_exit=collect != "full", trace=trace_spec(collect)
    )
    return eng, res


def trace_spec(collect=None):
    """The figure grids' flight-recorder spec: a ``TraceSpec`` with the
    BENCH_TRACE ring size when tracing is on (summary mode only — the
    recorder rides the telemetry carry), else None."""
    if TRACE <= 0 or (collect or COLLECT) != "summary":
        return None
    from repro.netsim.tracer import TraceSpec

    return TraceSpec(ring=TRACE)


def sweep_rows(rows, res, fmt=None, derive=None, collect=None,
               derive_res=None):
    """Emit one row per sweep cell (seed-0 metrics == the serial run).

    ``fmt(name, summary) -> str`` picks the derived string per cell
    (default: completion format); ``derive(case, summary, state) -> str``
    overrides it when the string needs the cell's final state (fig03's
    served shares, fig05's cohort FCTs); ``derive_res(case, summary, res)
    -> str`` when it needs the whole sweep result (the arena's telemetry
    sketch columns via ``res.telemetry_for``).  Wall attribution: a cell's
    us_per_call is its bucket's exec wall split evenly over the bucket's
    cells; ticks_per_sec stays the fleet-aggregate definition, here
    bucket-aggregate (rows x ticks over bucket wall).  ``collect`` stamps
    the rows with the mode the sweep actually ran under (callers that
    override the BENCH_COLLECT global must pass it).
    """
    sums = res.summaries()
    for b in res.buckets:
        share_us = b.exec_wall_s / max(len(b.cells), 1) * 1e6
        tps = b.ticks_run * b.n_rows / max(b.exec_wall_s, 1e-9)
        for c in b.cells:
            s = sums[c.case.name][0]
            if derive_res is not None:
                d = derive_res(c.case, s, res)
            elif derive is not None:
                d = derive(c.case, s, res.state_for(c.case.name))
            elif fmt is not None:
                d = fmt(c.case.name, s)
            else:
                d = completion_fmt(s)
            rows.add(
                c.case.name, share_us, d,
                ticks=b.ticks, ticks_run=b.ticks_run,
                n_runs=len(c.case.seeds),
                ticks_per_sec=tps, bucket_rows=b.n_rows,
                bucket_wall_s=b.exec_wall_s,
                collect=collect or COLLECT,
            )
    return sums


def figure_grid(rows, fig, cfg, cases, fmt=None, derive=None, packer=None,
                collect=None, derive_res=None):
    """Run a declarative figure grid (list of SweepCases) as one sweep
    submission and emit its rows plus a ``{fig}/sweep_total`` row.

    This is the figure→sweep-batch path every grid figure rides: the
    cost-aware packer (netsim/sweep.pack) fuses near-identical cell shapes
    and tick horizons into a few bucket scans, and the sweep_total row
    records the plan shape (cells/buckets/compiled programs/merge waste)
    next to aggregate throughput so CI can gate it (±20% median-normalized
    vs the committed BENCH_netsim.json).

    Each bucket additionally emits a ``{fig}/bucket/*`` row pairing its
    PackPlan key with the *measured* wall clock — bucket_ticks_per_sec and
    measured_row_tick_us next to the packer's est_row_tick_cost — the
    measured tick-cost feedback ``sweep.pack(measured_costs=...)`` consumes
    on BENCH_MEASURED_COSTS=1 runs (kept out of the CI ticks_per_sec gate:
    single-bucket walls are noisier than figure aggregates).

    With BENCH_KERNELS=pallas off-TPU the grid runs the tiled Pallas
    kernels in interpret mode: bit-identical metrics, but throughput is an
    emulation artifact — so the grid emits ONLY one informational
    ``{fig}/sweep_total_pallas_interpret`` row (ticks_per_sec_info key),
    leaving every gated row untouched.
    """
    from repro.distrib.sharding import mesh_platform

    collect = collect or COLLECT
    eng, res = run_sweep(cfg, cases, packer=packer, collect=collect)
    # one shared platform rule with the engine's backend resolution — a
    # pallas sweep off-TPU ran interpret=True and must only emit info rows
    interpret_info = (
        eng.kernels_backend == "pallas" and mesh_platform(eng.mesh) != "tpu"
    )
    if interpret_info:
        agg_ticks = sum(b.ticks_run * b.n_rows for b in res.buckets)
        rows.add(
            f"{fig}/sweep_total_pallas_interpret", res.exec_wall_s * 1e6,
            f"cells={len(cases)};buckets={len(res.buckets)};"
            f"collect={collect};kernels=pallas-interpret",
            ticks_per_sec_info=agg_ticks / max(res.exec_wall_s, 1e-9),
            collect=collect,
        )
        return eng, res
    sweep_rows(rows, res, fmt=fmt, derive=derive, collect=collect,
               derive_res=derive_res)
    plan = eng.plan
    for i, b in enumerate(res.buckets):
        t, ad, nc, msg, f, w = b.plan.key
        wall = max(b.exec_wall_s, 1e-9)
        rows.add(
            f"{fig}/bucket/g{b.plan.group}.{i}", b.exec_wall_s * 1e6,
            f"key=t{t}.ad{int(ad)}.nc{nc}.msg{msg}.f{f}.w{w};"
            f"rows={b.n_rows}+{b.plan.pad_rows}pad;cells={len(b.cells)};"
            f"ticks_run={b.ticks_run}",
            bucket_key=list(b.plan.key),
            bucket_group=b.plan.group,
            ticks_run=b.ticks_run,
            bucket_rows=b.n_rows,
            padded_rows=b.plan.n_padded_rows,
            bucket_ticks_per_sec=b.ticks_run * b.n_rows / wall,
            measured_row_tick_us=(
                wall * 1e6 / max(b.ticks_run * b.plan.n_padded_rows, 1)
            ),
            est_row_tick_cost=b.plan.est_row_cost / max(b.plan.ticks, 1),
            collect=collect,
        )
    agg_ticks = sum(b.ticks_run * b.n_rows for b in res.buckets)
    rows.add(
        f"{fig}/sweep_total", res.exec_wall_s * 1e6,
        f"cells={len(cases)};buckets={len(res.buckets)};"
        f"programs={plan.n_groups};rows={plan.n_rows};"
        f"merge_waste={plan.merge_waste:.3f};collect={collect}",
        ticks_per_sec=agg_ticks / max(res.exec_wall_s, 1e-9),
        compile_wall_s=res.compile_wall_s,
        buckets=len(res.buckets),
        collect=collect,
    )
    return eng, res


def completion_fmt(s):
    return (
        f"runtime_ticks={s.runtime_ticks};completed={s.completed}/{s.n_conns};"
        f"drops={s.drops_cong}+{s.drops_fail};timeouts={s.timeouts}"
    )


class Rows:
    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []
        self.records: list[dict] = []

    def add(self, name: str, us: float, derived: str, **extra):
        self.rows.append((name, us, derived))
        # every row carries the run context it was produced under, so that
        # BENCH_ONLY subset merges into BENCH_netsim.json stay attributable
        # row-by-row (run.py derives honest meta flags from these).
        self.records.append(
            {
                "name": name, "us_per_call": us, "derived": derived,
                "seeds": SEEDS, "full_scale": FULL, "smoke": SMOKE,
                "collect": COLLECT, "trace": TRACE,
                **extra,
            }
        )
        print(f"{name},{us:.0f},{derived}", flush=True)

    def extend(self, other: "Rows"):
        self.rows.extend(other.rows)
        self.records.extend(other.records)


def throughput_extra(ticks: int | None, n_runs: int, wall: float) -> dict:
    """Structured throughput fields for BENCH_netsim.json rows (the single
    definition of ticks_per_sec: fleet-aggregate ticks over exec wall)."""
    if not ticks:
        return {}
    return {
        "ticks": ticks,
        "n_runs": n_runs,
        "ticks_per_sec": (ticks * n_runs) / max(wall, 1e-9),
    }


def completion_row(rows: Rows, tag: str, s, wall: float, ticks: int | None = None,
                   n_runs: int = 1):
    extra = throughput_extra(ticks, n_runs, wall)
    rows.add(
        tag,
        wall * 1e6,
        f"runtime_ticks={s.runtime_ticks};completed={s.completed}/{s.n_conns};"
        f"drops={s.drops_cong}+{s.drops_fail};timeouts={s.timeouts}",
        **extra,
    )
