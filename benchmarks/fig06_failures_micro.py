"""Fig 6: two transient uplink failures (100us-ish and 200us-ish); REPS
freezes within ~1 RTO and avoids the failed paths; OPS keeps spraying.

Both LB cells (and the BENCH_SEEDS seed axis) run as one sweep bucket via
figure_grid — the failure schedules pad to a common shape and the OPS/REPS
columns share one compiled scan behind a lax.switch branch index.
"""
from benchmarks.common import SMOKE, Rows, ci_cfg, figure_grid, msg, sweep_case
from repro.netsim import FailureSchedule, Topology, failures, workloads


def cases(cfg, smoke=SMOKE):
    topo = Topology.build(cfg)
    ups = topo.t0_up_queues(0)
    fs = FailureSchedule.concat(
        failures.link_down([int(ups[0])], 150, 800),
        failures.link_down([int(ups[1])], 1200, 2400),
    )
    wl = workloads.permutation(
        cfg.n_hosts, min(msg(768, 4096), cfg.max_msg_pkts), seed=3
    )
    watch = topo.t0_up_queues(0)
    return [
        sweep_case("fig06/ops", wl, "ops", 8000, cfg, failures=fs,
                   watch=watch),
        sweep_case("fig06/reps", wl, "reps", 8000, cfg, failures=fs,
                   watch=watch, freezing_timeout=800),
    ]


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    figure_grid(
        rows, "fig06", cfg, cases(cfg),
        fmt=lambda _name, s: (
            f"runtime={s.runtime_ticks};drops_fail={s.drops_fail};"
            f"timeouts={s.timeouts};completed={s.completed}/{s.n_conns}"
        ),
    )
    return rows


if __name__ == "__main__":
    main()
