"""Fig 6: two transient uplink failures (100us-ish and 200us-ish); REPS
freezes within ~1 RTO and avoids the failed paths; OPS keeps spraying.

Runs through the batched FleetRunner (BENCH_SEEDS seeds in one compiled
scan; metrics reported for seed 0 == the serial run).
"""
from benchmarks.common import Rows, ci_cfg, lb_for, msg, run_fleet, throughput_extra
from repro.netsim import FailureSchedule, Topology, failures, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    topo = Topology.build(cfg)
    ups = topo.t0_up_queues(0)
    fs = FailureSchedule.concat(
        failures.link_down([int(ups[0])], 150, 800),
        failures.link_down([int(ups[1])], 1200, 2400),
    )
    wl = workloads.permutation(cfg.n_hosts, msg(768, 4096), seed=3)
    ticks = 8000
    for lbn in ["ops", "reps"]:
        fleet, _, _, sums, wall = run_fleet(
            cfg, wl, lb_for(cfg, lbn, **({"freezing_timeout": 800} if lbn == "reps" else {})),
            ticks, fs, topo.t0_up_queues(0),
        )
        s = sums[0]
        rows.add(
            f"fig06/{lbn}", wall * 1e6,
            f"runtime={s.runtime_ticks};drops_fail={s.drops_fail};"
            f"timeouts={s.timeouts};completed={s.completed}/{s.n_conns}",
            **throughput_extra(ticks, fleet.n_runs, wall),
        )
    return rows


if __name__ == "__main__":
    main()
