"""Benchmark harness: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
``BENCH_netsim.json`` (name -> us_per_call / derived / ticks-per-sec where
applicable) so perf trajectory is tracked across PRs.

BENCH_FULL=1 switches to paper-scale constants.  Select subsets with
BENCH_ONLY=fig02,fig13.  BENCH_SMOKE=1 shrinks figure mains to CI-smoke
subsets; BENCH_SEEDS=N runs netsim scenarios as N-seed vmapped fleets.
``--collect {none,summary,full}`` (or BENCH_COLLECT) picks the sweep
collection mode figure grids run under: "summary" (default) folds
on-device telemetry sketch channels into the scans
(repro.netsim.telemetry) and builds figure metrics from the sketches,
"none" keeps state-built summaries only, "full" streams raw traces as a
parity reference and forgoes quiescence early exit.
``--trace N`` (or BENCH_TRACE) folds the on-device flight recorder into
summary-mode grids with an N-slot ring; rows are stamped with their trace
context and CI throughput gates only compare trace-off rows.
"""
import argparse
import json
import os
import platform
import sys
import time

MODULES = [
    "table1_footprint",
    "scale_smoke",  # no-op unless BENCH_SCALE_CONNS is set (scale-smoke CI)
    "fig13_balls_bins",
    "fig16_evs_imbalance",
    "fig17_coalesced_bins",
    "fig01_tornado_micro",
    "fig03_asym_micro",
    "fig05_background",
    "fig06_failures_micro",
    "fig09_fpga_analogue",
    "fig15_forced_freezing",
    "fig18_three_tier",
    "fig11_ack_coalescing",
    "fig12_evs_cc",
    "fig04_asym_macro",
    "fig07_failures_macro",
    "fig08_extreme",
    "fig19_incremental",
    "fig02_symmetric",
    "arena",
    "reps_channels_bench",
]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_netsim.json")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--collect",
        choices=["none", "summary", "full"],
        default=os.environ.get("BENCH_COLLECT", "summary"),
        help="sweep collection mode for figure grids (default: "
        "BENCH_COLLECT or 'summary')",
    )
    ap.add_argument(
        "--trace",
        type=int,
        default=int(os.environ.get("BENCH_TRACE", "0")),
        help="flight-recorder ring size for summary-mode figure grids "
        "(0 = off, the default; also BENCH_TRACE).  Observation-only: "
        "metrics are bit-identical either way; rows are stamped with the "
        "trace context so CI throughput gates skip traced rows.",
    )
    args = ap.parse_args(argv)
    if args.trace < 0:
        ap.error(f"--trace must be >= 0, got {args.trace}")
    if args.collect not in ("none", "summary", "full"):
        # argparse validates `choices` only for flag-provided values, not
        # for the BENCH_COLLECT-derived default
        ap.error(f"invalid BENCH_COLLECT {args.collect!r} "
                 "(choose from none, summary, full)")
    # benchmarks.common reads the env at import; set it before importing so
    # the flag plumbs through figure_grid and into every row's context stamp.
    # Programmatic callers may have imported benchmarks.common already — its
    # COLLECT global is read at call time, so patch it too.
    os.environ["BENCH_COLLECT"] = args.collect
    os.environ["BENCH_TRACE"] = str(args.trace)
    if "benchmarks.common" in sys.modules:
        sys.modules["benchmarks.common"].COLLECT = args.collect
        sys.modules["benchmarks.common"].TRACE = args.trace
    from benchmarks.common import COLLECT, FULL, SEEDS, SMOKE, TRACE, Rows

    only = os.environ.get("BENCH_ONLY")
    selected = MODULES
    if only:
        keys = [k.strip() for k in only.split(",")]
        selected = [m for m in MODULES if any(m.startswith(k) for k in keys)]
    print("name,us_per_call,derived")
    t0 = time.time()
    failed = []
    records: dict[str, dict] = {}
    for mod_name in selected:
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        try:
            result = mod.main()
        except Exception as e:  # noqa: BLE001
            failed.append((mod_name, repr(e)))
            print(f"{mod_name},0,ERROR={e!r}", flush=True)
            continue
        if isinstance(result, Rows):
            for rec in result.records:
                records[rec["name"]] = {k: v for k, v in rec.items() if k != "name"}
    wall = time.time() - t0
    print(f"# total_wall_s={wall:.0f} failed={len(failed)}")
    modules = list(selected)
    if only and os.path.exists(JSON_PATH):
        # Subset run: merge into the existing baseline instead of erasing
        # rows for modules that were not selected — BENCH_netsim.json is
        # the cross-PR perf trajectory, each row keeps its latest sample.
        # meta must then describe the *merged* file, not just this run:
        # modules become the union, and full_scale/smoke/seeds are derived
        # from the per-row context stamps (mixed runs are marked "mixed").
        try:
            with open(JSON_PATH) as f:
                prev = json.load(f)
            # {fig}/bucket/* row names encode the PackPlan's bucketing, so a
            # replan (packer/grid change) can retire names a plain key merge
            # would carry forever: drop every stale bucket row of a figure
            # this run re-planned (its fresh bucket rows are in `records`).
            replanned = {
                n.split("/bucket/")[0] for n in records if "/bucket/" in n
            }
            prev_rows = {
                k: v
                for k, v in prev.get("rows", {}).items()
                if not (
                    "/bucket/" in k and k.split("/bucket/")[0] in replanned
                )
            }
            records = {**prev_rows, **records}
            modules = sorted(set(prev.get("meta", {}).get("modules", [])) | set(selected))
        except (json.JSONDecodeError, OSError):
            pass

    def _row_consensus(key, default):
        # rows without a context stamp (pre-stamp legacy merges) must not
        # be backfilled with the current run's flag — that would launder a
        # mixed file into a unanimous one; treat "absent" as its own value.
        vals = {rec.get(key) for rec in records.values()}
        if len(vals) != 1:
            return "mixed"
        v = vals.pop()
        return default if v is None else v

    payload = {
        "meta": {
            "full_scale": _row_consensus("full_scale", FULL),
            "smoke": _row_consensus("smoke", SMOKE),
            "seeds": _row_consensus("seeds", SEEDS),
            "collect": _row_consensus("collect", COLLECT),
            "trace": _row_consensus("trace", TRACE),
            "modules": modules,
            # figures that ran as sweep batches (figure_grid emits one
            # aggregate row per figure; CI gates these)
            "sweep_totals": sorted(
                k for k in records if k.endswith("/sweep_total")
            ),
            "failed": [m for m, _ in failed],
            "total_wall_s": wall,
            "platform": platform.platform(),
            "python": platform.python_version(),
        },
        "rows": records,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {JSON_PATH} ({len(records)} rows)")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
