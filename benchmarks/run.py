"""Benchmark harness: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows.  BENCH_FULL=1 switches to
paper-scale constants.  Select subsets with BENCH_ONLY=fig02,fig13.
"""
import os
import sys
import time

MODULES = [
    "table1_footprint",
    "fig13_balls_bins",
    "fig16_evs_imbalance",
    "fig17_coalesced_bins",
    "fig01_tornado_micro",
    "fig03_asym_micro",
    "fig05_background",
    "fig06_failures_micro",
    "fig09_fpga_analogue",
    "fig15_forced_freezing",
    "fig18_three_tier",
    "fig11_ack_coalescing",
    "fig12_evs_cc",
    "fig04_asym_macro",
    "fig07_failures_macro",
    "fig08_extreme",
    "fig19_incremental",
    "fig02_symmetric",
    "reps_channels_bench",
]


def main() -> None:
    only = os.environ.get("BENCH_ONLY")
    selected = MODULES
    if only:
        keys = [k.strip() for k in only.split(",")]
        selected = [m for m in MODULES if any(m.startswith(k) for k in keys)]
    print("name,us_per_call,derived")
    t0 = time.time()
    failed = []
    for mod_name in selected:
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001
            failed.append((mod_name, repr(e)))
            print(f"{mod_name},0,ERROR={e!r}", flush=True)
    print(f"# total_wall_s={time.time()-t0:.0f} failed={len(failed)}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
