"""Fig 15 (Appendix A): forcing freezing mode WITHOUT a failure costs ~1%
— entering freezing conservatively is safe."""
import jax.numpy as jnp

from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.core.load_balancers import RepsLB
from repro.core import reps as reps_core
from repro.netsim import workloads


class ForcedFreezeReps(RepsLB):
    name = "reps_forced_freeze"

    def __init__(self, force_at: int, **kw):
        super().__init__(**kw)
        self.force_at = force_at

    def on_ack(self, state, mask, ev, ecn, now, key):
        state = super().on_ack(state, mask, ev, ecn, now, key)
        force = jnp.asarray(now == self.force_at)
        all_conns = jnp.ones(state.head.shape, bool) & force
        return reps_core.on_failure_detection(self.cfg, state, all_conns, now)


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    wl = workloads.tornado(cfg.n_hosts, msg(384, 4096))
    base = lb_for(cfg, "reps")
    forced = ForcedFreezeReps(force_at=900, evs_size=cfg.evs_size)
    for tag, lb in [("normal", base), ("forced_freeze", forced)]:
        _, _, _, s, wall = run_one(cfg, wl, lb, 6000)
        completion_row(rows, f"fig15/{tag}", s, wall)
    return rows


if __name__ == "__main__":
    main()
