"""Fig 16 (Appendix B): EV-space load imbalance at a 32-uplink switch for
1 and 32 flows across EVS sizes (small EVS => >10% imbalance)."""
import time

import jax
import numpy as np

from benchmarks.common import Rows
from repro.core.balls_bins import evs_load_imbalance


def main(rows=None):
    rows = rows or Rows()
    for flows in [1, 32]:
        for evs_bits in [4, 8, 12, 16]:
            t0 = time.time()
            lam = np.asarray(
                evs_load_imbalance(
                    jax.random.PRNGKey(0), 32, 2**evs_bits, flows, 64
                )
            )
            rows.add(
                f"fig16/flows{flows}/evs2^{evs_bits}",
                (time.time() - t0) * 1e6,
                f"mean_imbalance={lam.mean():.4f};p95={np.percentile(lam,95):.4f}",
            )
    return rows


if __name__ == "__main__":
    main()
