"""Fig 3: asymmetric micro — one TOR uplink degraded to half rate; REPS
skews selection away from the slow link, OPS stays uniform.

Both LB cells share one sweep bucket (figure_grid); the slow-link share is
derived from each cell's final q_served state, bit-identical to the serial
per-cell path.
"""
import numpy as np

from benchmarks.common import SMOKE, Rows, ci_cfg, figure_grid, msg, sweep_case
from repro.netsim import Topology, failures, workloads


def cases(cfg, smoke=SMOKE):
    topo = Topology.build(cfg)
    slow = int(topo.t0_up_queues(0)[0])
    fs = failures.link_degraded([slow], 0, failures.FOREVER)
    wl = workloads.permutation(cfg.n_hosts, msg(256, 2048), seed=3)
    watch = topo.t0_up_queues(0)
    return [
        sweep_case(f"fig03/{lbn}", wl, lbn, 4000, cfg, failures=fs,
                   watch=watch)
        for lbn in ["ops", "reps"]
    ]


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    watch = Topology.build(cfg).t0_up_queues(0)

    def derive(case, s, st):
        served = np.asarray(st.q_served)[watch]
        share = served[0] / max(served.sum(), 1)
        return (
            f"runtime={s.runtime_ticks};slow_link_share={share:.3f};"
            f"uniform_share={1 / len(watch):.3f}"
        )

    figure_grid(rows, "fig03", cfg, cases(cfg), derive=derive)
    return rows


if __name__ == "__main__":
    main()
