"""Fig 3: asymmetric micro — one TOR uplink degraded to half rate; REPS
skews selection away from the slow link, OPS stays uniform."""
import numpy as np

from benchmarks.common import Rows, ci_cfg, lb_for, msg, run_one
from repro.netsim import Topology, failures, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    topo = Topology.build(cfg)
    slow = int(topo.t0_up_queues(0)[0])
    fs = failures.link_degraded([slow], 0, 2**30)
    wl = workloads.permutation(cfg.n_hosts, msg(256, 2048), seed=3)
    watch = topo.t0_up_queues(0)
    for lbn in ["ops", "reps"]:
        sim, st, tr, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 4000, fs, watch)
        served = np.asarray(st.q_served)[watch]
        share = served[0] / max(served.sum(), 1)
        rows.add(
            f"fig03/{lbn}", wall * 1e6,
            f"runtime={s.runtime_ticks};slow_link_share={share:.3f};"
            f"uniform_share={1/len(watch):.3f}",
        )
    return rows


if __name__ == "__main__":
    main()
