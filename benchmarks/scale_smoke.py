"""Scale-mode smoke: one 1e5-conn conn-sharded sweep row (ARCHITECTURE.md §10).

Opt-in via ``BENCH_SCALE_CONNS`` (the default aggregate run emits
nothing): builds a staggered-start many-conn workload at that size, runs
it as ONE sweep row through the conn-sharded engine
(``SimConfig.conn_sharding`` + ``SweepEngine(conn_devices=...)``), and
asserts the scale mode's two external contracts:

* the packed REPS per-conn state holds the paper's <= 25 B/conn claim
  (``table1_footprint.measure_scale`` — measured, round-tripped);
* the run finishes under a wall-clock ceiling (``BENCH_SCALE_WALL_S``)
  with the lifetime-bounded packet table (NP is independent of the conn
  count — the property that makes 1e6 conns representable at all).

CI (`scale-smoke` job) runs it with 4 host devices and
``BENCH_SCALE_CONN_DEVICES=4`` so the connection axis genuinely shards;
rows land under ``scale/`` in BENCH_netsim.json.
"""
import os
import time

import numpy as np

from benchmarks.common import Rows
from benchmarks.table1_footprint import measure_scale
from repro.netsim import SimConfig, SweepCase, SweepEngine
from repro.netsim.engine import Workload


def scale_workload(n_conns: int, n_hosts: int, stagger: int = 3) -> Workload:
    """``n_conns`` single-packet messages spread round-robin over hosts,
    each host starting one conn every ``stagger`` ticks — the active set
    stays O(hosts · lifetime) while the conn *tables* carry the full
    n_conns, which is exactly the regime the scale mode targets."""
    i = np.arange(n_conns, dtype=np.int64)
    src = (i % n_hosts).astype(np.int32)
    r = (i // n_hosts).astype(np.int64)  # per-host conn rank
    dst = ((src + 1 + r % (n_hosts - 1)) % n_hosts).astype(np.int32)
    return Workload(
        src=src,
        dst=dst,
        msg_pkts=np.ones(n_conns, np.int32),
        start=(r * stagger).astype(np.int32),
        dep=np.full(n_conns, -1, np.int32),
        name=f"scale{n_conns}",
    )


def main(rows=None):
    rows = rows or Rows()
    conns = int(os.environ.get("BENCH_SCALE_CONNS", "0"))
    if not conns:
        return rows  # scale rows are produced only by the scale-smoke job
    conn_devices = int(os.environ.get("BENCH_SCALE_CONN_DEVICES", "1"))
    ticks = int(os.environ.get("BENCH_SCALE_TICKS", "300"))
    ceiling_s = float(os.environ.get("BENCH_SCALE_WALL_S", "600"))

    measure_scale(conns, rows)  # asserts <= 25 B/conn, round-trip exact

    cfg = SimConfig(
        n_hosts=128, hosts_per_tor=16, uplinks_per_tor=16,
        conn_sharding=True,
    )
    wl = scale_workload(conns, cfg.n_hosts)
    case = SweepCase(f"scale/row{conns}", wl, "reps", ticks=ticks, seeds=(0,))
    t0 = time.time()
    eng = SweepEngine(cfg, [case], conn_devices=conn_devices)
    res = eng.run(collect="none")
    wall = time.time() - t0
    sim = eng.buckets[0].sim
    st = res.state_for(case.name)
    done = int(np.asarray(st.c_done).sum())
    assert done > 0, "scale row made no progress"
    # the lifetime bound, not the conn count, sizes the packet table
    assert sim.NP * 11 * 4 < 64e6, f"packet table ballooned: NP={sim.NP}"
    assert wall <= ceiling_s, (
        f"scale smoke exceeded its wall-clock ceiling: {wall:.1f}s > "
        f"{ceiling_s:.0f}s (compile {res.compile_wall_s:.1f}s + exec "
        f"{res.exec_wall_s:.1f}s)"
    )
    rows.add(
        f"scale/engine_conns{conns}", res.exec_wall_s * 1e6,
        f"ticks={ticks};done={done};NP={sim.NP};"
        f"conn_devices={conn_devices};"
        f"ticks_per_sec={ticks / max(res.exec_wall_s, 1e-9):.1f}",
        ticks_per_sec=ticks / max(res.exec_wall_s, 1e-9),
    )
    return rows


if __name__ == "__main__":
    main()
