"""Fig 12: EVS-size sensitivity (REPS works with 32 EVs; OPS needs many)
and CC-algorithm sensitivity (DCTCP / EQDS-like / delay-based)."""
from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.netsim import workloads


def main(rows=None):
    rows = rows or Rows()
    wl_msg = msg(256, 2048)
    for evs in [32, 256, 65536]:
        cfg = ci_cfg(evs_size=evs)
        wl = workloads.permutation(cfg.n_hosts, wl_msg, seed=3)
        for lbn in ["ops", "reps"]:
            _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn, evs_size=evs), 5000)
            completion_row(rows, f"fig12/evs{evs}/{lbn}", s, wall)
    for cc in ["dctcp", "eqds", "delay"]:
        cfg = ci_cfg(cc=cc)
        wl = workloads.permutation(cfg.n_hosts, wl_msg, seed=3)
        for lbn in ["ops", "reps"]:
            _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 5000)
            completion_row(rows, f"fig12/cc_{cc}/{lbn}", s, wall)
    return rows


if __name__ == "__main__":
    main()
