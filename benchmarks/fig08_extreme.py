"""Fig 8: extreme failures — up to 50% of uplinks down; REPS stays close to
ideal while others degrade.

The failure-fraction axis only changes the schedule length (F), which is a
near-zero term of the packer's cost model — the whole grid fuses into ONE
bucket scan (failure rows pad to the max F with inert rows; the
never-resurrect pad semantics live on FailureSchedule).  BENCH_SMOKE=1
drops the middle fraction and the PLB column.
"""
from benchmarks.common import SMOKE, Rows, ci_cfg, figure_grid, msg, sweep_case
from repro.netsim import failures, workloads

LBS = ["ops", "reps", "plb"]
SMOKE_LBS = ["ops", "reps"]


def cases(cfg, smoke=SMOKE):
    """Declarative cell list for the fig08 grid (smoke = CI subset)."""
    wl = workloads.permutation(cfg.n_hosts, msg(192, 2048), seed=5)
    fracs = [0.125, 0.5] if smoke else [0.125, 0.25, 0.5]
    lbs = SMOKE_LBS if smoke else LBS
    out = []
    for frac in fracs:
        fs = failures.random_down_uplinks(cfg, frac, 150, failures.FOREVER,
                                          seed=11)
        for lbn in lbs:
            kw = {"freezing_timeout": 800} if lbn == "reps" else {}
            out.append(
                sweep_case(f"fig08/fail{int(frac * 100)}pct/{lbn}", wl, lbn,
                           12000, cfg, failures=fs, **kw)
            )
    return out


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    figure_grid(rows, "fig08", cfg, cases(cfg))
    return rows


if __name__ == "__main__":
    main()
