"""Fig 8: extreme failures — up to 50% of uplinks down; REPS stays close to
ideal while others degrade."""
from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.netsim import failures, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    wl = workloads.permutation(cfg.n_hosts, msg(192, 2048), seed=5)
    for frac in [0.125, 0.25, 0.5]:
        fs = failures.random_down_uplinks(cfg, frac, 150, 2**30, seed=11)
        for lbn in ["ops", "reps", "plb"]:
            kw = {"freezing_timeout": 800} if lbn == "reps" else {}
            _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn, **kw), 12000, fs)
            completion_row(rows, f"fig08/fail{int(frac*100)}pct/{lbn}", s, wall)
    return rows


if __name__ == "__main__":
    main()
