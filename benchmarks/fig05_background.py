"""Fig 5: coexistence — REPS foreground with ECMP background traffic
(incremental deployment).

Both mixed-cohort cells ride one sweep bucket: MixedLB is registry-backed
(`make_lb("mixed", fg=..., bg=..., bg_conns=...)`), so the foreground
variants share a lax.switch scan like any other LB column; cohort FCTs are
derived from each cell's final c_done_tick state.
"""
import numpy as np

from benchmarks.common import SMOKE, Rows, ci_cfg, figure_grid, msg, sweep_case
from repro.netsim import workloads


def _workload(cfg):
    return workloads.permutation_with_background(
        cfg.n_hosts, msg(256, 2048), 0.1, seed=1
    )


def cases(cfg, smoke=SMOKE):
    wl, bg = _workload(cfg)
    bg_conns = tuple(int(i) for i in np.nonzero(bg)[0])
    return [
        sweep_case(f"fig05/{fg}+ecmp_bg", wl, "mixed", 5000, cfg,
                   fg=fg, bg="ecmp", bg_conns=bg_conns)
        for fg in ["ops", "reps"]
    ]


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    wl, bg = _workload(cfg)

    def derive(case, s, st):
        done_tick = np.asarray(st.c_done_tick)[: wl.n_conns]
        fg_fct = done_tick[~bg & (done_tick > 0)].max() if (~bg).any() else -1
        bg_fct = done_tick[bg & (done_tick > 0)].max() if bg.any() else -1
        return (
            f"fg_runtime={fg_fct};bg_runtime={bg_fct};"
            f"completed={s.completed}/{s.n_conns}"
        )

    figure_grid(rows, "fig05", cfg, cases(cfg), derive=derive)
    return rows


if __name__ == "__main__":
    main()
