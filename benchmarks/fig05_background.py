"""Fig 5: coexistence — REPS foreground with ECMP background traffic
(incremental deployment)."""
from benchmarks.common import Rows, ci_cfg, lb_for, msg, run_one
from repro.netsim import MixedLB, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    wl, bg = workloads.permutation_with_background(
        cfg.n_hosts, msg(256, 2048), 0.1, seed=1
    )
    import numpy as np
    for fg in ["ops", "reps"]:
        lb = MixedLB(lb_for(cfg, fg), lb_for(cfg, "ecmp"), bg)
        sim, st, tr, s, wall = run_one(cfg, wl, lb, 5000)
        done_tick = np.asarray(st.c_done_tick)
        fg_fct = done_tick[~bg & (done_tick > 0)].max() if (~bg).any() else -1
        bg_fct = done_tick[bg & (done_tick > 0)].max() if bg.any() else -1
        rows.add(
            f"fig05/{fg}+ecmp_bg", wall * 1e6,
            f"fg_runtime={fg_fct};bg_runtime={bg_fct};"
            f"completed={s.completed}/{s.n_conns}",
        )
    return rows


if __name__ == "__main__":
    main()
