"""Figs 13/14 + Theorem 5.1: batched balls-into-bins (OPS — unbounded
growth at high load, worse with more bins) vs recycled balls-into-bins
(bounded by tau, converges)."""
import time

import jax
import numpy as np

from benchmarks.common import FULL, Rows
from repro.core.balls_bins import simulate_ops_bins, simulate_recycled_bins


def main(rows=None):
    rows = rows or Rows()
    steps = 10000 if FULL else 4000
    for n in [8, 32, 128]:
        t0 = time.time()
        ml = simulate_ops_bins(jax.random.PRNGKey(0), n, 0.99, steps)
        ml = np.asarray(ml)
        rows.add(
            f"fig13/ops/n{n}", (time.time() - t0) * 1e6,
            f"max_load_end={ml[-1]};peak={ml.max()};steps={steps}",
        )
    for n in [8, 32, 128]:
        tau = int(4 * np.log(n))
        b = int(np.ceil(2.4 * np.log(n)))
        t0 = time.time()
        tr = simulate_recycled_bins(jax.random.PRNGKey(0), n, b, tau, steps)
        rows.add(
            f"fig14/recycled/n{n}", (time.time() - t0) * 1e6,
            f"max_load_end={int(tr.max_load[-1])};tau={tau};"
            f"frac_remember={float(tr.frac_remember[-1]):.3f}",
        )
    return rows


if __name__ == "__main__":
    main()
