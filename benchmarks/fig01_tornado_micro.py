"""Fig 1: tornado microscopics — uplink utilization and queue occupancy over
time, OPS (noisy, queues above Kmin) vs REPS (converges below Kmin)."""
import numpy as np

from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.netsim import Topology, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    wl = workloads.tornado(cfg.n_hosts, msg(512, 4096))
    topo = Topology.build(cfg)
    watch = topo.t0_up_queues(0)
    ticks = 2500 if not workloads and False else (6000 if msg(0,1) else 2500)
    ticks = 2500
    for lbn in ["ops", "reps"]:
        sim, st, tr, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), ticks, watch=watch)
        ql = np.asarray(tr.watch_qlen)  # (T, W)
        served = np.asarray(tr.watch_served)
        active = ql.sum(1) + served.sum(1) > 0
        window = 200
        util = served[: (len(served) // window) * window].reshape(-1, window, served.shape[1]).mean(1)
        rows.add(
            f"fig01/{lbn}",
            wall * 1e6,
            f"runtime={s.runtime_ticks};mean_q={ql[active].mean():.2f};"
            f"max_q={ql.max()};kmin={cfg.kmin};util_std={util.std():.3f};"
            f"ecn={s.ecn_marks}",
        )
    return rows


if __name__ == "__main__":
    main()
