"""Live soak dashboard: watch a checkpointed sweep run, chunk by chunk.

Drives the fig07-class soak grid (benchmarks.soak_fig07.cases) through
``SoakRunner`` and renders a live per-cell view after every ``advance`` —
**without finalizing anything**: every number comes from ``inspect()``
(``TelemetryProgram.live_row`` sketches + the flight recorder's decoded
ring tail), so the view is meaningful mid-run, long before the horizon.

Per cell: a progress bar, delivered/drops/timeouts counters, a per-window
utilization sparkline (the streamed windowed-series channel), the
RecoveryTracker's live first-drop → first-redelivery span as soon as the
redelivery lands, and — when tracing — the cell's flight-ring cursor and
most recent decision events.

Renders with curses when stdout is a terminal (q quits, run keeps its
checkpoints); ``--plain`` prints one frame per chunk to stdout instead —
that is what the CI trace-smoke job drives to prove the dashboard renders
from a running soak.  ``--inject-spine N`` kills a spine mid-run so the
failure machinery has something to show.

    python -m benchmarks.soak_dashboard --plain --ticks 240 --chunk 80
    python -m benchmarks.soak_dashboard --ckpt /tmp/ck --trace 512
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import ci_cfg
from benchmarks.soak_fig07 import MIN_FAILURE_SLOTS, cases
from repro.netsim import SoakConfig, SoakRunner, SweepEngine, failures
from repro.netsim.tracer import CODE_NAMES, TraceSpec

SPARK = " .:-=+*#%@"


def sparkline(fracs, width: int = 16) -> str:
    """Map [0, 1] window values onto a fixed-width character ramp."""
    if len(fracs) == 0:
        return " " * width
    fracs = np.asarray(fracs, np.float64)[-width:]
    chars = [SPARK[int(min(max(f, 0.0), 1.0) * (len(SPARK) - 1))] for f in fracs]
    return "".join(chars).ljust(width)


def bar(cursor: int, ticks: int, width: int = 20) -> str:
    fill = int(width * min(cursor, ticks) / max(ticks, 1))
    return "[" + "#" * fill + "-" * (width - fill) + "]"


def cell_lines(name: str, info: dict) -> list[str]:
    """Render one cell of an ``inspect()`` snapshot as text lines."""
    head = (
        f"{name:<34} {bar(info['cursor'], info['ticks'])} "
        f"{info['cursor']:>6}/{info['ticks']:<6}"
        f"{' done' if info['done'] else ''}"
    )
    lines = [head]
    tel = info.get("telemetry")
    if tel is not None:
        c = tel["counters"]
        body = (
            f"  delivered={c['delivered']:<8} drops={c['drops_cong']}"
            f"+{c['drops_fail']:<6} timeouts={c['timeouts']:<6}"
        )
        if "windows" in tel and len(tel["windows"]["util_frac"]):
            util = tel["windows"]["util_frac"].mean(axis=1)
            peak = float(util.max())
            scaled = util / peak if peak > 0 else util
            body += f" util|{sparkline(scaled)}| peak={peak:.2f}"
        lines.append(body)
        rec = tel.get("recovery")
        if rec is not None and rec["first_drop_tick"] >= 0:
            span = (
                f"recovered in {rec['recovery_us']:.2f}us "
                f"(t{rec['first_drop_tick']}->t{rec['first_redeliver_tick']})"
                if rec["recovery_ticks"] >= 0
                else "awaiting redelivery"
            )
            lines.append(f"  first drop t{rec['first_drop_tick']}: {span}")
    fl = info.get("flight")
    if fl is not None:
        tail = [
            f"{CODE_NAMES.get(int(k), '?')}@t{int(t)}"
            for t, k in zip(fl["tick"][-4:], fl["code"][-4:])
        ]
        lines.append(
            f"  flight: {fl['cursor']} events"
            + (f", lost {fl['lost']}" if fl["lost"] else "")
            + ("  last: " + " ".join(tail) if tail else "")
        )
    return lines


def frame(soak: SoakRunner) -> list[str]:
    lines = [
        f"soak cursor {soak.cursor}/{soak.horizon}  "
        f"chunk={soak.config.chunk}  "
        f"injections={len(soak.injections)}  "
        f"trace={'on' if soak.trace is not None else 'off'}"
    ]
    for name, info in sorted(soak.inspect().items()):
        lines.extend(cell_lines(name, info))
    return lines


def run_plain(soak: SoakRunner, chunk: int, inject_at, inject_spine, cfg):
    while not soak.done:
        if (inject_at is not None and soak.cursor == inject_at
                and not soak.injections):
            soak.inject(failures.spine_down(cfg, inject_spine, start=inject_at))
        soak.advance(chunk)
        print("\n".join(frame(soak)))
        print("-" * 72, flush=True)


def run_curses(soak: SoakRunner, chunk: int, inject_at, inject_spine, cfg):
    import curses

    def loop(scr):
        curses.use_default_colors()
        scr.nodelay(True)
        while not soak.done:
            if (inject_at is not None and soak.cursor == inject_at
                    and not soak.injections):
                soak.inject(
                    failures.spine_down(cfg, inject_spine, start=inject_at)
                )
            soak.advance(chunk)
            scr.erase()
            h, w = scr.getmaxyx()
            for y, line in enumerate(frame(soak)[: h - 1]):
                scr.addnstr(y, 0, line, w - 1)
            scr.addnstr(h - 1, 0, "q: quit (checkpoints kept)", w - 1)
            scr.refresh()
            if scr.getch() in (ord("q"), ord("Q")):
                return

    curses.wrapper(loop)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ticks", type=int, default=480,
                    help="permutation-block horizon (AllReduce runs 2x)")
    ap.add_argument("--chunk", type=int, default=120,
                    help="ticks per chunk == frames per refresh")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint root (enables resume + flight parts)")
    ap.add_argument("--trace", type=int, default=512,
                    help="flight-recorder ring size (0 disables tracing)")
    ap.add_argument("--inject-spine", type=int, default=None,
                    help="inject a spine_down delta one chunk in")
    ap.add_argument("--plain", action="store_true",
                    help="print frames to stdout instead of curses")
    args = ap.parse_args(argv)

    cfg = ci_cfg()
    engine = SweepEngine(
        cfg, cases(cfg, args.ticks), min_failure_slots=MIN_FAILURE_SLOTS
    )
    trace = TraceSpec(ring=args.trace) if args.trace else None
    soak = SoakRunner(
        engine, SoakConfig(chunk=args.chunk, ckpt_dir=args.ckpt, trace=trace)
    )
    inject_at = args.chunk if args.inject_spine is not None else None
    plain = args.plain or not sys.stdout.isatty()
    if plain:
        run_plain(soak, args.chunk, inject_at, args.inject_spine, cfg)
    else:
        run_curses(soak, args.chunk, inject_at, args.inject_spine, cfg)
    print(f"finished at cursor {soak.cursor}/{soak.horizon} "
          f"(checkpoints{' at ' + args.ckpt if args.ckpt else ' off'})")
    return soak


if __name__ == "__main__":
    main()
