"""Fig 17 (Appendix D.1): recycled balls-into-bins under n:1 recycling
ratios — 2:1/4:1 barely exceed tau, 8:1 still beats OPS."""
import time

import jax
import numpy as np

from benchmarks.common import Rows
from repro.core.balls_bins import simulate_ops_bins, simulate_recycled_bins


def main(rows=None):
    rows = rows or Rows()
    n, steps = 32, 4000
    tau = int(4 * np.log(n))
    b = int(np.ceil(2.4 * np.log(n)))
    for ratio in [1, 2, 4, 8]:
        t0 = time.time()
        tr = simulate_recycled_bins(
            jax.random.PRNGKey(0), n, b, tau, steps, coalesce=ratio
        )
        rows.add(
            f"fig17/recycled_c{ratio}", (time.time() - t0) * 1e6,
            f"max_load_end={int(tr.max_load[-1])};tau={tau}",
        )
    t0 = time.time()
    ml = simulate_ops_bins(jax.random.PRNGKey(0), n, 1.0, steps)
    rows.add(
        "fig17/ops_reference", (time.time() - t0) * 1e6,
        f"max_load_end={int(np.asarray(ml)[-1])}",
    )
    return rows


if __name__ == "__main__":
    main()
