"""Fig 7: failure modes macro — persistent partial failures during
permutation / DC traces / ring AllReduce.

Runs as one sweep submission (figure_grid): the three workload blocks have
different conn counts *and* tick horizons, so they bucket separately unless
the cost-aware packer can fuse them under the waste budget (horizon-merged
rows freeze bit-exactly at their own horizon).  LB columns within a block
share one lax.switch scan.  BENCH_SMOKE=1 drops the websearch trace block.
"""
from benchmarks.common import SMOKE, Rows, ci_cfg, figure_grid, msg, sweep_case
from repro.netsim import failures, workloads

LBS = ["ops", "reps", "plb"]
SMOKE_LBS = ["ops", "reps"]


def cases(cfg, smoke=SMOKE):
    """Declarative cell list for the fig07 grid (smoke = CI subset)."""
    fs = failures.random_down_uplinks(cfg, 0.05, 150, failures.FOREVER, seed=7)
    n = cfg.n_hosts
    lbs = SMOKE_LBS if smoke else LBS
    blocks = [
        ("permutation", workloads.permutation(n, msg(256, 2048), seed=1), 8000),
        ("ring_allreduce", workloads.ring_allreduce(16, msg(96, 1024)), 16000),
    ]
    if not smoke:
        blocks.insert(1, (
            "websearch100",
            workloads.websearch_trace(n, 0.9, 1200, seed=2,
                                      max_pkts=cfg.max_msg_pkts),
            6000,
        ))
    out = []
    for wname, wl, ticks in blocks:
        for lbn in lbs:
            kw = {"freezing_timeout": 800} if lbn == "reps" else {}
            out.append(
                sweep_case(f"fig07/{wname}/{lbn}", wl, lbn, ticks, cfg,
                           failures=fs, **kw)
            )
    return out


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    figure_grid(rows, "fig07", cfg, cases(cfg))
    return rows


if __name__ == "__main__":
    main()
