"""Fig 7: failure modes macro — persistent partial failures during
permutation / DC traces / ring AllReduce."""
from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.netsim import failures, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    fs = failures.random_down_uplinks(cfg, 0.05, 150, 2**30, seed=7)
    n = cfg.n_hosts
    for wname, wl, ticks in [
        ("permutation", workloads.permutation(n, msg(256, 2048), seed=1), 8000),
        ("websearch100", workloads.websearch_trace(n, 0.9, 1200, seed=2, max_pkts=cfg.max_msg_pkts), 6000),
        ("ring_allreduce", workloads.ring_allreduce(16, msg(96, 1024)), 16000),
    ]:
        for lbn in ["ops", "reps", "plb"]:
            kw = {"freezing_timeout": 800} if lbn == "reps" else {}
            _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn, **kw), ticks, fs)
            completion_row(rows, f"fig07/{wname}/{lbn}", s, wall)
    return rows


if __name__ == "__main__":
    main()
