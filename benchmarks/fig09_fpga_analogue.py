"""Figs 9/10 (FPGA testbed) — simulated analogue at the same scale: 16
endpoints / 2 TORs, per-flow goodput under asymmetry; packet drops under a
mid-run link failure.  (No FPGA hardware here; experiment design is
reproduced in the simulator — DESIGN.md §8.)"""
import numpy as np

from benchmarks.common import Rows, ci_cfg, lb_for, msg, run_one
from repro.netsim import Topology, failures, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg(n_hosts=16, hosts_per_tor=8, uplinks_per_tor=4)
    topo = Topology.build(cfg)
    # asymmetry: one of the uplinks at half rate (fig 9b)
    fs = failures.link_degraded([int(topo.t0_up_queues(0)[0])], 0, 2**30)
    wl = workloads.tornado(16, msg(256, 2048))
    for lbn in ["ops", "reps"]:
        _, st, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 6000, fs)
        fct = np.asarray(st.c_done_tick)
        goodput = wl.msg_pkts.sum() / max(s.runtime_ticks, 1)
        rows.add(
            f"fig09/asym/{lbn}", wall * 1e6,
            f"agg_goodput_pkts_per_tick={goodput:.2f};runtime={s.runtime_ticks}",
        )
    # failure drops (fig 10b)
    fs2 = failures.link_down([int(topo.t0_up_queues(0)[1])], 800, 2**30)
    for lbn in ["ops", "reps"]:
        _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn, **({"freezing_timeout": 800} if lbn=="reps" else {})), 8000, fs2)
        rows.add(
            f"fig10/linkdown/{lbn}", wall * 1e6,
            f"drops_fail={s.drops_fail};timeouts={s.timeouts};runtime={s.runtime_ticks}",
        )
    return rows


if __name__ == "__main__":
    main()
