"""Fig 19 (Appendix D.3): staggered permanent failures of all-but-one
uplink of one TOR; REPS re-freezes after each probe, OPS collapses."""
from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.netsim import failures, workloads


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    n_up = cfg.uplinks_per_tor
    fs = failures.incremental_uplink_failures(
        cfg, tor=0, n_fail=n_up - 1, first_start=200, interval=500
    )
    wl = workloads.permutation(cfg.n_hosts, msg(512, 4096), seed=5)
    for lbn in ["ops", "reps"]:
        kw = {"freezing_timeout": 800} if lbn == "reps" else {}
        _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn, **kw), 15000, fs)
        completion_row(rows, f"fig19/{lbn}", s, wall)
    return rows


if __name__ == "__main__":
    main()
