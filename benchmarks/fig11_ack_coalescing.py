"""Fig 11: ACK coalescing ratios — REPS retains its advantage up to 8:1,
and under asymmetry/failure even at 16:1."""
from benchmarks.common import Rows, ci_cfg, completion_row, lb_for, msg, run_one
from repro.netsim import Topology, failures, workloads


def main(rows=None):
    rows = rows or Rows()
    wl_msg = msg(256, 2048)
    for ratio in [1, 2, 4, 8, 16]:
        cfg = ci_cfg(ack_coalesce=ratio)
        wl = workloads.permutation(cfg.n_hosts, wl_msg, seed=3)
        for lbn in ["ops", "reps"]:
            _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 5000)
            completion_row(rows, f"fig11/sym/c{ratio}/{lbn}", s, wall)
    # asymmetric variant at the extreme ratio
    cfg = ci_cfg(ack_coalesce=16)
    topo = Topology.build(cfg)
    fs = failures.link_degraded(topo.t0_up_queues(0)[:1], 0, 2**30)
    wl = workloads.permutation(cfg.n_hosts, wl_msg, seed=3)
    for lbn in ["ops", "reps"]:
        _, _, _, s, wall = run_one(cfg, wl, lb_for(cfg, lbn), 6000, fs)
        completion_row(rows, f"fig11/asym/c16/{lbn}", s, wall)
    return rows


if __name__ == "__main__":
    main()
