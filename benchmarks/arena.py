"""LB arena: every registered load balancer head-to-head (ROADMAP's
"algorithm arena") — the paper's REPS claims pressure-tested against its
literature (PRIME multi-part entropy, SeqBalance reorder-free re-pathing,
CONGA-style flowlet tables) and the in-repo zoo, on one figure_grid
submission.

Three workload blocks × all LBs, a handful of compiled bucket scans:

  * symmetric  — permutation traffic, the paper's §4.2 baseline regime;
  * asymmetric — incast fan-in, persistent congestion at one downlink;
  * failure    — permutation under randomly downed uplinks (§5 recovery).

Per-cell columns report completion, FCT p99, and failure-recovery latency
from the on-device telemetry sketch channels (`recovery_us` is NaN on the
failure-free blocks, and whenever collect != "summary" the recovery column
degrades to "-" since no sketches exist).  BENCH_SMOKE=1 shrinks horizons
and drops the asymmetric block; LB columns always stay complete so the
arena keeps covering the whole registry.
"""
from benchmarks.common import SMOKE, Rows, ci_cfg, figure_grid, msg, sweep_case
from repro.core.load_balancers import REGISTRY
from repro.netsim import failures, workloads

# every registered single-LB contender ("mixed" needs cohort kwargs and is
# a composition, not a contender); keep registry order for stable columns
ARENA_LBS = [n for n in REGISTRY if n != "mixed"]

LB_KW = {"reps": {"freezing_timeout": 800}}


def cases(cfg, smoke=SMOKE):
    """Declarative cell list for the arena grid (smoke = CI subset)."""
    n = cfg.n_hosts
    fs = failures.random_down_uplinks(cfg, 0.05, 150, failures.FOREVER, seed=7)
    blocks = [
        ("symmetric", workloads.permutation(n, msg(192, 1024), seed=1),
         2500 if smoke else 8000, None),
        ("failure", workloads.permutation(n, msg(192, 1024), seed=3),
         3000 if smoke else 9000, fs),
    ]
    if not smoke:
        blocks.insert(1, (
            "asymmetric", workloads.incast(n, 8, msg(192, 1024)), 9000, None,
        ))
    out = []
    for wname, wl, ticks, f in blocks:
        for lbn in ARENA_LBS:
            out.append(
                sweep_case(f"arena/{wname}/{lbn}", wl, lbn, ticks, cfg,
                           failures=f, **LB_KW.get(lbn, {}))
            )
    return out


def _derive(case, s, res):
    """Completion + sketch columns: FCT p99 and recovery latency."""
    try:
        rec = res.telemetry_for(case.name).get("recovery")
        rec_us = f"{rec['recovery_us']:.1f}" if rec else "-"
    except ValueError:  # collect != "summary": no sketches were reduced
        rec_us = "-"
    return (
        f"completed={s.completed}/{s.n_conns};p99_fct={s.p99_fct_ticks:.0f};"
        f"recovery_us={rec_us};timeouts={s.timeouts}"
    )


def main(rows=None):
    rows = rows or Rows()
    cfg = ci_cfg()
    figure_grid(rows, "arena", cfg, cases(cfg), derive_res=_derive)
    return rows


if __name__ == "__main__":
    main()
