"""Quickstart: REPS vs OPS vs ECMP on a small fat-tree — the paper's story
in thirty seconds.  PYTHONPATH=src python examples/quickstart.py"""
import jax

from repro.configs.arcane_paper import FATTREE_32_CI
from repro.core import make_lb
from repro.netsim import Simulator, Topology, failures, summarize, workloads

cfg = FATTREE_32_CI
wl = workloads.permutation(cfg.n_hosts, 64, seed=1)
topo = Topology.build(cfg)
fs = failures.link_down(list(topo.t0_up_queues(0)[:2]), 300, 2**30)

print("== healthy symmetric network (64-pkt permutation) ==")
for lbn in ["ecmp", "ops", "reps"]:
    sim = Simulator(cfg, wl, make_lb(lbn, evs_size=cfg.evs_size), seed=0)
    st, _ = sim.run(1500)
    jax.block_until_ready(st.c_done)
    s = summarize(sim, st)
    print(f"  {lbn:5s} runtime={s.runtime_ticks:5d} ticks  drops={s.drops_cong:3d} "
          f"timeouts={s.timeouts}")

print("== two uplinks fail at t=300 ==")
for lbn in ["ops", "reps"]:
    lb = make_lb(lbn, evs_size=cfg.evs_size,
                 **({"freezing_timeout": 600} if lbn == "reps" else {}))
    sim = Simulator(cfg, wl, lb, failures=fs, seed=0)
    st, _ = sim.run(4000)
    jax.block_until_ready(st.c_done)
    s = summarize(sim, st)
    print(f"  {lbn:5s} runtime={s.runtime_ticks:5d} ticks  lost={s.drops_fail:3d} "
          f"timeouts={s.timeouts}  (freezing mode reroutes within ~1 RTO)")
