"""Live failover on the soak runtime: advance a running fabric, kill a
spine mid-flight, watch REPS recycle around it.

The paper's failover claim is a *latency*: after the first failure drop,
the sender's next delivery over a healthy path lands within ~100µs (first
drop → first successful reroute, fig 7's recovery story).  This demo drives
that scenario interactively through the scenario API of
``repro.netsim.soak``:

1. build one sweep grid (OPS baseline vs REPS) and a ``SoakRunner``,
2. ``advance`` simulated time until traffic is in full flight,
3. ``inject`` a whole-spine failure *at the current tick* — validated and
   merged through the same code path a pre-declared schedule takes, so the
   injected run is bit-identical to one that declared the failure up front,
4. keep advancing and ``inspect`` the live RecoveryTracker channel: the
   recovery latency is readable the moment the first re-routed delivery
   lands, no need to wait for the horizon.

  PYTHONPATH=src python examples/failover_demo.py"""
from repro.configs.arcane_paper import FATTREE_32_CI
from repro.netsim import (
    SoakConfig, SoakRunner, SweepCase, SweepEngine, failures, workloads,
)

cfg = FATTREE_32_CI
TICKS = 3000
SPINE = 2
wl = workloads.permutation(cfg.n_hosts, 384, seed=3)
cases = [
    SweepCase(name=lbn, workload=wl, lb=lbn, ticks=TICKS,
              lb_kwargs={"evs_size": cfg.evs_size}, seeds=(0,))
    for lbn in ("ops", "reps")
]
# min_failure_slots reserves inert failure rows so the injected delta
# re-materializes without a shape change (and the plan matches the
# statically-declared equivalent exactly)
engine = SweepEngine(cfg, cases, min_failure_slots=8)
soak = SoakRunner(engine, SoakConfig(chunk=250, collect="summary"))

print(f"permutation traffic on a {cfg.n_hosts}-host 2-tier fabric; "
      f"horizon {TICKS} ticks")
soak.advance(250)
live = soak.inspect()
print(f"t={soak.cursor}: in flight, delivered so far: " + ", ".join(
    f"{n}={v['telemetry']['counters']['delivered']}" for n, v in live.items()
))

delta = failures.spine_down(cfg, SPINE, start=soak.cursor)
soak.inject(delta)
print(f"t={soak.cursor}: spine {SPINE} down — "
      f"{len(delta)} uplinks blackholed (one per TOR)")

soak.advance(500)
live = soak.inspect()
print(f"t={soak.cursor}: live RecoveryTracker (first drop -> first "
      "re-routed delivery):")
for name, v in live.items():
    r = v["telemetry"]["recovery"]
    print(f"  {name:4s}: first_drop={r['first_drop_tick']:4d}  "
          f"first_redeliver={r['first_redeliver_tick']:4d}  "
          f"recovery={r['recovery_us']:.2f}us")

soak.advance(TICKS)
res = soak.result()
print(f"t={soak.cursor}: horizon reached")
for name, (s,) in sorted(res.summaries().items()):
    r = res.telemetry_for(name)["recovery"]
    print(f"  {name:4s}: completed={s.completed:3d}/{s.n_conns}  "
          f"drops_fail={s.drops_fail:4d}  timeouts={s.timeouts:3d}  "
          f"recovery={r['recovery_us']:.2f}us")
