"""The paper's technique inside the trainer: cross-pod gradient reduction
over 16 DCN channels; 6 channels die mid-run; REPS freezes, reroutes, and
recovers — the OPS baseline keeps hitting dead channels.

  PYTHONPATH=src python examples/failover_demo.py"""
from repro.ft import (
    ChannelSim,
    ChannelSimConfig,
    OpsChannelScheduler,
    RepsChannelScheduler,
    run_cross_pod_reduce,
)

cfg = ChannelSimConfig(n_channels=16)
print("cross-pod gradient reduce: 256 chunks over 16 DCN channels")
for phase, fail in [("healthy", ()), ("6/16 channels down", range(6))]:
    print(f"-- {phase} --")
    for name, mk in [
        ("ops ", lambda: OpsChannelScheduler(16, seed=0)),
        ("reps", lambda: RepsChannelScheduler(16, seed=0)),
    ]:
        sim = ChannelSim(cfg, seed=0)
        sim.set_failed(list(fail))
        rep = run_cross_pod_reduce(mk(), sim, 256, 32)
        print(
            f"  {name}: makespan={rep.total_latency_us:7.0f}us "
            f"rounds={rep.rounds:3d} timeouts={rep.timeouts:3d} "
            f"p99={rep.p99_chunk_latency_us:.0f}us"
        )
