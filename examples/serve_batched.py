"""Batched serving example: prefill + token-by-token decode with a KV cache
(reduced gemma3 with its 5:1 local:global attention).

  PYTHONPATH=src python examples/serve_batched.py"""
import sys

sys.argv = [sys.argv[0], "--arch", "gemma3-4b", "--reduced", "--batch", "4",
            "--prompt-len", "32", "--gen", "16"]
from repro.launch.serve import main  # noqa: E402

main()
