"""Reproduce a subset of the paper's figures quickly (fig 1/3/6 micro runs).

  PYTHONPATH=src python examples/paper_figures.py
Full benchmark suite: PYTHONPATH=src python -m benchmarks.run"""
import sys

sys.path.insert(0, ".")
from benchmarks import fig01_tornado_micro, fig03_asym_micro, fig06_failures_micro
from benchmarks.common import Rows

rows = Rows()
print("name,us_per_call,derived")
fig01_tornado_micro.main(rows)
fig03_asym_micro.main(rows)
fig06_failures_micro.main(rows)
