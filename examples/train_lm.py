"""End-to-end driver: train a small LM for a few hundred steps on the
deterministic Markov stream, with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py            # ~10M params, fast
  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch mistral-nemo-12b \
      --reduced --steps 200     # same thing via the launcher
"""
import argparse
import sys

sys.argv = [sys.argv[0], "--arch", "mistral-nemo-12b", "--reduced",
            "--steps", "200", "--batch", "8", "--seq", "128",
            "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "50"] + sys.argv[1:]
from repro.launch.train import main  # noqa: E402

main()
