"""Docs consistency check (CI `docs` job).

Fails on:
  * broken intra-repo markdown links (``[text](path)`` where ``path`` is
    not an http(s)/mailto URL and does not resolve to a file or directory
    relative to the markdown file, repo-root ``/``-prefixed paths allowed;
    ``#fragment``-only links are checked against the same file's headings);
  * figure-table rows (any markdown table whose cells name a
    ``benchmarks/figNN_*.py`` or ``benchmarks/table*.py`` module) pointing
    at files that don't exist;
  * backticked repo paths of the form ``src/...``, ``benchmarks/...``,
    ``tests/...``, ``docs/...``, ``tools/...`` that don't exist.

Scope: README.md, ROADMAP.md, and every ``docs/*.md``.

Run: ``python tools/check_docs.py`` (exit 1 on any failure).
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(
    r"\[[^\]]*\]\(\s*([^)\s]+)(?:\s+(?:\"[^\"]*\"|'[^']*'))?\s*\)"
)
BENCH_RE = re.compile(r"benchmarks/(?:fig|table)\w*\.py")
PATH_RE = re.compile(
    r"`((?:src|benchmarks|tests|docs|tools|examples)/[\w./-]+"
    r"\.(?:py|md|json|yml|yaml|txt|sh))`"
)
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor: lowercase, drop non-word chars, each space
    becomes one dash (GitHub does not collapse runs)."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def file_anchors(text: str) -> set[str]:
    """All anchors GitHub generates for a document's headings, including
    the ``-1``/``-2`` suffixes it appends to duplicate headings."""
    anchors: set[str] = set()
    seen: dict[str, int] = {}
    for h in HEADING_RE.findall(text):
        slug = slugify(h)
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def check_file(md_path: str) -> list[str]:
    errors: list[str] = []
    text = open(md_path, encoding="utf-8").read()
    rel = os.path.relpath(md_path, ROOT)
    base = os.path.dirname(md_path)
    anchors = file_anchors(text)

    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, frag = target.partition("#")
        if not path:  # same-file fragment
            if frag and slugify(frag) not in anchors and frag not in anchors:
                errors.append(f"{rel}: broken anchor #{frag}")
            continue
        resolved = (
            os.path.join(ROOT, path.lstrip("/"))
            if path.startswith("/")
            else os.path.join(base, path)
        )
        if not os.path.exists(resolved):
            errors.append(f"{rel}: broken link {target}")
        elif frag and resolved.endswith(".md"):
            # cross-file fragment: validate against that file's headings
            tgt_anchors = file_anchors(
                open(resolved, encoding="utf-8").read()
            )
            if slugify(frag) not in tgt_anchors and frag not in tgt_anchors:
                errors.append(f"{rel}: broken anchor {target}")

    for mod in set(BENCH_RE.findall(text)):
        if not os.path.exists(os.path.join(ROOT, mod)):
            errors.append(f"{rel}: figure table names nonexistent {mod}")

    for p in set(PATH_RE.findall(text)):
        if not os.path.exists(os.path.join(ROOT, p)):
            errors.append(f"{rel}: backticked path {p} does not exist")

    return errors


def main() -> int:
    files = [os.path.join(ROOT, "README.md"), os.path.join(ROOT, "ROADMAP.md")]
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    errors: list[str] = []
    for f in files:
        if os.path.exists(f):
            errors += check_file(f)
    for e in errors:
        print(f"ERROR: {e}")
    print(
        f"checked {len(files)} files: "
        + ("FAIL" if errors else "ok")
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
