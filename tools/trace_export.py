"""Export streamed flight-recorder parts to Chrome/Perfetto trace JSON.

Input: a soak run's ``<ckpt>/flight`` directory — the atomic
``flight_b*_t*_n*.npz`` parts ``SoakRunner.advance`` drains from the
on-device ring at every chunk boundary, plus the ``flight_meta.json``
sidecar mapping (bucket, row) to (cell, seed) and carrying the event code
table (see ``repro.netsim.tracer``).  No engine or JAX import is needed to
decode: parts are plain npz, the sidecar is plain JSON.

Output: the Chrome trace-event JSON format (the ``traceEvents`` array),
loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``:

* one *process* per cell row (``pid``; process_name metadata records the
  cell name and seed);
* counter tracks (``ph: "C"``) per decision family — EV-cache
  hit/miss/recycle, re-path causes, queue backlog heartbeat — one sample
  per recorded tick, value = events that tick;
* instant events (``ph: "i"``) for failure edges: window activation,
  first failure drop, freezing entries;
* one *duration* event (``ph: "X"``, name ``recovery``) per row that saw a
  failure drop followed by a re-routed delivery: ``ts`` is the first-drop
  time, ``dur`` the first-drop → first-redelivery span — by construction
  (tracer mirrors ``telemetry.RecoveryTracker`` bit-exactly) ``dur`` in
  microseconds equals the tracker's ``recovery_us``, the paper's <100 µs
  re-route claim rendered as a span on the timeline.

Timestamps are microseconds (tick × TICK_NS / 1000), the unit Chrome JSON
expects.  Run::

    python tools/trace_export.py --flight <ckpt>/flight --out trace.json
    python tools/trace_export.py --flight <ckpt>/flight --cell 'fig07soak/*'
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import re
import sys

import numpy as np

_PART_RE = re.compile(r"^flight_b(\d+)_t(\d{9})_n(\d+)\.npz$")

# counter-track grouping: code name -> (track, series) so related causes
# share one Perfetto counter lane
_COUNTER_TRACKS = {
    "ev_hit": ("ev_cache", "hit"),
    "ev_miss": ("ev_cache", "miss"),
    "ev_recycle": ("ev_cache", "recycle"),
    "repath_ack_ecn": ("repath", "ack_ecn"),
    "repath_rto": ("repath", "rto"),
    "repath_flowlet": ("repath", "flowlet"),
    "repath_epoch": ("repath", "epoch"),
    "mark": ("backlog", "queued_pkts"),
}
_INSTANTS = {"ev_freeze", "fail_active", "fail_first_drop", "fail_rerouted"}


def load_meta(flight_dir: str) -> dict:
    path = os.path.join(flight_dir, "flight_meta.json")
    with open(path) as f:
        meta = json.load(f)
    meta["codes"] = {int(k): v for k, v in meta["codes"].items()}
    return meta


def iter_parts(flight_dir: str):
    """Yield ``(bucket_idx, t0, n, npz dict)`` in (bucket, window) order."""
    for fname in sorted(os.listdir(flight_dir)):
        m = _PART_RE.match(fname)
        if m is None:
            continue
        with np.load(os.path.join(flight_dir, fname)) as z:
            yield int(m.group(1)), int(m.group(2)), int(m.group(3)), {
                k: z[k] for k in z.files
            }


def row_labels(meta: dict) -> dict[tuple[int, int], tuple[str, int]]:
    """(bucket, kept-row) -> (cell name, seed)."""
    out: dict[tuple[int, int], tuple[str, int]] = {}
    for bi, b in enumerate(meta["buckets"]):
        for c in b["cells"]:
            for si, r in enumerate(c["rows"]):
                out[(bi, int(r))] = (c["name"], int(c["seeds"][si]))
    return out


def export(flight_dir: str, cell_glob: str | None = None) -> dict:
    """Build the Chrome trace dict from one flight directory."""
    meta = load_meta(flight_dir)
    tick_us = float(meta["tick_ns"]) / 1000.0
    labels = row_labels(meta)
    pids: dict[tuple[int, int], int] = {}
    events: list[dict] = []
    lost_total = 0
    # per-row failure edges (min across parts; -1 = not seen)
    edges: dict[tuple[int, int], tuple[int, int]] = {}

    def pid_for(key: tuple[int, int]) -> int | None:
        if key not in labels:
            return None  # padded row or stale meta: skip, never mislabel
        name, seed = labels[key]
        if cell_glob is not None and not fnmatch.fnmatch(name, cell_glob):
            return None
        if key not in pids:
            pid = len(pids) + 1
            pids[key] = pid
            events.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": f"{name} seed={seed}"},
            })
        return pids[key]

    for bi, _t0, _n, part in iter_parts(flight_dir):
        lost_total += int(part["lost"].sum())
        for key_row in range(part["cursor"].shape[0]):
            key = (bi, key_row)
            pid = pid_for(key)
            if pid is None:
                continue
            fd = int(part["first_drop_tick"][key_row])
            fr = int(part["first_redeliver_tick"][key_row])
            prev = edges.get(key, (-1, -1))
            edges[key] = (fd if prev[0] < 0 else prev[0],
                          fr if prev[1] < 0 else prev[1])
        sel_rows = part["row"]
        for i in range(sel_rows.shape[0]):
            key = (bi, int(sel_rows[i]))
            pid = pid_for(key)
            if pid is None:
                continue
            code = meta["codes"].get(int(part["code"][i]), "unknown")
            ts = float(part["tick"][i]) * tick_us
            val = int(part["value"][i])
            if code in _COUNTER_TRACKS:
                track, series = _COUNTER_TRACKS[code]
                events.append({
                    "ph": "C", "name": track, "pid": pid, "tid": 0,
                    "ts": ts, "args": {series: val},
                })
            elif code in _INSTANTS:
                events.append({
                    "ph": "i", "name": code, "pid": pid, "tid": 0,
                    "ts": ts, "s": "p", "args": {"value": val},
                })

    # recovery spans: one X event per row whose drop->redeliver pair closed
    for key, (fd, fr) in sorted(edges.items()):
        if fd < 0 or fr < 0:
            continue
        pid = pids.get(key)
        if pid is None:
            continue
        events.append({
            "ph": "X", "name": "recovery", "pid": pid, "tid": 0,
            "ts": fd * tick_us, "dur": (fr - fd) * tick_us,
            "args": {
                "first_drop_tick": fd, "first_redeliver_tick": fr,
                "recovery_ticks": fr - fd,
                "recovery_us": (fr - fd) * tick_us,
            },
        })

    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "source": "repro.netsim.tracer flight parts",
            "flight_dir": os.path.abspath(flight_dir),
            "tick_ns": meta["tick_ns"],
            "ring": meta["ring"],
            "rows": len(pids),
            "lost_events": lost_total,
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--flight", required=True,
                    help="the soak run's <ckpt>/flight directory")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: stdout)")
    ap.add_argument("--cell", default=None,
                    help="glob over cell names (e.g. 'fig07soak/*/reps')")
    args = ap.parse_args(argv)
    trace = export(args.flight, args.cell)
    blob = json.dumps(trace, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        spans = sum(1 for e in trace["traceEvents"] if e["ph"] == "X")
        print(f"wrote {args.out}: {len(trace['traceEvents'])} events, "
              f"{spans} recovery span(s), "
              f"{trace['otherData']['lost_events']} lost")
    else:
        print(blob)
    return 0


if __name__ == "__main__":
    sys.exit(main())
